#!/usr/bin/env python3
"""Docstring-drift check: every "DESIGN.md §N" reference must resolve.

Docstrings across src/ (and the satellite trees that cite the design
document) anchor themselves to DESIGN.md sections — "the fused ring fold
(DESIGN.md §11)".  Sections get added and renumbered as the design grows,
and a stale §N silently points readers at the wrong subsystem, which is
worse than no pointer at all.  This check extracts every such reference
and fails if the section header does not exist in DESIGN.md.

Runs in CI beside ruff (no dependencies, stdlib only):

    python tools/check_design_refs.py

Exit 0 when every reference resolves, 1 with a file:line listing of every
dangling reference otherwise.
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DESIGN = REPO / "DESIGN.md"
# every tree whose prose cites the design document
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
SCAN_SUFFIXES = {".py", ".md"}
# "DESIGN.md §11", "DESIGN.md §11/§14", "DESIGN.md  §8" — the section
# sigil may chain with slashes; capture every §N in the chain
REF = re.compile(r"DESIGN\.md[^\S\n]*((?:§\d+[/,]?\s?)+)")
SECTION = re.compile(r"^##\s+§(\d+)\b", re.MULTILINE)


def design_sections() -> set[int]:
    return {int(n) for n in SECTION.findall(DESIGN.read_text())}


def references(root: pathlib.Path):
    """Yield (path, lineno, section) for every DESIGN.md §N reference."""
    for scan in SCAN_DIRS:
        base = root / scan
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*")):
            if path.suffix not in SCAN_SUFFIXES or not path.is_file():
                continue
            text = path.read_text(errors="replace")
            for match in REF.finditer(text):
                line = text.count("\n", 0, match.start()) + 1
                for n in re.findall(r"§(\d+)", match.group(1)):
                    yield path.relative_to(root), line, int(n)


def main() -> int:
    if not DESIGN.is_file():
        print(f"missing {DESIGN}", file=sys.stderr)
        return 1
    sections = design_sections()
    total, dangling = 0, []
    for path, line, n in references(REPO):
        total += 1
        if n not in sections:
            dangling.append((path, line, n))
    if dangling:
        print(
            f"{len(dangling)} dangling DESIGN.md reference(s) "
            f"(existing sections: §{min(sections)}..§{max(sections)}):"
        )
        for path, line, n in dangling:
            print(f"  {path}:{line}: DESIGN.md §{n} does not exist")
        return 1
    print(
        f"ok: {total} DESIGN.md section references across "
        f"{'/'.join(SCAN_DIRS)} all resolve "
        f"(§{min(sections)}..§{max(sections)})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
