"""Observability overhead: the disabled instrumentation must be free.

Every registry backend is wrapped at registration with a count+time seam
(repro.obs.metrics.wrap_backend), so the disabled-mode cost per dispatch
is one extra Python frame plus a module-flag check.  The DESIGN.md §15
budget makes that a gate, not a hope: this bench times
``SketchBank.update_many`` with the shipped (disabled) instrumentation
against a passthrough baseline — the seam wrappers swapped back to the
raw backends and the call-site record fns no-op'd — and asserts the
median overhead stays within ``OVERHEAD_GATE`` (3%).  Enabled-mode and
trace-capture costs are measured and reported unasserted: they are paid
only by runs that asked for them.

Writes ``BENCH_obs.json`` so the overhead trajectory is tracked like
every other bench (smoke runs write the gitignored ``.smoke.json``
sibling).
"""

from __future__ import annotations

import contextlib

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, write_bench_json
from repro.obs import metrics, tracing
from repro.sketch import HLLConfig, SketchBank
from repro.sketch import plan as planlib

JSON_PATH = "BENCH_obs.json"
OVERHEAD_GATE = 1.03  # disabled-mode median ceiling vs passthrough (§15)


@contextlib.contextmanager
def _passthrough():
    """The pre-instrumentation dispatch path, restored temporarily.

    Seam wrappers keep the raw backend on ``__sketch_backend__``; swapping
    those back in and no-op'ing the call-site record fns yields a baseline
    with zero observability code on the hot path.  The underlying (jitted)
    backend objects are untouched, so both arms share compile caches.
    """
    saved = {
        reg_name: dict(reg)
        for reg_name, reg in (
            ("_BACKENDS", planlib._BACKENDS),
            ("_BANK_BACKENDS", planlib._BANK_BACKENDS),
        )
    }
    saved_record = metrics.inc, metrics.observe
    try:
        for reg_name, entries in saved.items():
            reg = getattr(planlib, reg_name)
            for k, fn in entries.items():
                reg[k] = getattr(fn, "__sketch_backend__", fn)
        metrics.inc = lambda name, value=1: None
        metrics.observe = lambda name, value: None
        yield
    finally:
        for reg_name, entries in saved.items():
            reg = getattr(planlib, reg_name)
            reg.clear()
            reg.update(entries)
        metrics.inc, metrics.observe = saved_record


def _median_s(rows: int, n: int, iters: int) -> float:
    """Median wall seconds for one ``update_many`` over a fixed stream."""
    cfg = HLLConfig(p=10, hash_bits=64)
    rng = np.random.default_rng(rows)
    bank = SketchBank.empty(rows, cfg)
    keys = jnp.asarray(rng.integers(0, rows, n, dtype=np.int32))
    items = jnp.asarray(rng.integers(0, 2**31, n, dtype=np.int32))

    def step():
        return bank.update_many(keys, items).registers

    return time_fn(step, warmup=3, iters=iters)


def run(full: bool = False, smoke: bool = False):
    assert not metrics.enabled(), "bench_obs must start with metrics off"
    if tracing.active():
        # a run.py --trace capture would put the seam path back on the
        # "disabled" arm; the gate measures the shipped default instead
        tracing.stop_trace()
    rows, n = (16, 1024) if smoke else (64, 4096)
    iters = 7 if smoke else 15
    rounds = 3 if smoke else 5

    # interleave the arms and keep each arm's best median: scheduling
    # noise inflates both sides equally, the min strips it
    disabled, baseline = [], []
    for _ in range(rounds):
        disabled.append(_median_s(rows, n, iters))
        with _passthrough():
            baseline.append(_median_s(rows, n, iters))
    disabled_s, baseline_s = min(disabled), min(baseline)
    ratio = disabled_s / baseline_s

    # enabled-mode + live-trace costs: reported, not gated — only runs
    # that asked for metrics/tracing pay them
    metrics.enable()
    enabled_s = _median_s(rows, n, iters)
    metrics.disable()
    metrics.reset()
    tracing.start_trace()
    traced_s = _median_s(rows, n, iters)
    tracing.stop_trace()

    emit(
        "obs_overhead_disabled",
        disabled_s * 1e6,
        f"B={rows} n={n} baseline={baseline_s * 1e6:.0f}us "
        f"ratio={ratio:.3f}x gate={OVERHEAD_GATE}x",
    )
    emit(
        "obs_overhead_enabled",
        enabled_s * 1e6,
        f"ratio={enabled_s / baseline_s:.3f}x (unasserted)",
    )
    emit(
        "obs_overhead_traced",
        traced_s * 1e6,
        f"ratio={traced_s / baseline_s:.3f}x (unasserted)",
    )

    out = {
        "B": rows,
        "n_items": n,
        "baseline_us": baseline_s * 1e6,
        "disabled_us": disabled_s * 1e6,
        "disabled_over_baseline": ratio,
        "enabled_us": enabled_s * 1e6,
        "enabled_over_baseline": enabled_s / baseline_s,
        "traced_us": traced_s * 1e6,
        "traced_over_baseline": traced_s / baseline_s,
        "gate": OVERHEAD_GATE,
        "smoke": smoke,
    }
    write_bench_json(JSON_PATH, out, smoke)

    # the §15 acceptance gate, asserted AFTER the JSON lands so a noisy
    # CI box still leaves the measurement on disk for triage
    if ratio > OVERHEAD_GATE:
        raise AssertionError(
            f"disabled-mode instrumentation overhead {ratio:.3f}x exceeds "
            f"the {OVERHEAD_GATE}x gate on SketchBank.update_many "
            f"(B={rows}, n={n})"
        )
    return out


if __name__ == "__main__":
    run(full=True)
