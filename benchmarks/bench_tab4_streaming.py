"""Paper Tab. IV: sustained streaming throughput (the NIC deployment).

Analogue of the 100 Gbit/s NIC experiment: data arrives in chunks from the
pipeline (host -> device, the 'network'), each chunk is sketched on arrival
by k pipelines, and the constant-time finalization happens once at the end
(the paper's 203 us bucket drain).  Reported: sustained GByte/s vs k and the
finalization latency — including the paper's observation that it is
independent of the streamed volume.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.sketch import ExecutionPlan, hll, update_registers
from repro.sketch import HLLConfig
from repro.data.pipeline import DataConfig, batch_at_step

CHUNKS = 8
CHUNK_ITEMS = 1 << 20
PIPELINES = (1, 2, 4, 8, 16)


def run(full: bool = False, smoke: bool = False):
    cfg = HLLConfig(p=16, hash_bits=64)
    chunk_items = 1 << 14 if smoke else CHUNK_ITEMS
    chunks = 2 if smoke else CHUNKS
    data = DataConfig(
        vocab_size=2**31 - 1, global_batch=1024,
        seq_len=chunk_items // 1024, distribution="unique",
    )
    rows = []
    for k in (1, 2) if smoke else PIPELINES:
        update = jax.jit(
            lambda r, x, k=k: update_registers(
                r, x, cfg, ExecutionPlan(backend="jnp", pipelines=k)
            )
        )
        regs = hll.init_registers(cfg)
        # warmup compile
        jax.block_until_ready(update(regs, batch_at_step(data, jnp.asarray(0))["tokens"]))
        t0 = time.perf_counter()
        n_total = 0
        for step in range(chunks):
            batch = batch_at_step(data, jnp.asarray(step, jnp.int32))
            regs = update(regs, batch["tokens"])
            n_total += batch["tokens"].size
        jax.block_until_ready(regs)
        dt = time.perf_counter() - t0
        gbps = n_total * 4 / dt / 1e9
        # constant-time finalization (paper: 203 us independent of volume)
        t1 = time.perf_counter()
        est = hll.estimate(regs, cfg)
        fin_us = (time.perf_counter() - t1) * 1e6
        exact_seen = n_total  # 'unique' stream
        err = abs(est - exact_seen) / exact_seen
        rows.append(dict(pipelines=k, gbytes_s=gbps, finalize_us=fin_us, err=err))
        emit(
            "tab4_streaming", dt / chunks * 1e6,
            f"pipelines={k} sustained={gbps:.3f}GB/s finalize={fin_us:.0f}us "
            f"est_err={err:.4f}",
        )
    return rows


if __name__ == "__main__":
    run(full=True)
