"""Paper Tab. III: resource usage vs #pipelines — TPU analogue.

The FPGA table reports BRAM/DSP/LUT/FF per pipeline count.  The TPU
equivalents per pipeline count k:

  register memory   k x m bytes of bucket state (BRAM analogue)
  VMEM working set  the fused kernel's scratch + tile footprint
  HLO flops/bytes   per item, from the scan-aware analyzer (DSP analogue:
                    the hash's integer-multiply work is the dominant term)

Like the paper, resources scale linearly in k while per-item cost is flat —
the scaling buys bandwidth, not efficiency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.sketch import ExecutionPlan, update_registers
from repro.sketch import HLLConfig
from repro.launch import hlo_analysis

N = 327_680  # divisible by every pipeline count incl. the paper's 10
PIPELINES = (1, 2, 4, 8, 10, 16)


def run(full: bool = False, smoke: bool = False):
    cfg = HLLConfig(p=16, hash_bits=64)
    rows = []
    for k in (1, 2) if smoke else PIPELINES:
        fn = jax.jit(
            lambda r, x, k=k: update_registers(
                r, x, cfg, ExecutionPlan(backend="jnp", pipelines=k)
            )
        )
        compiled = fn.lower(
            jax.ShapeDtypeStruct((cfg.m,), jnp.uint8),
            jax.ShapeDtypeStruct((N,), jnp.uint32),
        ).compile()
        an = hlo_analysis.analyze(compiled.as_text())
        reg_bytes = k * cfg.m  # uint8 partial sketches (BRAM analogue)
        # hash is pure integer VPU work (no dots): analytic op count —
        # murmur3-64 via 16-bit limbs ~ 4 mul64 (19 ops) + ~30 logic ops
        int_ops_per_item = 106 if cfg.hash_bits == 64 else 18
        bytes_per_item = an.bytes / N
        rows.append(
            dict(pipelines=k, register_bytes=reg_bytes,
                 int_ops_per_item=int_ops_per_item,
                 bytes_per_item=bytes_per_item)
        )
        emit(
            "tab3_resources", 0.0,
            f"pipelines={k} registers={reg_bytes/1024:.0f}KiB "
            f"hash_int_ops/item={int_ops_per_item} (DSP analogue) "
            f"hlo_bytes/item={bytes_per_item:.0f}",
        )
    # VMEM working set of the fused Pallas pipeline (small-p engine)
    small = HLLConfig(p=10, hash_bits=64)
    vmem = (
        small.m * 4  # scratch registers (int32)
        + 8 * 128 * 4  # input tile
        + 128 * small.m * 4  # one-hot compare tile
    )
    emit(
        "tab3_vmem_fused", 0.0,
        f"p={small.p} fused-kernel VMEM~{vmem/2**20:.2f}MiB of 16MiB "
        f"(paper: BRAM 5.5%@10pipes)",
    )
    return rows


if __name__ == "__main__":
    run(full=True)
