"""Windowed query latency: fused ring fold vs per-bucket merge loop, and
the incremental decomposition vs the full refold.

A sliding-window reading over a ``WindowedBank`` is ONE masked max-reduce
across the (W, B, m) ring into a scratch bank plus one batched
``estimate_many`` (DESIGN.md §11).  The pre-subsystem shape of the same
query is a python loop that merges each live bucket into an accumulator —
W separate device dispatches — before the same finalization.  This bench
times both across W, asserts the estimates are bit-identical, and writes
``BENCH_window.json`` so the windowed-query perf trajectory populates
across PRs next to the ingest-side ``BENCH_bank_streaming.json``.

The second sweep measures the tentpole of DESIGN.md §14: a steady
advance/observe/query cycle where full-window reads answer from the
prefix/suffix decomposition (three (B, m) fragments merged, amortized one
O(W) rebuild per W rotations) instead of refolding the whole ring.  The
per-query incremental cost must stay FLAT as W grows — the gate asserts
the max/min ratio across the sweep stays under ``INC_FLATNESS_GATE`` —
and the incremental answers are asserted bit-identical to a direct
backend refold for EVERY registered window backend before any number is
written.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, write_bench_json
from repro.sketch import (
    ExecutionPlan,
    HLLConfig,
    WindowedBank,
    available_window_backends,
    estimate_many,
)
from repro.sketch.plan import get_window_backend

JSON_PATH = "BENCH_window.json"
WINDOW_SIZES = (4, 16, 64)
# the incremental sweep stretches further: flat per-query cost in W is
# the whole point, so the sweep must cover an order of magnitude
INC_WINDOW_SIZES = (4, 16, 64, 256)
# full runs gate W in {16, 64, 256} at 1.2x (steady-state, cache-warm);
# smoke runs cover {4, 16} on whatever CI hardware with a loose gate
INC_FLATNESS_GATE = 1.2
INC_FLATNESS_GATE_SMOKE = 2.5
ROWS = 64


def _filled_ring(window: int, rows: int, cfg: HLLConfig, seed: int = 0):
    """A ring whose every bucket holds a real ingested chunk."""
    rng = np.random.default_rng(seed)
    win = WindowedBank.empty(window, rows, cfg)
    for epoch in range(window):
        if epoch:
            win = win.advance()
        items = jnp.asarray(rng.integers(0, 2**31, 4096, dtype=np.int32))
        win = win.observe(items % rows, items)
    jax.block_until_ready(win.registers)
    return win


def _steady_chunks(rows: int, n: int, count: int, seed: int):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(count):
        items = jnp.asarray(rng.integers(0, 2**31, n, dtype=np.int32))
        out.append((items % rows, items))
    return out


def _time_steady(win, chunks, query, steps: int, repeats: int = 3):
    """Median per-QUERY seconds across a steady advance/observe/query
    cycle.

    Only the read is timed — the functional ring update itself copies
    (W, B, m) state and so can never be flat in W; the §14 claim is
    about the QUERY.  Each step mutates the ring first (untimed), so
    every timed read is the first read of a fresh instance: cold fold
    cache, hidden state threaded forward.

    The reported number is the MEDIAN over every timed query.  The
    steady-state read is three (B, m) fragment merges regardless of W;
    the once-per-W prefix rebuild shows up as a 1-in-W latency spike
    whose FREQUENCY differs across the sweep (W=16 pays it twice in 32
    steps, W=256 never), so a mean would compare different mixtures of
    spike and steady cost and the flatness gate would measure rebuild
    frequency, not query cost.  The median is the typical dashboard
    read; the rebuild amortization itself is pinned separately by
    ``test_prefix_rebuilds_once_per_window``.
    """
    queries = []
    for r in range(repeats):
        for s in range(steps):
            keys, items = chunks[(r * steps + s) % len(chunks)]
            win = win.advance().observe(keys, items)
            t0 = time.perf_counter()
            jax.block_until_ready(
                win.estimate_window() if query is None else query(win)
            )
            queries.append(time.perf_counter() - t0)
    queries.sort()
    return win, queries[len(queries) // 2]


def _refold_query(cfg, plan):
    """The pre-§14 read: refold the whole ring through the backend."""
    fold = get_window_backend(plan.backend)

    def query(win):
        regs = fold(win.registers, win._live_mask(win.window), cfg, plan)
        return estimate_many(regs, cfg)

    return query


def _incremental_sweep(window_sizes, rows, cfg, smoke: bool):
    plan = ExecutionPlan(backend="jnp")
    steps = 8 if smoke else 32
    results = []
    for window in window_sizes:
        win = _filled_ring(window, rows, cfg, seed=window)
        chunks = _steady_chunks(rows, 1024, 8, seed=window)
        jax.block_until_ready(win.estimate_window())  # prime the state
        win, inc_s = _time_steady(win, chunks, None, steps)
        win, ref_s = _time_steady(win, chunks, _refold_query(cfg, plan), steps)

        # the §14 identity, asserted in-bench for EVERY backend before a
        # number lands in the JSON: the incremental merge answers exactly
        # what a direct backend refold of the same ring answers
        identical = {}
        for backend in available_window_backends():
            bplan = ExecutionPlan(backend=backend)
            inc_est = np.asarray(win.estimate_window(plan=bplan))
            ref_est = np.asarray(_refold_query(cfg, bplan)(win))
            identical[backend] = bool(np.array_equal(inc_est, ref_est))
            if not identical[backend]:
                raise AssertionError(
                    f"incremental window read diverged from the "
                    f"{backend} refold at W={window}"
                )
        row = dict(
            W=window,
            B=rows,
            inc_query_us=inc_s * 1e6,
            refold_query_us=ref_s * 1e6,
            refold_over_inc=ref_s / inc_s,
            bit_identical=identical,
        )
        results.append(row)
        emit(
            "window_incremental",
            inc_s * 1e6,
            f"W={window} B={rows} inc={inc_s * 1e6:.0f}us "
            f"refold={ref_s * 1e6:.0f}us "
            f"refold/inc={ref_s / inc_s:.2f}x",
        )

    # the flatness gate: per-query incremental cost must not grow with W
    gate = INC_FLATNESS_GATE_SMOKE if smoke else INC_FLATNESS_GATE
    gated = [r for r in results if smoke or r["W"] >= 16]
    costs = [r["inc_query_us"] for r in gated]
    ratio = max(costs) / min(costs)
    if ratio > gate:
        raise AssertionError(
            f"incremental per-query cost grew with W: max/min = {ratio:.2f}x "
            f"over W in {[r['W'] for r in gated]} (gate {gate}x)"
        )
    flatness = dict(
        ws=[r["W"] for r in gated],
        max_over_min=ratio,
        gate=gate,
        passed=True,
    )
    emit(
        "window_incremental_flatness",
        ratio,
        f"max/min={ratio:.2f}x over W={[r['W'] for r in gated]} "
        f"(gate {gate}x)",
    )
    return results, flatness


def run(full: bool = False, smoke: bool = False):
    cfg = HLLConfig(p=10, hash_bits=64)
    window_sizes = (2, 4) if smoke else WINDOW_SIZES
    rows = 8 if smoke else ROWS
    plan = ExecutionPlan(backend="jnp")
    fold = get_window_backend(plan.backend)

    results = []
    for window in window_sizes:
        win = _filled_ring(window, rows, cfg, seed=window)
        mask = win._live_mask(window)

        @jax.jit
        def fused(ring, mask):
            return estimate_many(fold(ring, mask, cfg, plan), cfg)

        def loop(ring):
            # the pre-subsystem query: one device dispatch per bucket
            acc = jnp.zeros((rows, cfg.m), ring.dtype)
            for w in range(window):
                acc = jnp.maximum(acc, ring[w])
            return estimate_many(acc, cfg)

        fused_s = time_fn(fused, win.registers, mask)
        loop_s = time_fn(loop, win.registers)
        fused_est = np.asarray(fused(win.registers, mask))
        loop_est = np.asarray(loop(win.registers))
        identical = bool(np.array_equal(fused_est, loop_est))
        if not identical:
            # the documented gate: CI bench-smoke must fail on divergence
            raise AssertionError(
                f"fused ring fold diverged from the merge loop at W={window}"
            )
        row = dict(
            W=window,
            B=rows,
            fused_us=fused_s * 1e6,
            loop_us=loop_s * 1e6,
            speedup=loop_s / fused_s,
            bit_identical=identical,
        )
        results.append(row)
        emit(
            "window_fold",
            fused_s * 1e6,
            f"W={window} B={rows} fused={fused_s * 1e6:.0f}us "
            f"loop={loop_s * 1e6:.0f}us "
            f"speedup={loop_s / fused_s:.1f}x identical={identical}",
        )

    inc_sizes = (4, 16) if smoke else INC_WINDOW_SIZES
    inc_results, inc_flatness = _incremental_sweep(
        inc_sizes, rows, cfg, smoke
    )

    out = {
        "config": {"p": cfg.p, "hash_bits": cfg.hash_bits, "m": cfg.m},
        "smoke": smoke,
        "windows": results,
        "incremental": inc_results,
        "incremental_flatness": inc_flatness,
    }
    write_bench_json(JSON_PATH, out, smoke)
    return results


if __name__ == "__main__":
    run(full=True)
