"""Windowed query latency: fused ring fold vs per-bucket merge loop.

A sliding-window reading over a ``WindowedBank`` is ONE masked max-reduce
across the (W, B, m) ring into a scratch bank plus one batched
``estimate_many`` (DESIGN.md §11).  The pre-subsystem shape of the same
query is a python loop that merges each live bucket into an accumulator —
W separate device dispatches — before the same finalization.  This bench
times both across W in {4, 16, 64}, asserts the estimates are
bit-identical, and writes ``BENCH_window.json`` so the windowed-query perf
trajectory populates across PRs next to the ingest-side
``BENCH_bank_streaming.json``.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.sketch import ExecutionPlan, HLLConfig, WindowedBank, estimate_many
from repro.sketch.plan import get_window_backend

JSON_PATH = "BENCH_window.json"
WINDOW_SIZES = (4, 16, 64)
ROWS = 64


def _filled_ring(window: int, rows: int, cfg: HLLConfig, seed: int = 0):
    """A ring whose every bucket holds a real ingested chunk."""
    rng = np.random.default_rng(seed)
    win = WindowedBank.empty(window, rows, cfg)
    for epoch in range(window):
        if epoch:
            win = win.advance()
        items = jnp.asarray(rng.integers(0, 2**31, 4096, dtype=np.int32))
        win = win.observe(items % rows, items)
    jax.block_until_ready(win.registers)
    return win


def run(full: bool = False, smoke: bool = False):
    cfg = HLLConfig(p=10, hash_bits=64)
    window_sizes = (2, 4) if smoke else WINDOW_SIZES
    rows = 8 if smoke else ROWS
    plan = ExecutionPlan(backend="jnp")
    fold = get_window_backend(plan.backend)

    results = []
    for window in window_sizes:
        win = _filled_ring(window, rows, cfg, seed=window)
        mask = win._live_mask(window)

        @jax.jit
        def fused(ring, mask):
            return estimate_many(fold(ring, mask, cfg, plan), cfg)

        def loop(ring):
            # the pre-subsystem query: one device dispatch per bucket
            acc = jnp.zeros((rows, cfg.m), ring.dtype)
            for w in range(window):
                acc = jnp.maximum(acc, ring[w])
            return estimate_many(acc, cfg)

        fused_s = time_fn(fused, win.registers, mask)
        loop_s = time_fn(loop, win.registers)
        fused_est = np.asarray(fused(win.registers, mask))
        loop_est = np.asarray(loop(win.registers))
        identical = bool(np.array_equal(fused_est, loop_est))
        if not identical:
            # the documented gate: CI bench-smoke must fail on divergence
            raise AssertionError(
                f"fused ring fold diverged from the merge loop at W={window}"
            )
        row = dict(
            W=window,
            B=rows,
            fused_us=fused_s * 1e6,
            loop_us=loop_s * 1e6,
            speedup=loop_s / fused_s,
            bit_identical=identical,
        )
        results.append(row)
        emit(
            "window_fold",
            fused_s * 1e6,
            f"W={window} B={rows} fused={fused_s * 1e6:.0f}us "
            f"loop={loop_s * 1e6:.0f}us "
            f"speedup={loop_s / fused_s:.1f}x identical={identical}",
        )

    out = {
        "config": {"p": cfg.p, "hash_bits": cfg.hash_bits, "m": cfg.m},
        "smoke": smoke,
        "windows": results,
    }
    # smoke writes a SIBLING file (uploaded by CI, gitignored locally) so it
    # can never clobber the tracked full-run perf trajectory
    path = JSON_PATH.replace(".json", ".smoke.json") if smoke else JSON_PATH
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return results


if __name__ == "__main__":
    run(full=True)
