"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` widens sweeps;
``--smoke`` shrinks every bench to seconds-scale sizes (the CI bench-smoke
job runs this, so benchmark scripts can no longer rot unexecuted).

  fig1  error vs cardinality, (p,H) x estimator sweep    (paper Fig. 1)
  fig4a throughput scaling vs #pipelines                 (paper Fig. 4a)
  fig4b hash-width cost, CPU-analogue baseline           (paper Fig. 4b)
  tab2  memory footprint grid                            (paper Tab. II)
  tab3  per-pipeline resource analogue (HLO + VMEM)      (paper Tab. III)
  tab4  sustained streaming throughput + finalization    (paper Tab. IV)
  estimators  accuracy + finalization latency per estimator, single vs
              batched; also writes BENCH_estimators.json
  bank  batched multi-tenant ingest (update_many vs per-sketch loop);
        also writes BENCH_bank_streaming.json
  window  sliding-window query (fused ring fold vs per-bucket merge loop);
          also writes BENCH_window.json
  sparse  hybrid sparse/dense tenant-row storage (memory + ingest latency
          vs the dense bank under Zipf traffic); writes BENCH_sparse.json
  heavy   heavy-hitter ingest (fused d-hash scatter vs per-row loop);
          writes BENCH_heavy.json

JSON-writing benches write in every mode: full runs update the tracked
``BENCH_*.json`` perf trajectory, smoke runs write sibling
``BENCH_*.smoke.json`` files (tagged ``"smoke": true``, gitignored) that
the CI bench-smoke job uploads as artifacts — a smoke run can never
clobber the tracked full-run numbers.

A failing sub-benchmark no longer aborts the rest of the suite: every bench
runs, every failure is reported, and the process exits non-zero at the end,
so one broken bench can't mask another and the CI smoke job still gates.
"""

from __future__ import annotations

import argparse
import importlib
import sys
import traceback

# bench name -> module under benchmarks/; imported lazily per bench so a
# module that rots at import level fails alone instead of masking the rest
SUITE = {
    "fig1": "bench_fig1_error",
    "fig4a": "bench_fig4a_scaling",
    "fig4b": "bench_fig4b_hash_width",
    "tab2": "bench_tab2_memory",
    "tab3": "bench_tab3_resources",
    "tab4": "bench_tab4_streaming",
    "estimators": "bench_estimators",
    "bank": "bench_bank_streaming",
    "window": "bench_window",
    "sparse": "bench_sparse",
    "heavy": "bench_heavy",
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="widen sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: just prove every bench still runs")
    ap.add_argument("--only", default=None,
                    help=f"comma list of benchmarks: {','.join(SUITE)}")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")

    selected = args.only.split(",") if args.only else list(SUITE)
    unknown = [name for name in selected if name not in SUITE]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; "
                 f"available: {', '.join(sorted(SUITE))}")

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        try:
            mod = importlib.import_module(f"benchmarks.{SUITE[name]}")
            mod.run(full=args.full, smoke=args.smoke)
        except Exception:
            failures.append(name)
            print(f"BENCH-FAILED,{name}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} benchmark(s) failed: {failures}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
