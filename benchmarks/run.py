"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` widens sweeps;
``--smoke`` shrinks every bench to seconds-scale sizes (the CI bench-smoke
job runs this, so benchmark scripts can no longer rot unexecuted).

  fig1  error vs cardinality, (p,H) x estimator sweep    (paper Fig. 1)
  fig4a throughput scaling vs #pipelines                 (paper Fig. 4a)
  fig4b hash-width cost, CPU-analogue baseline           (paper Fig. 4b)
  tab2  memory footprint grid                            (paper Tab. II)
  tab3  per-pipeline resource analogue (HLO + VMEM)      (paper Tab. III)
  tab4  sustained streaming throughput + finalization    (paper Tab. IV)
  estimators  accuracy + finalization latency per estimator, single vs
              batched; also writes BENCH_estimators.json
  bank  batched multi-tenant ingest (update_many vs per-sketch loop);
        also writes BENCH_bank_streaming.json
  window  sliding-window query (fused ring fold vs per-bucket merge loop);
          also writes BENCH_window.json
  sparse  hybrid sparse/dense tenant-row storage (memory + ingest latency
          vs the dense bank under Zipf traffic); writes BENCH_sparse.json
  heavy   heavy-hitter ingest (fused d-hash scatter vs per-row loop);
          writes BENCH_heavy.json
  obs   observability overhead (disabled-mode seam cost vs passthrough,
        gated at 3%); writes BENCH_obs.json
  serve production serve path: coalesced row-sharded ingest vs
        one-request-at-a-time under Zipf traffic, plus read-latency
        p50/p99 (gated at 2x coalesced speedup); writes BENCH_serve.json

JSON-writing benches write in every mode: full runs update the tracked
``BENCH_*.json`` perf trajectory, smoke runs write sibling
``BENCH_*.smoke.json`` files (tagged ``"smoke": true``, gitignored) that
the CI bench-smoke job uploads as artifacts — a smoke run can never
clobber the tracked full-run numbers.  Every payload carries an ``env``
block (jax/jaxlib version, backend platform, CPU count) so trajectory
jumps can be told apart from runner swaps; ``--summary`` renders the
tracked files plus their env stamps as one table without running
anything.

``--trace`` wraps each bench in a Chrome-trace capture and writes
``TRACE_<name>.json`` (load in Perfetto / chrome://tracing; DESIGN.md
§15).  ``--metrics-check`` runs the suite with metrics ENABLED and
asserts the final snapshot round-trips through JSON with the §15 schema
and live dispatch counters — the CI hook that keeps the instrumentation
from rotting silently.

A failing sub-benchmark no longer aborts the rest of the suite: every bench
runs, every failure is reported, and the process exits non-zero at the end,
so one broken bench can't mask another and the CI smoke job still gates.
"""

from __future__ import annotations

import argparse
import glob
import importlib
import json
import os
import sys
import traceback

# bench name -> module under benchmarks/; imported lazily per bench so a
# module that rots at import level fails alone instead of masking the rest
SUITE = {
    "fig1": "bench_fig1_error",
    "fig4a": "bench_fig4a_scaling",
    "fig4b": "bench_fig4b_hash_width",
    "tab2": "bench_tab2_memory",
    "tab3": "bench_tab3_resources",
    "tab4": "bench_tab4_streaming",
    "estimators": "bench_estimators",
    "bank": "bench_bank_streaming",
    "window": "bench_window",
    "sparse": "bench_sparse",
    "heavy": "bench_heavy",
    "obs": "bench_obs",
    "serve": "bench_serve",
}


def summarize() -> None:
    """One table over the tracked BENCH_*.json perf-trajectory files."""
    paths = sorted(
        p for p in glob.glob("BENCH_*.json") if not p.endswith(".smoke.json")
    )
    if not paths:
        print("no tracked BENCH_*.json files found", file=sys.stderr)
        sys.exit(1)
    rows = [("bench", "records", "jax", "backend", "cpus", "smoke")]
    for path in paths:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            rows.append((os.path.basename(path), f"UNREADABLE: {e}",
                         "-", "-", "-", "-"))
            continue
        env = payload.get("env", {})
        records = sum(
            len(v) for v in payload.values() if isinstance(v, list)
        ) or len(payload)
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        rows.append((
            name,
            str(records),
            str(env.get("jax", "-")),
            str(env.get("backend", "-")),
            str(env.get("cpu_count", "-")),
            str(payload.get("smoke", "-")).lower(),
        ))
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    for i, row in enumerate(rows):
        print("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip())
        if i == 0:
            print("  ".join("-" * w for w in widths))


def check_metrics_snapshot() -> None:
    """Assert the post-suite snapshot has the §15 schema and live data."""
    from repro.obs import metrics

    snap = json.loads(metrics.to_json())  # must round-trip through JSON
    missing = {"enabled", "counters", "gauges", "histograms"} - set(snap)
    assert not missing, f"snapshot missing top-level keys: {sorted(missing)}"
    assert snap["enabled"] is True
    dispatch_calls = [
        k for k in snap["counters"]
        if k.startswith("dispatch.") and k.endswith(".calls")
    ]
    assert dispatch_calls, (
        f"no dispatch.*.calls counters recorded; counters="
        f"{sorted(snap['counters'])}"
    )
    seconds = [
        k for k in snap["histograms"]
        if k.endswith(".seconds") and snap["histograms"][k]["count"] > 0
    ]
    assert seconds, "no populated *.seconds histograms recorded"
    for hist in snap["histograms"].values():
        missing = {"count", "sum", "mean", "min", "max", "p50", "p90",
                   "p99"} - set(hist)
        assert not missing, f"histogram summary missing {sorted(missing)}"
    print(
        f"metrics-check,OK,{len(dispatch_calls)} dispatch counters + "
        f"{len(seconds)} latency histograms live"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="widen sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: just prove every bench still runs")
    ap.add_argument("--only", default=None,
                    help=f"comma list of benchmarks: {','.join(SUITE)}")
    ap.add_argument("--trace", action="store_true",
                    help="write a Chrome-trace TRACE_<name>.json per bench")
    ap.add_argument("--metrics-check", action="store_true",
                    help="run with metrics enabled; assert the snapshot "
                         "parses with the DESIGN.md §15 schema (CI hook)")
    ap.add_argument("--summary", action="store_true",
                    help="print one table over the tracked BENCH_*.json "
                         "files and exit (runs nothing)")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    if args.summary:
        summarize()
        return

    selected = args.only.split(",") if args.only else list(SUITE)
    unknown = [name for name in selected if name not in SUITE]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; "
                 f"available: {', '.join(sorted(SUITE))}")

    if args.metrics_check:
        from repro.obs import metrics

        # bench_obs gates the DISABLED path and manages the flag itself
        selected = [n for n in selected if n != "obs"]
        metrics.reset()
        metrics.enable()
    if args.trace:
        from repro.obs import tracing

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        try:
            mod = importlib.import_module(f"benchmarks.{SUITE[name]}")
            if args.trace:
                tracing.start_trace()
                try:
                    mod.run(full=args.full, smoke=args.smoke)
                finally:
                    tracing.stop_trace()
                    path = tracing.write_trace(f"TRACE_{name}.json")
                    print(f"trace,{name},{path}", file=sys.stderr)
            else:
                mod.run(full=args.full, smoke=args.smoke)
        except Exception:
            failures.append(name)
            print(f"BENCH-FAILED,{name}", file=sys.stderr)
            traceback.print_exc()
    if args.metrics_check and not failures:
        try:
            check_metrics_snapshot()
        except AssertionError:
            failures.append("metrics-check")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} benchmark(s) failed: {failures}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
