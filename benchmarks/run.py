"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` widens sweeps.

  fig1  error vs cardinality, (p,H) x estimator sweep    (paper Fig. 1)
  fig4a throughput scaling vs #pipelines                 (paper Fig. 4a)
  fig4b hash-width cost, CPU-analogue baseline           (paper Fig. 4b)
  tab2  memory footprint grid                            (paper Tab. II)
  tab3  per-pipeline resource analogue (HLO + VMEM)      (paper Tab. III)
  tab4  sustained streaming throughput + finalization    (paper Tab. IV)
  estimators  accuracy + finalization latency per estimator, single vs
              batched; also writes BENCH_estimators.json
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="widen sweeps")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig4a,fig4b,tab2,tab3,tab4,"
                         "estimators")
    args = ap.parse_args()

    from benchmarks import (
        bench_estimators,
        bench_fig1_error,
        bench_fig4a_scaling,
        bench_fig4b_hash_width,
        bench_tab2_memory,
        bench_tab3_resources,
        bench_tab4_streaming,
    )

    suite = {
        "fig1": bench_fig1_error.run,
        "fig4a": bench_fig4a_scaling.run,
        "fig4b": bench_fig4b_hash_width.run,
        "tab2": bench_tab2_memory.run,
        "tab3": bench_tab3_resources.run,
        "tab4": bench_tab4_streaming.run,
        "estimators": bench_estimators.run,
    }
    selected = args.only.split(",") if args.only else list(suite)
    print("name,us_per_call,derived")
    for name in selected:
        suite[name](full=args.full)


if __name__ == "__main__":
    main()
