"""Paper Fig. 1, widened into an estimator-comparison sweep.

Reproduces the profiling of §IV — synthetic data sampled from [0, 2^32),
Murmur3 of the configured width, max/median/min relative error over trials —
but finalizes every trial's registers through each registered estimator
(original / ertl_improved / ertl_mle), so one sweep shows both the paper's
claims (32-bit hash degrades with scale, 64-bit stays ~1% across the range,
the LC->HLL transition bump sits near 5/2 * m for the original estimator)
and what the Ertl finalizers buy (no transition bump, no empirical
thresholds) on identical register state.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.sketch import HLLConfig, available_estimators, hll


CARDINALITIES = [1_000, 10_000, 40_000, 160_000, 640_000, 2_560_000]
TRIALS = 3


def run(full: bool = False, smoke: bool = False):
    rows = []
    grid = [(14, 64)] if smoke else [(14, 32), (14, 64), (16, 32), (16, 64)]
    if smoke:
        cardinalities = CARDINALITIES[:2]
    elif full:
        cardinalities = CARDINALITIES
    else:
        cardinalities = CARDINALITIES[:5]
    estimators = available_estimators()
    for p, h in grid:
        cfg = HLLConfig(p=p, hash_bits=h)
        for n in cardinalities:
            errs = {name: [] for name in estimators}
            for t in range(TRIALS):
                rng = np.random.default_rng(1000 * t + n % 997)
                items = rng.integers(0, 2**32, n, dtype=np.uint32)
                exact = len(np.unique(items))
                # one aggregation, every finalizer: the registers are shared
                regs = hll.update(
                    hll.init_registers(cfg), jnp.asarray(items), cfg
                )
                for name in estimators:
                    est = hll.estimate(regs, cfg, estimator=name)
                    errs[name].append(abs(est - exact) / exact)
            for name in estimators:
                e = sorted(errs[name])
                rows.append(
                    dict(p=p, H=h, n=n, estimator=name, err_min=e[0],
                         err_med=e[len(e) // 2], err_max=e[-1],
                         expected=hll.standard_error(cfg))
                )
    # timing of the full sketch path at the largest n
    cfg = HLLConfig(p=16, hash_bits=64)
    items = jnp.asarray(
        np.random.default_rng(0).integers(
            0, 2**32, 1 << (12 if smoke else 20), dtype=np.uint32
        )
    )
    regs = hll.init_registers(cfg)
    sec = time_fn(lambda r, x: hll.update(r, x, cfg), regs, items)
    for r in rows:
        tag = (
            f"p={r['p']} H={r['H']} n={r['n']} est={r['estimator']} "
            f"errmax={r['err_max']:.4f} errmed={r['err_med']:.4f} "
            f"sigma={r['expected']:.4f}"
        )
        emit("fig1_error", sec * 1e6, tag)
    return rows


if __name__ == "__main__":
    run(full=True)
