"""Paper Fig. 1: HLL standard error vs cardinality for (p,H) grid.

Reproduces the profiling of §IV: synthetic data sampled from [0, 2^32),
Murmur3 of the configured width, max/median/min relative error over trials.
Checks the paper's claims: 32-bit hash degrades beyond ~1e8 (approximated
here at smaller scale by saturation behaviour), 64-bit stays ~1% across the
range, and the LC->HLL transition bump sits near 5/2 * m.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.sketch import hll
from repro.sketch import HLLConfig


CARDINALITIES = [1_000, 10_000, 40_000, 160_000, 640_000, 2_560_000]
TRIALS = 3


def run(full: bool = False):
    rows = []
    grid = [(14, 32), (14, 64), (16, 32), (16, 64)]
    for p, h in grid:
        cfg = HLLConfig(p=p, hash_bits=h)
        for n in CARDINALITIES if full else CARDINALITIES[:5]:
            errs = []
            for t in range(TRIALS):
                rng = np.random.default_rng(1000 * t + n % 997)
                items = rng.integers(0, 2**32, n, dtype=np.uint32)
                exact = len(np.unique(items))
                est = hll.cardinality(jnp.asarray(items), cfg)
                errs.append(abs(est - exact) / exact)
            errs.sort()
            rows.append(
                dict(p=p, H=h, n=n, err_min=errs[0], err_med=errs[len(errs)//2],
                     err_max=errs[-1], expected=hll.standard_error(cfg))
            )
    # timing of the full sketch path at the largest n
    cfg = HLLConfig(p=16, hash_bits=64)
    items = jnp.asarray(
        np.random.default_rng(0).integers(0, 2**32, 1 << 20, dtype=np.uint32)
    )
    regs = hll.init_registers(cfg)
    sec = time_fn(lambda r, x: hll.update(r, x, cfg), regs, items)
    for r in rows:
        tag = (
            f"p={r['p']} H={r['H']} n={r['n']} errmax={r['err_max']:.4f} "
            f"errmed={r['err_med']:.4f} sigma={r['expected']:.4f}"
        )
        emit("fig1_error", sec * 1e6, tag)
    return rows


if __name__ == "__main__":
    run(full=True)
