"""Heavy-hitter ingest throughput: fused d-hash scatter vs per-row loop.

``CountMinBank.update_many`` lands a keyed stream into a (B, d, w)
counter bank with ONE fused multi-row hash-increment scatter (DESIGN.md
§13).  The pre-subsystem shape of the same ingest is a python loop that
updates each tenant row separately — B device dispatches of (1, d, w)
scatters over the per-row slices of the stream.  This bench times both
at B in {1, 64, 1024} with the stream size held constant, asserts the
resulting counter banks are bit-identical (the documented CI gate), and
writes ``BENCH_heavy.json`` so the heavy-hitter perf trajectory
populates across PRs next to ``BENCH_bank_streaming.json``.  (The
Pallas flavors run in interpret mode off-TPU, so their wall-clock here
is meaningless; their bit-identity is gated by tests/test_countmin.py.)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, write_bench_json
from repro.sketch import CMConfig, ExecutionPlan, update_cm_counters

JSON_PATH = "BENCH_heavy.json"
ROW_COUNTS = (1, 64, 1024)
TOTAL_ITEMS = 65_536


def _stream(rows: int, per_row: int, seed: int):
    """per_row items for each row, shuffled into one keyed stream."""
    rng = np.random.default_rng(seed)
    keys = np.repeat(np.arange(rows, dtype=np.int32), per_row)
    items = rng.integers(0, 2**31, rows * per_row, dtype=np.int32)
    order = rng.permutation(keys.size)
    return keys[order], items[order]


def run(full: bool = False, smoke: bool = False):
    cfg = CMConfig(depth=4, width=256 if smoke else 1024, seed=0)
    row_counts = (1, 16) if smoke else ROW_COUNTS
    total = 2_048 if smoke else TOTAL_ITEMS

    results = []
    for rows in row_counts:
        per_row = max(1, total // rows)
        keys, items = _stream(rows, per_row, seed=rows)
        zero = jnp.zeros((rows, cfg.depth, cfg.width), jnp.uint32)
        jnp_plan = ExecutionPlan(backend="jnp")

        def fused(counters, ks, xs):
            return update_cm_counters(counters, ks, xs, cfg, jnp_plan)

        # the pre-subsystem ingest: one (1, d, w) scatter dispatch per
        # tenant row; every row chunk shares one shape so the jitted
        # update compiles once and the loop cost is pure dispatch fan-out
        row_items = [
            jnp.asarray(items[keys == b]) for b in range(rows)
        ]
        row_zero_keys = jnp.zeros((per_row,), jnp.int32)

        def loop(counters):
            out = []
            for b in range(rows):
                out.append(
                    update_cm_counters(
                        counters[b : b + 1],
                        row_zero_keys,
                        row_items[b],
                        cfg,
                        jnp_plan,
                    )
                )
            return jnp.concatenate(out, axis=0)

        jkeys, jitems = jnp.asarray(keys), jnp.asarray(items)
        fused_s = time_fn(fused, zero, jkeys, jitems)
        loop_s = time_fn(loop, zero)

        want = np.asarray(loop(zero))
        got = np.asarray(fused(zero, jkeys, jitems))
        if not np.array_equal(got, want):
            # the documented gate: CI bench-smoke must fail on divergence
            raise AssertionError(
                f"fused cm ingest diverged from the per-row loop at B={rows}"
            )
        row = dict(
            B=rows,
            n=int(keys.size),
            depth=cfg.depth,
            width=cfg.width,
            fused_us=fused_s * 1e6,
            loop_us=loop_s * 1e6,
            speedup=loop_s / fused_s,
            bit_identical=True,
        )
        results.append(row)
        emit(
            "heavy_ingest",
            fused_s * 1e6,
            f"B={rows} n={keys.size} fused={fused_s * 1e6:.0f}us "
            f"loop={loop_s * 1e6:.0f}us "
            f"speedup={loop_s / fused_s:.1f}x identical=True",
        )

    out = {
        "config": {
            "depth": cfg.depth,
            "width": cfg.width,
            "total_items": total,
        },
        "smoke": smoke,
        "banks": results,
    }
    write_bench_json(JSON_PATH, out, smoke)
    return results


if __name__ == "__main__":
    run(full=True)
