"""Sustained serve-path load: coalesced sharded ingest vs one-at-a-time.

The production serve path (DESIGN.md §16) makes two structural bets: that
landing many tenants' pending updates as ONE coalesced ``update_many``
tick beats dispatching each request alone, and that sharding the bank's
tenant-row axis over devices costs nothing in correctness.  This bench
measures both under sustained Zipf traffic and writes ``BENCH_serve.json``
so "heavy traffic from millions of users" is a tracked number:

* **ingest sweep** — R requests against a B-tenant ``SketchBank``, Zipf
  tenant popularity.  Baseline: the pre-§16 serve loop, one blocking
  ``update_many`` per request.  Coalesced: the same requests submitted to
  a ``CoalescingQueue`` and drained every TICK requests through the
  double-buffered staging ring under the row-sharded plan.  The in-bench
  gate asserts coalesced ≥ ``COALESCE_GATE``x one-at-a-time items/s at
  B=1024 on CPU (relaxed under --smoke).
* **bit-identity** — before any number lands, the coalesced + sharded
  registers and counters are asserted bit-identical to the sequential
  local ingest for EVERY registered bank backend (§6 lattice laws made
  observable).
* **read latency** — a sustained tick/read cycle times every per-tenant
  dashboard read into the PR-9 ``serve.request.seconds`` histogram; the
  JSON carries its p50/p99.

The registry flag is left exactly as found: under ``--metrics-check`` the
suite already enabled it (and resetting here would wipe the other
benches' counters); standalone runs enable it just for the latency sweep.
"""

from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, write_bench_json
from repro.launch.mesh import make_auto_mesh
from repro.obs import metrics, tracing
from repro.serve.coalesce import CoalescingQueue
from repro.sketch import (
    ExecutionPlan,
    HLLConfig,
    SketchBank,
    available_bank_backends,
)

JSON_PATH = "BENCH_serve.json"
TENANTS = 1024
REQUESTS = 256
ITEMS_PER_REQUEST = 512
TICK_REQUESTS = 32  # coalescer drain cadence (requests per tick)
READ_TICKS = 8  # sustained tick/read cycles for the latency histogram
COALESCE_GATE = 2.0
COALESCE_GATE_SMOKE = 1.1
ZIPF_A = 1.2


def _zipf_requests(rows, requests, items_per_req, seed=0):
    """Per-request (tenant, items): Zipf-popular tenants, uniform tokens."""
    rng = np.random.default_rng(seed)
    tenants = (rng.zipf(ZIPF_A, requests) - 1) % rows
    streams = rng.integers(0, 2**31, (requests, items_per_req), dtype=np.int32)
    return tenants.astype(np.int32), streams


def _ingest_sequential(bank, tenants, streams, plan):
    """The pre-§16 loop: one blocking update_many per request."""
    for tenant, items in zip(tenants, streams):
        keys = np.full(items.shape[0], tenant, np.int32)
        bank = bank.update_many(keys, items, plan)
        jax.block_until_ready(bank.registers)
    return bank


def _ingest_coalesced(bank, tenants, streams, plan, tick_requests):
    """Submit per tenant, drain every ``tick_requests`` as one dispatch."""
    queue = CoalescingQueue()
    for i, (tenant, items) in enumerate(zip(tenants, streams)):
        queue.submit_row(int(tenant), items)
        if (i + 1) % tick_requests == 0:
            bank = queue.flush_into(bank, plan)
    bank = queue.flush_into(bank, plan)
    jax.block_until_ready(bank.registers)
    return bank


def _assert_bit_identical(rows, tenants, streams, shard_plan):
    """Coalesced+sharded == sequential+local, every registered backend."""
    cfg = HLLConfig(p=8, hash_bits=64)
    verdicts = {}
    for backend in available_bank_backends():
        local = ExecutionPlan(backend=backend)
        sharded = local.with_sharding(shard_plan.mesh, shard_plan.data_axes)
        ref = _ingest_sequential(SketchBank.empty(rows, cfg), tenants, streams, local)
        got = _ingest_coalesced(
            SketchBank.empty(rows, cfg), tenants, streams, sharded, 8
        )
        same = bool(
            np.array_equal(np.asarray(ref.registers), np.asarray(got.registers))
            and np.array_equal(ref.counts, got.counts)
        )
        verdicts[backend] = same
        if not same:
            raise AssertionError(
                f"coalesced sharded ingest diverged from sequential local "
                f"ingest under backend {backend!r}"
            )
        ref_est = np.asarray(ref.estimate_many())
        got_est = np.asarray(got.estimate_many(plan=sharded))
        if not np.array_equal(ref_est, got_est):
            raise AssertionError(
                f"sharded estimate_many diverged from local under "
                f"backend {backend!r}"
            )
    return verdicts


def _latency_sweep(rows, items_per_req, plan, ticks):
    """Sustained tick/read cycle -> serve.request.seconds p50/p99."""
    cfg = HLLConfig(p=12, hash_bits=64)
    bank = SketchBank.empty(rows, cfg)
    queue = CoalescingQueue()
    tenants, streams = _zipf_requests(rows, ticks * 4, items_per_req, seed=7)
    for i in range(ticks):
        for j in range(4):
            r = i * 4 + j
            queue.submit_row(int(tenants[r]), streams[r])
        bank = queue.flush_into(bank, plan)
        with tracing.span("serve.request", metric="serve.request.seconds", tick=i):
            jax.block_until_ready(bank.estimate_many(plan=plan))
    hist = metrics.snapshot()["histograms"].get("serve.request.seconds")
    if not hist or not hist["count"]:
        raise AssertionError("latency sweep recorded no serve.request.seconds samples")
    return {"p50_s": hist["p50"], "p99_s": hist["p99"], "reads": hist["count"]}


def run(full: bool = False, smoke: bool = False):
    import time

    rows = 128 if smoke else TENANTS
    requests = 32 if smoke else REQUESTS
    items_per_req = 64 if smoke else ITEMS_PER_REQUEST
    gate = COALESCE_GATE_SMOKE if smoke else COALESCE_GATE
    cfg = HLLConfig(p=12, hash_bits=64)
    mesh = make_auto_mesh((jax.device_count(),), ("data",))
    local = ExecutionPlan(backend="jnp")
    sharded = local.with_sharding(mesh)

    # correctness first: no number lands unless every backend agrees
    small_t, small_s = _zipf_requests(64, 24, 48, seed=3)
    identical = _assert_bit_identical(64, small_t, small_s, sharded)
    emit(
        "serve_bit_identity",
        0.0,
        f"coalesced+sharded == sequential+local for "
        f"{sorted(identical)} at B=64",
    )

    tenants, streams = _zipf_requests(rows, requests, items_per_req)
    total_items = requests * items_per_req

    def timed(fn):
        fn()  # warm the compile caches outside the timed run
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    seq_s = timed(
        lambda: _ingest_sequential(SketchBank.empty(rows, cfg), tenants, streams, local)
    )
    coal_s = timed(
        lambda: _ingest_coalesced(
            SketchBank.empty(rows, cfg),
            tenants,
            streams,
            sharded,
            TICK_REQUESTS,
        )
    )
    seq_rate = total_items / seq_s
    coal_rate = total_items / coal_s
    speedup = coal_rate / seq_rate
    emit(
        "serve_ingest",
        coal_s * 1e6,
        f"B={rows} R={requests} n/req={items_per_req} "
        f"coalesced={coal_rate:,.0f} items/s "
        f"sequential={seq_rate:,.0f} items/s speedup={speedup:.2f}x",
    )
    if speedup < gate:
        raise AssertionError(
            f"coalesced ingest only {speedup:.2f}x one-at-a-time at "
            f"B={rows} (gate {gate}x)"
        )

    # the latency sweep needs a live registry; leave the flag as found
    was_enabled = metrics.enabled()
    if not was_enabled:
        metrics.enable()
    try:
        latency = _latency_sweep(
            rows, items_per_req, sharded, 4 if smoke else READ_TICKS
        )
    finally:
        if not was_enabled:
            metrics.disable()
    emit(
        "serve_read_latency",
        latency["p50_s"] * 1e6,
        f"p50={latency['p50_s'] * 1e6:.0f}us "
        f"p99={latency['p99_s'] * 1e6:.0f}us over {latency['reads']} reads",
    )

    payload = {
        "smoke": smoke,
        "devices": jax.device_count(),
        "ingest": {
            "tenants": rows,
            "requests": requests,
            "items_per_request": items_per_req,
            "tick_requests": TICK_REQUESTS,
            "zipf_a": ZIPF_A,
            "sequential_items_per_s": seq_rate,
            "coalesced_items_per_s": coal_rate,
            "speedup": speedup,
            "gate": gate,
            "bit_identical": identical,
        },
        "read_latency": latency,
    }
    path = write_bench_json(JSON_PATH, payload, smoke)
    emit("serve_json", 0.0, path)


if __name__ == "__main__":
    run()
