"""Shared benchmark helpers: timing, CSV emission, JSON trajectory writes."""

from __future__ import annotations

import json
import os
import platform
import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (blocks on async dispatch)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def env_block() -> dict:
    """Where these numbers were measured (stamped into every BENCH_*.json).

    The perf trajectory spans PRs and machines; without the environment
    block a 1.4x "regression" is indistinguishable from a CI runner swap.
    """
    import jaxlib

    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def write_bench_json(json_path: str, payload: dict, smoke: bool) -> str:
    """Stamp the env block and write the bench JSON; returns the path.

    Smoke runs write a SIBLING ``*.smoke.json`` file (uploaded by CI,
    gitignored locally) so they can never clobber the tracked full-run
    perf trajectory.
    """
    payload = dict(payload)
    payload["env"] = env_block()
    path = json_path.replace(".json", ".smoke.json") if smoke else json_path
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path
