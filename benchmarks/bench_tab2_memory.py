"""Paper Tab. II: HLL memory footprint over the (p, H) grid.

Validates eq. (3) B = 2^p * ceil(log2(H-p+1)) against the paper's numbers
(10/12/40/48 KiB) and reports the actual register-array bytes the
implementation allocates (uint8 registers: the TPU trades the 6-bit packing
for lane-addressable bytes; the table reports both).
"""

from __future__ import annotations


from benchmarks.common import emit
from repro.sketch.exact import naive_distinct_mem_bytes
from repro.sketch import HLLConfig

PAPER_KIB = {(14, 32): 10, (14, 64): 12, (16, 32): 40, (16, 64): 48}


def run(full: bool = False, smoke: bool = False):
    # analytic table: already tiny, smoke changes nothing
    rows = []
    for (p, h), paper_kib in PAPER_KIB.items():
        cfg = HLLConfig(p=p, hash_bits=h)
        packed_kib = cfg.memory_footprint_bits / 8 / 1024
        alloc_kib = cfg.m * 1 / 1024  # uint8 registers
        assert packed_kib == paper_kib, (p, h, packed_kib)
        rows.append(
            dict(p=p, H=h, packed_kib=packed_kib, alloc_kib=alloc_kib,
                 register_bits=cfg.register_bits, max_rank=cfg.max_rank)
        )
        emit(
            "tab2_memory", 0.0,
            f"p={p} H={h} packed={packed_kib:.0f}KiB(paper={paper_kib}) "
            f"alloc_uint8={alloc_kib:.0f}KiB regbits={cfg.register_bits}",
        )
    # the paper's motivation: naive set memory at 1e9 distinct items
    naive = naive_distinct_mem_bytes(10**9) / 2**30
    emit("tab2_naive_set", 0.0, f"exact_set_at_1e9={naive:.1f}GiB vs 48KiB sketch")
    return rows


if __name__ == "__main__":
    run(full=True)
