"""Paper Fig. 4(a): throughput vs #pipelines (the FPGA scaling figure).

TPU analogue: k sub-sketch pipelines per device (ExecutionPlan(pipelines=k)).  We
measure measured-vs-theoretical scaling exactly as the paper plots it: the
theoretical line is k x single-pipeline rate; the measured line saturates at
the platform's I/O bound (PCIe for the paper; here the host CPU's memory
bandwidth plays that role).  On a real v5e the same harness saturates HBM at
819 GB/s (= the paper's '10 pipelines saturate PCIe' moment).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.sketch import ExecutionPlan, hll, update_registers
from repro.sketch import HLLConfig

N_ITEMS = 1 << 21  # 2M items, 8 MiB
PIPELINES = (1, 2, 4, 8, 16)


def run(full: bool = False, smoke: bool = False):
    cfg = HLLConfig(p=16, hash_bits=64)
    n_items = 1 << 12 if smoke else N_ITEMS
    items = jnp.asarray(
        np.random.default_rng(0).integers(0, 2**32, n_items, dtype=np.uint32)
    )
    regs = hll.init_registers(cfg)

    base_sec = None
    rows = []
    for k in (1, 2) if smoke else PIPELINES:
        fn = lambda r, x, k=k: update_registers(
                r, x, cfg, ExecutionPlan(backend="jnp", pipelines=k)
            )
        sec = time_fn(fn, regs, items)
        gbps = n_items * 4 / sec / 1e9
        if base_sec is None:
            base_sec = sec
        theoretical = n_items * 4 / (base_sec / k) / 1e9
        rows.append(dict(pipelines=k, gbytes_s=gbps, theoretical=theoretical))
        emit(
            "fig4a_scaling", sec * 1e6,
            f"pipelines={k} measured={gbps:.3f}GB/s "
            f"theoretical={theoretical:.3f}GB/s items_s={n_items/sec:,.0f}",
        )
    return rows


if __name__ == "__main__":
    run(full=True)
