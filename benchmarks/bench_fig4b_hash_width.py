"""Paper Fig. 4(b): CPU baseline + the 32-vs-64-bit hash cost.

The paper's CPU result: the 64-bit hash runs at ~60% of the 32-bit rate
(compute-bound), while the FPGA holds identical throughput for both by
unrolling in space.  Here: the jitted jnp scatter path is the 'CPU baseline'
and the 16-bit-limb 64-bit hash measurably costs more than murmur3_32 —
reproducing the CPU-side claim; the roofline analysis of the Pallas kernel
(bench_tab3) shows the TPU side is memory-bound, i.e. width-insensitive, at
the paper's FPGA conclusion.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.sketch import hll, murmur3
from repro.sketch import HLLConfig

N = 1 << 21


def run(full: bool = False, smoke: bool = False):
    n = 1 << 12 if smoke else N
    items = jnp.asarray(
        np.random.default_rng(0).integers(0, 2**32, n, dtype=np.uint32)
    )
    rows = []

    h32 = jax.jit(lambda x: murmur3.murmur3_32(x, 0))
    h64 = jax.jit(lambda x: murmur3.murmur3_64(x, 0))
    s32 = time_fn(h32, items)
    s64 = time_fn(h64, items)
    ratio = s32 / s64
    rows.append(dict(hash32_s=s32, hash64_s=s64, rate_ratio=ratio))
    emit("fig4b_hash32", s32 * 1e6, f"items_s={n/s32:,.0f}")
    emit(
        "fig4b_hash64", s64 * 1e6,
        f"items_s={n/s64:,.0f} rate_vs_32bit={ratio:.2f} (paper CPU: ~0.60)",
    )

    # end-to-end sketch update, both widths (aggregation included)
    for bits in (32, 64):
        cfg = HLLConfig(p=16, hash_bits=bits)
        regs = hll.init_registers(cfg)
        sec = time_fn(lambda r, x, c=cfg: hll.update(r, x, c), regs, items)
        rows.append(dict(bits=bits, update_s=sec))
        emit(
            f"fig4b_update{bits}", sec * 1e6,
            f"GB_s={n*4/sec/1e9:.3f} items_s={n/sec:,.0f}",
        )
    return rows


if __name__ == "__main__":
    run(full=True)
