"""Table-4-style batched multi-tenant ingest: update_many vs per-sketch loop.

The paper's Tab. IV measures sustained single-sketch ingest; the bank-scale
question (ROADMAP: per-user cardinality for millions of users) is how fast a
keyed stream lands in B sketches at once.  This bench routes one uniform
keyed stream into a (B, m) SketchBank two ways:

* ``update_many`` — ONE fused keyed scatter-max per chunk (DESIGN.md §9),
* the per-sketch loop — route on the host, then one ``hll.update`` dispatch
  per bank row (the pre-bank shape of the ingest path),

verifies they are bit-identical, and reports items/sec at B in {1, 64, 1024}
plus the batched-vs-loop speedup.  Writes ``BENCH_bank_streaming.json`` so
the ingest-side perf trajectory populates across PRs, next to the
finalization-side ``BENCH_estimators.json``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, write_bench_json
from repro.sketch import ExecutionPlan, HLLConfig, SketchBank, hll

JSON_PATH = "BENCH_bank_streaming.json"
BANK_SIZES = (1, 64, 1024)
CHUNKS = 4


def _grouped(items: np.ndarray, keys: np.ndarray, rows: int) -> list:
    """Host-side routing for the loop path: items[keys == b], edge-padded.

    Every non-empty group is padded to one common length by repeating its
    last element — idempotent on the max-lattice, so the loop stays
    bit-identical while compiling a single update shape.
    """
    groups = [items[keys == b] for b in range(rows)]
    width = max(g.size for g in groups)
    out = []
    for g in groups:
        if g.size == 0:
            out.append(None)
        elif g.size < width:
            out.append(np.pad(g, (0, width - g.size), mode="edge"))
        else:
            out.append(g)
    return out


def run(full: bool = False, smoke: bool = False):
    cfg = HLLConfig(p=10, hash_bits=64)
    bank_sizes = (1, 8) if smoke else BANK_SIZES
    n = 1 << (12 if smoke else (20 if full else 18))
    chunks = 1 if smoke else CHUNKS

    rng = np.random.default_rng(0)
    results = []
    for rows in bank_sizes:
        items_np = rng.integers(0, 2**31, (chunks, n), dtype=np.int32)
        keys_np = rng.integers(0, rows, (chunks, n), dtype=np.int32)
        items = jnp.asarray(items_np)
        keys = jnp.asarray(keys_np)
        plan = ExecutionPlan(backend="jnp")

        bank = SketchBank.empty(rows, cfg)

        def ingest_batched(b, ks, xs):
            for c in range(chunks):
                b = b.update_many(ks[c], xs[c], plan)
            return b.registers

        batched_s = time_fn(ingest_batched, bank, keys, items)
        batched_regs = np.asarray(ingest_batched(bank, keys, items))

        update = jax.jit(lambda r, x: hll.update(r, x, cfg))
        grouped = [_grouped(items_np[c], keys_np[c], rows) for c in range(chunks)]

        def ingest_loop(groups):
            regs = [hll.init_registers(cfg) for _ in range(rows)]
            for chunk_groups in groups:
                for b, g in enumerate(chunk_groups):
                    if g is not None:
                        regs[b] = update(regs[b], jnp.asarray(g))
            return jnp.stack(regs)

        loop_s = time_fn(ingest_loop, grouped, warmup=1, iters=3)
        loop_regs = np.asarray(ingest_loop(grouped))

        identical = bool(np.array_equal(batched_regs, loop_regs))
        if not identical:
            # the documented gate: CI bench-smoke must fail on divergence
            raise AssertionError(
                f"update_many diverged from the per-sketch loop at B={rows}"
            )
        total = chunks * n
        row = dict(
            B=rows,
            items_per_chunk=n,
            chunks=chunks,
            batched_items_per_s=total / batched_s,
            loop_items_per_s=total / loop_s,
            speedup=loop_s / batched_s,
            bit_identical=identical,
        )
        results.append(row)
        emit(
            "bank_streaming",
            batched_s / chunks * 1e6,
            f"B={rows} batched={total / batched_s:,.0f}items/s "
            f"loop={total / loop_s:,.0f}items/s "
            f"speedup={loop_s / batched_s:.1f}x identical={identical}",
        )

    out = {
        "config": {"p": cfg.p, "hash_bits": cfg.hash_bits, "m": cfg.m},
        "smoke": smoke,
        "banks": results,
    }
    write_bench_json(JSON_PATH, out, smoke)
    return results


if __name__ == "__main__":
    run(full=True)
