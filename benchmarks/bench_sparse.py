"""Sparse vs dense tenant-row storage: memory footprint + ingest latency.

The §12 acceptance experiment: B-row banks under Zipf-skewed tenant
traffic (<= 10% of rows hot, the rest nearly empty) ingested into a dense
``SketchBank`` and a hybrid ``HybridBank`` side by side, at
B in {64, 1024, 16384}.  For each size the bench measures

  * actual storage bytes of both representations and the reduction factor
    (the acceptance gate: >= 4x at B=16384),
  * full-stream ingest latency for both paths — the hybrid timing
    INCLUDES the deferred append-buffer compaction (the final
    block-until-ready settles the bank), so the reported
    ``hybrid_over_dense_ratio`` is the honest end-to-end cost of the
    amortized path (full-run gate: <= 1.5x dense at every B; smoke runs
    gate at 2.0x to absorb tiny-stream noise),
  * estimate quality: hybrid estimates vs the TRUE per-row distinct
    counts, asserted within an order-statistic-corrected error band —
    the per-row tolerance uses the Bonferroni z for the max over B
    normal deviates (z = Phi^-1(1 - alpha / (2B)) at alpha = 0.01, e.g.
    ~4.99 sigma at B=16384: with 16384 rows a ~4.5-sigma worst row is
    EXPECTED, so a flat 3-sigma claim would be wrong) plus small-count
    slack for the near-empty cold rows where sigma*true is
    sub-collision-sized, and
  * bit-identity: the hybrid bank materialized to dense must equal the
    dense bank register-for-register — promoted rows included, which
    pins "promoted == dense-from-scratch" at benchmark scale — and the
    hybrid estimates (LC fast path for sparse rows) must equal the dense
    bank's device estimates bit-for-bit.

Writes ``BENCH_sparse.json`` (smoke runs write the gitignored
``BENCH_sparse.smoke.json`` sibling, like every other JSON bench).
"""

from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.sketch import HLLConfig, HybridBank, SketchBank, estimate_many

JSON_PATH = "BENCH_sparse.json"
BANK_SIZES = (64, 1024, 16384)
HOT_FRAC = 0.1  # <= 10% of rows take ~90% of the traffic (acceptance)
HOT_SHARE = 0.9
CHUNKS = 4
BAND_ALPHA = 0.01  # family-wise error budget for the B-row estimate band
RATIO_GATE_FULL = 1.5  # hybrid/dense ingest ceiling, full runs (§12)
RATIO_GATE_SMOKE = 2.0  # looser smoke ceiling: tiny streams, fixed overheads


def _zipf_traffic(rows: int, n: int, rng):
    """Keyed stream: HOT_FRAC of the rows receive HOT_SHARE of the items."""
    hot = max(1, int(rows * HOT_FRAC))
    hot_keys = rng.integers(0, hot, n)
    cold_keys = rng.integers(hot, rows, n) if rows > hot else hot_keys
    keys = np.where(rng.random(n) < HOT_SHARE, hot_keys, cold_keys)
    items = rng.integers(0, 2**31, n, dtype=np.int32)
    return keys.astype(np.int32), items


def _true_distinct(keys: np.ndarray, items: np.ndarray, rows: int):
    """(B,) exact distinct item counts per row (the oracle)."""
    combo = keys.astype(np.int64) * (1 << 31) + items.astype(np.int64)
    uniq = np.unique(combo)
    return np.bincount((uniq >> 31).astype(np.int64), minlength=rows)


def _band_z(rows: int, alpha: float = BAND_ALPHA) -> float:
    """Bonferroni z for the max error over ``rows`` estimate deviates.

    Per-row two-sided budget alpha / rows, so P(any row outside the band)
    <= alpha under the estimator's normal error model — the
    order-statistic correction the flat 3-sigma claim was missing.
    """
    return statistics.NormalDist().inv_cdf(1.0 - alpha / (2.0 * rows))


def _ingest_all(empty_bank, key_chunks, item_chunks):
    bank = empty_bank
    for k, it in zip(key_chunks, item_chunks):
        bank = bank.update_many(k, it)
    if isinstance(bank, SketchBank):
        jax.block_until_ready(bank.registers)
    else:
        # .dense_rows / .pairs settle the append buffer: deferred
        # compaction cost lands INSIDE the timed region, by design
        jax.block_until_ready(bank.dense if bank.dense_rows else bank.pairs)
    return bank


def _time(fn, iters: int) -> float:
    fn()  # warmup (compiles the fixed chunk shapes)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def run(full: bool = False, smoke: bool = False):
    cfg = HLLConfig(p=8, hash_bits=64) if smoke else HLLConfig(p=12, hash_bits=64)
    sizes = (16, 64) if smoke else BANK_SIZES
    sigma = 1.04 / np.sqrt(cfg.m)
    ratio_gate = RATIO_GATE_SMOKE if smoke else RATIO_GATE_FULL

    results = []
    for rows in sizes:
        rng = np.random.default_rng(rows)
        # enough hot traffic to push hot rows well past the m//4 threshold
        n = 64 * rows if smoke else 222 * rows
        keys, items = _zipf_traffic(rows, n, rng)
        key_chunks = [
            jnp.asarray(c) for c in np.array_split(keys, CHUNKS)
        ]
        item_chunks = [
            jnp.asarray(c) for c in np.array_split(items, CHUNKS)
        ]

        iters = 1 if rows >= 16384 else 3
        dense_s = _time(
            lambda: _ingest_all(
                SketchBank.empty(rows, cfg), key_chunks, item_chunks
            ),
            iters,
        )
        hybrid_s = _time(
            lambda: _ingest_all(
                HybridBank.empty(rows, cfg), key_chunks, item_chunks
            ),
            iters,
        )
        dense = _ingest_all(SketchBank.empty(rows, cfg), key_chunks, item_chunks)
        hybrid = _ingest_all(HybridBank.empty(rows, cfg), key_chunks, item_chunks)

        # bit-identity: promoted rows (and everything else) must equal the
        # dense-from-scratch bank exactly — the documented CI gate
        if not np.array_equal(
            np.asarray(hybrid.to_dense().registers), np.asarray(dense.registers)
        ):
            raise AssertionError(
                f"hybrid ingest diverged from dense registers at B={rows}"
            )
        # ...and the hybrid estimates (LC fast path on sparse rows) must
        # equal the dense device estimates bit-for-bit (DESIGN.md §12)
        est = np.asarray(hybrid.estimate_many())
        dense_est = np.asarray(estimate_many(dense.registers, cfg))
        if not np.array_equal(est, dense_est):
            worst = int(np.argmax(est != dense_est))
            raise AssertionError(
                f"B={rows} row {worst}: hybrid estimate {est[worst]!r} != "
                f"dense estimate {dense_est[worst]!r}"
            )

        # order-statistic-corrected band vs the exact oracle: Bonferroni z
        # for the max over B rows, + small-count slack for cold rows
        z = _band_z(rows)
        true = _true_distinct(keys, items, rows)
        est64 = est.astype(np.float64)
        tol = z * sigma * true + 3.0 * np.sqrt(true + 1.0)
        err = np.abs(est64 - true)
        if not (err <= tol).all():
            worst = int(np.argmax(err - tol))
            raise AssertionError(
                f"B={rows} row {worst}: estimate {est64[worst]:.1f} vs true "
                f"{true[worst]} leaves the {z:.2f}-sigma Bonferroni band "
                f"(tol {tol[worst]:.1f})"
            )

        density = hybrid.density()
        reduction = dense.nbytes / hybrid.nbytes
        ratio = hybrid_s / dense_s
        row = dict(
            B=rows,
            n_items=int(n),
            hot_rows=max(1, int(rows * HOT_FRAC)),
            promoted_rows=hybrid.dense_rows,
            sparse_capacity=hybrid.capacity,
            dense_nbytes=dense.nbytes,
            hybrid_nbytes=hybrid.nbytes,
            memory_reduction=reduction,
            dense_ingest_us=dense_s * 1e6,
            hybrid_ingest_us=hybrid_s * 1e6,
            ingest_items_per_s=n / hybrid_s,
            hybrid_over_dense_ratio=ratio,
            occupancy_mean=density["occupancy_mean"],
            err_band_sigma=float(z),
            max_err_sigma=float((err / np.maximum(sigma * true, 1e-9)).max()),
            bit_identical=True,
        )
        results.append(row)
        emit(
            "sparse_bank",
            hybrid_s * 1e6,
            f"B={rows} mem {dense.nbytes / 2**20:.1f}MiB->"
            f"{hybrid.nbytes / 2**20:.1f}MiB ({reduction:.1f}x) "
            f"promoted={hybrid.dense_rows} ingest dense={dense_s * 1e6:.0f}us "
            f"hybrid={hybrid_s * 1e6:.0f}us ({ratio:.2f}x, "
            f"{n / hybrid_s / 1e6:.1f}M items/s)",
        )
        if ratio > ratio_gate:
            # the §12 perf gate the append-buffer path exists to hold
            raise AssertionError(
                f"hybrid ingest is {ratio:.2f}x dense at B={rows}, over "
                f"the {ratio_gate}x {'smoke ' if smoke else ''}gate"
            )

    if not smoke and results[-1]["memory_reduction"] < 4.0:
        # the §12 acceptance gate: >= 4x at the largest bank size
        raise AssertionError(
            f"memory reduction {results[-1]['memory_reduction']:.2f}x at "
            f"B={results[-1]['B']} is below the 4x acceptance bar"
        )

    out = {
        "config": {"p": cfg.p, "hash_bits": cfg.hash_bits, "m": cfg.m},
        "traffic": {"hot_frac": HOT_FRAC, "hot_share": HOT_SHARE},
        "band": {"alpha": BAND_ALPHA, "correction": "bonferroni_max_over_B"},
        "smoke": smoke,
        "banks": results,
    }
    write_bench_json(JSON_PATH, out, smoke)
    return results


if __name__ == "__main__":
    run(full=True)
