"""Sparse vs dense tenant-row storage: memory footprint + ingest latency.

The §12 acceptance experiment: B-row banks under Zipf-skewed tenant
traffic (<= 10% of rows hot, the rest nearly empty) ingested into a dense
``SketchBank`` and a hybrid ``HybridBank`` side by side, at
B in {64, 1024, 16384}.  For each size the bench measures

  * actual storage bytes of both representations and the reduction factor
    (the acceptance gate: >= 4x at B=16384),
  * full-stream ingest latency for both paths,
  * estimate quality: hybrid estimates vs the TRUE per-row distinct
    counts, asserted within the estimator's 3-sigma band (+ small-count
    slack), and
  * bit-identity: the hybrid bank materialized to dense must equal the
    dense bank register-for-register — promoted rows included, which
    pins "promoted == dense-from-scratch" at benchmark scale.

Writes ``BENCH_sparse.json`` (smoke runs write the gitignored
``BENCH_sparse.smoke.json`` sibling, like every other JSON bench).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.sketch import HLLConfig, HybridBank, SketchBank

JSON_PATH = "BENCH_sparse.json"
BANK_SIZES = (64, 1024, 16384)
HOT_FRAC = 0.1  # <= 10% of rows take ~90% of the traffic (acceptance)
HOT_SHARE = 0.9
CHUNKS = 4


def _zipf_traffic(rows: int, n: int, rng):
    """Keyed stream: HOT_FRAC of the rows receive HOT_SHARE of the items."""
    hot = max(1, int(rows * HOT_FRAC))
    hot_keys = rng.integers(0, hot, n)
    cold_keys = rng.integers(hot, rows, n) if rows > hot else hot_keys
    keys = np.where(rng.random(n) < HOT_SHARE, hot_keys, cold_keys)
    items = rng.integers(0, 2**31, n, dtype=np.int32)
    return keys.astype(np.int32), items


def _true_distinct(keys: np.ndarray, items: np.ndarray, rows: int):
    """(B,) exact distinct item counts per row (the oracle)."""
    combo = keys.astype(np.int64) * (1 << 31) + items.astype(np.int64)
    uniq = np.unique(combo)
    return np.bincount((uniq >> 31).astype(np.int64), minlength=rows)


def _ingest_all(empty_bank, key_chunks, item_chunks):
    bank = empty_bank
    for k, it in zip(key_chunks, item_chunks):
        bank = bank.update_many(k, it)
    if isinstance(bank, SketchBank):
        jax.block_until_ready(bank.registers)
    else:
        jax.block_until_ready(bank.dense if bank.dense_rows else bank.pairs)
    return bank


def _time(fn, iters: int) -> float:
    fn()  # warmup (compiles the fixed chunk shapes)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def run(full: bool = False, smoke: bool = False):
    cfg = HLLConfig(p=8, hash_bits=64) if smoke else HLLConfig(p=12, hash_bits=64)
    sizes = (16, 64) if smoke else BANK_SIZES
    sigma = 1.04 / np.sqrt(cfg.m)

    results = []
    for rows in sizes:
        rng = np.random.default_rng(rows)
        # enough hot traffic to push hot rows well past the m//4 threshold
        n = 64 * rows if smoke else 222 * rows
        keys, items = _zipf_traffic(rows, n, rng)
        key_chunks = [
            jnp.asarray(c) for c in np.array_split(keys, CHUNKS)
        ]
        item_chunks = [
            jnp.asarray(c) for c in np.array_split(items, CHUNKS)
        ]

        iters = 1 if rows >= 16384 else 3
        dense_s = _time(
            lambda: _ingest_all(
                SketchBank.empty(rows, cfg), key_chunks, item_chunks
            ),
            iters,
        )
        hybrid_s = _time(
            lambda: _ingest_all(
                HybridBank.empty(rows, cfg), key_chunks, item_chunks
            ),
            iters,
        )
        dense = _ingest_all(SketchBank.empty(rows, cfg), key_chunks, item_chunks)
        hybrid = _ingest_all(HybridBank.empty(rows, cfg), key_chunks, item_chunks)

        # bit-identity: promoted rows (and everything else) must equal the
        # dense-from-scratch bank exactly — the documented CI gate
        if not np.array_equal(
            np.asarray(hybrid.to_dense().registers), np.asarray(dense.registers)
        ):
            raise AssertionError(
                f"hybrid ingest diverged from dense registers at B={rows}"
            )

        # 3-sigma band vs the exact oracle (small-count slack for the
        # near-empty cold rows, where sigma*true is sub-collision-sized)
        true = _true_distinct(keys, items, rows)
        est = np.asarray(hybrid.estimate_many(), np.float64)
        tol = 3.0 * sigma * true + 3.0 * np.sqrt(true + 1.0)
        err = np.abs(est - true)
        if not (err <= tol).all():
            worst = int(np.argmax(err - tol))
            raise AssertionError(
                f"B={rows} row {worst}: estimate {est[worst]:.1f} vs true "
                f"{true[worst]} leaves the 3-sigma band (tol {tol[worst]:.1f})"
            )

        density = hybrid.density()
        reduction = dense.nbytes / hybrid.nbytes
        row = dict(
            B=rows,
            n_items=int(n),
            hot_rows=max(1, int(rows * HOT_FRAC)),
            promoted_rows=hybrid.dense_rows,
            sparse_capacity=hybrid.capacity,
            dense_nbytes=dense.nbytes,
            hybrid_nbytes=hybrid.nbytes,
            memory_reduction=reduction,
            dense_ingest_us=dense_s * 1e6,
            hybrid_ingest_us=hybrid_s * 1e6,
            occupancy_mean=density["occupancy_mean"],
            max_err_sigma=float((err / np.maximum(sigma * true, 1e-9)).max()),
            bit_identical=True,
        )
        results.append(row)
        emit(
            "sparse_bank",
            hybrid_s * 1e6,
            f"B={rows} mem {dense.nbytes / 2**20:.1f}MiB->"
            f"{hybrid.nbytes / 2**20:.1f}MiB ({reduction:.1f}x) "
            f"promoted={hybrid.dense_rows} ingest dense={dense_s * 1e6:.0f}us "
            f"hybrid={hybrid_s * 1e6:.0f}us",
        )

    if not smoke and results[-1]["memory_reduction"] < 4.0:
        # the §12 acceptance gate: >= 4x at the largest bank size
        raise AssertionError(
            f"memory reduction {results[-1]['memory_reduction']:.2f}x at "
            f"B={results[-1]['B']} is below the 4x acceptance bar"
        )

    out = {
        "config": {"p": cfg.p, "hash_bits": cfg.hash_bits, "m": cfg.m},
        "traffic": {"hot_frac": HOT_FRAC, "hot_share": HOT_SHARE},
        "smoke": smoke,
        "banks": results,
    }
    path = JSON_PATH.replace(".json", ".smoke.json") if smoke else JSON_PATH
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return results


if __name__ == "__main__":
    run(full=True)
