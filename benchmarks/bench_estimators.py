"""Estimator comparison: accuracy + finalization latency, single vs batched.

The paper reports the computation phase as a constant 203 us (§V).  This
bench sweeps every registered estimator over the same register banks and
records, per estimator:

  * relative error vs exact cardinality at small/mid/large ranges,
  * exact host finalization latency (histogram + O(H-p) finalizer),
  * float32 device finalization latency for one sketch,
  * batched ``estimate_many`` latency over a 64-sketch bank, amortized
    per sketch — the StreamSketch-board / serving-fleet path.

Besides the usual CSV rows it writes ``BENCH_estimators.json`` so the
perf trajectory of the fourth algorithm phase populates across PRs.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, write_bench_json
from repro.sketch import HLLConfig, estimate_many, hll
from repro.sketch import estimators as estlib

JSON_PATH = "BENCH_estimators.json"
BANK_SIZE = 64


def _sketch(cfg, n, seed):
    items = np.random.default_rng(seed).integers(0, 2**31, n, dtype=np.int32)
    regs = hll.update(hll.init_registers(cfg), jnp.asarray(items), cfg)
    return regs, len(np.unique(items))


def run(full: bool = False, smoke: bool = False, json_path: str = JSON_PATH):
    cfg = HLLConfig(p=10 if smoke else 14, hash_bits=64)
    if smoke:
        cardinalities = [1_000, 20_000]
    elif full:
        cardinalities = [1_000, 50_000, 1_000_000]
    else:
        cardinalities = [1_000, 50_000]

    # accuracy sweeps reuse one register bank per cardinality
    banks = {n: _sketch(cfg, n, seed=n) for n in cardinalities}
    # latency bank: BANK_SIZE mid-range sketches stacked (B, m)
    lat_regs, _ = banks[cardinalities[-1] if smoke else 50_000]
    stacked = jnp.stack([lat_regs] * BANK_SIZE)

    out = {
        "config": {"p": cfg.p, "hash_bits": cfg.hash_bits, "m": cfg.m},
        "bank_size": BANK_SIZE,
        "estimators": {},
    }
    for name in estlib.available_estimators():
        acc = []
        for n, (regs, exact) in banks.items():
            est = estlib.estimate(regs, cfg, name)
            acc.append(
                {"n": n, "exact": exact, "estimate": est,
                 "rel_err": abs(est - exact) / exact}
            )

        # time_fn works for the host path too (block_until_ready is a no-op
        # on a python float), keeping all three latencies the same statistic
        host_s = time_fn(lambda r: estlib.estimate(r, cfg, name), lat_regs)
        dev_s = time_fn(
            lambda r: estlib.estimate_device(r, cfg, name), lat_regs
        )
        many_s = time_fn(lambda b: estimate_many(b, cfg, name), stacked)

        row = {
            "accuracy": acc,
            "host_us": host_s * 1e6,
            "device_us": dev_s * 1e6,
            "batched_us_total": many_s * 1e6,
            "batched_us_per_sketch": many_s * 1e6 / BANK_SIZE,
            "batch_speedup_vs_device": dev_s / (many_s / BANK_SIZE),
        }
        out["estimators"][name] = row
        worst = max(a["rel_err"] for a in acc)
        emit(
            "estimators",
            row["host_us"],
            f"est={name} host_us={row['host_us']:.0f} "
            f"device_us={row['device_us']:.0f} "
            f"batched_us/sketch={row['batched_us_per_sketch']:.1f} "
            f"errmax={worst:.4f}",
        )

    out["smoke"] = smoke
    write_bench_json(json_path, out, smoke)
    return out


if __name__ == "__main__":
    run(full=True)
