"""Elastic rescale demo: train, checkpoint, resume on a different topology.

    PYTHONPATH=src python examples/elastic_rescale.py

Simulates the production story on CPU: phase 1 trains N steps and
checkpoints; phase 2 'loses half the fleet' — the same checkpoint resumes
onto a different mesh layout with every array resharded on restore
(checkpoint/ckpt.py), the step-indexed data pipeline continues exactly
where it left off, and the HLL sketch registers survive verbatim (a
max-lattice cannot be corrupted by topology changes or replayed batches).
"""

import shutil
import tempfile

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.launch.mesh import make_auto_mesh
from repro.configs import get_arch
from repro.sketch import HLLConfig, estimate
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptimizerConfig
from repro.train.loop import LoopConfig, train
from repro.train.step import TrainConfig


def main():
    arch = get_arch("smollm-360m").reduced()
    cfg = TrainConfig(
        optimizer=OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=40),
        sketch=HLLConfig(p=10, hash_bits=64),
    )
    data = DataConfig(vocab_size=arch.vocab_size, global_batch=4, seq_len=64)
    d = tempfile.mkdtemp(prefix="repro_elastic_")
    try:
        print("=== phase 1: 'big mesh' — 20 steps, checkpoint at 20")
        loop1 = LoopConfig(total_steps=20, ckpt_every=20, ckpt_dir=d,
                           async_ckpt=False, log_every=10)
        state1, _ = train(arch, cfg, data, loop1)
        sketch_before = np.asarray(state1["sketch"])

        print("\n=== phase 2: fleet rescaled — resume from the checkpoint "
              "onto a different device layout, continue to step 40")
        mesh = make_auto_mesh((jax.device_count(),), ("data",))
        # restore with explicit (re)shardings: the elastic path
        template = state1
        shardings = jax.tree.map(
            lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            template,
        )
        restored = ckpt.restore(template, d, 20, shardings=shardings)
        np.testing.assert_array_equal(
            np.asarray(restored["sketch"]), sketch_before
        )
        print("sketch registers survived resharding bit-exactly")

        loop2 = LoopConfig(total_steps=40, ckpt_every=40, ckpt_dir=d,
                           async_ckpt=False, log_every=10)
        state2, _ = train(arch, cfg, data, loop2)
        est = estimate(state2["sketch"], cfg.sketch,
                       estimator=cfg.sketch_estimator)
        print(f"\nresumed to step {int(state2['step'])}; distinct tokens "
              f"seen across BOTH topologies: {est:,.0f}")
    finally:
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
