"""Serve a small model with batched requests + sketch telemetry.

    PYTHONPATH=src python examples/serve_lm.py --arch tinyllama-1.1b --requests 8

Prefill + batched greedy decode through the ring-buffered KV cache, with two
HLL streams on the serving datapath (the paper's NIC use-case): distinct
request ids (how many unique users) and distinct generated tokens
(vocabulary coverage of outputs).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.sketch import HLLConfig
from repro.models import transformer
from repro.serve import engine
from repro.telemetry.sketchboard import StreamSketch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    arch = get_arch(args.arch).reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), arch)
    board = StreamSketch(HLLConfig(p=12, hash_bits=64))

    B, S, T = args.requests, args.prompt_len, args.gen_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 arch.vocab_size)
    request_ids = jnp.arange(1000, 1000 + B, dtype=jnp.int32)

    batch = {"tokens": prompts}
    if arch.mrope:
        batch["positions"] = transformer.default_positions(arch, B, S)
    if arch.frontend_stub_len:
        batch["frontend_embeds"] = (
            jax.random.normal(jax.random.PRNGKey(2),
                              (B, arch.frontend_stub_len, arch.d_model))
            .astype(jnp.bfloat16) * 0.02
        )

    t0 = time.perf_counter()
    logits, cache = engine.prefill(params, batch, arch, kv_len=S + T + 1)
    first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    prefill_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    generated, _ = engine.decode_loop(
        params, cache, first, jnp.asarray(S, jnp.int32), arch, steps=T
    )
    jax.block_until_ready(generated)
    decode_s = time.perf_counter() - t1

    board.observe("request_ids", request_ids)
    board.observe("prompt_tokens", prompts)
    board.observe("generated_tokens", generated)

    print(f"served {B} requests: prefill {B * S / prefill_s:,.0f} tok/s, "
          f"decode {B * T / decode_s:,.0f} tok/s")
    print(f"sample output: {np.asarray(generated[0])[:16].tolist()}")
    print("\nsketch telemetry (48KiB/stream, free on the datapath):")
    for name, row in board.report().items():
        print(f"  {name:18s} distinct~{row['estimate']:8.0f} "
              f"seen={row['items_seen']:6d} dup_factor={row['duplication']:.2f}")


if __name__ == "__main__":
    main()
