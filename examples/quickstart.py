"""Quickstart: the HLL sketch API in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import hll
from repro.core.exact import exact_distinct
from repro.core.hll import HLLConfig
from repro.core.sketch import update_pipelined


def main():
    # the paper's production configuration: p=16, 64-bit Murmur3
    cfg = HLLConfig(p=16, hash_bits=64)
    print(f"sketch: m=2^{cfg.p} buckets, H={cfg.hash_bits}-bit hash, "
          f"{cfg.memory_footprint_bits // 8 // 1024} KiB packed, "
          f"expected stderr {hll.standard_error(cfg):.2%}")

    # 1) one-shot cardinality of a 5M-item stream with ~3.3M distinct values
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.integers(0, 2**22, 5_000_000, dtype=np.int32))
    est = hll.cardinality(items, cfg)
    exact = exact_distinct(items)
    print(f"\n5M items: exact={exact:,} estimate={est:,.0f} "
          f"error={abs(est - exact) / exact:.3%}")

    # 2) incremental streaming + merge (the paper's multi-pipeline fold)
    regs = hll.init_registers(cfg)
    for chunk in np.split(np.asarray(items), 5):
        regs = update_pipelined(regs, jnp.asarray(chunk), cfg, pipelines=8)
    print(f"streamed in 5 chunks x 8 pipelines: {hll.estimate(regs, cfg):,.0f}")

    # 3) sketches merge losslessly: union of two disjoint streams
    a = hll.update(hll.init_registers(cfg), items[: 2_500_000], cfg)
    b = hll.update(hll.init_registers(cfg), items[2_500_000:], cfg)
    merged = hll.merge(a, b)
    print(f"merge(a, b) estimate:        {hll.estimate(merged, cfg):,.0f}")
    print("(bit-identical to sketching the union — see tests/test_hll.py)")


if __name__ == "__main__":
    main()
