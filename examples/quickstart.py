"""Quickstart: the HLL sketch API in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Everything goes through ``repro.sketch``: one ``HyperLogLog`` carrier, one
``update()`` entry point, and an ``ExecutionPlan`` that picks the backend
(jnp scatter / Pallas kernels), placement, and pipeline count.
"""

import numpy as np
import jax.numpy as jnp

from repro.sketch import (
    CMConfig,
    CountMinBank,
    ExecutionPlan,
    HLLConfig,
    HyperLogLog,
    WindowedBank,
    available_estimators,
    standard_error,
)
from repro.sketch.exact import exact_distinct


def main():
    # the paper's production configuration: p=16, 64-bit Murmur3
    cfg = HLLConfig(p=16, hash_bits=64)
    print(f"sketch: m=2^{cfg.p} buckets, H={cfg.hash_bits}-bit hash, "
          f"{cfg.memory_footprint_bits // 8 // 1024} KiB packed, "
          f"expected stderr {standard_error(cfg):.2%}")

    # 1) one-shot cardinality of a 5M-item stream with ~3.3M distinct values
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.integers(0, 2**22, 5_000_000, dtype=np.int32))
    sk = HyperLogLog.of(items, cfg)
    exact = exact_distinct(items)
    est = sk.estimate()
    print(f"\n5M items: exact={exact:,} estimate={est:,.0f} "
          f"error={abs(est - exact) / exact:.3%}")

    # 2) incremental streaming through k pipelines (the paper's Fig. 3 fold);
    #    chunk sizes need not divide the pipeline count — padding is uniform
    plan = ExecutionPlan(backend="jnp", pipelines=8)
    streamed = HyperLogLog.empty(cfg)
    for chunk in np.split(np.asarray(items), 5):
        streamed = streamed.update(jnp.asarray(chunk), plan)
    print(f"streamed in 5 chunks x 8 pipelines: {streamed.estimate():,.0f} "
          f"({streamed.count:,} items counted exactly)")

    # 3) sketches merge losslessly: union of two disjoint streams
    a = HyperLogLog.of(items[: 2_500_000], cfg)
    b = HyperLogLog.of(items[2_500_000:], cfg)
    merged = a | b
    print(f"(a | b) estimate:            {merged.estimate():,.0f}")
    print(f"jaccard(a, b):               {a.jaccard(b):.3f}")
    print("(bit-identical to sketching the union — see tests/test_sketch_api.py)")

    # 4) sketches serialize densely: checkpoint, ship, resume anywhere
    blob = merged.to_bytes()
    back = HyperLogLog.from_bytes(blob)
    assert back.estimate() == merged.estimate()
    print(f"serialized sketch: {len(blob):,} bytes, survives round-trip")

    # 5) finalization is pluggable: every estimator reads the same register
    #    histogram (one device bincount), so switching costs nothing
    print("\nestimators on the same sketch "
          f"(exact distinct = {exact:,}):")
    for name in available_estimators():
        e = sk.estimate(estimator=name)
        print(f"  {name:14s} {e:12,.0f}  ({(e - exact) / exact:+.3%})")

    # 6) sliding windows: "distinct in the last k epochs", not all time.
    #    A WindowedBank rings W time buckets; observe() fills the current
    #    bucket, advance() slides the window, and estimate_window(k) is one
    #    fused ring fold + one batched finalization (DESIGN.md §11)
    wcfg = HLLConfig(p=12, hash_bits=64)
    win = WindowedBank.empty(4, 1, wcfg)   # W=4 epochs, one tenant row
    for epoch in range(6):
        if epoch:
            win = win.advance()            # epoch - 4 slides out
        lo = epoch * 50_000                # each epoch sees a fresh range
        chunk = jnp.arange(lo, lo + 80_000, dtype=jnp.int32)
        win = win.observe(jnp.zeros(chunk.shape, jnp.int32), chunk)
    rolling = float(win.estimate_window()[0])    # last 4 epochs
    newest = float(win.estimate_window(1)[0])    # current epoch only
    print(f"\nwindowed (epoch {win.epoch}): last-4-epochs distinct"
          f"~{rolling:,.0f}, current-epoch~{newest:,.0f} "
          f"(epochs 0-1 expired)")

    # 7) heavy hitters: "WHICH items dominate", not just how many distinct.
    #    A CountMinBank rides the same plan/backend spine — one fused
    #    d-hash scatter-add per update_many, query() for point frequency
    #    upper bounds, topk(k) for Topkapi label recovery (DESIGN.md §13)
    hcfg = CMConfig(depth=4, width=1024)
    hot = np.repeat(np.arange(8, dtype=np.int32), 5_000)      # 8 heavy ids
    tail = rng.integers(1_000, 2**20, 60_000).astype(np.int32)
    stream = np.concatenate([hot, tail])
    rng.shuffle(stream)
    hh = CountMinBank.empty(1, hcfg)                           # one tenant row
    hh = hh.update_many(np.zeros(stream.shape, np.int32), stream)
    vals, cnts = hh.topk(8)
    print(f"\nheavy hitters (d={hcfg.depth}, w={hcfg.width}, "
          f"{hh.nbytes // 1024} KiB bank): "
          + ", ".join(f"{v}x{c}" for v, c in zip(vals[0], cnts[0])))
    est = np.asarray(hh.query(jnp.arange(8)))[0]
    print(f"point queries for ids 0-7 (true 5,000 each, CM upper bounds): "
          f"{est.tolist()}")


if __name__ == "__main__":
    main()
