"""End-to-end streaming cardinality service — the paper's deployment, on JAX.

A data stream (synthetic, counter-addressed — think NIC packets / storage
scan) flows through k sketch pipelines per device and across all available
devices; partial sketches fold by max (Fig. 3) and the exact host-side
finalization reports the distinct count with its error. This is the
paper-kind end-to-end driver: throughput-oriented stream processing with
constant-memory state.

    PYTHONPATH=src python examples/stream_cardinality.py --chunks 16 --pipelines 8

``--tenants B`` switches to the multi-tenant SketchBank mode (DESIGN.md §9):
each item is routed to one of B per-tenant sketches by key (item mod B —
think per-user / per-flow cardinality) and every chunk lands in the whole
bank with ONE keyed update_many dispatch; finalization is one batched
estimate_many over the (B, m) bank.

    PYTHONPATH=src python examples/stream_cardinality.py --tenants 64

``--window W`` switches to the sliding-window mode (DESIGN.md §11): the
keyed stream lands in the current bucket of a W-bucket ``WindowedBank``
ring, ``--advance-every N`` opens a new epoch every N chunks, and the
rolling per-tenant distinct count ("distinct in the last k epochs") is one
fused ring fold + one batched estimate_many.

    PYTHONPATH=src python examples/stream_cardinality.py \\
        --tenants 16 --window 8 --advance-every 2
"""

import argparse
import time

import jax
import jax.numpy as jnp

import numpy as np

from repro.sketch import (
    ExecutionPlan, HLLConfig, MultiResWindowedBank, SketchBank, WindowedBank,
    available_estimators, hll, update_registers,
)
from repro.data.pipeline import DataConfig, batch_at_step
from repro.launch.mesh import make_auto_mesh


def stream_bank(args, cfg, data):
    """Multi-tenant mode: route the stream into a B-row SketchBank."""
    tenants = args.tenants
    plan = ExecutionPlan(backend="jnp", pipelines=args.pipelines,
                         estimator=args.estimator)
    bank = SketchBank.empty(tenants, cfg)
    warm = batch_at_step(data, jnp.asarray(0))["tokens"].reshape(-1)
    # synthetic flow routing: key = item mod B (per-user / per-flow split)
    jax.block_until_ready(
        bank.update_many(warm % tenants, warm, plan).registers
    )

    t0 = time.perf_counter()
    n = 0
    for step in range(args.chunks):
        tokens = batch_at_step(data, jnp.asarray(step, jnp.int32))["tokens"]
        flat = tokens.reshape(-1)
        bank = bank.update_many(flat % tenants, flat, plan)
        n += flat.size
    jax.block_until_ready(bank.registers)
    dt = time.perf_counter() - t0

    t1 = time.perf_counter()
    ests = np.asarray(bank.estimate_many(args.estimator))
    fin = time.perf_counter() - t1
    total = float(ests.sum())  # keys partition the stream: tenants are disjoint

    print(f"\nsustained: {n * 4 / dt / 1e9:.3f} GB/s  ({n / dt:,.0f} items/s) "
          f"across {tenants} tenants (one update_many per chunk)")
    print(f"batched finalization of {tenants} sketches: {fin * 1e6:.0f} us")
    print(f"per-tenant distinct: min={ests.min():,.0f} "
          f"mean={ests.mean():,.0f} max={ests.max():,.0f}")
    print(f"summed distinct: {total:,.0f} of {n:,} streamed")


def stream_window(args, cfg, data):
    """Sliding-window mode: a W-bucket ring over the keyed stream."""
    if args.advance_every < 1:
        raise SystemExit("--advance-every must be >= 1")
    rows = max(1, args.tenants)
    plan = ExecutionPlan(backend="jnp", pipelines=args.pipelines,
                         estimator=args.estimator)
    if args.window_levels > 0:
        # multi-res ring (DESIGN.md §14): same observe/advance/estimate
        # surface, horizon stretched to W*(2**L - 1) epochs
        win = MultiResWindowedBank.empty(
            args.window, rows, cfg, levels=args.window_levels
        )
    else:
        win = WindowedBank.empty(args.window, rows, cfg)
    # the dense ring exposes the whole (W, B, m) stack; the EH carrier's
    # hot surface is its current bucket
    live_regs = lambda w: (
        w.registers if isinstance(w, WindowedBank) else w.current.registers
    )
    warm = batch_at_step(data, jnp.asarray(0))["tokens"].reshape(-1)
    jax.block_until_ready(live_regs(win.observe(warm % rows, warm, plan)))

    t0 = time.perf_counter()
    n = 0
    for step in range(args.chunks):
        if step and step % args.advance_every == 0:
            win = win.advance()  # one epoch slides out of the window
        tokens = batch_at_step(data, jnp.asarray(step, jnp.int32))["tokens"]
        flat = tokens.reshape(-1)
        win = win.observe(flat % rows, flat, plan)
        n += flat.size
    jax.block_until_ready(live_regs(win))
    dt = time.perf_counter() - t0

    t1 = time.perf_counter()
    rolling = np.asarray(win.estimate_window(plan=plan))   # last W epochs
    newest = np.asarray(win.estimate_window(1, plan))      # current epoch
    fin = time.perf_counter() - t1

    print(f"\nsustained: {n * 4 / dt / 1e9:.3f} GB/s  ({n / dt:,.0f} items/s) "
          f"across {rows} tenants x {args.window} epoch buckets "
          f"(epoch {win.epoch}, advance every {args.advance_every} chunks)")
    print(f"two windowed readings (fused ring fold + estimate_many): "
          f"{fin * 1e6:.0f} us")
    if args.window_levels > 0:
        d = win.density()
        print(f"multi-res ring: {d['slots']} slots over a {d['horizon']}-"
              f"epoch horizon ({d['reduction']:.1f}x smaller than dense)")
    print(f"rolling distinct (last {win.window} epochs): "
          f"min={rolling.min():,.0f} mean={rolling.mean():,.0f} "
          f"max={rolling.max():,.0f}")
    print(f"current-epoch distinct:            "
          f"min={newest.min():,.0f} mean={newest.mean():,.0f} "
          f"max={newest.max():,.0f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--chunk-items", type=int, default=1 << 20)
    ap.add_argument("--pipelines", type=int, default=8)
    ap.add_argument("--p", type=int, default=16)
    ap.add_argument("--tenants", type=int, default=1,
                    help=">1 switches to the keyed SketchBank mode")
    ap.add_argument("--window", type=int, default=0,
                    help=">0 switches to the sliding WindowedBank mode "
                         "with this many ring buckets")
    ap.add_argument("--advance-every", type=int, default=4,
                    help="window mode: open a new epoch every N chunks")
    ap.add_argument("--window-levels", type=int, default=0,
                    help="window mode: >0 uses the multi-resolution "
                         "exponential-histogram ring (DESIGN.md §14) with "
                         "this many levels")
    ap.add_argument("--distribution", default="zipf",
                    choices=["zipf", "uniform", "unique"])
    ap.add_argument("--estimator", default="original",
                    choices=available_estimators(),
                    help="phase-4 finalizer (see repro/sketch/estimators.py)")
    args = ap.parse_args()

    cfg = HLLConfig(p=args.p, hash_bits=64)
    data = DataConfig(
        vocab_size=2**31 - 1, global_batch=1024,
        seq_len=args.chunk_items // 1024, distribution=args.distribution,
    )
    if args.window > 0:
        return stream_window(args, cfg, data)
    if args.tenants > 1:
        return stream_bank(args, cfg, data)
    devices = jax.devices()
    mesh = make_auto_mesh((len(devices),), ("data",))
    print(f"streaming {args.chunks} x {args.chunk_items:,} items "
          f"({args.distribution}) through {args.pipelines} pipelines "
          f"x {len(devices)} device(s)")

    local_plan = ExecutionPlan(backend="jnp", pipelines=args.pipelines)
    sharded_plan = ExecutionPlan(
        backend="jnp", placement="mesh", mesh=mesh,
        pipelines=args.pipelines,
    )
    regs = hll.init_registers(cfg)
    update = jax.jit(lambda r, x: update_registers(r, x, cfg, local_plan))
    # warmup/compile off the clock (the paper measures steady-state line rate)
    jax.block_until_ready(update(regs, batch_at_step(data, jnp.asarray(0))["tokens"]))

    t0 = time.perf_counter()
    n = 0
    for step in range(args.chunks):
        batch = batch_at_step(data, jnp.asarray(step, jnp.int32))
        tokens = batch["tokens"]
        if len(devices) > 1:
            regs = update_registers(regs, tokens, cfg, sharded_plan)
        else:
            regs = update(regs, tokens)
        n += tokens.size
    jax.block_until_ready(regs)
    dt = time.perf_counter() - t0

    t1 = time.perf_counter()
    # volume-independent finalization (paper: 203us): histogram + O(H-p) sum
    est = hll.estimate(regs, cfg, estimator=args.estimator)
    fin = time.perf_counter() - t1

    print(f"\nsustained: {n * 4 / dt / 1e9:.3f} GB/s  ({n / dt:,.0f} items/s)")
    print(f"finalization: {fin * 1e6:.0f} us (volume-independent)")
    print(f"estimated distinct: {est:,.0f} of {n:,} streamed")
    if args.distribution == "unique":
        print(f"true distinct = {n:,}; error = {abs(est - n) / n:.3%} "
              f"(expected sigma {hll.standard_error(cfg):.3%})")


if __name__ == "__main__":
    main()
