"""End-to-end streaming cardinality service — the paper's deployment, on JAX.

A data stream (synthetic, counter-addressed — think NIC packets / storage
scan) flows through k sketch pipelines per device and across all available
devices; partial sketches fold by max (Fig. 3) and the exact host-side
finalization reports the distinct count with its error. This is the
paper-kind end-to-end driver: throughput-oriented stream processing with
constant-memory state.

    PYTHONPATH=src python examples/stream_cardinality.py --chunks 16 --pipelines 8
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.sketch import (
    ExecutionPlan, HLLConfig, available_estimators, hll, update_registers,
)
from repro.data.pipeline import DataConfig, batch_at_step
from repro.launch.mesh import make_auto_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--chunk-items", type=int, default=1 << 20)
    ap.add_argument("--pipelines", type=int, default=8)
    ap.add_argument("--p", type=int, default=16)
    ap.add_argument("--distribution", default="zipf",
                    choices=["zipf", "uniform", "unique"])
    ap.add_argument("--estimator", default="original",
                    choices=available_estimators(),
                    help="phase-4 finalizer (see repro/sketch/estimators.py)")
    args = ap.parse_args()

    cfg = HLLConfig(p=args.p, hash_bits=64)
    data = DataConfig(
        vocab_size=2**31 - 1, global_batch=1024,
        seq_len=args.chunk_items // 1024, distribution=args.distribution,
    )
    devices = jax.devices()
    mesh = make_auto_mesh((len(devices),), ("data",))
    print(f"streaming {args.chunks} x {args.chunk_items:,} items "
          f"({args.distribution}) through {args.pipelines} pipelines "
          f"x {len(devices)} device(s)")

    local_plan = ExecutionPlan(backend="jnp", pipelines=args.pipelines)
    sharded_plan = ExecutionPlan(
        backend="jnp", placement="mesh", mesh=mesh,
        pipelines=args.pipelines,
    )
    regs = hll.init_registers(cfg)
    update = jax.jit(lambda r, x: update_registers(r, x, cfg, local_plan))
    # warmup/compile off the clock (the paper measures steady-state line rate)
    jax.block_until_ready(update(regs, batch_at_step(data, jnp.asarray(0))["tokens"]))

    t0 = time.perf_counter()
    n = 0
    for step in range(args.chunks):
        batch = batch_at_step(data, jnp.asarray(step, jnp.int32))
        tokens = batch["tokens"]
        if len(devices) > 1:
            regs = update_registers(regs, tokens, cfg, sharded_plan)
        else:
            regs = update(regs, tokens)
        n += tokens.size
    jax.block_until_ready(regs)
    dt = time.perf_counter() - t0

    t1 = time.perf_counter()
    # volume-independent finalization (paper: 203us): histogram + O(H-p) sum
    est = hll.estimate(regs, cfg, estimator=args.estimator)
    fin = time.perf_counter() - t1

    print(f"\nsustained: {n * 4 / dt / 1e9:.3f} GB/s  ({n / dt:,.0f} items/s)")
    print(f"finalization: {fin * 1e6:.0f} us (volume-independent)")
    print(f"estimated distinct: {est:,.0f} of {n:,} streamed")
    if args.distribution == "unique":
        print(f"true distinct = {n:,}; error = {abs(est - n) / n:.3%} "
              f"(expected sigma {hll.standard_error(cfg):.3%})")


if __name__ == "__main__":
    main()
