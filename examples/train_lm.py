"""Train a small LM for a few hundred steps with sketch telemetry on the
datapath — checkpointed, restartable, CPU-runnable.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --arch smollm-360m

The --arch flag selects any of the 10 assigned architectures (reduced to a
CPU-sized twin unless --full-config); loss decreases and the HLL tap reports
the distinct-token count of everything the model has consumed — for free,
inside the jitted step.  Kill it mid-run and rerun: it resumes from the last
checkpoint (at most --ckpt-every steps lost).
"""

import argparse

from repro.configs import ARCH_IDS, get_arch
from repro.sketch import HLLConfig
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptimizerConfig
from repro.train.loop import LoopConfig, train
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the published size (needs a real pod)")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if not args.full_config:
        arch = arch.reduced()
    cfg = TrainConfig(
        optimizer=OptimizerConfig(
            lr=args.lr, warmup_steps=20, total_steps=args.steps,
            compress_grads=args.compress_grads,
        ),
        sketch=HLLConfig(p=14, hash_bits=64),
    )
    data = DataConfig(
        vocab_size=arch.vocab_size, global_batch=args.batch, seq_len=args.seq
    )
    loop = LoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, log_every=10,
    )
    print(f"training {args.arch} ({'full' if args.full_config else 'reduced'}) "
          f"for {args.steps} steps; checkpoints -> {args.ckpt_dir}")
    state, history = train(arch, cfg, data, loop)
    first, last = history[0], history[-1]
    print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} over "
          f"{args.steps} steps; distinct tokens seen ~"
          f"{last['distinct_tokens']:,.0f}")


if __name__ == "__main__":
    main()
