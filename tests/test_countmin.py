"""CountMinBank: fused d-hash keyed ingest, Topkapi top-k, and the wire fuzz.

Acceptance property for the heavy-hitter subsystem (DESIGN.md §13): for
EVERY registered cm backend, ``update_many`` on a (B, d, w) bank —
including the (1024, 4, 1024) acceptance size — is bit-identical to the
per-row per-depth ``np.add.at`` loop, for streams that divide nothing,
for out-of-range keys (dropped, never leaked), and under mesh placement.
Plus: query upper bounds, merge algebra, the RCMB/RCMW wire formats with
the same truncation/garbage/no-leak fuzz the RHLB suite runs, and the
spy-backend short-circuit guards (zero-length streams and zero-row banks
must dispatch NOTHING).
"""

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.sketch import (
    CMConfig,
    CountMinBank,
    ExecutionPlan,
    WindowedCountMinBank,
    available_cm_backends,
    available_cm_window_backends,
    cm_hash_index,
    cm_update_many,
    register_backend,
    register_cm_backend,
    register_cm_window_backend,
)
from repro.sketch.backends import (
    cm_query_jnp,
    cm_update_jnp,
    cm_window_fold_jnp,
    update_pipelined,
)

CFG = CMConfig(depth=4, width=64, seed=5)  # small w so pallas tiles many rows


def _stream(n, rows, seed=0):
    rng = np.random.default_rng(seed)
    items = rng.integers(0, 2**31, n, dtype=np.int32)
    keys = rng.integers(0, rows, n, dtype=np.int32)
    return jnp.asarray(keys), jnp.asarray(items)


def _loop_reference(keys, items, rows, cfg=CFG):
    """The pre-fusion shape of ingest: np.add.at per row per depth."""
    ks, it = np.asarray(keys), np.asarray(items)
    out = np.zeros((rows, cfg.depth, cfg.width), np.uint32)
    if it.size == 0:
        return out
    idx = np.asarray(cm_hash_index(jnp.asarray(it), cfg))  # (d, n)
    for b in range(rows):
        sel = ks == b
        for r in range(cfg.depth):
            np.add.at(out[b, r], idx[r][sel], np.uint32(1))
    return out


def _filled(rows=6, n=4000, seed=3, cfg=CFG):
    keys, items = _stream(n, rows, seed=seed)
    return CountMinBank.empty(rows, cfg).update_many(keys, items)


# ----------------------------------------------------------------------------
# update_many vs per-row loop (the acceptance property)
# ----------------------------------------------------------------------------


def test_cm_backends_registered():
    want = {"jnp", "pallas", "pallas_pipelined"}
    assert set(available_cm_backends()) >= want
    assert set(available_cm_window_backends()) >= want


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_pipelined"])
@pytest.mark.parametrize("n", [1, 1000, 4099])  # 4099 is prime: pads everywhere
def test_update_many_matches_loop(backend, n):
    rows = 17  # prime row count: divides no row block evenly
    keys, items = _stream(n, rows, seed=n)
    ref = _loop_reference(keys, items, rows)
    for pipelines in (1, 3, 8):
        plan = ExecutionPlan(backend=backend, pipelines=pipelines)
        bank = CountMinBank.empty(rows, CFG).update_many(keys, items, plan)
        np.testing.assert_array_equal(np.asarray(bank.counters), ref)


def test_acceptance_1024_row_bank_bit_identical():
    """The issue's acceptance size: (B=1024, d=4, w=1024) vs the loop."""
    cfg = CMConfig(depth=4, width=1024, seed=1)
    rows, n = 1024, 8191
    keys, items = _stream(n, rows, seed=42)
    ref = _loop_reference(keys, items, rows, cfg)
    bank = CountMinBank.empty(rows, cfg).update_many(
        keys, items, ExecutionPlan(backend="jnp")
    )
    np.testing.assert_array_equal(np.asarray(bank.counters), ref)
    np.testing.assert_array_equal(
        bank.counts, np.bincount(np.asarray(keys), minlength=rows)
    )


@pytest.mark.parametrize("backend", ["pallas", "pallas_pipelined"])
def test_pallas_row_block_clamps_to_one_row(backend):
    """d*w == MAX_BLOCK_CELLS forces row_block=1: every row its own slab."""
    cfg = CMConfig(depth=4, width=1024, seed=2)
    rows, n = 9, 3001
    keys, items = _stream(n, rows, seed=8)
    want = CountMinBank.empty(rows, cfg).update_many(
        keys, items, ExecutionPlan(backend="jnp")
    )
    got = CountMinBank.empty(rows, cfg).update_many(
        keys, items, ExecutionPlan(backend=backend)
    )
    np.testing.assert_array_equal(np.asarray(got.counters), np.asarray(want.counters))
    np.testing.assert_array_equal(np.asarray(got.labels), np.asarray(want.labels))
    np.testing.assert_array_equal(
        np.asarray(got.label_counts), np.asarray(want.label_counts)
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_pipelined"])
def test_out_of_range_keys_dropped_not_leaked(backend):
    rows, n = 11, 3001
    keys, items = _stream(n, rows, seed=7)
    pos = np.arange(n)
    bad = np.where(pos % 5 == 0, -2, np.asarray(keys))
    bad = np.where(pos % 7 == 0, rows + 3, bad)
    ref = _loop_reference(jnp.asarray(bad), items, rows)
    bank = CountMinBank.empty(rows, CFG).update_many(
        jnp.asarray(bad), items, ExecutionPlan(backend=backend)
    )
    np.testing.assert_array_equal(np.asarray(bank.counters), ref)
    in_range = bad[(bad >= 0) & (bad < rows)]
    np.testing.assert_array_equal(
        bank.counts, np.bincount(in_range, minlength=rows)
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_mesh_placement_matches_local(backend):
    rows, n = 9, 2503  # prime stream: forces the drop-key padding path
    keys, items = _stream(n, rows, seed=9)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    plan = ExecutionPlan(backend=backend).with_mesh(mesh)
    bank = CountMinBank.empty(rows, CFG).update_many(keys, items, plan)
    np.testing.assert_array_equal(
        np.asarray(bank.counters), _loop_reference(keys, items, rows)
    )
    local = CountMinBank.empty(rows, CFG).update_many(
        keys, items, ExecutionPlan(backend=backend)
    )
    np.testing.assert_array_equal(
        np.asarray(bank.labels), np.asarray(local.labels)
    )


def test_functional_entry_matches_method():
    rows = 5
    keys, items = _stream(777, rows, seed=13)
    a = cm_update_many(CountMinBank.empty(rows, CFG), keys, items)
    b = CountMinBank.empty(rows, CFG).update_many(keys, items)
    np.testing.assert_array_equal(np.asarray(a.counters), np.asarray(b.counters))


# ----------------------------------------------------------------------------
# query / merge / topk algebra
# ----------------------------------------------------------------------------


def test_query_never_undercounts():
    rows = 4
    keys, items = _stream(6000, rows, seed=11)
    bank = _filled(rows, 6000, seed=11)
    probe = np.unique(np.asarray(items))[:50]
    est = np.asarray(bank.query(jnp.asarray(probe)))
    ks, it = np.asarray(keys), np.asarray(items)
    for b in range(rows):
        true = np.array([(it[ks == b] == v).sum() for v in probe])
        assert (est[b] >= true).all()


def test_query_exact_without_collisions():
    """A stream narrower than w with d=4 rows: every probe lands clean."""
    cfg = CMConfig(depth=4, width=4096, seed=6)
    items = jnp.asarray(np.repeat(np.arange(8, dtype=np.int32), 37))
    bank = CountMinBank.empty(1, cfg).update_many(
        jnp.zeros(items.shape, jnp.int32), items
    )
    est = np.asarray(bank.query(jnp.arange(8)))
    np.testing.assert_array_equal(est[0], np.full(8, 37))


def test_merge_matches_concat_ingest_and_commutes():
    rows = 7
    k1, i1 = _stream(900, rows, seed=1)
    k2, i2 = _stream(1100, rows, seed=2)
    a = CountMinBank.empty(rows, CFG).update_many(k1, i1)
    b = CountMinBank.empty(rows, CFG).update_many(k2, i2)
    both = CountMinBank.empty(rows, CFG).update_many(
        jnp.concatenate([k1, k2]), jnp.concatenate([i1, i2])
    )
    merged = a | b
    # counters are exact mod 2^32: merge == single-pass concat ingest
    np.testing.assert_array_equal(
        np.asarray(merged.counters), np.asarray(both.counters)
    )
    np.testing.assert_array_equal(merged.counts, both.counts)
    # the Topkapi merge rule is commutative (labels may differ from the
    # single-pass vote — that's inherent to Topkapi — but never by order)
    swapped = b | a
    np.testing.assert_array_equal(
        np.asarray(merged.labels), np.asarray(swapped.labels)
    )
    np.testing.assert_array_equal(
        np.asarray(merged.label_counts), np.asarray(swapped.label_counts)
    )


def test_merge_rejects_mismatched_banks():
    a = CountMinBank.empty(3, CFG)
    with pytest.raises(ValueError, match="different configs"):
        a.merge(CountMinBank.empty(3, CMConfig(depth=3, width=64)))
    with pytest.raises(ValueError, match="different sizes"):
        a.merge(CountMinBank.empty(4, CFG))


def test_topk_recovers_heavy_hitters():
    cfg = CMConfig(depth=4, width=256, seed=4)
    rng = np.random.default_rng(0)
    hot = np.repeat(np.arange(100, 103, dtype=np.int32), 500)
    tail = rng.integers(1000, 2**20, 400).astype(np.int32)
    stream = np.concatenate([hot, tail])
    rng.shuffle(stream)
    bank = CountMinBank.empty(2, cfg).update_many(
        jnp.asarray(np.zeros(stream.shape, np.int32)), jnp.asarray(stream)
    )
    vals, cnts = bank.topk(3)
    assert set(int(v) for v in vals[0]) == {100, 101, 102}
    assert (cnts[0] >= 500).all()
    # row 1 saw nothing: padded output only
    assert (cnts[1] == 0).all()


def test_topk_pads_when_candidates_run_out():
    cfg = CMConfig(depth=2, width=32, seed=3)
    items = jnp.asarray(np.array([7, 7, 7, 9, 9], np.int32))
    bank = CountMinBank.empty(1, cfg).update_many(
        jnp.zeros(5, jnp.int32), items
    )
    vals, cnts = bank.topk(6)
    assert vals.shape == (1, 6) and cnts.shape == (1, 6)
    assert vals[0, 0] == 7 and cnts[0, 0] >= 3
    assert vals[0, 1] == 9 and cnts[0, 1] >= 2
    # beyond the surviving labels: -1 / 0 padding (label 0 may appear with
    # a zero estimate from untouched cells — never with a positive count)
    live = set(int(v) for v, c in zip(vals[0], cnts[0]) if c > 0)
    assert live == {7, 9}
    assert (vals[0][cnts[0] == 0] <= 0).all()


def test_topk_validates_k():
    with pytest.raises(ValueError, match="k >= 1"):
        _filled(2, 100).topk(0)


# ----------------------------------------------------------------------------
# validation + short-circuit guards (spy backend: NOTHING may dispatch)
# ----------------------------------------------------------------------------


def test_cmconfig_validation():
    with pytest.raises(ValueError, match="depth"):
        CMConfig(depth=0)
    with pytest.raises(ValueError, match="depth"):
        CMConfig(depth=17)
    with pytest.raises(ValueError, match="width"):
        CMConfig(width=0)
    with pytest.raises(ValueError, match="width"):
        CMConfig(width=(1 << 24) + 1)
    with pytest.raises(ValueError, match="seed"):
        CMConfig(seed=-1)
    with pytest.raises(ValueError, match="seed"):
        CMConfig(seed=1 << 64)


def test_empty_and_with_rows():
    with pytest.raises(ValueError, match="at least one row"):
        CountMinBank.empty(0, CFG)
    with pytest.raises(ValueError, match="at least one bucket"):
        WindowedCountMinBank.empty(0, 3, CFG)
    bank = _filled(3, 500)
    assert bank.with_rows(3) is bank
    grown = bank.with_rows(5)
    assert len(grown) == 5
    np.testing.assert_array_equal(
        np.asarray(grown.counters[:3]), np.asarray(bank.counters)
    )
    assert np.asarray(grown.counters[3:]).sum() == 0
    with pytest.raises(ValueError, match="cannot shrink"):
        bank.with_rows(2)


def test_update_many_length_mismatch():
    bank = CountMinBank.empty(2, CFG)
    with pytest.raises(ValueError, match="same length"):
        bank.update_many(jnp.zeros(2, jnp.int32), jnp.zeros(3, jnp.int32))
    # validation precedes the empty-stream short-circuit
    with pytest.raises(ValueError, match="same length"):
        bank.update_many(jnp.zeros(0, jnp.int32), jnp.zeros(3, jnp.int32))
    win = WindowedCountMinBank.empty(2, 2, CFG)
    with pytest.raises(ValueError, match="same length"):
        win.observe(jnp.zeros(1, jnp.int32), jnp.zeros(2, jnp.int32))


_SPY_CALLS = {"n": 0}


# the spies delegate to the real jnp paths so bit-identity suites that sweep
# every registered backend at runtime keep passing even with them registered
@register_backend("spy_cm_jnp")
def _spy_hll_backend(registers, items, cfg, plan):
    return update_pipelined(registers, items, cfg, plan.pipelines)


def _spy_cm_ingest(counters, keys, items, cfg, plan):
    _SPY_CALLS["n"] += 1
    return cm_update_jnp(counters, keys, items, cfg)


def _spy_cm_query(counters, items, cfg, plan):
    _SPY_CALLS["n"] += 1
    return cm_query_jnp(counters, items, cfg)


register_cm_backend("spy_cm_jnp", _spy_cm_ingest, _spy_cm_query)


@register_cm_window_backend("spy_cm_jnp")
def _spy_cm_window(ring, mask, cfg, plan):
    _SPY_CALLS["n"] += 1
    return cm_window_fold_jnp(ring, mask)


def _zero_row_bank(cfg=CFG):
    # empty() refuses rows=0 by design; a zero-row bank can still arrive
    # through slicing/deserialization layers, so build one directly
    shape = (0, cfg.depth, cfg.width)
    return CountMinBank(
        jnp.zeros(shape, jnp.uint32),
        jnp.zeros(shape, jnp.int32),
        jnp.zeros(shape, jnp.int32),
        jnp.zeros((0, 2), jnp.uint32),
        cfg,
    )


def test_empty_stream_short_circuits_without_dispatch():
    plan = ExecutionPlan(backend="spy_cm_jnp")
    bank = CountMinBank.empty(3, CFG)
    _SPY_CALLS["n"] = 0
    out = bank.update_many(jnp.zeros(0, jnp.int32), jnp.zeros(0, jnp.int32), plan)
    assert _SPY_CALLS["n"] == 0 and out is bank
    est = bank.query(jnp.zeros(0, jnp.int32), plan)
    assert _SPY_CALLS["n"] == 0 and est.shape == (3, 0)


def test_zero_row_bank_short_circuits_without_dispatch():
    plan = ExecutionPlan(backend="spy_cm_jnp")
    bank = _zero_row_bank()
    keys, items = _stream(64, 4, seed=21)
    _SPY_CALLS["n"] = 0
    out = bank.update_many(keys, items, plan)
    assert _SPY_CALLS["n"] == 0 and out is bank
    est = bank.query(items, plan)
    assert _SPY_CALLS["n"] == 0 and est.shape == (0, 64)
    vals, cnts = bank.topk(4)
    assert vals.shape == (0, 4) and cnts.shape == (0, 4)
    with pytest.raises(ValueError, match="same length"):
        bank.update_many(jnp.zeros(2, jnp.int32), jnp.zeros(3, jnp.int32))
    assert _SPY_CALLS["n"] == 0


def test_windowed_short_circuits_without_dispatch():
    plan = ExecutionPlan(backend="spy_cm_jnp")
    win = WindowedCountMinBank.empty(3, 2, CFG)
    _SPY_CALLS["n"] = 0
    out = win.observe(jnp.zeros(0, jnp.int32), jnp.zeros(0, jnp.int32), plan)
    assert _SPY_CALLS["n"] == 0 and out is win
    # a zero-row ring folds to a zero-row bank with no backend dispatch
    zr = WindowedCountMinBank(
        jnp.zeros((3, 0, CFG.depth, CFG.width), jnp.uint32),
        jnp.zeros((3, 0, CFG.depth, CFG.width), jnp.int32),
        jnp.zeros((3, 0, CFG.depth, CFG.width), jnp.int32),
        jnp.zeros((3, 0, 2), jnp.uint32),
        win.cursor,
        win.epochs,
        CFG,
    )
    fold = zr.fold_window(plan=plan)
    assert _SPY_CALLS["n"] == 0 and len(fold) == 0
    # a live ring DOES dispatch exactly one fused fold
    keys, items = _stream(128, 2, seed=5)
    win = win.observe(keys, items, plan)
    assert _SPY_CALLS["n"] == 1
    win.fold_window(plan=plan)
    assert _SPY_CALLS["n"] == 2


def test_jnp_cm_rejects_int32_cell_space_overflow():
    """B*d*w >= 2^31 would wrap the flattened segment ids; the jnp path
    must refuse loudly (shape-only check — no giant allocation)."""
    cfg = CMConfig(depth=4, width=1024)
    big = jax.ShapeDtypeStruct((1 << 19, 4, 1024), jnp.uint32)  # == 2^31
    keys = jax.ShapeDtypeStruct((8,), jnp.int32)
    items = jax.ShapeDtypeStruct((8,), jnp.int32)
    with pytest.raises(ValueError, match="overflows int32"):
        jax.eval_shape(partial(cm_update_jnp, cfg=cfg), big, keys, items)


# ----------------------------------------------------------------------------
# serialization (RCMB wire format + corruption fuzz, mirroring RHLB)
# ----------------------------------------------------------------------------


def _blob_layout(rows, cfg=CFG):
    cells = rows * cfg.depth * cfg.width
    counts_end = 24 + rows * 8
    return counts_end, cells


def test_cm_bytes_roundtrip():
    bank = _filled(rows=5, n=6000)
    blob = bank.to_bytes()
    counts_end, cells = _blob_layout(5)
    assert len(blob) == counts_end + 3 * 4 * cells
    back = CountMinBank.from_bytes(blob)
    assert back.cfg == bank.cfg and len(back) == len(bank)
    np.testing.assert_array_equal(np.asarray(back.counters), np.asarray(bank.counters))
    np.testing.assert_array_equal(np.asarray(back.labels), np.asarray(bank.labels))
    np.testing.assert_array_equal(
        np.asarray(back.label_counts), np.asarray(bank.label_counts)
    )
    np.testing.assert_array_equal(back.counts, bank.counts)


def test_cm_bytes_rejects_garbage():
    blob = _filled(rows=3).to_bytes()
    with pytest.raises(ValueError, match="truncated"):
        CountMinBank.from_bytes(blob[:10])
    with pytest.raises(ValueError, match="magic"):
        CountMinBank.from_bytes(b"NOPE" + blob[4:])
    with pytest.raises(ValueError, match="version"):
        CountMinBank.from_bytes(blob[:4] + b"\x09" + blob[5:])
    with pytest.raises(ValueError, match="payload"):
        CountMinBank.from_bytes(blob[:-1])


@pytest.mark.parametrize("frac", [0.0, 0.05, 0.2, 0.45, 0.7, 0.9, 0.999])
def test_cm_bytes_rejects_truncation_anywhere(frac):
    """A blob cut at ANY point — mid-header, mid-counts, mid-counter,
    mid-label-table — must raise ValueError cleanly, never hand back a
    short-read bank (the same contract RHLB enforces)."""
    blob = _filled(rows=5, n=4000).to_bytes()
    cut = int(len(blob) * frac)
    with pytest.raises(ValueError):
        CountMinBank.from_bytes(blob[:cut])
    with pytest.raises(ValueError):
        CountMinBank.from_bytes(blob + b"\x00")  # trailing garbage too


def test_cm_bytes_rejects_cut_mid_label_table():
    rows = 4
    blob = _filled(rows=rows).to_bytes()
    counts_end, cells = _blob_layout(rows)
    # end the payload halfway through the Topkapi label table
    cut = counts_end + 4 * cells + 4 * (cells // 2)
    assert cut < len(blob)
    with pytest.raises(ValueError, match="payload"):
        CountMinBank.from_bytes(blob[:cut])


def test_corrupted_blob_never_leaks_across_rows():
    """Flipping row j's counters to max values must not move ANY other
    row's point queries, labels, or top-k report."""
    rows = 6
    bank = _filled(rows=rows, n=9000)
    probe = jnp.asarray(np.arange(64, dtype=np.int32))
    clean_q = np.asarray(bank.query(probe))
    clean_v, clean_c = bank.topk(5)
    counts_end, _ = _blob_layout(rows)
    row_cells = CFG.depth * CFG.width
    blob = bytearray(bank.to_bytes())
    corrupt_row = 3
    start = counts_end + corrupt_row * row_cells * 4
    blob[start : start + row_cells * 4] = b"\xff" * (row_cells * 4)
    fuzzed = CountMinBank.from_bytes(bytes(blob))
    dirty_q = np.asarray(fuzzed.query(probe))
    dirty_v, dirty_c = fuzzed.topk(5)
    for b in range(rows):
        if b == corrupt_row:
            continue
        np.testing.assert_array_equal(dirty_q[b], clean_q[b], err_msg=f"row {b}")
        np.testing.assert_array_equal(dirty_v[b], clean_v[b], err_msg=f"row {b}")
        np.testing.assert_array_equal(dirty_c[b], clean_c[b], err_msg=f"row {b}")
    assert (dirty_q[corrupt_row] == np.uint32(0xFFFFFFFF)).all()


# ----------------------------------------------------------------------------
# the windowed ring: rotation, expiry, RCMW fuzz
# ----------------------------------------------------------------------------


def _filled_window(window=4, rows=3, epochs=5, seed=2):
    win = WindowedCountMinBank.empty(window, rows, CFG)
    rng = np.random.default_rng(seed)
    for e in range(epochs):
        if e:
            win = win.advance()
        n = int(rng.integers(64, 256))
        keys = jnp.asarray(rng.integers(0, rows, n, dtype=np.int32))
        items = jnp.asarray(rng.integers(0, 500, n, dtype=np.int32))
        win = win.observe(keys, items)
    return win


def test_window_rotation_and_expiry():
    win = WindowedCountMinBank.empty(3, 1, CFG)
    for e in range(5):
        if e:
            win = win.advance()
        win = win.observe(
            jnp.zeros(10, jnp.int32), jnp.full(10, e, jnp.int32)
        )
    assert win.epoch == 4
    # epochs 0-1 expired: only epochs 2,3,4 remain in the window
    assert int(win.window_counts()[0]) == 30
    est = np.asarray(win.query_window(jnp.arange(5)))
    assert (est[0, :2] <= 10).all()  # expired probes see only collisions
    assert (est[0, 2:] >= 10).all()
    newest = np.asarray(win.query_window(jnp.arange(5), last_k=1))
    assert newest[0, 4] >= 10 and (newest[0, :4] <= 10).all()
    vals, cnts = win.topk_window(3)
    assert set(int(v) for v in vals[0]) >= {2, 3, 4}


def test_advance_to_is_monotone_and_expires_whole_ring():
    win = _filled_window()
    epoch = win.epoch
    # a target at or before the current epoch is a no-op
    same = win.advance_to(epoch - 2)
    assert same.epoch == epoch
    np.testing.assert_array_equal(
        np.asarray(same.counters), np.asarray(win.counters)
    )
    with pytest.raises(ValueError, match="steps >= 1"):
        win.advance(0)
    # a jump >= W wipes counters, labels, AND votes
    gone = win.advance_to(epoch + win.window + 3)
    assert gone.epoch == epoch + win.window + 3
    assert np.asarray(gone.counters).sum() == 0
    assert np.asarray(gone.labels).sum() == 0
    assert np.asarray(gone.label_counts).sum() == 0
    assert int(gone.window_counts().sum()) == 0


def test_window_last_k_validation():
    win = _filled_window(window=4)
    with pytest.raises(ValueError, match="last_k"):
        win.window_counts(0)
    with pytest.raises(ValueError, match="last_k"):
        win.query_window(jnp.arange(3), last_k=5)


def test_windowed_with_rows_grows_in_place():
    win = _filled_window(rows=2)
    assert win.with_rows(2) is win
    grown = win.with_rows(4)
    assert grown.rows == 4
    np.testing.assert_array_equal(
        np.asarray(grown.counters[:, :2]), np.asarray(win.counters)
    )
    with pytest.raises(ValueError, match="cannot shrink"):
        win.with_rows(1)


def test_cmw_bytes_roundtrip():
    win = _filled_window()
    back = WindowedCountMinBank.from_bytes(win.to_bytes())
    assert back.cfg == win.cfg
    assert back.window == win.window and back.rows == win.rows
    assert int(back.cursor) == int(win.cursor)
    np.testing.assert_array_equal(np.asarray(back.epochs), np.asarray(win.epochs))
    np.testing.assert_array_equal(
        np.asarray(back.counters), np.asarray(win.counters)
    )
    np.testing.assert_array_equal(np.asarray(back.labels), np.asarray(win.labels))
    np.testing.assert_array_equal(
        np.asarray(back.label_counts), np.asarray(win.label_counts)
    )
    np.testing.assert_array_equal(back.counts, win.counts)


@pytest.mark.parametrize("frac", [0.0, 0.05, 0.2, 0.45, 0.7, 0.9, 0.999])
def test_cmw_bytes_rejects_truncation_anywhere(frac):
    blob = _filled_window().to_bytes()
    cut = int(len(blob) * frac)
    with pytest.raises(ValueError):
        WindowedCountMinBank.from_bytes(blob[:cut])
    with pytest.raises(ValueError):
        WindowedCountMinBank.from_bytes(blob + b"\x00")


def test_cmw_bytes_rejects_garbage():
    win = _filled_window(window=3)
    blob = win.to_bytes()
    with pytest.raises(ValueError, match="magic"):
        WindowedCountMinBank.from_bytes(b"NOPE" + blob[4:])
    with pytest.raises(ValueError, match="version"):
        WindowedCountMinBank.from_bytes(blob[:4] + b"\x09" + blob[5:])
    # cursor out of range: the last header field is the uint32 cursor
    bad_cursor = bytearray(blob)
    bad_cursor[28:32] = (7).to_bytes(4, "little")
    with pytest.raises(ValueError, match="cursor"):
        WindowedCountMinBank.from_bytes(bytes(bad_cursor))
    # garbage epoch labels violate the slot-congruence ring invariant
    bad_epochs = bytearray(blob)
    bad_epochs[32 : 32 + 4 * win.window] = b"\x63\x00\x00\x00" * win.window
    with pytest.raises(ValueError, match="epoch"):
        WindowedCountMinBank.from_bytes(bytes(bad_epochs))


# ----------------------------------------------------------------------------
# pytree / jit behavior
# ----------------------------------------------------------------------------


def test_cm_bank_is_a_pytree_and_jits():
    bank = _filled(rows=3, n=512)
    leaves = jax.tree_util.tree_leaves(bank)
    assert len(leaves) == 4  # counters, labels, label_counts, n_items

    @jax.jit
    def probe(b):
        return b.query(jnp.arange(16))

    np.testing.assert_array_equal(
        np.asarray(probe(bank)), np.asarray(bank.query(jnp.arange(16)))
    )
    flat, treedef = jax.tree_util.tree_flatten(bank)
    back = jax.tree_util.tree_unflatten(treedef, flat)
    assert back.cfg == bank.cfg
    np.testing.assert_array_equal(np.asarray(back.counters), np.asarray(bank.counters))


def test_windowed_cm_bank_is_a_pytree():
    win = _filled_window(window=3, rows=2)
    flat, treedef = jax.tree_util.tree_flatten(win)
    assert len(flat) == 6  # 4 tables + cursor + epochs; cfg is static
    back = jax.tree_util.tree_unflatten(treedef, flat)
    assert back.cfg == win.cfg and back.epoch == win.epoch
