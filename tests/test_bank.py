"""SketchBank: keyed batched ingestion, serialization, and the no-leak rules.

Acceptance property for the bank subsystem (DESIGN.md §9): for EVERY
registered bank backend, ``update_many`` on a (B, m) bank — including the
(1024, m) acceptance size — is bit-identical to the per-sketch update loop
``for b: bank[b].update(items[keys == b])``, for streams that divide
nothing, for out-of-range keys (dropped, never leaked into a neighbor), and
under mesh placement.  Plus: exact per-row counters, the RHLB wire format,
and the serialization fuzz extending PR 2's histogram no-leak guard to
corrupted bank blobs.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.sketch import (
    ExecutionPlan,
    HLLConfig,
    HyperLogLog,
    SketchBank,
    available_bank_backends,
    hll,
    update_many,
)

CFG = HLLConfig(p=6, hash_bits=64)  # small m so the pallas bank path runs


def _stream(n, rows, seed=0):
    rng = np.random.default_rng(seed)
    items = rng.integers(0, 2**31, n, dtype=np.int32)
    keys = rng.integers(0, rows, n, dtype=np.int32)
    return jnp.asarray(keys), jnp.asarray(items)


def _loop_reference(keys, items, rows, cfg=CFG):
    """The pre-bank shape of ingest: one hll.update per bank row."""
    ks, it = np.asarray(keys), np.asarray(items)
    out = np.zeros((rows, cfg.m), np.uint8)
    for b in range(rows):
        regs = hll.update(hll.init_registers(cfg), jnp.asarray(it[ks == b]), cfg)
        out[b] = np.asarray(regs)
    return out


def _filled_bank(rows=8, n=5000, seed=3):
    keys, items = _stream(n, rows, seed=seed)
    return update_many(SketchBank.empty(rows, CFG), keys, items)


# ----------------------------------------------------------------------------
# update_many vs per-sketch loop (the acceptance property)
# ----------------------------------------------------------------------------


def test_bank_backends_registered():
    assert set(available_bank_backends()) >= {
        "jnp",
        "pallas",
        "pallas_pipelined",
    }


@pytest.mark.parametrize("backend", available_bank_backends())
@pytest.mark.parametrize("n", [1, 1000, 4099])  # 4099 is prime: pads everywhere
def test_update_many_matches_loop(backend, n):
    rows = 17  # prime row count: divides no row block evenly
    keys, items = _stream(n, rows, seed=n)
    ref = _loop_reference(keys, items, rows)
    for pipelines in (1, 3, 8):
        plan = ExecutionPlan(backend=backend, pipelines=pipelines)
        bank = update_many(SketchBank.empty(rows, CFG), keys, items, plan)
        np.testing.assert_array_equal(np.asarray(bank.registers), ref)


@pytest.mark.parametrize("backend", available_bank_backends())
def test_acceptance_1024_row_bank_bit_identical(backend):
    rows, n = 1024, 8191
    keys, items = _stream(n, rows, seed=42)
    ref = _loop_reference(keys, items, rows)
    bank = update_many(
        SketchBank.empty(rows, CFG), keys, items, ExecutionPlan(backend=backend)
    )
    np.testing.assert_array_equal(np.asarray(bank.registers), ref)


@pytest.mark.parametrize("backend", available_bank_backends())
def test_out_of_range_keys_dropped_not_leaked(backend):
    rows, n = 11, 3001
    keys, items = _stream(n, rows, seed=7)
    pos = np.arange(n)
    bad = np.where(pos % 5 == 0, -2, np.asarray(keys))
    bad = np.where(pos % 7 == 0, rows + 3, bad)
    ref = _loop_reference(jnp.asarray(bad), items, rows)
    bank = update_many(
        SketchBank.empty(rows, CFG),
        jnp.asarray(bad),
        items,
        ExecutionPlan(backend=backend),
    )
    np.testing.assert_array_equal(np.asarray(bank.registers), ref)
    # dropped observations must not count either
    in_range = bad[(bad >= 0) & (bad < rows)]
    np.testing.assert_array_equal(bank.counts, np.bincount(in_range, minlength=rows))


def test_mesh_placement_matches_local():
    rows, n = 9, 2503  # prime stream: forces the edge-padding path
    keys, items = _stream(n, rows, seed=9)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    plan = ExecutionPlan(backend="jnp").with_mesh(mesh)
    bank = update_many(SketchBank.empty(rows, CFG), keys, items, plan)
    np.testing.assert_array_equal(
        np.asarray(bank.registers), _loop_reference(keys, items, rows)
    )


def test_empty_stream_is_a_noop():
    bank = SketchBank.empty(4, CFG)
    out = update_many(bank, jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(out.registers), np.asarray(bank.registers)
    )
    assert out.counts.sum() == 0


def test_mismatched_key_item_lengths_raise():
    bank = SketchBank.empty(4, CFG)
    with pytest.raises(ValueError, match="same length"):
        update_many(bank, jnp.zeros((3,), jnp.int32), jnp.zeros((4,), jnp.int32))


def test_2d_keys_and_items_flatten_consistently():
    """The serve-style shape: (B, S) tokens keyed by their row index."""
    rows, cols = 6, 50
    items = jnp.asarray(
        np.random.default_rng(1).integers(0, 2**31, (rows, cols), np.int32)
    )
    keys = jnp.broadcast_to(jnp.arange(rows, dtype=jnp.int32)[:, None], (rows, cols))
    bank = update_many(SketchBank.empty(rows, CFG), keys, items)
    for b in range(rows):
        expected = np.asarray(hll.update(hll.init_registers(CFG), items[b], CFG))
        np.testing.assert_array_equal(np.asarray(bank.registers[b]), expected)


# ----------------------------------------------------------------------------
# counters, rows, merge
# ----------------------------------------------------------------------------


def test_counters_are_exact_and_rows_round_trip():
    rows, n = 5, 4001
    keys, items = _stream(n, rows, seed=11)
    bank = update_many(SketchBank.empty(rows, CFG), keys, items)
    np.testing.assert_array_equal(
        bank.counts, np.bincount(np.asarray(keys), minlength=rows)
    )
    sk = bank.row(2)
    assert isinstance(sk, HyperLogLog)
    assert sk.count == int(bank.counts[2])
    assert bank.row(-1).count == int(bank.counts[rows - 1])
    with pytest.raises(IndexError, match="out of range"):
        bank.row(rows)
    with pytest.raises(IndexError, match="out of range"):
        bank.estimate(-rows - 1)
    back = SketchBank.from_sketches(bank.to_sketches())
    np.testing.assert_array_equal(
        np.asarray(back.registers), np.asarray(bank.registers)
    )
    np.testing.assert_array_equal(back.counts, bank.counts)


def test_counter_is_overflow_safe_past_uint32():
    bank = SketchBank.empty(2, CFG)
    near_wrap = jnp.asarray(np.array([[0, 0xFFFFFFFF], [0, 5]], np.uint32))
    bank = SketchBank(bank.registers, near_wrap, CFG)
    keys = jnp.zeros((3,), jnp.int32)
    items = jnp.arange(3, dtype=jnp.int32)
    bank = update_many(bank, keys, items)
    assert int(bank.counts[0]) == 2**32 + 2  # crossed the limb boundary
    assert int(bank.counts[1]) == 5


def test_merge_banks_and_mismatches_raise():
    a = _filled_bank(rows=6, seed=1)
    b = _filled_bank(rows=6, seed=2)
    ab = a | b
    np.testing.assert_array_equal(
        np.asarray(ab.registers),
        np.maximum(np.asarray(a.registers), np.asarray(b.registers)),
    )
    np.testing.assert_array_equal(ab.counts, a.counts + b.counts)
    with pytest.raises(ValueError, match="different sizes"):
        a.merge(_filled_bank(rows=7))
    with pytest.raises(ValueError, match="different configs"):
        a.merge(SketchBank.empty(6, HLLConfig(p=8, hash_bits=64)))
    with pytest.raises(ValueError, match="one config"):
        SketchBank.from_sketches(
            [HyperLogLog.empty(CFG), HyperLogLog.empty(HLLConfig(p=8))]
        )


def test_estimates_match_per_row_sketches():
    bank = _filled_bank(rows=12, n=20_000)
    many = np.asarray(bank.estimate_many())
    for b in range(len(bank)):
        exact = bank.estimate(b)
        assert abs(many[b] - exact) / max(exact, 1.0) < 1e-4
        assert bank.row(b).estimate() == exact


# ----------------------------------------------------------------------------
# serialization (RHLB wire format + corruption fuzz)
# ----------------------------------------------------------------------------


def test_bank_bytes_roundtrip():
    bank = _filled_bank(rows=7, n=6000)
    blob = bank.to_bytes()
    assert len(blob) == 20 + 7 * 8 + 7 * CFG.m
    back = SketchBank.from_bytes(blob)
    assert back.cfg == bank.cfg and len(back) == len(bank)
    np.testing.assert_array_equal(
        np.asarray(back.registers), np.asarray(bank.registers)
    )
    np.testing.assert_array_equal(back.counts, bank.counts)


def test_bank_bytes_rejects_garbage():
    bank = _filled_bank(rows=3)
    blob = bank.to_bytes()
    with pytest.raises(ValueError, match="truncated"):
        SketchBank.from_bytes(blob[:10])
    with pytest.raises(ValueError, match="magic"):
        SketchBank.from_bytes(b"NOPE" + blob[4:])
    with pytest.raises(ValueError, match="version"):
        SketchBank.from_bytes(blob[:4] + b"\x09" + blob[5:])
    with pytest.raises(ValueError, match="payload"):
        SketchBank.from_bytes(blob[:-1])


@pytest.mark.parametrize("frac", [0.0, 0.05, 0.2, 0.45, 0.7, 0.9, 0.999])
def test_bank_bytes_rejects_truncation_anywhere(frac):
    """A blob cut at ANY point — mid-header, mid-counts, mid-row — must
    raise ValueError cleanly, never hand back a short-read bank (the same
    contract RHLW enforces per bucket, tests/test_window.py)."""
    bank = _filled_bank(rows=5, n=4000)
    blob = bank.to_bytes()
    cut = int(len(blob) * frac)
    with pytest.raises(ValueError):
        SketchBank.from_bytes(blob[:cut])
    with pytest.raises(ValueError):
        SketchBank.from_bytes(blob + b"\x00")  # trailing garbage too


def test_bank_bytes_rejects_cut_mid_row():
    rows = 4
    bank = _filled_bank(rows=rows)
    blob = bank.to_bytes()
    header_end = 20 + rows * 8
    # end the payload halfway through row 2's registers
    cut = header_end + 2 * CFG.m + CFG.m // 2
    assert cut < len(blob)
    with pytest.raises(ValueError, match="payload"):
        SketchBank.from_bytes(blob[:cut])


def test_corrupted_blob_never_leaks_across_rows():
    """The ingest-side extension of PR 2's histogram guard: flipping row
    j's registers to out-of-range values must not move ANY other row's
    estimate, and the exact host path must refuse the corrupted row."""
    rows = 6
    bank = _filled_bank(rows=rows, n=30_000)
    clean = np.asarray(bank.estimate_many())
    blob = bytearray(bank.to_bytes())
    header = 20 + rows * 8
    corrupt_row = 3
    start = header + corrupt_row * CFG.m
    blob[start : start + CFG.m] = bytes([255]) * CFG.m  # rank >> max_rank
    fuzzed = SketchBank.from_bytes(bytes(blob))
    dirty = np.asarray(fuzzed.estimate_many())
    for b in range(rows):
        if b == corrupt_row:
            continue
        assert dirty[b] == clean[b], f"row {b} leaked from row {corrupt_row}"
    with pytest.raises(ValueError, match="exceeds max_rank"):
        fuzzed.estimate(corrupt_row)


def test_jnp_bank_rejects_int32_cell_space_overflow():
    """B*m >= 2^31 would silently wrap the flattened segment ids; the jnp
    path must refuse loudly (shape-only check — no giant allocation)."""
    from functools import partial

    from repro.sketch.backends import bank_update_jnp

    cfg = HLLConfig(p=16, hash_bits=64)
    big = jax.ShapeDtypeStruct((1 << 15, cfg.m), jnp.uint8)  # B*m == 2^31
    keys = jax.ShapeDtypeStruct((8,), jnp.int32)
    items = jax.ShapeDtypeStruct((8,), jnp.int32)
    with pytest.raises(ValueError, match="overflows int32"):
        jax.eval_shape(partial(bank_update_jnp, cfg=cfg), big, keys, items)


def test_empty_returns_rejects_bad_row_count():
    with pytest.raises(ValueError, match="at least one row"):
        SketchBank.empty(0, CFG)
    with pytest.raises(ValueError, match="at least one sketch"):
        SketchBank.from_sketches([])


def _zero_row_bank():
    # empty() refuses rows=0 by design; a zero-row bank can still arrive
    # through slicing/deserialization layers, so build one directly
    return SketchBank(
        jnp.zeros((0, CFG.m), jnp.uint8), jnp.zeros((0, 2), jnp.uint32), CFG
    )


def test_zero_row_bank_update_many_short_circuits():
    bank = _zero_row_bank()
    keys, items = _stream(64, 4, seed=21)
    out = bank.update_many(keys, items)  # every key is out of range
    assert out is bank
    assert out.counts.shape == (0,)
    with pytest.raises(ValueError, match="same length"):
        bank.update_many(jnp.zeros((2,), jnp.int32), jnp.zeros((3,), jnp.int32))


def test_zero_row_bank_estimate_many_short_circuits():
    bank = _zero_row_bank()
    est = bank.estimate_many()
    assert est.shape == (0,) and est.dtype == jnp.float32
    for estimator in ("original", "ertl_improved", "ertl_mle"):
        assert bank.estimate_many(estimator).shape == (0,)


def test_v2_blob_rejected_with_pointer_and_fuzz():
    """The v1 parser must refuse RHLB v2 (hybrid) blobs loudly at any cut
    point — the wire-format mirror of the version-gated parse rule in
    repro/sketch/sparse.py (DESIGN.md §12)."""
    from repro.sketch import HybridBank

    keys, items = _stream(2000, 6, seed=33)
    hb = HybridBank.empty(6, CFG, threshold=8).update_many(keys, items)
    blob = hb.to_bytes()
    with pytest.raises(ValueError, match="HybridBank.from_bytes"):
        SketchBank.from_bytes(blob)
    for frac in (0.1, 0.5, 0.9):
        with pytest.raises(ValueError):
            SketchBank.from_bytes(blob[: int(len(blob) * frac)])
        with pytest.raises(ValueError):
            HybridBank.from_bytes(blob[: int(len(blob) * frac)])
    # and the hybrid parser holds the same line on cut v1 blobs
    v1 = _filled_bank(rows=3).to_bytes()
    for frac in (0.1, 0.5, 0.9):
        with pytest.raises(ValueError):
            HybridBank.from_bytes(v1[: int(len(v1) * frac)])


# ----------------------------------------------------------------------------
# pytree / jit behavior
# ----------------------------------------------------------------------------


def test_bank_is_a_pytree_and_jits():
    bank = _filled_bank(rows=4, n=512)
    leaves = jax.tree_util.tree_leaves(bank)
    assert len(leaves) == 2  # registers + counters; cfg is static

    @jax.jit
    def bump(b, keys, items):
        return b.update_many(keys, items)

    keys, items = _stream(256, 4, seed=5)
    out = bump(bank, keys, items)
    assert isinstance(out, SketchBank) and out.cfg == CFG
    ref = update_many(bank, keys, items)
    np.testing.assert_array_equal(np.asarray(out.registers), np.asarray(ref.registers))
