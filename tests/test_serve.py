"""Serving engine: prefill+decode equivalence, ring buffers, decode loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer
from repro.serve import engine

B, S, T = 2, 32, 6


def _setup(arch_id, kv_len=None):
    arch = get_arch(arch_id).reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), arch)
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (B, S + T), 0, arch.vocab_size
    )
    batch_full = {"tokens": toks}
    if arch.mrope:
        batch_full["positions"] = transformer.default_positions(arch, B, S + T)
    fe = None
    if arch.frontend_stub_len:
        fe = (
            jax.random.normal(
                jax.random.PRNGKey(2), (B, arch.frontend_stub_len, arch.d_model)
            ).astype(jnp.bfloat16)
            * 0.02
        )
        batch_full["frontend_embeds"] = fe
    return arch, params, toks, batch_full, fe


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch_id):
    arch, params, toks, batch_full, fe = _setup(arch_id)
    logits_full, _, _ = transformer.forward(params, batch_full, arch)

    batch_pre = {"tokens": toks[:, :S]}
    if arch.mrope:
        batch_pre["positions"] = transformer.default_positions(arch, B, S)
    if fe is not None:
        batch_pre["frontend_embeds"] = fe
    logits_pre, cache = engine.prefill(params, batch_pre, arch, kv_len=S + T)

    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(logits_full[:, :S], np.float32),
        atol=0.1,
    )
    for t in range(T):
        logits_t, cache = engine.decode_step(
            params, cache, toks[:, S + t], jnp.asarray(S + t), arch
        )
        np.testing.assert_allclose(
            np.asarray(logits_t, np.float32),
            np.asarray(logits_full[:, S + t], np.float32),
            atol=0.15,
            err_msg=f"{arch_id} step {t}",
        )


def test_ring_buffer_swa_equals_full_window():
    """SWA ring cache must reproduce full-cache attention within the window."""
    arch, params, toks, batch_full, _ = _setup("mixtral-8x7b")
    assert arch.sliding_window == 64
    # kv_len larger than window: ring width clamps to window
    cache = engine.init_cache(arch, B, kv_len=S + T)
    w = arch.sliding_window
    k_shape = cache["stages"][0]["sub0"]["k"].shape
    assert k_shape[2] == min(w, S + T)


def test_decode_loop_greedy():
    arch, params, toks, _, _ = _setup("tinyllama-1.1b")
    batch_pre = {"tokens": toks[:, :S]}
    _, cache = engine.prefill(params, batch_pre, arch, kv_len=S + T + 4)
    out, _ = engine.decode_loop(
        params, cache, toks[:, S], jnp.asarray(S, jnp.int32), arch, steps=4
    )
    assert out.shape == (B, 4)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < arch.vocab_size).all()


def test_long_context_cache_is_bounded_for_swa():
    """long_500k qualification: SWA/hybrid/ssm caches do not scale with S."""
    for arch_id in ("mixtral-8x7b", "recurrentgemma-9b", "rwkv6-3b"):
        arch = get_arch(arch_id)  # full config, shapes only (no alloc)
        cache = jax.eval_shape(lambda a=arch: engine.init_cache(a, 1, 524_288))
        total = sum(
            np.prod(l.shape) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache)
        )
        # must be far below the unbounded 500k KV cache size
        assert total < 3e9, (arch_id, total)


def test_kv_pos_validity_masking():
    """Ring slots not yet written must never be attended to."""
    arch, params, toks, _, _ = _setup("tinyllama-1.1b")
    # decode from an empty cache at pos 0: only slot 0 valid
    cache = engine.init_cache(arch, B, kv_len=8)
    logits, cache = engine.decode_step(
        params, cache, toks[:, 0], jnp.asarray(0), arch
    )
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    kv_pos = cache["kv_pos_8"]
    assert int(kv_pos[0]) == 0 and (np.asarray(kv_pos[1:]) == -1).all()
