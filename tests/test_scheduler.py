"""Continuous batching: per-slot decode correctness + slot recycling."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer
from repro.serve import engine
from repro.serve.scheduler import ContinuousBatcher, Request

ARCH = get_arch("tinyllama-1.1b").reduced()
PARAMS = transformer.init_params(jax.random.PRNGKey(0), ARCH)


def _solo_greedy(prompt: np.ndarray, max_new: int):
    """Reference: single-request prefill + greedy decode."""
    batch = {"tokens": jnp.asarray(prompt[None])}
    logits, cache = engine.prefill(PARAMS, batch, ARCH, kv_len=64)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    pos = len(prompt)
    for _ in range(max_new - 1):
        lg, cache = engine.decode_step(
            PARAMS, cache, jnp.asarray([tok], jnp.int32), jnp.asarray(pos), ARCH
        )
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def test_mixed_batch_matches_solo():
    """Requests of different lengths in one batch == each decoded alone."""
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, ARCH.vocab_size, 12, dtype=np.int32),
        rng.integers(0, ARCH.vocab_size, 23, dtype=np.int32),
    ]
    solo = [_solo_greedy(p, 6) for p in prompts]

    b = ContinuousBatcher(PARAMS, ARCH, n_slots=2, kv_len=64)
    for i, p in enumerate(prompts):
        b.submit(Request(uid=i, prompt=p, max_new=6))
    out = b.run()
    assert out[0] == solo[0], (out[0], solo[0])
    assert out[1] == solo[1], (out[1], solo[1])


def test_slot_recycling_admits_queued_requests():
    """3 requests through 1 slot: all finish, sequentially recycled."""
    rng = np.random.default_rng(1)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, ARCH.vocab_size, 8, np.int32),
                max_new=3)
        for i in range(3)
    ]
    b = ContinuousBatcher(PARAMS, ARCH, n_slots=1, kv_len=32)
    for r in reqs:
        b.submit(r)
    out = b.run()
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 3 for v in out.values())
    assert all(r.done for r in reqs)


def test_recycled_slot_is_clean():
    """A recycled slot must not leak the previous request's KV."""
    rng = np.random.default_rng(2)
    p = rng.integers(0, ARCH.vocab_size, 10, np.int32)
    # run the same prompt first and third through one slot with a different
    # request in between: outputs must be identical
    b = ContinuousBatcher(PARAMS, ARCH, n_slots=1, kv_len=32)
    b.submit(Request(uid=0, prompt=p, max_new=4))
    b.submit(Request(uid=1, prompt=rng.integers(0, ARCH.vocab_size, 15, np.int32),
                     max_new=4))
    b.submit(Request(uid=2, prompt=p, max_new=4))
    out = b.run()
    assert out[0] == out[2], (out[0], out[2])
