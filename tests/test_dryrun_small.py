"""Dry-run machinery on a small faked-device mesh (subprocess-isolated).

The production dry-run needs 512 placeholder devices, which must be
configured before jax initializes — so these tests exec a fresh python with
XLA_FLAGS set, proving the exact code path the launcher uses (reduced
configs, 2x2 mesh) without polluting this process's device count.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_reduced_cell_lowers_on_faked_mesh():
    out = _run_in_subprocess("""
        import jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch
        from repro.sharding import specs as shardspecs, ctx as shardctx
        from repro.train.step import TrainConfig, init_train_state, train_step
        from repro.sketch import HLLConfig
        from repro.launch import hlo_analysis

        arch = get_arch("tinyllama-1.1b").reduced()
        cfg = TrainConfig(sketch=HLLConfig(p=8, hash_bits=32))
        from repro.launch.mesh import make_auto_mesh
        mesh = make_auto_mesh((4, 2), ("data", "model"))
        state_avals = jax.eval_shape(
            lambda k: init_train_state(k, arch, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        pspecs = shardspecs.param_specs(
            state_avals["params"], arch, data_size=4, model_size=2)
        named = lambda t: jax.tree.map(lambda sp: NamedSharding(mesh, sp), t)
        state_sh = {"params": named(pspecs),
                    "opt": {"mu": named(pspecs), "nu": named(pspecs),
                            "count": NamedSharding(mesh, P()), "ef": None},
                    "step": NamedSharding(mesh, P()),
                    "sketch": NamedSharding(mesh, P())}
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        batch_sh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
        hints = shardctx.ActivationHints(batch_axes=("data",), model_axis="model")
        with mesh, shardctx.use_hints(hints):
            lowered = jax.jit(partial(train_step, arch=arch, cfg=cfg),
                              in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)).lower(state_avals, batch)
        compiled = lowered.compile()
        an = hlo_analysis.analyze(compiled.as_text())
        assert an.flops > 0 and an.n_while_loops >= 1
        assert an.collective_bytes > 0  # TP all-reduces must be present
        print("OK", an.n_while_loops, int(an.collective_bytes))
    """)
    assert out.startswith("OK")


@pytest.mark.slow
def test_make_production_mesh_shapes():
    out = _run_in_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh, n_chips
        m1 = make_production_mesh(multi_pod=False)
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}, m2.shape
        assert n_chips(m1) == 256 and n_chips(m2) == 512
        m3 = make_production_mesh(multi_pod=False, tp=4)
        assert dict(m3.shape) == {"data": 64, "model": 4}
        print("OK")
    """)
    assert out.startswith("OK")


def test_dryrun_artifacts_complete():
    """The committed sweep must cover every (arch x shape x mesh) cell."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("sweep artifacts not present")
    from repro.configs import ARCH_IDS, SHAPES

    files = {f for f in os.listdir(d) if f.endswith(".json")}
    missing, bad = [], []
    for a in ARCH_IDS:
        for s in SHAPES:
            for mesh in ("pod16x16", "pod2x16x16"):
                name = f"{a}__{s}__{mesh}.json"
                if name not in files:
                    missing.append(name)
                    continue
                rec = json.load(open(os.path.join(d, name)))
                if rec["status"] == "error":
                    bad.append(name)
    assert not missing, missing
    assert not bad, bad
