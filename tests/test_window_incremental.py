"""Incremental window maintenance (DESIGN.md §14).

The contract under test: every cached or incrementally-maintained window
read is BIT-IDENTICAL to a cold full fold of the same ring — for random
interleavings of observe/advance/advance_to/estimate_window, for every
registered backend, and for rings resurrected through ``from_bytes``
(which drops the hidden state by construction).  Plus: the
``register_window_merge_backend`` axis (three built-in entries, jnp
fallback for plugins), the one-rebuild-per-W amortization schedule, hidden
state staying out of the pytree and out of jit traces, the shared
``last_k`` validation across all three window carriers, and the
``MultiResWindowedBank`` exponential histogram (dense-ring bit-identity
inside the horizon, slot-merge schedule invariants, RHLW v3).
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from hypothesis_compat import given, st

from repro.sketch import (
    CMConfig,
    ExecutionPlan,
    HLLConfig,
    HybridWindowedBank,
    MultiResWindowedBank,
    SketchBank,
    WindowedBank,
    available_window_backends,
    available_window_merge_backends,
    estimate_many,
    get_window_merge_backend,
)
from repro.kernels.window_fold import window_merge_max
from repro.telemetry.sketchboard import StreamSketch

CFG = HLLConfig(p=6, hash_bits=64)  # small m so the pallas paths run


def _chunk(n, rows, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, rows, n, dtype=np.int32))
    items = jnp.asarray(rng.integers(0, 2**31, n, dtype=np.int32))
    return keys, items


def _cold_fold(win, last_k):
    """The reference read: a fresh numpy fold of the ring, no caches."""
    ring = np.asarray(win.registers)
    mask = np.asarray(win._live_mask(last_k))
    acc = np.zeros(ring.shape[1:], ring.dtype)
    for w in range(ring.shape[0]):
        if mask[w]:
            acc = np.maximum(acc, ring[w])
    return acc, np.asarray(estimate_many(jnp.asarray(acc), CFG))


def _assert_reads_cold(win, plan, last_ks=None):
    """Every (cached, incremental) read equals the cold fold, twice over
    so the second read exercises the cache-hit path."""
    for last_k in last_ks or (win.window, max(1, win.window // 2), 1):
        ref_regs, ref_est = _cold_fold(win, last_k)
        for _ in range(2):
            regs = np.asarray(win._fold_registers(last_k, plan))
            np.testing.assert_array_equal(regs, ref_regs)
            est = np.asarray(win.estimate_window(last_k, plan))
            np.testing.assert_array_equal(est, ref_est)


# ----------------------------------------------------------------------------
# the register_window_merge_backend axis
# ----------------------------------------------------------------------------


def test_merge_backends_registered():
    assert set(available_window_merge_backends()) >= {
        "jnp",
        "pallas",
        "pallas_pipelined",
    }


def test_unknown_merge_backend_falls_back_to_jnp():
    # plugins registered only for flat updates still get full-window
    # reads: the merge axis degrades to the jnp fold instead of raising
    assert get_window_merge_backend("definitely_not_registered") is (
        get_window_merge_backend("jnp")
    )


@pytest.mark.parametrize("k", [1, 2, 3, 5])
def test_window_merge_kernel_matches_jnp(k):
    rng = np.random.default_rng(k)
    parts = jnp.asarray(rng.integers(0, 60, (k, 8, CFG.m), dtype=np.int32))
    got = window_merge_max(parts, m=CFG.m, row_block=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(parts).max(0))


@pytest.mark.parametrize("backend", available_window_backends())
def test_merge_backend_equals_stack_max(backend):
    rng = np.random.default_rng(7)
    parts = jnp.asarray(rng.integers(0, 60, (3, 9, CFG.m), dtype=np.int32))
    plan = ExecutionPlan(backend=backend).validate()
    got = get_window_merge_backend(backend)(parts, CFG, plan)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(parts).max(0))


# ----------------------------------------------------------------------------
# cache/state coherence: incremental reads == cold folds, always
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("backend", available_window_backends())
def test_random_walk_reads_bit_identical(backend):
    plan = ExecutionPlan(backend=backend, pipelines=3)
    rng = np.random.default_rng(42)
    win = WindowedBank.empty(6, 9, CFG)
    for step in range(48):
        op = rng.integers(0, 5)
        if op <= 1:
            keys, items = _chunk(int(rng.integers(1, 300)), 9, int(step))
            win = win.observe(keys, items, plan)
        elif op == 2:
            win = win.advance()
        elif op == 3:
            win = win.advance(int(rng.integers(2, 4)))
        else:
            win = win.advance_to(win.epoch + int(rng.integers(1, 9)))
        _assert_reads_cold(win, plan)


@given(ops=st.lists(st.integers(min_value=0, max_value=9), max_size=24))
def test_random_walk_reads_bit_identical_property(ops):
    plan = ExecutionPlan(backend="jnp")
    win = WindowedBank.empty(4, 5, CFG)
    for i, op in enumerate(ops):
        if op <= 4:
            keys, items = _chunk(40 + op, 5, i)
            win = win.observe(keys, items, plan)
        elif op <= 7:
            win = win.advance()
        else:
            win = win.advance_to(win.epoch + op)
        _assert_reads_cold(win, plan, last_ks=(4, 2, 1))


@pytest.mark.parametrize("backend", available_window_backends())
def test_from_bytes_ring_reads_bit_identical(backend):
    plan = ExecutionPlan(backend=backend)
    win = WindowedBank.empty(5, 7, CFG)
    for e in range(7):
        if e:
            win = win.advance()
        win = win.observe(*_chunk(200, 7, seed=e), plan)
        win.estimate_window(plan=plan)  # prime the hidden state + cache
    back = WindowedBank.from_bytes(win.to_bytes())
    # the resurrected ring starts stateless; both must read identically
    # through further lockstep mutation
    for e in range(7):
        keys, items = _chunk(150, 7, seed=100 + e)
        win = win.advance().observe(keys, items, plan)
        back = back.advance().observe(keys, items, plan)
        _assert_reads_cold(back, plan)
        np.testing.assert_array_equal(
            np.asarray(win.estimate_window(plan=plan)),
            np.asarray(back.estimate_window(plan=plan)),
        )


def test_replayed_estimates_match_original_run():
    # the exact sequence a dashboard runs: interleaved ingest/rotation with
    # a read per epoch; replaying the stream on a fresh ring must reproduce
    # every reading bit-for-bit even though the original run answered from
    # the incremental path and the replay from cold folds
    plan = ExecutionPlan(backend="jnp")
    readings = []
    win = WindowedBank.empty(4, 6, CFG)
    for e in range(12):
        win = win.observe(*_chunk(120, 6, seed=e), plan)
        readings.append(np.asarray(win.estimate_window(plan=plan)))
        win = win.advance()
    replay = WindowedBank.empty(4, 6, CFG)
    for e in range(12):
        replay = replay.observe(*_chunk(120, 6, seed=e), plan)
        ref_regs, ref_est = _cold_fold(replay, 4)
        np.testing.assert_array_equal(readings[e], ref_est)
        replay = replay.advance()


# ----------------------------------------------------------------------------
# the amortization schedule and pytree/jit hygiene
# ----------------------------------------------------------------------------


def test_prefix_rebuilds_once_per_window(monkeypatch):
    calls = []
    orig = WindowedBank._rebuild_suffix

    def counted(self):
        calls.append(1)
        return orig(self)

    monkeypatch.setattr(WindowedBank, "_rebuild_suffix", counted)
    window, epochs = 8, 64
    win = WindowedBank.empty(window, 4, CFG)
    for e in range(epochs):
        win = win.observe(*_chunk(50, 4, seed=e))
        win.estimate_window()  # full-window read every epoch
        win = win.advance()
    # steady state costs ONE O(W) rebuild per W rotations (the O(1)
    # amortized bound); allow the warmup rebuild on top
    assert len(calls) <= epochs // window + 2
    assert len(calls) >= epochs // window


def test_hidden_state_stays_out_of_the_pytree():
    win = WindowedBank.empty(4, 3, CFG)
    win = win.observe(*_chunk(100, 3, seed=0))
    win.estimate_window()
    win = win.advance()
    win.estimate_window()
    assert "_inc" in win.__dict__ and "_fold_cache" in win.__dict__
    assert len(jax.tree_util.tree_leaves(win)) == 4
    # flatten/unflatten (what jit does at the boundary) drops the state
    leaves, treedef = jax.tree_util.tree_flatten(win)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert "_inc" not in rebuilt.__dict__
    assert "_fold_cache" not in rebuilt.__dict__
    _assert_reads_cold(rebuilt, ExecutionPlan(backend="jnp"))


def test_closure_captured_ring_is_jit_safe():
    # regression: a CONCRETE ring captured in someone else's jit closure
    # sees its ops bound to the active trace, so the state machinery must
    # stand down even though every pytree leaf looks concrete
    win = WindowedBank.empty(4, 3, CFG)
    win = win.observe(*_chunk(80, 3, seed=1))
    win.estimate_window()  # prime hidden state on the captured instance
    win = win.advance()

    out = jax.jit(lambda k, it: win.observe(k, it))(*_chunk(60, 3, seed=2))
    assert "_inc" not in out.__dict__ and "_fold_cache" not in out.__dict__
    _assert_reads_cold(out, ExecutionPlan(backend="jnp"))

    est = jax.jit(lambda _: win.estimate_window())(0)
    np.testing.assert_array_equal(np.asarray(est), _cold_fold(win, 4)[1])
    # and nothing traced leaked into the instance caches
    for cached in win.__dict__.get("_fold_cache", {}).values():
        assert not isinstance(cached, jax.core.Tracer)


def test_trace_context_does_not_poison_multires_cache():
    mr = MultiResWindowedBank.empty(2, 3, CFG, levels=2)
    mr = mr.observe(*_chunk(90, 3, seed=3)).advance()
    mr = mr.observe(*_chunk(90, 3, seed=4))
    eager = np.asarray(mr.estimate_window())
    traced = jax.jit(lambda _: mr.estimate_window())(0)
    np.testing.assert_array_equal(np.asarray(traced), eager)
    for cached in mr.__dict__.get("_fold_cache", {}).values():
        assert not isinstance(cached, jax.core.Tracer)


# ----------------------------------------------------------------------------
# shared last_k validation (one helper, one message, three carriers)
# ----------------------------------------------------------------------------


def test_last_k_validation_identical_across_carriers():
    carriers = [
        WindowedBank.empty(4, 3, CFG),
        HybridWindowedBank.empty(4, 3, CFG),
        MultiResWindowedBank.empty(4, 3, CFG, levels=1),  # horizon == 4
    ]
    for bad in (0, -1, 5, 99):
        messages = set()
        for car in carriers:
            with pytest.raises(ValueError) as exc:
                car.estimate_window(bad)
            messages.add(str(exc.value))
        # the deduplicated helper guarantees ONE message, not three copies
        assert messages == {f"last_k must be in [1, 4], got {bad}"}


def test_window_counts_identical_dense_vs_hybrid():
    dense = WindowedBank.empty(4, 5, CFG)
    hybrid = HybridWindowedBank.empty(4, 5, CFG)
    for e in range(6):
        if e:
            dense, hybrid = dense.advance(), hybrid.advance()
        keys, items = _chunk(100, 5, seed=e)
        dense = dense.observe(keys, items)
        hybrid = hybrid.observe(keys, items)
    for last_k in (1, 2, 4):
        np.testing.assert_array_equal(
            dense.window_counts(last_k), hybrid.window_counts(last_k)
        )


# ----------------------------------------------------------------------------
# MultiResWindowedBank: the exponential-histogram ring
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("backend", available_window_backends())
def test_multires_matches_dense_ring_inside_horizon(backend):
    plan = ExecutionPlan(backend=backend)
    base, levels = 2, 3  # horizon = 2 * (2**3 - 1) = 14
    mr = MultiResWindowedBank.empty(base, 3, CFG, levels=levels)
    dense = WindowedBank.empty(mr.horizon, 3, CFG)
    for e in range(9):  # stays inside the horizon: nothing expires
        if e:
            mr, dense = mr.advance(), dense.advance()
        keys, items = _chunk(130, 3, seed=e)
        mr = mr.observe(keys, items, plan)
        dense = dense.observe(keys, items, plan)
    # a full-horizon query covers every epoch on both carriers, and the
    # EH buckets partition the same registers the dense ring holds
    np.testing.assert_array_equal(
        np.asarray(mr.fold_window(plan=plan).registers),
        np.asarray(dense.fold_window(plan=plan).registers),
    )
    np.testing.assert_array_equal(
        np.asarray(mr.estimate_window(plan=plan)),
        np.asarray(dense.estimate_window(plan=plan)),
    )
    np.testing.assert_array_equal(
        mr.window_counts(), dense.window_counts()
    )


def test_multires_slot_bound_and_schedule_invariants():
    base, levels = 2, 3
    mr = MultiResWindowedBank.empty(base, 2, CFG, levels=levels)
    for e in range(64):
        mr = mr.observe(*_chunk(30, 2, seed=e)).advance()
        assert mr.slots <= 1 + base * levels
        sizes = [b.size for b in mr.closed]  # newest first
        assert all(s & (s - 1) == 0 for s in sizes)
        assert sizes == sorted(sizes)  # non-decreasing toward the old end
        per_level = mr.density()["buckets_per_size"]
        assert all(n <= base for n in per_level.values())
        # labels are strictly older going down the list, never overlapping
        for newer, older in zip(mr.closed, mr.closed[1:]):
            assert newer.start > older.end
        # nothing outlives the horizon
        assert all(b.end > mr.epoch - mr.horizon for b in mr.closed)


def test_multires_empty_epochs_cost_no_slots():
    mr = MultiResWindowedBank.empty(2, 2, CFG, levels=2)
    mr = mr.observe(*_chunk(50, 2, seed=0))
    mr = mr.advance_to(40)  # one occupied epoch, then a long quiet gap
    assert mr.slots <= 2  # current + at most the one occupied bucket
    assert mr.epoch == 40


def test_multires_estimates_cover_rounded_window():
    # after coarsening, a short-suffix query answers over a SUPERSET of
    # the asked window (rounded up to bucket edges): its estimate can
    # only be >= the current-bucket-only reading, and the full-horizon
    # read is exact over everything retained
    mr = MultiResWindowedBank.empty(1, 2, CFG, levels=3)
    for e in range(7):
        mr = mr.observe(*_chunk(80, 2, seed=e)).advance()
    short = np.asarray(mr.estimate_window(1))
    full = np.asarray(mr.estimate_window())
    assert np.all(full >= short)


def test_multires_validates_shape():
    with pytest.raises(ValueError, match="at least one bucket"):
        MultiResWindowedBank.empty(0, 2, CFG)
    with pytest.raises(ValueError, match="levels must be in"):
        MultiResWindowedBank.empty(2, 2, CFG, levels=0)
    with pytest.raises(ValueError, match="levels must be in"):
        MultiResWindowedBank.empty(2, 2, CFG, levels=99)
    with pytest.raises(ValueError, match="overflows int32"):
        MultiResWindowedBank.empty(1 << 20, 2, CFG, levels=12)
    with pytest.raises(ValueError, match="at least one row"):
        MultiResWindowedBank.empty(2, 0, CFG)


def test_rhlw_v3_roundtrip():
    mr = MultiResWindowedBank.empty(2, 3, CFG, levels=3)
    for e in range(11):
        mr = mr.observe(*_chunk(120, 3, seed=e)).advance()
    mr = mr.observe(*_chunk(60, 3, seed=99))
    back = MultiResWindowedBank.from_bytes(mr.to_bytes())
    assert (back.epoch, back.base, back.levels) == (
        mr.epoch,
        mr.base,
        mr.levels,
    )
    assert [(b.start, b.end, b.size) for b in back.closed] == [
        (b.start, b.end, b.size) for b in mr.closed
    ]
    np.testing.assert_array_equal(
        np.asarray(back.fold_window().registers),
        np.asarray(mr.fold_window().registers),
    )
    np.testing.assert_array_equal(back.window_counts(), mr.window_counts())


def test_rhlw_v3_cross_version_rejection():
    mr = MultiResWindowedBank.empty(2, 3, CFG, levels=2)
    mr = mr.observe(*_chunk(60, 3, seed=0))
    blob = mr.to_bytes()
    with pytest.raises(ValueError, match="MultiResWindowedBank.from_bytes"):
        WindowedBank.from_bytes(blob)
    dense = WindowedBank.empty(4, 3, CFG).to_bytes()
    with pytest.raises(ValueError, match="unsupported window version"):
        MultiResWindowedBank.from_bytes(dense)
    with pytest.raises(ValueError, match="bad magic"):
        MultiResWindowedBank.from_bytes(b"XXXX" + blob[4:])


@pytest.mark.parametrize("frac", [0.2, 0.6, 0.95])
def test_rhlw_v3_rejects_truncation(frac):
    mr = MultiResWindowedBank.empty(2, 3, CFG, levels=2)
    for e in range(5):
        mr = mr.observe(*_chunk(80, 3, seed=e)).advance()
    blob = mr.to_bytes()
    with pytest.raises(ValueError):
        MultiResWindowedBank.from_bytes(blob[: int(len(blob) * frac)])


def test_rhlw_v3_rejects_corrupt_labels():
    mr = MultiResWindowedBank.empty(2, 3, CFG, levels=2)
    for e in range(6):
        mr = mr.observe(*_chunk(80, 3, seed=e)).advance()
    mr = mr.observe(*_chunk(40, 3, seed=9))
    # tamper the size field of the oldest bucket's label to a non-power-
    # of-two: the parser must refuse to resurrect a broken schedule
    import struct as _struct

    blob = bytearray(mr.to_bytes())
    header, base_sz = 28, 4
    bucket_sz = 12 + (20 + 3 * 8 + 3 * CFG.m)
    off = header + base_sz + (mr.slots - 1) * bucket_sz
    start, end, _size = _struct.unpack_from("<iiI", blob, off)
    _struct.pack_into("<iiI", blob, off, start, end, 3)
    with pytest.raises(ValueError, match="slot-merge schedule"):
        MultiResWindowedBank.from_bytes(bytes(blob))


# ----------------------------------------------------------------------------
# StreamSketch integration (window_levels)
# ----------------------------------------------------------------------------


def test_board_window_levels_reports_and_roundtrips():
    board = StreamSketch(cfg=CFG, window=2, window_levels=3)
    rng = np.random.default_rng(5)
    for _ in range(10):
        for name in ("api", "cdn"):
            board.observe(
                name, jnp.asarray(rng.integers(0, 2**31, 300, dtype=np.int32))
            )
        board.advance()
    assert isinstance(board._wbank, MultiResWindowedBank)
    assert board._wbank.horizon == 2 * (2**3 - 1)
    rep = board.report()
    assert set(rep) == {"api", "cdn"}
    assert all(v["estimate"] > 0 for v in rep.values())
    back = MultiResWindowedBank.from_bytes(board.window_bytes())
    np.testing.assert_array_equal(
        np.asarray(back.fold_window().registers),
        np.asarray(board._wbank.fold_window().registers),
    )


def test_board_window_levels_guards():
    with pytest.raises(ValueError, match="needs a windowed board"):
        StreamSketch(cfg=CFG, window_levels=2)
    with pytest.raises(ValueError, match="at least one level"):
        StreamSketch(cfg=CFG, window=4, window_levels=0)
    with pytest.raises(ValueError, match="cannot combine with track_topk"):
        StreamSketch(
            cfg=CFG,
            window=4,
            window_levels=2,
            track_topk=CMConfig(depth=2, width=64),
        )
