"""Int8 KV-cache: quantization roundtrip, decode fidelity, memory halving."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import transformer
from repro.serve import engine
from repro.serve.kvquant import dequantize_kv, quantize_kv


def test_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 2, (4, 16, 8, 64)), jnp.float32)
    q, s = quantize_kv(x)
    deq = dequantize_kv(q, s, jnp.float32)
    # symmetric int8 error <= scale/2, plus the bf16 rounding of the stored
    # scale (~0.4% relative on the reconstructed value)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    bound = np.asarray(s, np.float32) * 0.51 + np.abs(np.asarray(x)) * 0.01 + 1e-6
    assert (err <= bound).all()


def test_cache_memory_halves():
    arch = get_arch("qwen2-vl-72b")
    q_arch = dataclasses.replace(arch, kv_quant=True)
    full = jax.eval_shape(lambda: engine.init_cache(arch, 128, 32768))
    quant = jax.eval_shape(lambda: engine.init_cache(q_arch, 128, 32768))
    size = lambda t: sum(
        np.prod(l.shape) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(t)
    )
    ratio = size(quant) / size(full)
    assert 0.5 < ratio < 0.58, ratio  # int8 + 1/64-overhead scales


@pytest.mark.parametrize("arch_id", ["tinyllama-1.1b", "qwen2-vl-72b", "mixtral-8x7b"])
def test_quantized_decode_top1_agreement(arch_id):
    """int8 KV decode must agree with bf16 decode on nearly all argmax picks
    and stay within quantization-noise logit distance."""
    arch = get_arch(arch_id).reduced()
    q_arch = dataclasses.replace(arch, kv_quant=True)
    params = transformer.init_params(jax.random.PRNGKey(0), arch)
    B, S, T = 2, 32, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + T), 0, arch.vocab_size)

    def run(a):
        batch = {"tokens": toks[:, :S]}
        if a.mrope:
            batch["positions"] = transformer.default_positions(a, B, S)
        if a.frontend_stub_len:
            batch["frontend_embeds"] = (
                jax.random.normal(
                    jax.random.PRNGKey(2), (B, a.frontend_stub_len, a.d_model)
                ).astype(jnp.bfloat16) * 0.02
            )
        _, cache = engine.prefill(params, batch, a, kv_len=S + T)
        logits_seq = []
        for t in range(T):
            lg, cache = engine.decode_step(
                params, cache, toks[:, S + t], jnp.asarray(S + t), a
            )
            logits_seq.append(np.asarray(lg, np.float32))
        return np.stack(logits_seq)

    ref = run(arch)
    quant = run(q_arch)
    agree = (ref.argmax(-1) == quant.argmax(-1)).mean()
    assert agree >= 0.9, agree
    assert np.abs(ref - quant).max() < 2.5  # logit-scale quantization noise
