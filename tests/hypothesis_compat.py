"""Optional-hypothesis shim for the property-test modules.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  When it is
missing, the deterministic tests in a module must still collect and run, so
this module degrades gracefully: ``@given(...)`` turns the property test
into a skip, ``@settings(...)`` becomes a no-op, and ``st.<anything>(...)``
returns inert placeholders that are only ever passed to the stubbed
``given``.

When hypothesis IS present, importing this module registers the repo's
settings profiles (all with the deadline off — JAX dispatch latency is too
jittery for per-example deadlines — and derandomized, so CI failures
reproduce from the seed alone):

  ci       the PR-gate default: few examples, fast
  nightly  the ``schedule:`` CI runs: an order of magnitude more examples
  dev      local iteration: randomized for exploration

``HYPOTHESIS_PROFILE`` selects one (ci.yml sets it per trigger).
"""

import os

try:
    from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True

    _COMMON = dict(
        deadline=None,
        suppress_health_check=[
            HealthCheck.too_slow,
            HealthCheck.data_too_large,
        ],
    )
    settings.register_profile(
        "ci", max_examples=25, derandomize=True, **_COMMON
    )
    settings.register_profile(
        "nightly", max_examples=300, derandomize=True, **_COMMON
    )
    settings.register_profile("dev", max_examples=50, **_COMMON)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """st.integers(...)/st.lists(...)/... -> inert placeholder."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco
