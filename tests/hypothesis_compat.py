"""Optional-hypothesis shim for the property-test modules.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  When it is
missing, the deterministic tests in a module must still collect and run, so
this module degrades gracefully: ``@given(...)`` turns the property test
into a skip, ``@settings(...)`` becomes a no-op, and ``st.<anything>(...)``
returns inert placeholders that are only ever passed to the stubbed
``given``.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """st.integers(...)/st.lists(...)/... -> inert placeholder."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _StrategyStub()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco
