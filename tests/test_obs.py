"""Observability subsystem: metrics registry, trace hygiene, Chrome traces.

Pins the DESIGN.md §15 contracts:

* disabled is a TRUE no-op — update/estimate paths leave the registry
  empty and add zero backend dispatches;
* record sites inside jax-traced functions are skipped entirely (no
  tracer leaks, no double-booking when the compiled executable replays);
* ``to_json()`` round-trips the snapshot schema exactly;
* ``span``/``start_trace`` emit Perfetto-loadable Chrome trace events,
  with the dispatch seams visible under the outer spans.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import metrics, tracing
from repro.obs.format import (
    fmt_bytes,
    fmt_count,
    fmt_pct,
    fmt_rate,
    fmt_seconds,
    kv_line,
    metrics_report_line,
    truncated_note,
)
from repro.sketch import (
    ExecutionPlan,
    HLLConfig,
    SketchBank,
    estimate_many,
    register_bank_backend,
)
from repro.sketch import register_backend
from repro.sketch.backends import bank_update_jnp, update_pipelined
from repro.sketch.dispatch import update_registers
from repro.sketch.plan import get_bank_backend

CFG = HLLConfig(p=6, hash_bits=32)

_SPY = {"n": 0}


# delegates to the real jnp paths so backend-sweeping suites stay green
# (plan.validate needs the name on the single-sketch axis too)
@register_backend("obs_spy_jnp")
def _spy_backend(registers, items, cfg, plan):
    _SPY["n"] += 1
    return update_pipelined(registers, items, cfg, plan.pipelines)


@register_bank_backend("obs_spy_jnp")
def _spy_bank_backend(registers, keys, items, cfg, plan):
    _SPY["n"] += 1
    return bank_update_jnp(registers, keys, items, cfg)


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts and ends with metrics off/empty and no trace."""
    metrics.disable()
    metrics.reset()
    if tracing.active():
        tracing.stop_trace()
    yield
    metrics.disable()
    metrics.reset()
    if tracing.active():
        tracing.stop_trace()


def _ingest(bank, n=32, backend="jnp"):
    keys = jnp.arange(n, dtype=jnp.int32) % 4
    items = jnp.arange(n, dtype=jnp.int32)
    return bank.update_many(keys, items, plan=ExecutionPlan(backend=backend))


# ----------------------------------------------------------------------------
# disabled default: true no-op
# ----------------------------------------------------------------------------


def test_disabled_by_default_registry_stays_empty():
    assert not metrics.enabled()
    bank = _ingest(SketchBank.empty(4, CFG))
    np.asarray(estimate_many(bank.registers, CFG))
    snap = metrics.snapshot()
    assert snap["enabled"] is False
    assert snap["counters"] == {}
    assert snap["gauges"] == {}
    assert snap["histograms"] == {}


def test_disabled_adds_zero_backend_dispatches():
    """The seam wrapper forwards exactly one call per real dispatch."""
    bank = SketchBank.empty(4, CFG)
    _SPY["n"] = 0
    bank = _ingest(bank, backend="obs_spy_jnp")
    assert _SPY["n"] == 1  # wrapped, not doubled
    # empty streams short-circuit BEFORE the wrapper: no dispatch, and
    # nothing counted even with metrics on
    metrics.enable()
    _SPY["n"] = 0
    out = bank.update_many(
        jnp.zeros((0,), jnp.int32),
        jnp.zeros((0,), jnp.int32),
        plan=ExecutionPlan(backend="obs_spy_jnp"),
    )
    assert out is bank and _SPY["n"] == 0
    assert metrics.counter_value("dispatch.bank_update.obs_spy_jnp.calls") == 0
    # the single-sketch path counts its skips so the no-dispatch contract
    # stays observable
    regs = update_registers(
        jnp.zeros((CFG.m,), jnp.uint8),
        jnp.zeros((0,), jnp.int32),
        CFG,
        ExecutionPlan(backend="obs_spy_jnp"),
    )
    assert regs.shape == (CFG.m,) and _SPY["n"] == 0
    assert metrics.counter_value("dispatch.update.skipped_empty") == 1


def test_record_sites_noop_when_disabled():
    metrics.inc("x")
    metrics.gauge("g", 3.0)
    metrics.observe("h", 1.0)
    with metrics.timed("t"):
        pass
    assert metrics.snapshot()["counters"] == {}
    assert metrics.counter_value("x") == 0


# ----------------------------------------------------------------------------
# enabled: dispatch seams count and time
# ----------------------------------------------------------------------------


def test_enabled_counts_dispatches_per_axis_and_backend():
    metrics.enable()
    bank = _ingest(SketchBank.empty(4, CFG))
    np.asarray(estimate_many(bank.registers, CFG, estimator="original"))
    snap = metrics.snapshot()
    assert snap["counters"]["dispatch.bank_update.jnp.calls"] == 1
    assert snap["histograms"]["dispatch.bank_update.jnp.seconds"]["count"] == 1
    assert snap["counters"]["dispatch.estimate.original.calls"] == 1
    assert snap["histograms"]["bank.update_many.batch_items"]["count"] == 1
    assert snap["histograms"]["bank.update_many.batch_items"]["max"] == 32.0


def test_reset_clears_but_keeps_enabled():
    metrics.enable()
    metrics.inc("a")
    metrics.reset()
    snap = metrics.snapshot()
    assert snap["enabled"] is True and snap["counters"] == {}


# ----------------------------------------------------------------------------
# jit safety: no record site runs under an active jax trace
# ----------------------------------------------------------------------------


def test_record_sites_skipped_under_jit():
    metrics.enable()

    @jax.jit
    def f(x):
        metrics.inc("jit.counter")
        metrics.gauge("jit.gauge", 1.0)
        metrics.observe("jit.hist", 2.0)
        with metrics.timed("jit.timed"):
            y = x + 1
        return y

    np.asarray(f(jnp.arange(4)))  # traces + runs
    np.asarray(f(jnp.arange(4)))  # compiled: no python at all
    snap = metrics.snapshot()
    assert snap["counters"] == {} and snap["gauges"] == {}
    assert snap["histograms"] == {}


def test_wrapped_backend_seam_skipped_under_jit():
    """Tracing a jitted caller must not book a dispatch the executable
    replays without running Python again."""
    metrics.enable()
    wrapped = get_bank_backend("jnp")
    plan = ExecutionPlan(backend="jnp")
    regs = SketchBank.empty(4, CFG).registers
    keys = jnp.arange(8, dtype=jnp.int32) % 4
    items = jnp.arange(8, dtype=jnp.int32)

    @jax.jit
    def g(r, k, x):
        return wrapped(r, k, x, CFG, plan)

    inside = np.asarray(g(regs, keys, items))
    np.asarray(g(regs, keys, items))
    assert metrics.counter_value("dispatch.bank_update.jnp.calls") == 0
    # ...while the same wrapped fn called eagerly records exactly once
    outside = np.asarray(wrapped(regs, keys, items, CFG, plan))
    assert metrics.counter_value("dispatch.bank_update.jnp.calls") == 1
    np.testing.assert_array_equal(inside, outside)


def test_span_under_jit_emits_no_event():
    tracing.start_trace()

    @jax.jit
    def f(x):
        with tracing.span("traced.body"):
            return x * 2

    np.asarray(f(jnp.arange(3)))
    events = tracing.stop_trace()
    assert all(e["name"] != "traced.body" for e in events)


# ----------------------------------------------------------------------------
# snapshot schema / to_json round-trip
# ----------------------------------------------------------------------------


def test_to_json_roundtrips_snapshot():
    metrics.enable()
    metrics.inc("c", 3)
    metrics.gauge("g", 2.5)
    for v in (0.001, 0.01, 0.1):
        metrics.observe("h", v)
    snap = metrics.snapshot()
    assert json.loads(metrics.to_json()) == snap
    assert set(snap) == {"enabled", "counters", "gauges", "histograms"}
    hist = snap["histograms"]["h"]
    assert set(hist) == {"count", "sum", "mean", "min", "max", "p50", "p90", "p99"}
    assert hist["count"] == 3
    assert hist["min"] == pytest.approx(0.001)
    assert hist["max"] == pytest.approx(0.1)


def test_histogram_percentiles_sane():
    metrics.enable()
    for v in range(1, 1001):
        metrics.observe("lat", float(v))
    h = metrics.snapshot()["histograms"]["lat"]
    assert h["count"] == 1000
    assert h["mean"] == pytest.approx(500.5)
    # log-binned at 4 bins/decade: estimates land within one bin (~1.78x)
    assert 500 / 1.78 <= h["p50"] <= 500 * 1.78
    assert 900 / 1.78 <= h["p90"] <= 1000.0
    assert h["p99"] <= h["max"] <= 1000.0
    assert h["min"] == 1.0


# ----------------------------------------------------------------------------
# tracing: spans, nesting, Chrome-trace shape, seam events
# ----------------------------------------------------------------------------


def test_span_times_and_chrome_trace_shape():
    tracing.start_trace()
    with tracing.span("outer", phase="test") as outer:
        with tracing.span("inner") as inner:
            sum(range(1000))
    tracing.stop_trace()
    assert 0 < inner.elapsed_s <= outer.elapsed_s
    doc = tracing.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    events = {e["name"]: e for e in doc["traceEvents"]}
    assert set(events) == {"outer", "inner"}
    for e in events.values():
        assert e["ph"] == "X" and e["dur"] >= 0 and "pid" in e and "tid" in e
    # nesting is reconstructed from containment: inner ⊆ outer
    o, i = events["outer"], events["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3
    assert o["args"] == {"phase": "test"}
    json.dumps(doc)  # Perfetto-loadable


def test_span_metric_feeds_histogram():
    metrics.enable()
    with tracing.span("req", metric="req.seconds"):
        pass
    assert metrics.snapshot()["histograms"]["req.seconds"]["count"] == 1


def test_dispatch_seams_emit_trace_events():
    tracing.start_trace()
    _ingest(SketchBank.empty(4, CFG))
    tracing.stop_trace()
    names = {e["name"] for e in tracing.chrome_trace()["traceEvents"]}
    assert "bank_update[jnp]" in names
    # ...and nothing is recorded in the metrics registry by a pure trace
    assert metrics.snapshot()["counters"] == {}


def test_write_trace_and_buffer_lifecycle(tmp_path):
    tracing.start_trace()
    with tracing.span("once"):
        pass
    tracing.stop_trace()
    path = tracing.write_trace(str(tmp_path / "t.json"))
    with open(path) as f:
        assert len(json.load(f)["traceEvents"]) == 1
    with tracing.span("after_stop"):  # capture over: not buffered
        pass
    assert len(tracing.chrome_trace()["traceEvents"]) == 1
    tracing.start_trace()  # restarting clears the old buffer
    assert tracing.chrome_trace()["traceEvents"] == []
    tracing.stop_trace()


def test_stopwatch_semantics():
    w = tracing.Stopwatch()
    assert not w.running
    with pytest.raises(AssertionError):
        w.elapsed()
    w.start()
    assert w.running and w.elapsed() >= 0
    dt = w.stop()
    assert dt >= 0 and not w.running


# ----------------------------------------------------------------------------
# formatting helpers (serve report lines)
# ----------------------------------------------------------------------------


def test_format_helpers():
    assert fmt_count(1234567) == "1,234,567"
    assert fmt_pct(0.6667) == "66.7%"
    assert fmt_seconds(0.0000012) == "1µs"
    assert fmt_seconds(0.0034) == "3.4ms"
    assert fmt_seconds(2.5) == "2.50s"
    assert fmt_rate(1.25e6, "tok") == "1,250,000 tok/s"
    assert fmt_bytes(3 * 1024**2) == "3.0MiB"
    assert kv_line("board", [("rows", 4), ("hit", "66.7%")]) == (
        "  board: rows=4 hit=66.7%"
    )
    note = truncated_note(3, 8, "requests")
    assert "+5 more requests" in note and "8 total" in note


def test_metrics_report_line_reads_snapshot():
    metrics.enable()
    _ingest(SketchBank.empty(4, CFG))
    metrics.observe("serve.request.seconds", 0.002)
    metrics.inc("window.fold_cache.hits", 2)
    metrics.inc("window.fold_cache.misses", 1)
    line = metrics_report_line(metrics.snapshot())
    assert line.startswith("[metrics]")
    assert "p50=" in line and "dispatches=" in line and "hit=66.7%" in line
