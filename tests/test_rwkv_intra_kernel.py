"""rwkv_intra Pallas kernel vs jnp oracle vs the chunked model path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.kernels.rwkv_intra import rwkv_intra, rwkv_intra_ref
from repro.models import rwkv6


def _inputs(g, c, n, seed=0, decay_scale=1.0):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(0, 1, (g, c, n)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (g, c, n)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (g, c, n)), jnp.float32)
    # log-decays: negative, cumulative (decreasing) like the model produces
    lw = -jnp.asarray(rng.uniform(0.01, decay_scale, (g, c, n)), jnp.float32)
    lcum = jnp.cumsum(lw, axis=1)
    lex = lcum - lw
    u = jnp.asarray(rng.normal(0, 0.3, (g, n)), jnp.float32)
    return r, k, v, lex, lcum, u


@pytest.mark.parametrize("g,c,n", [(1, 8, 16), (4, 32, 64), (2, 64, 64)])
def test_kernel_matches_oracle(g, c, n):
    args = _inputs(g, c, n, seed=g * c)
    got = rwkv_intra(*args, interpret=True)
    want = rwkv_intra_ref(*args)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4
    )


def test_kernel_strong_decay_stable():
    args = _inputs(2, 32, 32, seed=7, decay_scale=50.0)  # extreme decay
    got = np.asarray(rwkv_intra(*args, interpret=True))
    want = np.asarray(rwkv_intra_ref(*args))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_kernel_matches_model_intra_term():
    """Kernel output == (chunked model output) - (inter-chunk part)."""
    arch = get_arch("rwkv6-3b").reduced()
    params = rwkv6.init_params(jax.random.PRNGKey(0), arch)
    b, s = 2, 64
    c = 32
    h, n = arch.n_heads, arch.rwkv_head_dim
    x = (jax.random.normal(jax.random.PRNGKey(1), (b, s, arch.d_model)) * 0.5
         ).astype(jnp.float32)

    r, k, v, g_, log_w = rwkv6._projections(params, x, arch)
    u = params["u"].astype(jnp.float32).reshape(h, n)
    nc = s // c
    chunked = lambda t: t.astype(jnp.float32).reshape(b, nc, c, h, n)
    rc, kc, vc, lwc = chunked(r), chunked(k), chunked(v), chunked(log_w)
    L = jnp.cumsum(lwc, axis=2)
    Lex = L - lwc

    # flatten (b, nc, h) into the kernel grid
    def to_grid(t):  # (b, nc, c, h, n) -> (b*nc*h, c, n)
        return jnp.moveaxis(t, 3, 2).reshape(b * nc * h, c, n)

    ug = jnp.broadcast_to(u[None, None], (b, nc, h, n)).reshape(b * nc * h, n)
    y_kernel = rwkv_intra(
        to_grid(rc), to_grid(kc), to_grid(vc), to_grid(Lex), to_grid(L), ug,
        interpret=True,
    )
    y_ref = rwkv_intra_ref(
        to_grid(rc), to_grid(kc), to_grid(vc), to_grid(Lex), to_grid(L), ug
    )
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_ref), rtol=1e-5, atol=1e-4
    )
