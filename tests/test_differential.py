"""Differential harness: every carrier vs the dict-of-sets oracle.

Three properties, checked over op sequences (update_many / merge / advance
/ serialize->deserialize / estimate) drawn from the shared grammar in
tests/reference_model.py:

1. **Backend bit-identity** — for dense, sparse, and mixed banks alike,
   running the SAME op sequence under every registered bank backend must
   leave BIT-IDENTICAL canonical state (registers, exact counters, and
   for hybrid carriers the per-row mode flags) as the jnp reference plan.
2. **Oracle bands** — every registered estimator's reading of every row
   stays within the 3-sigma band of the oracle's true distinct count
   (plus small-count slack; see reference_model.assert_within_band).
3. **Representation equivalence** — the hybrid carriers materialize to
   exactly the dense carriers' registers at every estimate point, so the
   sparse layout can never drift from the storage it compresses.

The fixed-seed sweeps below always run; with hypothesis installed the
same grammar also runs under generated op sequences (profile-controlled
example counts — see tests/hypothesis_compat.py).
"""

import numpy as np
import pytest

from repro.sketch import (
    CMConfig,
    HLLConfig,
    available_bank_backends,
    available_cm_backends,
    available_cm_window_backends,
    available_estimators,
    available_window_backends,
)
from tests.hypothesis_compat import HAVE_HYPOTHESIS, given, st
from tests.reference_model import (
    CounterReferenceModel,
    CountMinSUT,
    DenseBankSUT,
    DenseWindowSUT,
    HybridBankSUT,
    HybridWindowSUT,
    ReferenceModel,
    WindowedCountMinSUT,
    assert_cm_bounds,
    assert_within_band,
    gen_ops,
    gen_stream,
    make_plans,
    run_ops,
)

CFG = HLLConfig(p=8, hash_bits=64)  # m=256: small enough for pallas paths
ROWS = 23

# bank kind -> (SUT class, promotion threshold): "sparse" stays almost
# entirely in the COO layout, "mixed" promotes hot rows almost immediately
BANK_KINDS = {
    "dense": (DenseBankSUT, None),
    "sparse": (HybridBankSUT, CFG.m // 2),
    "mixed": (HybridBankSUT, 8),
}


def _estimate_checker(collected):
    def check(sut, oracle):
        true = oracle.true_cardinalities()
        for estimator in available_estimators():
            assert_within_band(sut.estimates(estimator), true, CFG.m)
        np.testing.assert_array_equal(sut.counts(), oracle.observed())
        collected.append(sut.canonical())

    return check


def _run_differential(kind, seed, windowed=False, window=4):
    sut_cls, threshold = BANK_KINDS[kind]
    if windowed:
        sut_cls = HybridWindowSUT if kind != "dense" else DenseWindowSUT
    backends = (
        available_window_backends() if windowed else available_bank_backends()
    )
    plans = make_plans(backends)
    states = {}
    for name, plan in plans.items():
        rng = np.random.default_rng(seed)  # same ops for every backend
        ops = gen_ops(rng, ROWS, n_ops=10, windowed=windowed)
        oracle = ReferenceModel(ROWS, window=window if windowed else None)
        if windowed:
            sut = sut_cls(window, ROWS, CFG, plan=plan, threshold=threshold)
        else:
            sut = sut_cls(ROWS, CFG, plan=plan, threshold=threshold)
        collected = []
        run_ops(ops, sut, oracle, on_estimate=_estimate_checker(collected))
        states[name] = collected
    ref = states["jnp"]
    for name, collected in states.items():
        assert len(collected) == len(ref)
        for step, (got, want) in enumerate(zip(collected, ref)):
            for g, w in zip(got, want):
                np.testing.assert_array_equal(
                    g, w, err_msg=f"backend {name} diverged at estimate {step}"
                )


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("kind", sorted(BANK_KINDS))
def test_flat_banks_match_oracle_and_backends(kind, seed):
    _run_differential(kind, seed, windowed=False)


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("kind", sorted(BANK_KINDS))
def test_windowed_banks_match_oracle_and_backends(kind, seed):
    _run_differential(kind, seed, windowed=True)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_hybrid_state_tracks_dense_state_bit_for_bit(seed):
    """Same ops -> hybrid materializes to the dense bank exactly."""
    rng = np.random.default_rng(100 + seed)
    ops = gen_ops(rng, ROWS, n_ops=8, windowed=False)
    oracle_a = ReferenceModel(ROWS)
    oracle_b = ReferenceModel(ROWS)
    dense = run_ops(ops, DenseBankSUT(ROWS, CFG), oracle_a)
    hybrid = run_ops(ops, HybridBankSUT(ROWS, CFG, threshold=8), oracle_b)
    np.testing.assert_array_equal(
        np.asarray(hybrid.bank.to_dense().registers),
        np.asarray(dense.bank.registers),
    )
    np.testing.assert_array_equal(hybrid.bank.counts, dense.bank.counts)
    # and the device estimates agree bit-for-bit as well (DESIGN.md §12)
    for estimator in available_estimators():
        np.testing.assert_array_equal(
            hybrid.estimates(estimator), dense.estimates(estimator)
        )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_deferred_dedup_tracks_eager_compaction_bit_for_bit(seed):
    """Same ops -> the deferred append-buffer path settles to EXACTLY the
    state the pre-change eager-dedup-per-update path produced.

    EagerHybridBankSUT compacts after every update/merge (the old
    behavior); the plain SUT lets the buffer ride until an estimate (or
    an explicit peek op) forces settlement.  Canonical state — registers,
    counters, mode flags — must be bit-identical at every estimate point,
    for every registered bank backend (the deferred-dedup regression
    anchor, DESIGN.md §12)."""
    from tests.reference_model import EagerHybridBankSUT

    plans = make_plans(available_bank_backends())
    for name, plan in plans.items():
        rng_a = np.random.default_rng(200 + seed)
        rng_b = np.random.default_rng(200 + seed)
        ops_a = gen_ops(rng_a, ROWS, n_ops=10, windowed=False)
        ops_b = gen_ops(rng_b, ROWS, n_ops=10, windowed=False)
        deferred_states, eager_states = [], []
        run_ops(
            ops_a,
            HybridBankSUT(ROWS, CFG, plan=plan, threshold=8),
            ReferenceModel(ROWS),
            on_estimate=lambda s, o: deferred_states.append(s.canonical()),
        )
        run_ops(
            ops_b,
            EagerHybridBankSUT(ROWS, CFG, plan=plan, threshold=8),
            ReferenceModel(ROWS),
            on_estimate=lambda s, o: eager_states.append(s.canonical()),
        )
        assert len(deferred_states) == len(eager_states) > 0
        for step, (got, want) in enumerate(zip(deferred_states, eager_states)):
            for g, w in zip(got, want):
                np.testing.assert_array_equal(
                    g,
                    w,
                    err_msg=(
                        f"backend {name}: deferred dedup diverged from "
                        f"eager compaction at estimate {step}"
                    ),
                )


def test_windowed_expiry_tracks_oracle_exactly():
    """Advancing past W expires oracle and carriers in lockstep."""
    window = 3
    for sut_cls, threshold in (
        (DenseWindowSUT, None),
        (HybridWindowSUT, 8),
    ):
        oracle = ReferenceModel(ROWS, window=window)
        sut = sut_cls(window, ROWS, CFG, threshold=threshold)
        rng = np.random.default_rng(9)
        for epoch in range(2 * window):
            keys, items = gen_stream(rng, ROWS, 300)
            sut.update(keys, items)
            oracle.update(keys, items)
            np.testing.assert_array_equal(sut.counts(), oracle.observed())
            assert_within_band(
                sut.estimates(), oracle.true_cardinalities(), CFG.m
            )
            sut.advance(1)
            oracle.advance(1)
        # everything beyond the window is gone on both sides
        sut.advance(window)
        oracle.advance(window)
        assert oracle.true_cardinalities().sum() == 0
        assert sut.counts().sum() == 0
        assert np.asarray(sut.estimates()).sum() == 0


# ----------------------------------------------------------------------------
# count-min family vs the dict-of-Counters oracle (DESIGN.md §13)
# ----------------------------------------------------------------------------

CM_CFG = CMConfig(depth=4, width=128, seed=11)
CM_PROBE = np.arange(50, dtype=np.int32)


def _cm_checker(collected):
    def check(sut, oracle):
        est = sut.query(CM_PROBE)
        assert_cm_bounds(
            est,
            oracle.true_counts(CM_PROBE),
            oracle.observed(),
            CM_CFG.width,
            CM_CFG.depth,
        )
        np.testing.assert_array_equal(sut.counts(), oracle.observed())
        collected.append(sut.canonical())

    return check


def _run_cm_differential(seed, windowed=False, window=4):
    """The count-min twin of _run_differential: same shared op grammar
    (update / merge-or-advance / roundtrip / estimate), every registered
    cm backend held bit-identical to jnp on the full canonical state
    (counters AND Topkapi labels AND exact counters), every estimate
    point held to the exact-oracle sandwich bounds."""
    backends = (
        available_cm_window_backends() if windowed else available_cm_backends()
    )
    plans = make_plans(backends)
    states = {}
    for name, plan in plans.items():
        rng = np.random.default_rng(seed)  # same ops for every backend
        ops = gen_ops(rng, ROWS, n_ops=8, windowed=windowed)
        oracle = CounterReferenceModel(
            ROWS, window=window if windowed else None
        )
        if windowed:
            sut = WindowedCountMinSUT(window, ROWS, CM_CFG, plan=plan)
        else:
            sut = CountMinSUT(ROWS, CM_CFG, plan=plan)
        collected = []
        run_ops(ops, sut, oracle, on_estimate=_cm_checker(collected))
        states[name] = collected
    ref = states["jnp"]
    for name, collected in states.items():
        assert len(collected) == len(ref)
        for step, (got, want) in enumerate(zip(collected, ref)):
            for g, w in zip(got, want):
                np.testing.assert_array_equal(
                    g, w, err_msg=f"cm backend {name} diverged at step {step}"
                )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_flat_countmin_matches_oracle_and_backends(seed):
    _run_cm_differential(seed, windowed=False)


@pytest.mark.parametrize("seed", [0, 1])
def test_windowed_countmin_matches_oracle_and_backends(seed):
    _run_cm_differential(seed, windowed=True)


def test_windowed_countmin_expiry_tracks_oracle_exactly():
    """Advancing past W expires the oracle and the ring in lockstep."""
    window = 3
    oracle = CounterReferenceModel(ROWS, window=window)
    sut = WindowedCountMinSUT(window, ROWS, CM_CFG)
    rng = np.random.default_rng(9)
    for epoch in range(2 * window):
        keys, items = gen_stream(rng, ROWS, 300, value_space=50)
        sut.update(keys, items)
        oracle.update(keys, items)
        np.testing.assert_array_equal(sut.counts(), oracle.observed())
        assert_cm_bounds(
            sut.query(CM_PROBE),
            oracle.true_counts(CM_PROBE),
            oracle.observed(),
            CM_CFG.width,
            CM_CFG.depth,
        )
        sut.advance(1)
        oracle.advance(1)
    # everything beyond the window is gone on both sides
    sut.advance(window)
    oracle.advance(window)
    assert oracle.observed().sum() == 0
    assert sut.counts().sum() == 0
    assert sut.query(CM_PROBE).sum() == 0


def test_topk_recall_on_zipf_traffic():
    """topk(k) recovers >= 0.9 of the true top-10 under Zipf(1.1) streams
    (the acceptance bar: heavy ids must survive Topkapi label voting and
    count-min ranking at production-ish d=4, w=1024)."""
    rows = 3
    cfg = CMConfig(depth=4, width=1024, seed=7)
    rng = np.random.default_rng(42)
    n = 50_000
    items = np.minimum(rng.zipf(1.1, size=n), 1 << 20).astype(np.int32)
    keys = rng.integers(0, rows, n).astype(np.int32)
    oracle = CounterReferenceModel(rows)
    sut = CountMinSUT(rows, cfg)
    sut.update(keys, items)
    oracle.update(keys, items)
    got_vals, got_counts = sut.topk(10)
    truth = oracle.top_k(10)
    recalls = []
    for r in range(rows):
        true_set = set(truth[r])
        got = set(int(v) for v in got_vals[r])
        recalls.append(len(got & true_set) / max(1, len(true_set)))
    assert float(np.mean(recalls)) >= 0.9, recalls
    # the reported counts are count-min estimates: upper bounds on truth
    live = oracle.live_counters()
    for r in range(rows):
        for v, c in zip(got_vals[r], got_counts[r]):
            if c > 0:
                assert int(c) >= live[r][int(v)]


# ----------------------------------------------------------------------------
# hypothesis-generated op sequences (skipped when hypothesis is absent)
# ----------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    op_seeds = st.lists(
        st.integers(min_value=0, max_value=2**31 - 1), min_size=1, max_size=6
    )
else:  # pragma: no cover - placeholder consumed by the stubbed @given
    op_seeds = None


# no @settings here: the example budget comes from the loaded profile
# (ci/nightly/dev — tests/hypothesis_compat.py), so the nightly schedule
# actually deepens this sweep
@given(seeds=op_seeds, windowed=st.booleans())
def test_hypothesis_ops_hybrid_matches_dense_and_oracle(seeds, windowed):
    """Generated sequences: hybrid == dense bit-for-bit, both in-band."""
    window = 3
    rng = np.random.default_rng(seeds[0])
    ops = []
    for s in seeds:
        op_rng = np.random.default_rng(s)
        ops.extend(gen_ops(op_rng, ROWS, n_ops=3, windowed=windowed))
    if windowed:
        dense = DenseWindowSUT(window, ROWS, CFG)
        hybrid = HybridWindowSUT(window, ROWS, CFG, threshold=8)
    else:
        dense = DenseBankSUT(ROWS, CFG)
        hybrid = HybridBankSUT(ROWS, CFG, threshold=8)
    oracle_a = ReferenceModel(ROWS, window=window if windowed else None)
    oracle_b = ReferenceModel(ROWS, window=window if windowed else None)
    run_ops(ops, dense, oracle_a)
    run_ops(ops, hybrid, oracle_b)
    np.testing.assert_array_equal(dense.counts(), oracle_a.observed())
    np.testing.assert_array_equal(hybrid.counts(), oracle_a.observed())
    d = dense.canonical()
    h = hybrid.canonical()
    np.testing.assert_array_equal(h[0], d[0])  # materialized registers
    np.testing.assert_array_equal(h[1], d[1])  # exact counters
    true = oracle_a.true_cardinalities()
    assert_within_band(dense.estimates(), true, CFG.m)
    assert_within_band(hybrid.estimates(), true, CFG.m)
