"""StreamSketch telemetry + MoE router-collapse detection."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.sketch import ExecutionPlan, HLLConfig, HyperLogLog
from repro.models import moe as moe_lib
from repro.telemetry.sketchboard import StreamSketch


def test_named_streams_and_report():
    board = StreamSketch(HLLConfig(p=10, hash_bits=64))
    rng = np.random.default_rng(0)
    board.observe("tokens", jnp.asarray(rng.integers(0, 5000, 20_000, np.int32)))
    board.observe("users", jnp.asarray(rng.integers(0, 37, 20_000, np.int32)))
    rep = board.report()
    assert set(rep) == {"tokens", "users"}
    assert abs(rep["users"]["estimate"] - 37) < 5
    assert rep["tokens"]["items_seen"] == 20_000
    assert rep["tokens"]["duplication"] > 2  # 20k draws over <=5k values


def test_merge_from_other_board():
    cfg = HLLConfig(p=10, hash_bits=64)
    a, b = StreamSketch(cfg), StreamSketch(cfg)
    a.observe("s", jnp.arange(0, 1000, dtype=jnp.int32))
    b.observe("s", jnp.arange(500, 1500, dtype=jnp.int32))
    a.merge_from(b)
    est = a.estimate("s")
    assert abs(est - 1500) / 1500 < 0.15


def test_board_serialize_roundtrip_including_empty():
    cfg = HLLConfig(p=10, hash_bits=64)
    board = StreamSketch(cfg)
    restored = StreamSketch.deserialize(board.serialize(), cfg=cfg)
    assert restored.cfg == cfg and not restored.sketches
    board.observe("s", jnp.arange(1000, dtype=jnp.int32))
    back = StreamSketch.deserialize(board.serialize())
    assert back.estimate("s") == board.estimate("s")
    assert back.report()["s"]["items_seen"] == 1000


def test_report_batched_matches_exact():
    """Default report() finalizes via one estimate_many dispatch; the
    float32 batched readings must track the exact host finalizer."""
    board = StreamSketch(HLLConfig(p=10, hash_bits=64))
    rng = np.random.default_rng(7)
    for i, n in enumerate((50, 4_000, 60_000)):
        board.observe(f"s{i}", jnp.asarray(rng.integers(0, n, 20_000, np.int32)))
    batched = board.report()
    exact = board.report(exact=True)
    assert set(batched) == set(exact)
    for name in batched:
        b, e = batched[name]["estimate"], exact[name]["estimate"]
        assert abs(b - e) / max(e, 1.0) < 1e-4
        assert batched[name]["items_seen"] == exact[name]["items_seen"]


def test_report_estimator_from_plan_and_override():
    cfg = HLLConfig(p=10, hash_bits=64)
    board = StreamSketch(cfg, plan=ExecutionPlan(estimator="ertl_improved"))
    board.observe("s", jnp.arange(30_000, dtype=jnp.int32))
    # plan's estimator is the default for report() and estimate()
    want = board.stream("s").estimate("ertl_improved")
    assert board.estimate("s") == want
    assert abs(board.report()["s"]["estimate"] - want) / want < 1e-4
    # per-call override wins over the plan
    mle = board.stream("s").estimate("ertl_mle")
    assert board.estimate("s", estimator="ertl_mle") == mle


def test_deserialize_cfg_mismatch_raises():
    cfg = HLLConfig(p=10, hash_bits=64)
    board = StreamSketch(cfg)
    board.observe("s", jnp.arange(100, dtype=jnp.int32))
    blobs = board.serialize()
    with pytest.raises(ValueError, match="cfg mismatch"):
        StreamSketch.deserialize(blobs, cfg=HLLConfig(p=12, hash_bits=64))
    # matching cfg (or no cfg) still round-trips
    assert StreamSketch.deserialize(blobs, cfg=cfg).estimate("s") == \
        board.estimate("s")
    assert StreamSketch.deserialize(blobs).estimate("s") == board.estimate("s")


def test_merge_from_cfg_mismatch_raises():
    a = StreamSketch(HLLConfig(p=10, hash_bits=64))
    b = StreamSketch(HLLConfig(p=12, hash_bits=64))
    b.observe("s", jnp.arange(10, dtype=jnp.int32))
    with pytest.raises(ValueError, match="different configs"):
        a.merge_from(b)


def test_buffered_ingest_matches_unbuffered_per_stream_updates():
    """observe() buffers; flush() lands everything with one update_many —
    bit-identical registers and exact counters vs direct per-stream updates."""
    cfg = HLLConfig(p=10, hash_bits=64)
    board = StreamSketch(cfg)
    rng = np.random.default_rng(3)
    chunks = {
        "a": [rng.integers(0, 10_000, 5_000, np.int32) for _ in range(3)],
        "b": [rng.integers(0, 300, 2_000, np.int32) for _ in range(2)],
        "c": [rng.integers(0, 2**31, 4_099, np.int32)],
    }
    for name, arrays in chunks.items():
        for a in arrays:
            board.observe(name, jnp.asarray(a))
    # nothing aggregated yet: the buffer holds every item
    assert board._pending_items == sum(
        a.size for arrays in chunks.values() for a in arrays
    )
    board.flush()
    assert board._pending_items == 0
    for name, arrays in chunks.items():
        direct = HyperLogLog.empty(cfg)
        for a in arrays:
            direct = direct.update(jnp.asarray(a))
        got = board.stream(name)
        np.testing.assert_array_equal(
            np.asarray(got.registers), np.asarray(direct.registers)
        )
        assert got.count == direct.count


def test_auto_flush_threshold_and_read_paths_flush():
    cfg = HLLConfig(p=10, hash_bits=64)
    board = StreamSketch(cfg, flush_items=100)
    board.observe("s", jnp.arange(200, dtype=jnp.int32))  # crosses threshold
    assert board._pending_items == 0  # auto-flushed on observe
    board.observe("s", jnp.arange(200, 230, dtype=jnp.int32))
    assert board._pending_items == 30
    # every read path drains the buffer first
    rep = board.report()
    assert board._pending_items == 0
    assert rep["s"]["items_seen"] == 230
    board.observe("s", jnp.arange(230, 250, dtype=jnp.int32))
    assert board.stream("s").count == 250
    board.observe("t", jnp.arange(5, dtype=jnp.int32))
    blobs = board.serialize()
    assert board._pending_items == 0
    assert StreamSketch.deserialize(blobs).report()["t"]["items_seen"] == 5


def test_plugin_backend_without_bank_path_still_ingests():
    """A backend registered only via register_backend (no bank entry) must
    keep working on a board: flush() falls back to per-stream updates."""
    from repro.sketch import get_backend, register_backend

    name = "tlm_single_only"
    try:
        get_backend(name)
    except ValueError:
        register_backend(name)(
            lambda regs, items, cfg, plan: get_backend("jnp")(
                regs, items, cfg, plan
            )
        )
    cfg = HLLConfig(p=10, hash_bits=64)
    board = StreamSketch(cfg, plan=ExecutionPlan(backend=name))
    board.observe("s", jnp.arange(5000, dtype=jnp.int32))
    board.observe("t", jnp.arange(100, dtype=jnp.int32))
    rep = board.report()
    assert rep["s"]["items_seen"] == 5000
    ref = StreamSketch(cfg)
    ref.observe("s", jnp.arange(5000, dtype=jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(board.stream("s").registers),
        np.asarray(ref.stream("s").registers),
    )


def test_merge_from_flushes_both_boards():
    cfg = HLLConfig(p=10, hash_bits=64)
    a, b = StreamSketch(cfg), StreamSketch(cfg)
    a.observe("s", jnp.arange(0, 1000, dtype=jnp.int32))
    b.observe("s", jnp.arange(500, 1500, dtype=jnp.int32))
    a.merge_from(b)  # both sides still buffered at this point
    assert a.stream("s").count == 2000
    est = a.estimate("s")
    assert abs(est - 1500) / 1500 < 0.15


def test_moe_assignment_stream_detects_collapse():
    """Distinct (token,expert) pairs drop when the router collapses."""
    cfg = HLLConfig(p=12, hash_bits=64)
    arch = get_arch("olmoe-1b-7b").reduced()
    rng = np.random.default_rng(1)
    B, S, k = 4, 64, arch.moe.top_k
    tokens = jnp.asarray(rng.integers(0, 400, (B, S), np.int32))

    healthy = jnp.asarray(
        rng.integers(0, arch.moe.num_experts, (B, S, k), np.int32)
    )
    collapsed = jnp.zeros((B, S, k), jnp.int32)  # everything -> expert 0

    board = StreamSketch(cfg)
    board.observe("healthy", moe_lib.assignment_stream(tokens, healthy))
    board.observe("collapsed", moe_lib.assignment_stream(tokens, collapsed))
    rep = board.report()
    assert rep["healthy"]["estimate"] > 1.5 * rep["collapsed"]["estimate"]


def test_assignment_stream_packing():
    tokens = jnp.asarray([[1, 2]], jnp.int32)
    experts = jnp.asarray([[[3, 4], [5, 6]]], jnp.int32)
    pairs = np.asarray(moe_lib.assignment_stream(tokens, experts))
    np.testing.assert_array_equal(
        pairs, [(1 << 8) | 3, (1 << 8) | 4, (2 << 8) | 5, (2 << 8) | 6]
    )
