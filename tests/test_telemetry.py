"""StreamSketch telemetry + MoE router-collapse detection."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.sketch import HLLConfig
from repro.models import moe as moe_lib
from repro.telemetry.sketchboard import StreamSketch


def test_named_streams_and_report():
    board = StreamSketch(HLLConfig(p=10, hash_bits=64))
    rng = np.random.default_rng(0)
    board.observe("tokens", jnp.asarray(rng.integers(0, 5000, 20_000, np.int32)))
    board.observe("users", jnp.asarray(rng.integers(0, 37, 20_000, np.int32)))
    rep = board.report()
    assert set(rep) == {"tokens", "users"}
    assert abs(rep["users"]["estimate"] - 37) < 5
    assert rep["tokens"]["items_seen"] == 20_000
    assert rep["tokens"]["duplication"] > 2  # 20k draws over <=5k values


def test_merge_from_other_board():
    cfg = HLLConfig(p=10, hash_bits=64)
    a, b = StreamSketch(cfg), StreamSketch(cfg)
    a.observe("s", jnp.arange(0, 1000, dtype=jnp.int32))
    b.observe("s", jnp.arange(500, 1500, dtype=jnp.int32))
    a.merge_from(b)
    est = a.estimate("s")
    assert abs(est - 1500) / 1500 < 0.15


def test_board_serialize_roundtrip_including_empty():
    cfg = HLLConfig(p=10, hash_bits=64)
    board = StreamSketch(cfg)
    restored = StreamSketch.deserialize(board.serialize(), cfg=cfg)
    assert restored.cfg == cfg and not restored.sketches
    board.observe("s", jnp.arange(1000, dtype=jnp.int32))
    back = StreamSketch.deserialize(board.serialize())
    assert back.estimate("s") == board.estimate("s")
    assert back.report()["s"]["items_seen"] == 1000


def test_moe_assignment_stream_detects_collapse():
    """Distinct (token,expert) pairs drop when the router collapses."""
    cfg = HLLConfig(p=12, hash_bits=64)
    arch = get_arch("olmoe-1b-7b").reduced()
    rng = np.random.default_rng(1)
    B, S, k = 4, 64, arch.moe.top_k
    tokens = jnp.asarray(rng.integers(0, 400, (B, S), np.int32))

    healthy = jnp.asarray(
        rng.integers(0, arch.moe.num_experts, (B, S, k), np.int32)
    )
    collapsed = jnp.zeros((B, S, k), jnp.int32)  # everything -> expert 0

    board = StreamSketch(cfg)
    board.observe("healthy", moe_lib.assignment_stream(tokens, healthy))
    board.observe("collapsed", moe_lib.assignment_stream(tokens, collapsed))
    rep = board.report()
    assert rep["healthy"]["estimate"] > 1.5 * rep["collapsed"]["estimate"]


def test_assignment_stream_packing():
    tokens = jnp.asarray([[1, 2]], jnp.int32)
    experts = jnp.asarray([[[3, 4], [5, 6]]], jnp.int32)
    pairs = np.asarray(moe_lib.assignment_stream(tokens, experts))
    np.testing.assert_array_equal(
        pairs, [(1 << 8) | 3, (1 << 8) | 4, (2 << 8) | 5, (2 << 8) | 6]
    )
