"""Training substrate: loss decreases, optimizer, compression, data pipeline."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.sketch import HLLConfig
from repro.data.pipeline import DataConfig, batch_at_step, host_shard
from repro.optim import adamw
from repro.optim.adamw import OptimizerConfig
from repro.train.step import TrainConfig, init_train_state, make_jitted_step


def _cfg(**kw):
    return TrainConfig(
        optimizer=OptimizerConfig(
            lr=3e-3, warmup_steps=2, total_steps=50, **kw
        ),
        sketch=HLLConfig(p=8, hash_bits=32),
    )


def test_loss_decreases_20_steps():
    arch = get_arch("smollm-360m").reduced()
    cfg = _cfg()
    data = DataConfig(vocab_size=arch.vocab_size, global_batch=4, seq_len=64)
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)
    step_fn = make_jitted_step(arch, cfg)
    losses = []
    for step in range(20):
        batch = batch_at_step(data, jnp.asarray(step, jnp.int32))
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses
    assert all(np.isfinite(l) for l in losses)


def test_compressed_grads_training_still_converges():
    arch = get_arch("smollm-360m").reduced()
    cfg = _cfg(compress_grads=True)
    data = DataConfig(vocab_size=arch.vocab_size, global_batch=4, seq_len=64)
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)
    step_fn = make_jitted_step(arch, cfg)
    losses = []
    for step in range(20):
        batch = batch_at_step(data, jnp.asarray(step, jnp.int32))
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= lrs[10]  # warmup
    assert abs(lrs[10] - 1e-3) < 1e-4  # peak
    assert lrs[100] == pytest.approx(1e-4, rel=0.05)  # min_lr_ratio * lr


def test_grad_clip():
    grads = {"a": jnp.full((4,), 100.0), "b": jnp.full((2, 2), -50.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
    assert float(norm) > 100


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, (256,)), jnp.float32)
    q, scale = adamw.quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(adamw.dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """EF must carry the quantization error so the bias vanishes over steps."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(0, 1, (512,)), jnp.float32)
    total_raw = np.zeros(512, np.float32)
    total_comp = np.zeros(512, np.float32)
    ef = None
    for _ in range(50):
        comp, ef = adamw.compress_with_error_feedback({"g": g}, ef)
        total_comp += np.asarray(comp["g"])
        total_raw += np.asarray(g)
    # accumulated compressed sum converges to the true sum (EF property)
    rel = np.abs(total_comp - total_raw).max() / np.abs(total_raw).max()
    assert rel < 0.01, rel


# ----------------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------------


def test_data_deterministic_and_step_indexed():
    cfg = DataConfig(vocab_size=1000, global_batch=4, seq_len=32)
    b1 = batch_at_step(cfg, jnp.asarray(7))
    b2 = batch_at_step(cfg, jnp.asarray(7))
    b3 = batch_at_step(cfg, jnp.asarray(8))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    # targets are the one-shifted stream
    flat_t = np.asarray(b1["tokens"]).reshape(-1)
    flat_y = np.asarray(b1["targets"]).reshape(-1)
    np.testing.assert_array_equal(flat_y[:-1], flat_t[1:])


def test_host_shards_disjoint():
    cfg = DataConfig(vocab_size=1000, global_batch=8, seq_len=16)
    b = batch_at_step(cfg, jnp.asarray(0))
    s0 = host_shard(b, 0, 4)["tokens"]
    s1 = host_shard(b, 1, 4)["tokens"]
    assert s0.shape == (2, 16)
    assert not np.array_equal(np.asarray(s0), np.asarray(s1))


def test_distributions():
    for dist, check in [
        ("uniform", lambda t: 560 < len(np.unique(t)) < 720),
        ("zipf", lambda t: np.bincount(t.reshape(-1), minlength=1000)[:10].sum()
         > np.bincount(t.reshape(-1), minlength=1000)[-100:].sum()),
        ("unique", lambda t: len(np.unique(t)) == t.size),
    ]:
        cfg = DataConfig(
            vocab_size=100_000 if dist == "unique" else 1000,
            global_batch=8, seq_len=128, distribution=dist,
        )
        t = np.asarray(batch_at_step(cfg, jnp.asarray(0))["tokens"])
        assert check(t), dist
