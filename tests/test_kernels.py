"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.sketch import backends as ops
from repro.sketch import hll
from repro.sketch.hll import HLLConfig

RNG = np.random.default_rng(42)


def _items(n, dtype=np.uint32, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    hi = 2**31 if np.issubdtype(dtype, np.signedinteger) else 2**32
    return jnp.asarray(rng.integers(0, hi, n, dtype=dtype))


# ----------------------------------------------------------------------------
# hash_rank
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 127, 128, 1024, 8192, 10_000])
@pytest.mark.parametrize("hash_bits", [32, 64])
def test_hash_rank_shape_sweep(n, hash_bits):
    cfg = HLLConfig(p=16 if hash_bits == 64 else 14, hash_bits=hash_bits)
    items = _items(n, seed=n * hash_bits)
    idx, rank = ops.hash_rank(items, cfg, interpret=True)
    ridx, rrank = ref.hash_rank_ref(items, cfg)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rrank))


@pytest.mark.parametrize("dtype", [np.uint32, np.int32])
def test_hash_rank_dtype_sweep(dtype):
    cfg = HLLConfig(p=14, hash_bits=64)
    items = _items(2048, dtype=dtype, seed=7)
    idx, rank = ops.hash_rank(items, cfg, interpret=True)
    ridx, rrank = ref.hash_rank_ref(items, cfg)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rrank))


@pytest.mark.parametrize("block_rows", [8, 16, 64])
def test_hash_rank_block_shape_sweep(block_rows):
    cfg = HLLConfig(p=16, hash_bits=64)
    items = _items(block_rows * 128 * 3 + 5, seed=block_rows)
    idx, rank = ops.hash_rank(items, cfg, block_rows=block_rows, interpret=True)
    ridx, rrank = ref.hash_rank_ref(items, cfg)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rrank))


@settings(deadline=None, max_examples=15)
@given(
    st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=300),
    st.sampled_from([4, 8, 12, 16]),
)
def test_hash_rank_property(keys, p):
    cfg = HLLConfig(p=p, hash_bits=64)
    items = jnp.asarray(np.asarray(keys, np.uint32))
    idx, rank = ops.hash_rank(items, cfg, interpret=True)
    ridx, rrank = ref.hash_rank_ref(items, cfg)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rrank))
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < cfg.m).all()
    assert (np.asarray(rank) >= 1).all() and (
        np.asarray(rank) <= cfg.max_rank
    ).all()


# ----------------------------------------------------------------------------
# bucket_fold
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 7, 16])
@pytest.mark.parametrize("m", [256, 1024, 65536])
def test_bucket_fold_sweep(k, m):
    partials = jnp.asarray(RNG.integers(0, 50, (k, m), dtype=np.int32))
    got = ops.bucket_fold(partials, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.bucket_fold_ref(partials))
    )


@pytest.mark.parametrize("dtype", [np.int32, np.uint8])
def test_bucket_fold_dtypes(dtype):
    partials = jnp.asarray(RNG.integers(0, 49, (4, 2048), dtype=dtype))
    got = ops.bucket_fold(partials, interpret=True)
    assert got.dtype == partials.dtype
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.bucket_fold_ref(partials))
    )


# ----------------------------------------------------------------------------
# fused HLL update
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("p", [4, 8, 10, 12])
@pytest.mark.parametrize("n", [1, 100, 1024, 5000])
def test_fused_update_sweep(p, n):
    cfg = HLLConfig(p=p, hash_bits=64)
    regs0 = jnp.zeros((cfg.m,), jnp.uint8)
    items = _items(n, dtype=np.int32, seed=p * 1000 + n)
    got = ops.hll_update(regs0, items, cfg, interpret=True)
    want = ref.hll_update_fused_ref(regs0, items, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_update_accumulates_onto_existing():
    cfg = HLLConfig(p=10, hash_bits=64)
    a, b = _items(2000, seed=1), _items(2000, seed=2)
    r1 = ops.hll_update(jnp.zeros((cfg.m,), jnp.uint8), a, cfg, interpret=True)
    r2 = ops.hll_update(r1, b, cfg, interpret=True)
    both = hll.update(
        hll.update(hll.init_registers(cfg), a, cfg), b, cfg
    )
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(both))


def test_fused_update_rejects_large_p():
    cfg = HLLConfig(p=16, hash_bits=64)
    with pytest.raises(ValueError, match="p <= 12"):
        ops.hll_update(
            jnp.zeros((cfg.m,), jnp.uint8), _items(128), cfg, interpret=True
        )


def test_fused_padding_is_neutral():
    """Padding must never bump a register: sizes straddling tile boundaries."""
    cfg = HLLConfig(p=8, hash_bits=32)
    for n in (1, 1023, 1024, 1025):
        items = _items(n, seed=n)
        got = ops.hll_update(jnp.zeros((cfg.m,), jnp.uint8), items, cfg, interpret=True)
        want = ref.hll_update_fused_ref(jnp.zeros((cfg.m,), jnp.uint8), items, cfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------------------------
# composed multi-pipeline engine (paper Fig. 3 from kernels)
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("pipelines", [1, 2, 4, 8])
def test_pipelined_update_matches_scatter_path(pipelines):
    cfg = HLLConfig(p=10, hash_bits=64)
    items = _items(4096, dtype=np.int32, seed=pipelines)
    got = ops.pipelined_update(
        jnp.zeros((cfg.m,), jnp.uint8), items, cfg, pipelines, interpret=True
    )
    want = hll.update(hll.init_registers(cfg), items, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_estimates_from_kernel_registers_match_host():
    cfg = HLLConfig(p=12, hash_bits=64)
    items = _items(50_000, dtype=np.int32, seed=33)
    regs = ops.hll_update(jnp.zeros((cfg.m,), jnp.uint8), items, cfg, interpret=True)
    est = hll.estimate(regs, cfg)
    ref_regs = hll.update(hll.init_registers(cfg), items, cfg)
    assert est == hll.estimate(ref_regs, cfg)
