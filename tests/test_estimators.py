"""Estimator registry: golden pins, Ertl accuracy bands, host/device, batching.

Covers the phase-4 refactor contract (DESIGN.md §8):
  * ``original`` stays bit-identical to the pre-registry exact estimator
    (golden values captured from the seed implementation);
  * ``ertl_improved`` / ``ertl_mle`` stay within ~3 * (1.04/sqrt(m)) of the
    true cardinality across small/mid/large ranges;
  * every estimator's device path agrees with its exact host path;
  * ``estimate_many`` over a stacked register bank matches per-sketch
    ``estimate_device`` calls in one jitted dispatch;
  * ``estimate_device`` validates shape/dtype the same way ``estimate`` does.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.sketch import (
    ExecutionPlan,
    HyperLogLog,
    available_estimators,
    estimate_from_histogram,
    estimate_many,
    get_estimator,
    hll,
    register_estimator,
    register_histogram,
    setops,
)
from repro.sketch import estimators as estlib
from repro.sketch import exact as exactlib
from repro.sketch.hll import HLLConfig

ESTIMATORS = ("original", "ertl_improved", "ertl_mle")


def _items(n, seed):
    return np.random.default_rng(seed).integers(0, 2**31, n, dtype=np.int32)


def _regs(cfg, n, seed):
    return hll.update(hll.init_registers(cfg), jnp.asarray(_items(n, seed)), cfg)


# ----------------------------------------------------------------------------
# golden values: "original" is bit-compatible with the pre-registry estimator
# ----------------------------------------------------------------------------

# (p, H, n, rng seed, estimate) captured from the seed implementation, which
# accumulated sum_j 2^(max_rank - M[j]) as an exact python int.  The histogram
# path computes the same integer, so equality here is exact, not approx.
GOLDEN = [
    (10, 64, 100, 0, 105.2259675727554),
    (10, 64, 5000, 1, 5267.28249218302),
    (12, 64, 200000, 2, 197827.12799793802),
    (14, 32, 3000, 3, 3000.7620341689494),
    (14, 32, 2000000, 4, 2019074.3597214979),
    (16, 64, 1000000, 5, 996494.3822282938),
    (8, 32, 50, 6, 50.70589792309603),
    (14, 64, 50000, 7, 50449.459385639755),
]


@pytest.mark.parametrize("p,H,n,seed,expected", GOLDEN)
def test_original_bit_identical_to_seed(p, H, n, seed, expected):
    cfg = HLLConfig(p=p, hash_bits=H)
    regs = _regs(cfg, n, seed)
    assert hll.estimate(regs, cfg) == expected  # default estimator
    assert hll.estimate(regs, cfg, estimator="original") == expected
    assert estlib.estimate(regs, cfg, "original") == expected


def test_original_large_range_golden():
    """Synthetic deep registers: the 2^32 correction path, pinned exactly."""
    regs = jnp.asarray(np.full(1 << 14, 18, np.uint8))
    cfg32 = HLLConfig(p=14, hash_bits=32)
    assert hll.estimate(regs, cfg32) == 5486601362.617552
    raw = hll.alpha(cfg32.m) * cfg32.m * cfg32.m / (cfg32.m * 2.0**-18)
    cfg64 = HLLConfig(p=14, hash_bits=64)
    assert hll.estimate(regs, cfg64) == pytest.approx(raw)


# ----------------------------------------------------------------------------
# the histogram intermediate
# ----------------------------------------------------------------------------


def test_histogram_device_matches_host():
    cfg = HLLConfig(p=10, hash_bits=64)
    regs = _regs(cfg, 20_000, 3)
    dev = np.asarray(register_histogram(regs, cfg))
    host = estlib.register_histogram_host(regs, cfg)
    np.testing.assert_array_equal(dev, host)
    assert dev.shape == (estlib.histogram_size(cfg),)
    assert dev.sum() == cfg.m


def test_histogram_batched():
    cfg = HLLConfig(p=8, hash_bits=64)
    bank = jnp.stack([_regs(cfg, n, n) for n in (10, 1000, 50_000)])
    hs = np.asarray(register_histogram(bank, cfg))
    assert hs.shape == (3, estlib.histogram_size(cfg))
    for i in range(3):
        np.testing.assert_array_equal(
            hs[i], estlib.register_histogram_host(bank[i], cfg)
        )


def test_estimate_from_histogram_matches_estimate():
    cfg = HLLConfig(p=10, hash_bits=64)
    regs = _regs(cfg, 30_000, 4)
    counts = estlib.register_histogram_host(regs, cfg)
    for name in ESTIMATORS:
        assert estimate_from_histogram(counts, cfg, name) == hll.estimate(
            regs, cfg, estimator=name
        )


def test_estimate_from_histogram_validates():
    cfg = HLLConfig(p=8, hash_bits=64)
    with pytest.raises(ValueError, match="histogram"):
        estimate_from_histogram(np.zeros(5, np.int64), cfg)
    bad = np.zeros(estlib.histogram_size(cfg), np.int64)  # sums to 0, not m
    with pytest.raises(ValueError, match="sums to"):
        estimate_from_histogram(bad, cfg)


# ----------------------------------------------------------------------------
# Ertl estimators: accuracy bands across small / mid / large ranges
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("estimator", ESTIMATORS)
@pytest.mark.parametrize("n", [150, 2_000, 20_000, 160_000])
def test_estimator_within_three_sigma(estimator, n):
    cfg = HLLConfig(p=12, hash_bits=64)  # sigma = 1.625%
    items = _items(n, seed=n * 7 + 1)
    regs = hll.update(hll.init_registers(cfg), jnp.asarray(items), cfg)
    est = hll.estimate(regs, cfg, estimator=estimator)
    ex = exactlib.exact_distinct(items)
    assert abs(est - ex) / ex < 3 * hll.standard_error(cfg)


@pytest.mark.parametrize("estimator", ["ertl_improved", "ertl_mle"])
def test_ertl_no_transition_bump(estimator):
    """Ertl's point: accuracy holds *at* the 2.5m LC->raw threshold too."""
    cfg = HLLConfig(p=10, hash_bits=64)
    n = int(2.5 * cfg.m)  # the original estimator's worst spot
    errs = []
    for t in range(5):
        items = _items(n, seed=100 + t)
        regs = hll.update(hll.init_registers(cfg), jnp.asarray(items), cfg)
        est = hll.estimate(regs, cfg, estimator=estimator)
        ex = exactlib.exact_distinct(items)
        errs.append(abs(est - ex) / ex)
    assert np.median(errs) < 3 * hll.standard_error(cfg)


@settings(deadline=None, max_examples=15, derandomize=True)
@given(st.integers(10, 60_000), st.integers(0, 2**31 - 1))
def test_property_all_estimators_track_truth(n, seed):
    # fixed stream length (one compile), cardinality driven by value range
    cfg = HLLConfig(p=10, hash_bits=64)
    items = np.random.default_rng(seed).integers(0, n, 16_384, dtype=np.int32)
    regs = hll.update(hll.init_registers(cfg), jnp.asarray(items), cfg)
    ex = exactlib.exact_distinct(items)
    band = 3 * hll.standard_error(cfg)
    for name in ESTIMATORS:
        est = hll.estimate(regs, cfg, estimator=name)
        assert abs(est - ex) <= max(band * ex, 2.0), (name, est, ex)


# ----------------------------------------------------------------------------
# host vs device agreement, per estimator
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("estimator", ESTIMATORS)
def test_host_vs_device_agreement(estimator):
    for p, H in [(10, 64), (14, 32)]:
        cfg = HLLConfig(p=p, hash_bits=H)
        for n in (100, 5_000, 40 * cfg.m):
            regs = _regs(cfg, n, seed=n + p)
            host = hll.estimate(regs, cfg, estimator=estimator)
            dev = float(hll.estimate_device(regs, cfg, estimator=estimator))
            assert abs(dev - host) / host < 1e-4, (p, H, n, host, dev)


@settings(deadline=None, max_examples=10, derandomize=True)
@given(st.integers(1, 2**30), st.integers(0, 2**31 - 1))
def test_property_host_device_agree(n, seed):
    cfg = HLLConfig(p=8, hash_bits=64)
    items = np.random.default_rng(seed).integers(0, n, 16_384, dtype=np.int32)
    regs = hll.update(hll.init_registers(cfg), jnp.asarray(items), cfg)
    for name in ESTIMATORS:
        host = hll.estimate(regs, cfg, estimator=name)
        dev = float(hll.estimate_device(regs, cfg, estimator=name))
        assert abs(dev - host) <= 1e-4 * max(host, 1.0), (name, host, dev)


def test_degenerate_sketches():
    cfg = HLLConfig(p=8, hash_bits=64)
    empty = jnp.zeros((cfg.m,), jnp.uint8)
    saturated = jnp.full((cfg.m,), cfg.max_rank, jnp.uint8)
    for name in ESTIMATORS:
        assert hll.estimate(empty, cfg, estimator=name) == 0.0
        assert float(hll.estimate_device(empty, cfg, estimator=name)) == 0.0
    for name in ("ertl_improved", "ertl_mle"):
        assert hll.estimate(saturated, cfg, estimator=name) == math.inf
        assert math.isinf(
            float(hll.estimate_device(saturated, cfg, estimator=name))
        )
    # original, 32-bit hash: past 2^32 the large-range correction diverges;
    # it must saturate to +inf (not raise host-side / NaN device-side)
    cfg32 = HLLConfig(p=14, hash_bits=32)
    sat32 = jnp.full((cfg32.m,), cfg32.max_rank, jnp.uint8)
    assert hll.estimate(sat32, cfg32) == math.inf
    assert math.isinf(float(hll.estimate_device(sat32, cfg32)))


# ----------------------------------------------------------------------------
# estimate_many: the batched device path (acceptance criterion)
# ----------------------------------------------------------------------------


_BANK_CACHE = {}


def _bank64(cfg):
    """64 stacked sketches (incl. one empty), cardinalities ~10 .. ~8k."""
    if cfg not in _BANK_CACHE:
        rows = [hll.init_registers(cfg)]
        for i in range(63):
            vals = min(int(10 * 1.25**i), 1 << 30)
            items = np.random.default_rng(i).integers(
                0, vals, 16_384, dtype=np.int32
            )
            rows.append(
                hll.update(hll.init_registers(cfg), jnp.asarray(items), cfg)
            )
        _BANK_CACHE[cfg] = jnp.stack(rows)
    return _BANK_CACHE[cfg]


@pytest.mark.parametrize("estimator", ESTIMATORS)
def test_estimate_many_matches_individual(estimator):
    """64-sketch bank == 64 individual estimate_device calls, one dispatch."""
    cfg = HLLConfig(p=10, hash_bits=64)
    bank = _bank64(cfg)
    many = np.asarray(estimate_many(bank, cfg, estimator=estimator))
    assert many.shape == (64,)
    indiv = np.asarray(
        [
            float(hll.estimate_device(bank[i], cfg, estimator=estimator))
            for i in range(64)
        ]
    )
    np.testing.assert_allclose(many, indiv, rtol=1e-6)
    # and the device bank tracks the exact host finalizer per sketch
    hosts = np.asarray(
        [hll.estimate(bank[i], cfg, estimator=estimator) for i in range(64)]
    )
    np.testing.assert_allclose(many[1:], hosts[1:], rtol=1e-4)
    assert many[0] == hosts[0] == 0.0


def test_estimate_many_nd_bank():
    cfg = HLLConfig(p=8, hash_bits=64)
    bank = jnp.stack([_regs(cfg, 1000 * (i + 1), i) for i in range(6)])
    grid = bank.reshape(2, 3, cfg.m)
    out = np.asarray(estimate_many(grid, cfg))
    assert out.shape == (2, 3)
    np.testing.assert_allclose(
        out.reshape(-1), np.asarray(estimate_many(bank, cfg)), rtol=1e-6
    )


# ----------------------------------------------------------------------------
# validation (estimate_device now checks shape/dtype like estimate)
# ----------------------------------------------------------------------------


def test_estimate_validates_shape_and_dtype():
    cfg = HLLConfig(p=10, hash_bits=64)
    wrong_shape = jnp.zeros((100,), jnp.uint8)
    wrong_dtype = jnp.zeros((cfg.m,), jnp.float32)
    for fn in (hll.estimate, hll.estimate_device):
        with pytest.raises(ValueError, match="registers"):
            fn(wrong_shape, cfg)
        with pytest.raises(ValueError, match="integer"):
            fn(wrong_dtype, cfg)
    with pytest.raises(ValueError):
        estimate_many(jnp.zeros((4, 100), jnp.uint8), cfg)
    with pytest.raises(ValueError):
        estimate_many(jnp.zeros((4, cfg.m), jnp.float32), cfg)


def test_estimate_rejects_out_of_range_register_values():
    cfg = HLLConfig(p=8, hash_bits=64)
    corrupt = np.zeros(cfg.m, np.uint8)
    corrupt[0] = cfg.max_rank + 3
    with pytest.raises(ValueError, match="max_rank"):
        hll.estimate(jnp.asarray(corrupt), cfg)


def test_corrupt_registers_cannot_leak_into_neighboring_batch():
    """An out-of-range register value (only reachable via a corrupted blob)
    must skew its own sketch at worst — never the adjacent bank entry."""
    cfg = HLLConfig(p=8, hash_bits=64)
    valid = _regs(cfg, 5_000, 0)
    # too-large values would leak forward; negatives (a 0xFF blob byte read
    # through a signed dtype) would leak backward — both must be dropped
    for bad, corrupt_slot, valid_slot in [
        (cfg.max_rank + 7, 0, 1),
        (-1, 1, 0),
    ]:
        corrupt = np.asarray(valid).astype(np.int32)
        corrupt[:4] = bad
        rows = [None, None]
        rows[corrupt_slot] = jnp.asarray(corrupt)
        rows[valid_slot] = valid.astype(jnp.int32)
        bank = jnp.stack(rows)
        hists = np.asarray(register_histogram(bank, cfg))
        # corrupt sketch: the 4 bad registers are dropped, not redistributed
        assert hists[corrupt_slot].sum() == cfg.m - 4
        # neighbor: bit-identical to its standalone histogram
        np.testing.assert_array_equal(
            hists[valid_slot], estlib.register_histogram_host(valid, cfg)
        )
        many = np.asarray(estimate_many(bank, cfg))
        assert many[valid_slot] == pytest.approx(
            float(hll.estimate_device(valid, cfg)), rel=1e-6
        )


# ----------------------------------------------------------------------------
# registry + plan plumbing
# ----------------------------------------------------------------------------


def test_registry_contents_and_errors():
    assert set(ESTIMATORS) <= set(available_estimators())
    assert get_estimator("original").name == "original"
    with pytest.raises(ValueError, match="unknown estimator"):
        get_estimator("flajolet_martin")
    with pytest.raises(ValueError, match="already registered"):
        register_estimator(
            "original", lambda c, cfg: 0.0, lambda c, cfg: c[..., 0]
        )
    with pytest.raises(ValueError, match="unknown estimator"):
        hll.estimate(_regs(HLLConfig(p=8), 10, 0), HLLConfig(p=8), "nope")


def test_plan_carries_estimator():
    plan = ExecutionPlan(estimator="ertl_mle")
    assert plan.validate().estimator == "ertl_mle"
    with pytest.raises(ValueError, match="unknown estimator"):
        ExecutionPlan(estimator="bogus").validate()


def test_plugin_estimator_roundtrip():
    """A plugged-in estimator is reachable through every dispatch layer."""
    name = "const_fortytwo_test"
    register_estimator(
        name,
        host=lambda counts, cfg: 42.0,
        device=lambda counts, cfg: jnp.full(counts.shape[:-1], 42.0),
    )
    try:
        cfg = HLLConfig(p=8, hash_bits=64)
        regs = _regs(cfg, 1000, 0)
        assert hll.estimate(regs, cfg, estimator=name) == 42.0
        assert float(hll.estimate_device(regs, cfg, estimator=name)) == 42.0
        bank = jnp.stack([regs, regs])
        np.testing.assert_array_equal(
            np.asarray(estimate_many(bank, cfg, estimator=name)), [42.0, 42.0]
        )
    finally:
        # keep the process-global registry clean for every later test that
        # iterates available_estimators() expecting only real estimators
        estlib._ESTIMATORS.pop(name, None)
    assert name not in available_estimators()


# ----------------------------------------------------------------------------
# carrier + setops integration
# ----------------------------------------------------------------------------


def test_carrier_estimator_dispatch():
    cfg = HLLConfig(p=10, hash_bits=64)
    sk = HyperLogLog.of(jnp.arange(20_000, dtype=jnp.int32), cfg)
    hist = np.asarray(sk.histogram())
    assert hist.sum() == cfg.m
    for name in ESTIMATORS:
        est = sk.estimate(estimator=name)
        assert abs(est - 20_000) / 20_000 < 3 * sk.standard_error
        dev = float(sk.estimate_device(estimator=name))
        assert abs(dev - est) / est < 1e-4


@pytest.mark.parametrize("estimator", ESTIMATORS)
def test_setops_estimator_param(estimator):
    cfg = HLLConfig(p=12, hash_bits=64)
    a = HyperLogLog.of(jnp.arange(0, 60_000, dtype=jnp.int32), cfg)
    b = HyperLogLog.of(jnp.arange(40_000, 100_000, dtype=jnp.int32), cfg)
    eu = setops.union_estimate(a, b, cfg, estimator=estimator)
    assert abs(eu - 100_000) / 100_000 < 0.05
    inter, err = a.intersection_estimate(b, estimator=estimator)
    assert abs(inter - 20_000) <= max(3 * err, 8_000)
    jac = a.jaccard(b, estimator=estimator)
    assert abs(jac - 0.2) < 0.06
