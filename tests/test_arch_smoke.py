"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer
from repro.optim.adamw import OptimizerConfig
from repro.train.step import TrainConfig, init_train_state, train_step
from repro.sketch import HLLConfig

B, S = 2, 64


def _batch(arch, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, arch.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if arch.mrope:
        batch["positions"] = transformer.default_positions(arch, B, S)
    if arch.frontend_stub_len:
        batch["frontend_embeds"] = (
            jax.random.normal(
                jax.random.PRNGKey(key + 1),
                (B, arch.frontend_stub_len, arch.d_model),
            ).astype(jnp.bfloat16)
            * 0.02
        )
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_no_nans(arch_id):
    arch = get_arch(arch_id).reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), arch)
    batch = _batch(arch)
    logits, aux, _ = transformer.forward(params, batch, arch)
    assert logits.shape == (B, S, arch.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_train_step(arch_id):
    arch = get_arch(arch_id).reduced()
    cfg = TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10),
        sketch=HLLConfig(p=8, hash_bits=32),
    )
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)
    state, metrics = train_step(state, _batch(arch), arch, cfg)
    assert int(state["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["distinct_tokens"]) > 0
    # params actually moved
    leaves0 = jax.tree_util.tree_leaves(state["params"])
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves0)


def test_full_configs_match_published_sizes():
    """Guard against config drift: total params within 2% of published."""
    from repro.models import registry

    expect = {
        "olmoe-1b-7b": 6.9e9,
        "mixtral-8x7b": 46.7e9,
        "rwkv6-3b": 3.1e9,
        "tinyllama-1.1b": 1.1e9,
        "phi4-mini-3.8b": 3.84e9,
        "smollm-360m": 0.362e9,
        "qwen3-32b": 32.8e9,
        "musicgen-medium": 1.8e9,
        "recurrentgemma-9b": 9.4e9,
        "qwen2-vl-72b": 72.7e9,
    }
    for a, n in expect.items():
        got = registry.param_count(get_arch(a))
        assert abs(got - n) / n < 0.02, (a, got, n)


def test_moe_active_params():
    from repro.models import registry

    olmoe = get_arch("olmoe-1b-7b")
    assert abs(registry.param_count(olmoe, active_only=True) - 1.28e9) < 0.1e9
    mix = get_arch("mixtral-8x7b")
    assert abs(registry.param_count(mix, active_only=True) - 12.9e9) < 0.3e9


def test_layer_stages_cover_all_layers():
    from repro.models.transformer import layer_stages

    for arch_id in ARCH_IDS:
        arch = get_arch(arch_id)
        total = sum(len(p) * r for p, r in layer_stages(arch))
        assert total == arch.n_layers, arch_id
    rg = get_arch("recurrentgemma-9b")
    stages = layer_stages(rg)
    assert stages[0] == (("rec", "rec", "attn"), 12)
    assert stages[1] == (("rec", "rec"), 1)
