"""System-level model invariants (hypothesis property tests).

These pin behaviours the serving engine and dry-run rely on: causality,
position-shift consistency of windowed attention, determinism, and the
batch-independence of per-sequence computation.
"""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.configs import get_arch
from repro.models import transformer

ARCH = get_arch("tinyllama-1.1b").reduced()
PARAMS = transformer.init_params(jax.random.PRNGKey(0), ARCH)
SWA = get_arch("mixtral-8x7b").reduced()
SWA_PARAMS = transformer.init_params(jax.random.PRNGKey(0), SWA)


def _logits(params, arch, tokens):
    out, _, _ = transformer.forward(params, {"tokens": tokens}, arch)
    return np.asarray(out.astype(jnp.float32))


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2**31 - 1), st.integers(1, 30))
def test_causality(seed, flip_pos):
    """Changing token at position p must not change logits before p."""
    B, S = 1, 32
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, ARCH.vocab_size)
    base = _logits(PARAMS, ARCH, toks)
    flipped = toks.at[0, flip_pos].set((toks[0, flip_pos] + 7) % ARCH.vocab_size)
    mod = _logits(PARAMS, ARCH, flipped)
    np.testing.assert_allclose(
        base[:, :flip_pos], mod[:, :flip_pos], atol=2e-2
    )
    # and the flipped position's own logits DO change
    assert np.abs(base[0, flip_pos] - mod[0, flip_pos]).max() > 1e-3


@settings(deadline=None, max_examples=5)
@given(st.integers(0, 2**31 - 1))
def test_batch_independence(seed):
    """Each sequence's logits are independent of its batch neighbours."""
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (2, 32), 0, ARCH.vocab_size)
    both = _logits(PARAMS, ARCH, toks)
    solo = _logits(PARAMS, ARCH, toks[:1])
    np.testing.assert_allclose(both[0], solo[0], atol=2e-2)


def test_swa_locality():
    """With window w, logits at p depend only on tokens in (p-w, p]."""
    w = SWA.sliding_window
    S = 4 * w
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, SWA.vocab_size)
    base = _logits(SWA_PARAMS, SWA, toks)
    # change a token far outside the window of the last position
    far = S - 1 - (2 * w)
    mod_toks = toks.at[0, far].set((toks[0, far] + 3) % SWA.vocab_size)
    mod = _logits(SWA_PARAMS, SWA, mod_toks)
    # NOTE: information still propagates through stacked layers (receptive
    # field grows by w per layer), so only check a 1-layer-tight property:
    # the change must affect positions >= far (it does) and positions < far
    # must be identical (causality).
    np.testing.assert_allclose(base[:, :far], mod[:, :far], atol=2e-2)
    assert np.abs(base[0, far:] - mod[0, far:]).max() > 1e-3


def test_determinism():
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0, ARCH.vocab_size)
    a = _logits(PARAMS, ARCH, toks)
    b = _logits(PARAMS, ARCH, toks)
    np.testing.assert_array_equal(a, b)


def test_frontend_stub_only_affects_stub_region_inputs():
    """VLM: patch embeddings replace the first stub_len embeddings exactly."""
    arch = get_arch("qwen2-vl-72b").reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), arch)
    B, S = 1, 32
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, arch.vocab_size)
    fe = jax.random.normal(
        jax.random.PRNGKey(5), (B, arch.frontend_stub_len, arch.d_model)
    ).astype(jnp.bfloat16) * 0.02
    batch = {
        "tokens": toks,
        "frontend_embeds": fe,
        "positions": transformer.default_positions(arch, B, S),
    }
    x = transformer.embed_tokens(params, batch, arch)
    # stub region equals the provided embeddings; the rest are token embeds
    np.testing.assert_array_equal(
        np.asarray(x[:, : arch.frontend_stub_len]), np.asarray(fe)
    )
    tok_embed = jnp.take(params["embed"], toks, axis=0).astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(x[:, arch.frontend_stub_len :]),
        np.asarray(tok_embed[:, arch.frontend_stub_len :]),
    )
