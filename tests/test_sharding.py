"""Partition specs: divisibility guarantees + sharded-execution equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer
from repro.optim.compress import compressed_psum
from repro.sharding import ctx as shardctx
from repro.sharding import specs as shardspecs


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_specs_divisible(arch_id):
    """Every sharded dim must divide the production axis sizes (pjit rule)."""
    arch = get_arch(arch_id)
    shapes = jax.eval_shape(
        lambda k: transformer.init_params(k, arch),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    specs = shardspecs.param_specs(shapes, arch, data_size=16, model_size=16)

    def check(path, leaf, spec):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            size = 16  # both axes are 16 in production
            assert leaf.shape[dim] % size == 0, (
                jax.tree_util.keystr(path), leaf.shape, spec
            )

    jax.tree_util.tree_map_with_path(check, shapes, specs)


@pytest.mark.parametrize("arch_id", ["qwen2-vl-72b", "mixtral-8x7b"])
def test_fsdp_actually_shards_big_params(arch_id):
    """Large weights must carry the FSDP axis (ZeRO memory requirement)."""
    arch = get_arch(arch_id)
    shapes = jax.eval_shape(
        lambda k: transformer.init_params(k, arch),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    specs = shardspecs.param_specs(shapes, arch, data_size=16, model_size=16)
    big_unsharded = []

    def check(path, leaf, spec):
        n = int(np.prod(leaf.shape))
        if n > 50e6 and all(a is None for a in spec):
            big_unsharded.append((jax.tree_util.keystr(path), leaf.shape))

    jax.tree_util.tree_map_with_path(check, shapes, specs)
    assert not big_unsharded, big_unsharded


def test_sharded_train_matches_single_device():
    """Same step on a 1x1-device mesh with full spec machinery == unsharded."""
    from repro.sketch import HLLConfig
    from repro.optim.adamw import OptimizerConfig
    from repro.train.step import TrainConfig, init_train_state, train_step

    arch = get_arch("smollm-360m").reduced()
    cfg = TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10),
        sketch=HLLConfig(p=8, hash_bits=32),
    )
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, arch.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)

    s_plain, m_plain = jax.jit(
        lambda s, b: train_step(s, b, arch, cfg)
    )(state, batch)

    from repro.launch.mesh import make_auto_mesh
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    hints = shardctx.ActivationHints(batch_axes=("data",), model_axis="model")
    with mesh, shardctx.use_hints(hints):
        s_shard, m_shard = jax.jit(
            lambda s, b: train_step(s, b, arch, cfg)
        )(state, batch)
    assert float(m_plain["loss"]) == pytest.approx(
        float(m_shard["loss"]), rel=1e-5
    )


def test_compressed_psum_matches_f32():
    devs = jax.devices()
    from repro.launch.mesh import make_auto_mesh
    mesh = make_auto_mesh((len(devs),), ("data",))
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (len(devs), 64)),
                    jnp.float32)

    def local(xs):
        return compressed_psum(xs, "data")

    from repro.compat import shard_map
    out = jax.jit(
        shard_map(local, mesh=mesh, in_specs=P("data"), out_specs=P())
    )(x)
    want = np.sum(np.asarray(x), axis=0)
    got = np.asarray(out)[0] if out.ndim == 2 else np.asarray(out)
    np.testing.assert_allclose(got, want, atol=np.abs(want).max() * 0.02 + 1e-3)


def test_cache_specs_divisible_for_all_decode_cells():
    from repro.serve import engine
    from repro.configs import SHAPES, is_cell_supported

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch_id in ARCH_IDS:
        arch = get_arch(arch_id)
        for shape_name in ("decode_32k", "long_500k"):
            shape = SHAPES[shape_name]
            if not is_cell_supported(arch, shape):
                continue
            cache = jax.eval_shape(
                lambda a=arch, s=shape: engine.init_cache(
                    a, s.global_batch, s.seq_len
                )
            )
            specs = shardspecs.cache_specs(
                cache, arch, FakeMesh(), shape.global_batch
            )

            def check(path, leaf, spec):
                for dim, axis in enumerate(spec):
                    if axis is None:
                        continue
                    axes = axis if isinstance(axis, tuple) else (axis,)
                    size = int(np.prod([FakeMesh.shape[a] for a in axes]))
                    assert leaf.shape[dim] % size == 0, (
                        arch_id, shape_name,
                        jax.tree_util.keystr(path), leaf.shape, spec,
                    )

            jax.tree_util.tree_map_with_path(check, cache, specs)
