"""Scan-aware HLO analyzer: validated against known-FLOP graphs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _compile(fn, *avals):
    return jax.jit(fn).lower(*avals).compile()


def test_plain_matmul_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    an = H.analyze(c.as_text())
    assert an.flops == 2 * 64 * 128 * 32
    assert an.n_while_loops == 0


def test_scan_trip_count_correction():
    """The analyzer must recover the x8 the raw cost_analysis drops."""

    def scanned(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    c = _compile(scanned, x, ws)
    an = H.analyze(c.as_text())
    expected = 8 * 2 * 128 * 256 * 256
    assert an.flops == expected
    assert an.n_while_loops == 1
    assert list(an.trip_counts.values()) == [8]
    # and confirm the raw counter is indeed wrong (the reason this exists)
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns [per-device dict]
        ca = ca[0]
    raw = ca["flops"]
    assert raw == pytest.approx(expected / 8, rel=0.01)


def test_nested_scan_multiplies():
    def nested(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = _compile(nested, x, ws)
    an = H.analyze(c.as_text())
    assert an.flops == 5 * 4 * 2 * 32 * 64 * 64
    assert sorted(an.trip_counts.values()) == [4, 5]


def test_trip_hints_override():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 16, 16), jnp.float32)
    c = _compile(scanned, x, ws)
    an = H.analyze(c.as_text())
    body_name = list(an.trip_counts)[0]
    an2 = H.analyze(c.as_text(), trip_hints={body_name: 100})
    assert an2.flops == pytest.approx(an.flops * 100 / 3)


def test_bytes_reasonable_for_copy():
    """Memory accounting: a big elementwise op reads+writes its arrays."""
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda x: x * 2.0 + 1.0, a)
    an = H.analyze(c.as_text())
    nbytes = 1024 * 1024 * 4
    assert nbytes * 2 <= an.bytes <= nbytes * 6  # in + out (+fusion slack)


def test_collective_detection_and_bytes():
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    from repro.launch.mesh import make_auto_mesh
    mesh = make_auto_mesh((len(devs),), ("d",))
    from jax.sharding import PartitionSpec as P

    def f(x):
        return jax.lax.psum(x, "d")

    from repro.compat import shard_map
    sharded = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P())
    x = jax.ShapeDtypeStruct((len(devs) * 8, 128), jnp.float32)
    c = jax.jit(sharded).lower(x).compile()
    an = H.analyze(c.as_text())
    # single-device lowering may elide the collective; multi-device must not
    if len(devs) > 1:
        assert an.collective_bytes > 0
        assert "all-reduce" in an.collectives_by_kind


def test_roofline_terms_math():
    an = H.Analysis(
        flops=197e12, bytes=819e9, collective_bytes=100e9,
        collectives_by_kind={"all-reduce": 100e9}, n_while_loops=0,
        trip_counts={},
    )
    t = H.roofline_terms(an, n_chips=1, model_flops=197e12 / 2)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(2.0)
    assert t["dominant"] == "collective_s"
    assert t["useful_flop_ratio"] == pytest.approx(0.5)
