"""Murmur3 (32/64-bit) vs pure-python oracles + statistical sanity."""

import numpy as np
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.sketch import murmur3

KEYS = st.integers(min_value=0, max_value=2**32 - 1)
SEEDS = st.integers(min_value=0, max_value=2**32 - 1)


@settings(deadline=None, max_examples=50)
@given(st.lists(KEYS, min_size=1, max_size=64), SEEDS)
def test_murmur3_32_matches_oracle(keys, seed):
    k = np.asarray(keys, np.uint32)
    got = np.asarray(murmur3.murmur3_32(jnp.asarray(k), seed))
    exp = np.asarray([murmur3.murmur3_32_py(int(v), seed) for v in keys], np.uint32)
    np.testing.assert_array_equal(got, exp)


@settings(deadline=None, max_examples=50)
@given(st.lists(KEYS, min_size=1, max_size=64), SEEDS)
def test_murmur3_64_matches_oracle(keys, seed):
    k = np.asarray(keys, np.uint32)
    h = murmur3.murmur3_64(jnp.asarray(k), seed)
    got = (np.asarray(h.hi, np.uint64) << np.uint64(32)) | np.asarray(h.lo, np.uint64)
    exp = np.asarray([murmur3.murmur3_64_py(int(v), seed) for v in keys], np.uint64)
    np.testing.assert_array_equal(got, exp)


def test_known_vectors_32():
    # Canonical Murmur3_x86_32 4-byte vectors (verified against smhasher).
    # key bytes are the LE encoding of the uint32.
    assert murmur3.murmur3_32_py(0, 0) == 0x2362F9DE
    got = int(np.asarray(murmur3.murmur3_32(jnp.asarray([0], dtype=jnp.uint32), 0))[0])
    assert got == 0x2362F9DE


def test_determinism_and_seed_sensitivity():
    k = jnp.arange(1024, dtype=jnp.uint32)
    a = np.asarray(murmur3.murmur3_32(k, 1))
    b = np.asarray(murmur3.murmur3_32(k, 1))
    c = np.asarray(murmur3.murmur3_32(k, 2))
    np.testing.assert_array_equal(a, b)
    assert (a != c).mean() > 0.99


def test_uniformity_32():
    """Top-bit and bucket-occupancy uniformity of the 32-bit hash."""
    n = 1 << 16
    h = np.asarray(murmur3.murmur3_32(jnp.arange(n, dtype=jnp.uint32), 0))
    # each of the top 4 bits should be ~50/50
    for bit in range(28, 32):
        frac = ((h >> bit) & 1).mean()
        assert 0.48 < frac < 0.52, (bit, frac)
    # 256-bucket chi-square-ish occupancy bound
    counts = np.bincount(h >> 24, minlength=256)
    assert counts.min() > n / 256 * 0.8 and counts.max() < n / 256 * 1.2


def test_uniformity_64_high_and_low_words():
    n = 1 << 16
    h = murmur3.murmur3_64(jnp.arange(n, dtype=jnp.uint32), 0)
    for word in (np.asarray(h.hi), np.asarray(h.lo)):
        counts = np.bincount(word >> 24, minlength=256)
        # binomial(n, 1/256): mean 256, std ~16; allow +-4.5 sigma over 256 draws
        assert counts.min() > n / 256 * 0.72 and counts.max() < n / 256 * 1.28


def test_avalanche_32():
    """Flipping one input bit flips ~half of the output bits."""
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 2**32, 512, dtype=np.uint32)
    base = np.asarray(murmur3.murmur3_32(jnp.asarray(keys), 0))
    for bit in (0, 7, 19, 31):
        flipped = np.asarray(murmur3.murmur3_32(jnp.asarray(keys ^ (1 << bit)), 0))
        ham = np.unpackbits((base ^ flipped).view(np.uint8)).mean()
        assert 0.45 < ham < 0.55, (bit, ham)
