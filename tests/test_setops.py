"""HLL set algebra: union/intersection/difference/jaccard accuracy."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hll, setops
from repro.core.hll import HLLConfig

CFG = HLLConfig(p=14, hash_bits=64)


def _sketch(items):
    return hll.update(hll.init_registers(CFG), jnp.asarray(items, jnp.int32), CFG)


def test_union_intersection_difference():
    rng = np.random.default_rng(0)
    a_items = rng.permutation(600_000)[:300_000]  # 300k distinct
    b_items = np.concatenate([a_items[:100_000], 600_000 + np.arange(200_000)])
    a, b = _sketch(a_items), _sketch(b_items)

    eu = setops.union_estimate(a, b, CFG)
    assert abs(eu - 500_000) / 500_000 < 0.03

    inter, err = setops.intersection_estimate(a, b, CFG)
    assert abs(inter - 100_000) <= max(3 * err, 20_000)

    diff = setops.difference_estimate(a, b, CFG)
    assert abs(diff - 200_000) / 200_000 < 0.15

    jac = setops.jaccard_estimate(a, b, CFG)
    assert abs(jac - 0.2) < 0.05


def test_disjoint_intersection_near_zero():
    a = _sketch(np.arange(0, 50_000))
    b = _sketch(np.arange(50_000, 100_000))
    inter, err = setops.intersection_estimate(a, b, CFG)
    assert inter <= 3 * err + 1500


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 3000), st.integers(1, 3000), st.integers(0, 1000))
def test_union_bounds_property(na, nb, overlap):
    """|A∪B| estimate must sit near max(|A|,|B|)..|A|+|B| (within sigma)."""
    overlap = min(overlap, na, nb)
    a_items = np.arange(na)
    b_items = np.concatenate([np.arange(overlap), 10_000_000 + np.arange(nb - overlap)])
    a, b = _sketch(a_items), _sketch(b_items)
    eu = setops.union_estimate(a, b, CFG)
    true_union = na + nb - overlap
    assert abs(eu - true_union) / max(true_union, 1) < 0.1
