"""HLL set algebra: union/intersection/difference/jaccard accuracy."""

import numpy as np
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.sketch import HLLConfig, HyperLogLog, setops

CFG = HLLConfig(p=14, hash_bits=64)


def _sketch(items):
    return HyperLogLog.of(jnp.asarray(items, jnp.int32), CFG)


def test_union_intersection_difference():
    rng = np.random.default_rng(0)
    a_items = rng.permutation(600_000)[:300_000]  # 300k distinct
    b_items = np.concatenate([a_items[:100_000], 600_000 + np.arange(200_000)])
    a, b = _sketch(a_items), _sketch(b_items)

    eu = a.union_estimate(b)
    assert abs(eu - 500_000) / 500_000 < 0.03

    inter, err = a.intersection_estimate(b)
    assert abs(inter - 100_000) <= max(3 * err, 20_000)

    diff = a.difference_estimate(b)
    assert abs(diff - 200_000) / 200_000 < 0.15

    jac = a.jaccard(b)
    assert abs(jac - 0.2) < 0.05


def test_disjoint_intersection_near_zero():
    a = _sketch(np.arange(0, 50_000))
    b = _sketch(np.arange(50_000, 100_000))
    inter, err = a.intersection_estimate(b)
    assert inter <= 3 * err + 1500


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 3000), st.integers(1, 3000), st.integers(0, 1000))
def test_union_bounds_property(na, nb, overlap):
    """|A∪B| estimate must sit near max(|A|,|B|)..|A|+|B| (within sigma)."""
    overlap = min(overlap, na, nb)
    a_items = np.arange(na)
    b_items = np.concatenate([np.arange(overlap), 10_000_000 + np.arange(nb - overlap)])
    a, b = _sketch(a_items), _sketch(b_items)
    eu = setops.union_estimate(a, b, CFG)
    true_union = na + nb - overlap
    assert abs(eu - true_union) / max(true_union, 1) < 0.1
