"""Straggler watchdog: detection thresholds + loop integration."""

import time

import pytest

from repro.train.watchdog import StepWatchdog, Verdict


def _feed(wd, durations):
    verdicts = []
    for d in durations:
        wd.step_begin()
        wd._watch._t0 -= d  # simulate a step of length d without sleeping
        verdicts.append(wd.step_end())
    return verdicts


def test_warmup_steps_never_flag():
    wd = StepWatchdog(warmup_steps=5)
    v = _feed(wd, [10.0, 0.001, 5.0, 0.002, 0.001])
    assert all(x is Verdict.OK for x in v)


def test_steady_state_ok():
    wd = StepWatchdog(warmup_steps=5, min_timeout_s=0.0)
    v = _feed(wd, [0.10] * 20)
    assert all(x is Verdict.OK for x in v)
    assert wd.slow_count == 0 and wd.wedged_count == 0


def test_straggler_flagged_slow():
    wd = StepWatchdog(warmup_steps=5, k_mad=6.0, min_timeout_s=0.0,
                      timeout_factor=50.0)
    _feed(wd, [0.10] * 10)
    (v,) = _feed(wd, [0.30])  # 3x median: beyond median + 6*MAD, below 50x
    assert v is Verdict.SLOW
    assert wd.slow_count == 1


def test_wedge_flagged():
    wd = StepWatchdog(warmup_steps=5, min_timeout_s=0.0, timeout_factor=10.0)
    _feed(wd, [0.10] * 10)
    (v,) = _feed(wd, [2.0])  # 20x median
    assert v is Verdict.WEDGED


def test_stragglers_do_not_poison_baseline():
    wd = StepWatchdog(warmup_steps=5, min_timeout_s=0.0, timeout_factor=10.0)
    _feed(wd, [0.10] * 10)
    _feed(wd, [0.35] * 5)  # repeated stragglers
    # baseline median must still be ~0.10, so a 0.35 step still flags
    (v,) = _feed(wd, [0.35])
    assert v is Verdict.SLOW


def test_deadline_exported():
    wd = StepWatchdog(warmup_steps=3, timeout_factor=10.0, min_timeout_s=0.0)
    assert wd.deadline_s() == float("inf")
    _feed(wd, [0.2] * 5)
    assert wd.deadline_s() == pytest.approx(2.0, rel=0.2)


def test_loop_integration_snapshot_on_straggle(tmp_path):
    """An injected straggler step triggers an immediate checkpoint."""
    from repro.checkpoint import ckpt
    from repro.configs import get_arch
    from repro.sketch import HLLConfig
    from repro.data.pipeline import DataConfig
    from repro.optim.adamw import OptimizerConfig
    from repro.train.loop import LoopConfig, train
    from repro.train.step import TrainConfig
    from repro.train import watchdog as wd_mod

    # tighten the watchdog so a time.sleep straggler triggers reliably
    orig_init = wd_mod.StepWatchdog.__init__

    def tight_init(self, **kw):
        orig_init(self, warmup_steps=3, k_mad=4.0, timeout_factor=1e9,
                  min_timeout_s=1e9)

    wd_mod.StepWatchdog.__init__ = tight_init
    try:
        arch = get_arch("smollm-360m").reduced()
        cfg = TrainConfig(
            optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=12),
            sketch=HLLConfig(p=8, hash_bits=32),
        )
        data = DataConfig(vocab_size=arch.vocab_size, global_batch=2, seq_len=32)

        # monkey-patch the data fetch to inject one slow step
        from repro.train import loop as loop_mod
        real_batch = loop_mod.batch_at_step
        def slow_batch(c, s):
            if int(s) == 8:
                time.sleep(1.0)
            return real_batch(c, s)
        loop_mod.batch_at_step = slow_batch
        try:
            d = str(tmp_path / "wd")
            logs = []
            train(arch, cfg, data,
                  LoopConfig(total_steps=12, ckpt_every=1000, ckpt_dir=d,
                             async_ckpt=False, log_every=100),
                  log_fn=logs.append)
            assert any("[watchdog]" in l for l in logs), logs
            # the straggler snapshot exists (plus the final one)
            assert ckpt.latest_step(d) == 12
            assert any(
                s != 12 for s in [
                    int(x.split("_")[1]) for x in
                    __import__("os").listdir(d) if x.startswith("step_")
                ]
            )
        finally:
            loop_mod.batch_at_step = real_batch
    finally:
        wd_mod.StepWatchdog.__init__ = orig_init
