"""Production serve path (DESIGN.md §16): sharding, coalescing, bugfix pins.

Three families:

* differential — the same op grammar as tests/test_differential.py driven
  once under ``make_plans`` (local) and once under ``make_sharded_plans``
  (row-sharded over this process's devices), asserting canonical state
  and estimates bit-identical for every registered backend.  A
  subprocess leg forces 4 host devices so the block-local key re-basing
  and phantom-row padding run against REAL shards, not a 1-device mesh.
* coalescer — N interleaved per-tenant submits drained as one merged
  batch must land bit-for-bit with per-batch ingest (§6 lattice laws),
  plus the queue's edge semantics (empty drain, length validation,
  host-carrier routing, staging-ring rotation, shared window rings).
* serve-loop pins — the three launcher bugs this PR fixes stay fixed:
  zero-elapsed spans format instead of raising, empty decode slices do
  not expire the prompt epoch at W > T, and --report-every 0 means
  "snapshot at exit only".
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.obs import tracing
from repro.obs.format import fmt_count, fmt_rate, per_second
from repro.serve.coalesce import (
    CoalescingQueue,
    DoubleBuffer,
    SharedWindowRing,
)
from repro.sketch import (
    HLLConfig,
    HybridBank,
    SketchBank,
    WindowedBank,
    available_bank_backends,
    available_window_backends,
)

from tests.reference_model import (
    DenseBankSUT,
    DenseWindowSUT,
    HybridBankSUT,
    gen_ops,
    make_plans,
    make_sharded_plans,
    run_ops,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = HLLConfig(p=8, hash_bits=64)


# ----------------------------------------------------------------------------
# differential: sharded placement is invisible to every read
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("backend", available_bank_backends())
@pytest.mark.parametrize("kind", ["dense", "hybrid", "window"], ids=str)
def test_sharded_placement_bit_identical_to_local(kind, backend):
    """One op sequence, two placements, identical canonical state."""
    if kind == "window" and backend not in available_window_backends():
        pytest.skip(f"{backend!r} has no window fold path")
    sut_cls = {
        "dense": DenseBankSUT,
        "hybrid": HybridBankSUT,
        "window": DenseWindowSUT,
    }[kind]
    local = make_plans([backend])[backend]
    sharded = make_sharded_plans([backend])[backend]
    # 37 rows: does not divide any shard count > 1, so the forced-device
    # subprocess leg exercises the phantom-row padding path too
    rows, window = 37, 3
    ops = gen_ops(
        np.random.default_rng(20260808), rows, 12, windowed=(kind == "window")
    )

    def build(plan):
        if kind == "window":
            return sut_cls(window, rows, CFG, plan=plan)
        return sut_cls(rows, CFG, plan=plan, threshold=4)

    a, b = build(local), build(sharded)
    for op in ops:
        for sut in (a, b):
            run_ops([op], sut, _NullOracle())
        if op[0] == "estimate":
            np.testing.assert_array_equal(
                a.estimates(), b.estimates(), err_msg=f"{kind}/{backend}"
            )
    for got, want in zip(b.canonical(), a.canonical()):
        np.testing.assert_array_equal(got, want, err_msg=f"{kind}/{backend}")


class _NullOracle:
    """run_ops needs an oracle; the differential pair checks itself."""

    def __init__(self, rows=0):
        self.rows = rows

    def update(self, keys, items):
        pass

    def merge(self, other):
        pass

    def advance(self, steps=1):
        pass


@pytest.mark.slow
def test_sharded_routing_on_real_multi_device_mesh():
    """4 forced host devices: cross-block key routing must stay exact.

    Runs in a subprocess because the device count must be pinned before
    jax initializes.  B=37 does not divide 4, so phantom-row padding and
    the §9 drop rule both run against real shards.
    """
    code = """
        import numpy as np
        import jax
        assert jax.device_count() == 4, jax.device_count()
        from repro.launch.mesh import make_auto_mesh
        from repro.sketch import ExecutionPlan, HLLConfig, SketchBank

        cfg = HLLConfig(p=8, hash_bits=64)
        mesh = make_auto_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        for backend in ("jnp", "pallas"):
            local = ExecutionPlan(backend=backend)
            sharded = local.with_sharding(mesh)
            keys = rng.integers(-2, 40, 512).astype(np.int32)
            items = rng.integers(0, 1 << 20, 512).astype(np.int32)
            ref = SketchBank.empty(37, cfg).update_many(keys, items, local)
            got = SketchBank.empty(37, cfg).update_many(keys, items, sharded)
            np.testing.assert_array_equal(
                np.asarray(ref.registers), np.asarray(got.registers), backend
            )
            np.testing.assert_array_equal(ref.counts, got.counts)
            np.testing.assert_array_equal(
                np.asarray(ref.estimate_many()),
                np.asarray(got.estimate_many(plan=sharded)),
            )
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ----------------------------------------------------------------------------
# coalescer: merged ticks are pure batching
# ----------------------------------------------------------------------------


def test_coalesced_tick_matches_per_batch_ingest_bit_for_bit():
    """N interleaved tenant submits == one merged update_many."""
    rng = np.random.default_rng(1)
    rows = 16
    batches = [
        (
            rng.integers(0, rows, n).astype(np.int32),
            rng.integers(0, 1 << 20, n).astype(np.int32),
        )
        for n in (5, 1, 33, 17, 8)
    ]
    ref = SketchBank.empty(rows, CFG)
    for keys, items in batches:
        ref = ref.update_many(keys, items)

    queue = CoalescingQueue()
    for keys, items in batches:
        queue.submit(keys, items)
    assert queue.pending_batches() == len(batches)
    assert queue.pending_items() == sum(k.shape[0] for k, _ in batches)
    got = queue.flush_into(SketchBank.empty(rows, CFG))
    assert queue.pending_batches() == 0

    np.testing.assert_array_equal(np.asarray(ref.registers), np.asarray(got.registers))
    np.testing.assert_array_equal(ref.counts, got.counts)


def test_coalescer_host_routes_hybrid_carrier():
    """HybridBank ingests the merged batch on host (append-buffer path)."""
    rng = np.random.default_rng(2)
    rows = 8
    keys = rng.integers(0, rows, 64).astype(np.int32)
    items = rng.integers(0, 50, 64).astype(np.int32)
    ref = HybridBank.empty(rows, CFG, threshold=4).update_many(keys, items)

    queue = CoalescingQueue()
    queue.submit(keys[:40], items[:40])
    queue.submit(keys[40:], items[40:])
    got = queue.flush_into(HybridBank.empty(rows, CFG, threshold=4))

    ref, got = ref.compact(), got.compact()
    np.testing.assert_array_equal(
        np.asarray(ref.to_dense().registers),
        np.asarray(got.to_dense().registers),
    )
    np.testing.assert_array_equal(ref.counts, got.counts)
    np.testing.assert_array_equal(ref.modes, got.modes)


def test_coalescer_edge_semantics():
    queue = CoalescingQueue()
    assert queue.drain() is None  # a tick with no traffic dispatches nothing
    bank = SketchBank.empty(4, CFG)
    assert queue.flush_into(bank) is bank
    with pytest.raises(ValueError, match="same length"):
        queue.submit(np.arange(3), np.arange(4))
    assert queue.submit(np.empty(0, np.int32), np.empty(0, np.int32)) == 0
    assert queue.pending_batches() == 0  # empty submits are not queued
    queue.submit_row(2, np.arange(5))
    keys, items = queue.drain(stage=False)
    np.testing.assert_array_equal(keys, np.full(5, 2, np.int32))
    np.testing.assert_array_equal(items, np.arange(5))


def test_double_buffer_rotates_and_pins_in_flight_slots():
    buf = DoubleBuffer()
    assert buf.depth == 2
    with pytest.raises(ValueError, match="2 slots"):
        DoubleBuffer(depth=1)
    a = buf.stage(np.arange(4))
    b = buf.stage(np.arange(8))
    # both in-flight batches stay pinned by the ring; the third stage
    # overwrites the oldest slot only
    assert buf._slots[0] is a and buf._slots[1] is b
    c = buf.stage(np.arange(2))
    assert buf._slots[0] is c and buf._slots[1] is b
    np.testing.assert_array_equal(np.asarray(c[0]), np.arange(2))
    assert isinstance(c[0], jax.Array)


def test_shared_window_ring_reuses_and_swaps():
    SharedWindowRing.reset()
    try:
        key = ("test", 0, 2, 4, CFG)
        built = []
        factory = lambda: built.append(1) or WindowedBank.empty(2, 4, CFG)
        ring = SharedWindowRing.get_or_create(key, factory)
        again = SharedWindowRing.get_or_create(key, factory)
        assert again is ring and built == [1]  # factory ran exactly once
        advanced = ring.advance()
        assert SharedWindowRing.swap(key, advanced) is advanced
        assert SharedWindowRing.get_or_create(key, factory) is advanced
        assert built == [1]
    finally:
        SharedWindowRing.reset()


# ----------------------------------------------------------------------------
# serve-loop pins: the three launcher bugs stay fixed
# ----------------------------------------------------------------------------


def test_zero_elapsed_span_formats_instead_of_raising(monkeypatch):
    """A span quantized to 0.0s must yield a printable rate, not a crash."""
    monkeypatch.setattr(tracing.time, "perf_counter", lambda: 1234.5)
    with tracing.span("serve.prefill") as t:
        pass
    assert t.elapsed_s == 0.0
    # the exact serve.py report seam: fmt_rate(per_second(work, elapsed))
    assert fmt_rate(per_second(2048, t.elapsed_s), "tok") == "inf tok/s"
    assert per_second(0, t.elapsed_s) == 0.0
    assert per_second(-0.0, 0.0) == 0.0
    assert fmt_count(float("inf")) == "inf"
    assert fmt_count(float("-inf")) == "-inf"
    assert fmt_count(float("nan")) == "nan"


def test_empty_decode_slices_do_not_expire_prompt_epoch():
    """W > T: array_split's token-less tail slices must not advance.

    The serve loop splits T decode steps into W window slices; when
    --gen-len < --window-epochs the tail slices are empty.  Rotating on
    them expired the prompt epoch after fewer than W real slices — the
    rolling distinct count silently dropped the whole prompt.
    """
    W, B, S, T = 6, 3, 40, 2  # W > T: 4 of the 6 slices are empty
    rng = np.random.default_rng(3)
    # disjoint value ranges so prompt-vs-decode attribution is exact
    prompts = rng.integers(1 << 10, 1 << 20, (B, S)).astype(np.int32)
    out = rng.integers(0, 8, (B, T)).astype(np.int32)
    rows = np.broadcast_to(np.arange(B, dtype=np.int32)[:, None], (B, S))

    win = WindowedBank.empty(W, B, CFG).observe(rows, prompts)
    advances = 0
    for chunk in np.array_split(out, W, axis=1):
        if chunk.shape[1] == 0:
            continue  # the serve.py guard under test
        win = win.advance()
        advances += 1
        keys = np.broadcast_to(np.arange(B, dtype=np.int32)[:, None], chunk.shape)
        win = win.observe(keys, chunk)
    assert advances == T  # only REAL decode slices rotate the ring
    # prompt epoch alive: rolling window still counts the prompt tokens
    rolling = np.asarray(win.estimate_window())
    floor = 0.5 * S  # far above anything T<=2 decode tokens can explain
    assert (rolling > floor).all(), rolling
    # regression shape: advancing on every split slice expires the prompt
    bad = WindowedBank.empty(W, B, CFG).observe(rows, prompts)
    for chunk in np.array_split(out, W, axis=1):
        bad = bad.advance()
        if chunk.shape[1]:
            keys = np.broadcast_to(np.arange(B, dtype=np.int32)[:, None], chunk.shape)
            bad = bad.observe(keys, chunk)
    assert (np.asarray(bad.estimate_window()) < floor).all()


@pytest.mark.slow
def test_serve_launcher_end_to_end_sharded_report_every_zero(tmp_path):
    """The full launcher under the new flags: --placement sharded plus
    --report-every 0 must emit no periodic [metrics] lines (previously 0
    was clamped to every-request) while still writing the exit snapshot."""
    metrics_out = tmp_path / "metrics.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.serve",
            "--requests",
            "4",
            "--prompt-len",
            "16",
            "--gen-len",
            "2",
            "--window-epochs",
            "4",
            "--placement",
            "sharded",
            "--report-every",
            "0",
            "--metrics-out",
            str(metrics_out),
        ],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "[metrics]" not in out.stdout  # report-every 0: exit-only
    assert metrics_out.exists()
    import json

    snap = json.loads(metrics_out.read_text())
    assert snap["counters"]["serve.coalesce.ticks"] >= 1
    assert snap["counters"]["serve.coalesce.submitted"] >= 4
    assert snap["histograms"]["serve.request.seconds"]["count"] == 4
