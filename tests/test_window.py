"""WindowedBank: ring rotation, fused sliding-window estimates, RHLW format.

Acceptance property for the windowed subsystem (DESIGN.md §11): for EVERY
registered window backend (local and mesh placement), ``estimate_window``
over any suffix window is bit-identical to the naive
merge-each-bucket-then-estimate reference, for W up to 64 and B up to 256.
Plus: rotation/expiry exactness (after W rotations a bucket contributes
nothing, and a full-window estimate equals the merged-HyperLogLog union
bit-for-bit), exact per-bucket counters, the RHLW wire format with
garbage/truncation rejection, StreamSketch's windowed mode, and the
empty-ingest short-circuit (no backend dispatch for zero-length streams).
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.sketch import (
    ExecutionPlan,
    HLLConfig,
    HyperLogLog,
    SketchBank,
    WindowedBank,
    available_window_backends,
    estimate_many,
    get_window_backend,
    register_backend,
    register_bank_backend,
    update_many,
)
from repro.sketch.backends import bank_update_jnp, update_pipelined
from repro.telemetry.sketchboard import StreamSketch

CFG = HLLConfig(p=6, hash_bits=64)  # small m so the pallas paths run


def _chunk(n, rows, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, rows, n, dtype=np.int32))
    items = jnp.asarray(rng.integers(0, 2**31, n, dtype=np.int32))
    return keys, items


def _ring_from_chunks(window, rows, chunks, plan=None):
    """One epoch per chunk: observe, then advance into the next epoch."""
    win = WindowedBank.empty(window, rows, CFG)
    for e, (keys, items) in enumerate(chunks):
        if e:
            win = win.advance()
        win = win.observe(keys, items, plan)
    return win


def _naive_window(win, last_k):
    """The reference: merge each live bucket one by one, then estimate."""
    ring = np.asarray(win.registers)
    mask = np.asarray(win._live_mask(last_k))
    acc = np.zeros(ring.shape[1:], ring.dtype)
    for w in range(ring.shape[0]):
        if mask[w]:
            acc = np.maximum(acc, ring[w])
    return acc, np.asarray(estimate_many(jnp.asarray(acc), CFG))


def _plans():
    plans = [ExecutionPlan(backend=b) for b in available_window_backends()]
    plans += [
        ExecutionPlan(backend=b, pipelines=3) for b in available_window_backends()
    ]
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    plans += [
        ExecutionPlan(backend=b).with_mesh(mesh)
        for b in available_window_backends()
    ]
    return plans


# ----------------------------------------------------------------------------
# the fused fold vs the naive merge loop (the acceptance property)
# ----------------------------------------------------------------------------


def test_window_backends_registered():
    assert set(available_window_backends()) >= {
        "jnp",
        "pallas",
        "pallas_pipelined",
    }


def test_unknown_window_backend_raises_targeted():
    with pytest.raises(ValueError, match="no window fold path"):
        get_window_backend("definitely_not_registered")


@pytest.mark.parametrize("backend", available_window_backends())
def test_estimate_window_matches_naive_suffixes(backend):
    window, rows = 8, 17  # prime row count: divides no row block evenly
    chunks = [_chunk(700, rows, seed=100 + e) for e in range(11)]  # rotates past W
    win = _ring_from_chunks(window, rows, chunks)
    for last_k in (1, 2, 5, 8):
        ref_regs, ref_est = _naive_window(win, last_k)
        for pipelines in (1, 3, 8):
            plan = ExecutionPlan(backend=backend, pipelines=pipelines)
            fold = np.asarray(win._fold_registers(last_k, plan))
            np.testing.assert_array_equal(fold, ref_regs)
            got = np.asarray(win.estimate_window(last_k, plan))
            np.testing.assert_array_equal(got, ref_est)


def test_acceptance_w64_b256_bit_identical_all_plans():
    window, rows = 64, 256
    rng = np.random.default_rng(7)
    base = WindowedBank.empty(window, rows, CFG).advance_to(1000)
    regs = rng.integers(0, CFG.max_rank + 1, (window, rows, CFG.m), np.uint8)
    win = dataclasses.replace(base, registers=jnp.asarray(regs))
    for last_k in (1, 17, 64):
        ref_regs, ref_est = _naive_window(win, last_k)
        for plan in _plans():
            got = np.asarray(win.estimate_window(last_k, plan))
            np.testing.assert_array_equal(
                got, ref_est, err_msg=f"{plan.backend}/{plan.placement}/k={last_k}"
            )
        np.testing.assert_array_equal(
            np.asarray(win._fold_registers(last_k, None)), ref_regs
        )


def test_estimate_window_validates_last_k():
    win = WindowedBank.empty(4, 3, CFG)
    with pytest.raises(ValueError, match="last_k"):
        win.estimate_window(0)
    with pytest.raises(ValueError, match="last_k"):
        win.estimate_window(5)


# ----------------------------------------------------------------------------
# rotation, expiry, and the merged-union equivalence
# ----------------------------------------------------------------------------


def test_advance_expiry_is_exact():
    """After W rotations a bucket's items contribute nothing to any window."""
    window, rows = 4, 5
    poison = _chunk(3000, rows, seed=1)
    later = [_chunk(800, rows, seed=10 + e) for e in range(window)]
    nothing = (jnp.zeros((0,), jnp.int32),) * 2
    with_poison = _ring_from_chunks(window, rows, [poison] + later)
    without = _ring_from_chunks(window, rows, [nothing] + later)
    assert with_poison.epoch == without.epoch == window
    for last_k in range(1, window + 1):
        np.testing.assert_array_equal(
            np.asarray(with_poison._fold_registers(last_k, None)),
            np.asarray(without._fold_registers(last_k, None)),
        )


@pytest.mark.parametrize("backend", available_window_backends())
def test_full_window_equals_merged_union_bit_for_bit(backend):
    """Windowed estimate over all live buckets == merged-HLL union estimate."""
    window, rows = 5, 6
    chunks = [_chunk(1200, rows, seed=40 + e) for e in range(window)]
    win = _ring_from_chunks(window, rows, chunks)
    merged = []
    for b in range(rows):
        sk = HyperLogLog.empty(CFG)
        for keys, items in chunks:
            sel = np.asarray(items)[np.asarray(keys) == b]
            sk = sk.merge(HyperLogLog.of(jnp.asarray(sel), CFG))
        merged.append(sk)
    plan = ExecutionPlan(backend=backend)
    folded = win.fold_window(plan=plan)
    np.testing.assert_array_equal(
        np.asarray(folded.registers),
        np.stack([np.asarray(sk.registers) for sk in merged]),
    )
    # device path: one fused fold + estimate_many == union registers finalized
    np.testing.assert_array_equal(
        np.asarray(win.estimate_window(plan=plan)),
        np.asarray(estimate_many(jnp.stack([sk.registers for sk in merged]), CFG)),
    )
    # exact host path agrees row by row, bit for bit
    for b in range(rows):
        assert folded.row(b).estimate() == merged[b].estimate()


def test_advance_to_jump_expires_everything():
    rows = 3
    win = _ring_from_chunks(4, rows, [_chunk(500, rows, seed=2)])
    far = win.advance_to(win.epoch + 4)
    assert far.counts.sum() == 0
    assert np.asarray(far.registers).sum() == 0
    assert far.epoch == win.epoch + 4


def test_advance_to_is_monotone_and_keeps_invariants():
    win = _ring_from_chunks(4, 2, [_chunk(300, 2, seed=3)])
    win = win.advance_to(9)
    assert win.epoch == 9
    noop = win.advance_to(5)  # the past never returns
    assert noop.epoch == 9
    np.testing.assert_array_equal(
        np.asarray(noop.registers), np.asarray(win.registers)
    )
    for steps in (1, 2, 3, 5):
        win = win.advance(steps)
    epochs = np.asarray(win.epochs)
    window = win.window
    np.testing.assert_array_equal(np.mod(epochs, window), np.arange(window))
    assert epochs.max() == win.epoch and epochs.max() - epochs.min() == window - 1
    with pytest.raises(ValueError, match="steps"):
        win.advance(0)


def test_observe_counts_current_bucket_and_drops_bad_keys():
    rows = 7
    win = WindowedBank.empty(3, rows, CFG)
    keys, items = _chunk(2000, rows, seed=5)
    bad = np.asarray(keys).copy()
    bad[::5] = -1
    bad[::7] = rows + 2
    win = win.observe(jnp.asarray(bad), items)
    in_range = bad[(bad >= 0) & (bad < rows)]
    np.testing.assert_array_equal(win.counts[0], np.bincount(in_range, minlength=rows))
    assert win.counts[1:].sum() == 0  # only the current bucket moved
    ref = update_many(SketchBank.empty(rows, CFG), jnp.asarray(bad), items)
    np.testing.assert_array_equal(
        np.asarray(win.registers[0]), np.asarray(ref.registers)
    )
    win2 = win.advance()
    win2 = win2.observe(keys, items)
    assert int(win2.counts[1].sum()) == 2000  # epoch 1 lives in slot 1
    with pytest.raises(ValueError, match="same length"):
        win.observe(jnp.zeros((3,), jnp.int32), jnp.zeros((4,), jnp.int32))


def test_window_counts_sum_live_buckets():
    rows = 4
    chunks = [_chunk(600, rows, seed=60 + e) for e in range(5)]
    win = _ring_from_chunks(3, rows, chunks)
    per_epoch = [np.bincount(np.asarray(k), minlength=rows) for k, _ in chunks]
    np.testing.assert_array_equal(
        win.window_counts(), sum(per_epoch[2:])  # epochs 2..4 are live
    )
    np.testing.assert_array_equal(win.window_counts(1), per_epoch[4])


def test_with_rows_grows_and_refuses_shrink():
    win = _ring_from_chunks(3, 2, [_chunk(400, 2, seed=8)])
    grown = win.with_rows(5)
    assert grown.rows == 5 and grown.window == 3
    np.testing.assert_array_equal(
        np.asarray(grown.registers[:, :2]), np.asarray(win.registers)
    )
    assert np.asarray(grown.registers[:, 2:]).sum() == 0
    assert grown.with_rows(5) is grown
    with pytest.raises(ValueError, match="shrink"):
        grown.with_rows(4)


def test_empty_validates_shape():
    with pytest.raises(ValueError, match="bucket"):
        WindowedBank.empty(0, 4, CFG)
    with pytest.raises(ValueError, match="row"):
        WindowedBank.empty(4, 0, CFG)


def test_windowed_bank_is_a_pytree_and_jits():
    win = _ring_from_chunks(3, 4, [_chunk(300, 4, seed=9)])
    leaves = jax.tree_util.tree_leaves(win)
    assert len(leaves) == 4  # registers, counters, cursor, epochs; cfg static

    @jax.jit
    def step(w, keys, items):
        return w.advance().observe(keys, items)

    keys, items = _chunk(256, 4, seed=10)
    out = step(win, keys, items)
    assert isinstance(out, WindowedBank) and out.cfg == CFG
    ref = win.advance().observe(keys, items)
    np.testing.assert_array_equal(np.asarray(out.registers), np.asarray(ref.registers))
    np.testing.assert_array_equal(np.asarray(out.epochs), np.asarray(ref.epochs))


# ----------------------------------------------------------------------------
# RHLW wire format (roundtrip + garbage/truncation rejection)
# ----------------------------------------------------------------------------


def test_rhlw_roundtrip():
    win = _ring_from_chunks(3, 5, [_chunk(900, 5, seed=20 + e) for e in range(4)])
    blob = win.to_bytes()
    bucket = 20 + 5 * 8 + 5 * CFG.m
    assert len(blob) == 28 + 3 * 4 + 3 * bucket
    back = WindowedBank.from_bytes(blob)
    assert back.cfg == win.cfg
    assert int(back.cursor) == int(win.cursor) and back.epoch == win.epoch
    np.testing.assert_array_equal(
        np.asarray(back.registers), np.asarray(win.registers)
    )
    np.testing.assert_array_equal(np.asarray(back.epochs), np.asarray(win.epochs))
    np.testing.assert_array_equal(back.counts, win.counts)
    np.testing.assert_array_equal(
        np.asarray(back.estimate_window()), np.asarray(win.estimate_window())
    )


def test_rhlw_rejects_garbage():
    win = _ring_from_chunks(2, 3, [_chunk(500, 3, seed=30)])
    blob = win.to_bytes()
    with pytest.raises(ValueError, match="magic"):
        WindowedBank.from_bytes(b"NOPE" + blob[4:])
    with pytest.raises(ValueError, match="version"):
        WindowedBank.from_bytes(blob[:4] + b"\x09" + blob[5:])
    bad_cursor = bytearray(blob)
    bad_cursor[24:28] = (7).to_bytes(4, "little")  # cursor >= W
    with pytest.raises(ValueError, match="cursor"):
        WindowedBank.from_bytes(bytes(bad_cursor))
    bad_epochs = bytearray(blob)
    bad_epochs[28:36] = b"\xff" * 8  # epoch labels off the ring
    with pytest.raises(ValueError, match="epoch"):
        WindowedBank.from_bytes(bytes(bad_epochs))
    bucket_magic = bytearray(blob)
    bucket_magic[36:40] = b"JUNK"  # first bucket's RHLB magic
    with pytest.raises(ValueError, match="magic"):
        WindowedBank.from_bytes(bytes(bucket_magic))


@pytest.mark.parametrize("frac", [0.0, 0.1, 0.3, 0.5, 0.8, 0.99])
def test_rhlw_rejects_truncation_anywhere(frac):
    win = _ring_from_chunks(3, 4, [_chunk(700, 4, seed=31)])
    blob = win.to_bytes()
    cut = int(len(blob) * frac)
    with pytest.raises(ValueError):
        WindowedBank.from_bytes(blob[:cut])
    with pytest.raises(ValueError, match="payload|truncated"):
        WindowedBank.from_bytes(blob + b"\x00")


# ----------------------------------------------------------------------------
# StreamSketch windowed mode
# ----------------------------------------------------------------------------


def _windowed_board(window=3, plan=None):
    return StreamSketch(CFG, plan=plan, window=window)


def test_board_window_mode_reports_rolling_counts():
    board = _windowed_board(window=2)
    rng = np.random.default_rng(0)
    old = jnp.asarray(rng.integers(0, 1 << 20, 4000, np.int32))
    board.observe("users", old)
    board.advance()
    fresh = jnp.asarray(rng.integers(0, 50, 4000, np.int32))
    board.observe("users", fresh)
    both = board.report()["users"]
    assert both["items_seen"] == 8000
    board.advance()  # `old` slides out of the 2-epoch window
    rolled = board.report()["users"]
    assert rolled["items_seen"] == 4000
    assert rolled["estimate"] < both["estimate"] / 10
    # flat-board schema is preserved
    assert set(rolled) == {
        "estimate",
        "items_seen",
        "duplication",
        "stderr_expected",
    }


def test_board_window_reads_flush_first():
    board = _windowed_board(window=3)
    items = jnp.arange(1000, dtype=jnp.int32)
    board.observe("s", items)  # buffered, not yet flushed
    rep = board.report()  # must flush before reading
    assert rep["s"]["items_seen"] == 1000
    board.observe("s", items)
    assert board.stream("s").count == 2000  # stream() flushes too
    est = board.estimate("s")
    assert abs(est - rep["s"]["estimate"]) / rep["s"]["estimate"] < 1e-6


def test_board_window_exact_report_matches_batched():
    board = _windowed_board(window=2)
    rng = np.random.default_rng(4)
    for e in range(3):
        if e:
            board.advance()
        board.observe("a", jnp.asarray(rng.integers(0, 9000, 3000, np.int32)))
        board.observe("b", jnp.asarray(rng.integers(0, 80, 3000, np.int32)))
    fast = board.report()
    exact = board.report(exact=True)
    for name in ("a", "b"):
        assert fast[name]["items_seen"] == exact[name]["items_seen"]
        rel = abs(fast[name]["estimate"] - exact[name]["estimate"])
        assert rel / exact[name]["estimate"] < 1e-4


def test_board_window_bytes_roundtrip_and_rows():
    board = _windowed_board(window=2)
    board.observe("x", jnp.arange(500, dtype=jnp.int32))
    board.observe("y", jnp.arange(300, dtype=jnp.int32))
    assert board.window_rows() == ("x", "y")
    back = WindowedBank.from_bytes(board.window_bytes())
    assert back.window == 2 and back.rows == 2
    np.testing.assert_array_equal(
        back.window_counts(), np.asarray([500, 300], np.uint64)
    )


def test_board_window_mode_guards():
    flat = StreamSketch(CFG)
    with pytest.raises(ValueError, match="windowed board"):
        flat.advance()
    with pytest.raises(ValueError, match="windowed board"):
        flat.window_bytes()
    with pytest.raises(ValueError, match="at least one bucket"):
        StreamSketch(CFG, window=0)
    board = _windowed_board()
    board.observe("s", jnp.arange(10, dtype=jnp.int32))
    with pytest.raises(ValueError, match="window_bytes"):
        board.serialize()
    with pytest.raises(ValueError, match="do not merge"):
        board.merge_from(_windowed_board())
    with pytest.raises(ValueError, match="do not merge"):
        flat.merge_from(board)


# ----------------------------------------------------------------------------
# empty-ingest short-circuit (no backend dispatch for zero-length streams)
# ----------------------------------------------------------------------------

_SPY_CALLS = {"n": 0}


# the spies delegate to the real jnp paths so bit-identity suites that sweep
# every registered backend at runtime keep passing even with them registered
@register_backend("spy_counting_jnp")
def _spy_backend(registers, items, cfg, plan):
    _SPY_CALLS["n"] += 1
    return update_pipelined(registers, items, cfg, plan.pipelines)


@register_bank_backend("spy_counting_jnp")
def _spy_bank_backend(registers, keys, items, cfg, plan):
    _SPY_CALLS["n"] += 1
    return bank_update_jnp(registers, keys, items, cfg)


def test_empty_update_dispatches_no_backend():
    plan = ExecutionPlan(backend="spy_counting_jnp")
    sk = HyperLogLog.empty(CFG)
    _SPY_CALLS["n"] = 0
    out = sk.update(jnp.zeros((0,), jnp.int32), plan)
    assert _SPY_CALLS["n"] == 0 and out is sk
    out = out.update(jnp.zeros((0, 7), jnp.int32), plan)  # empty 2-d too
    assert _SPY_CALLS["n"] == 0
    out = out.update(jnp.arange(8, dtype=jnp.int32), plan)
    assert _SPY_CALLS["n"] == 1 and out.count == 8


def test_empty_update_many_dispatches_no_backend():
    plan = ExecutionPlan(backend="spy_counting_jnp")
    bank = SketchBank.empty(4, CFG)
    _SPY_CALLS["n"] = 0
    empty = jnp.zeros((0,), jnp.int32)
    out = bank.update_many(empty, empty, plan)
    assert _SPY_CALLS["n"] == 0 and out is bank
    with pytest.raises(ValueError, match="same length"):
        bank.update_many(jnp.zeros((2,), jnp.int32), empty, plan)
    assert _SPY_CALLS["n"] == 0  # validation still precedes the short-circuit
    keys, items = _chunk(64, 4, seed=50)
    out = out.update_many(keys, items, plan)
    assert _SPY_CALLS["n"] == 1 and out.counts.sum() == 64


def test_empty_windowed_observe_dispatches_no_backend():
    plan = ExecutionPlan(backend="spy_counting_jnp")
    win = WindowedBank.empty(2, 3, CFG)
    _SPY_CALLS["n"] = 0
    empty = jnp.zeros((0,), jnp.int32)
    assert win.observe(empty, empty, plan) is win
    assert _SPY_CALLS["n"] == 0


def test_zero_row_bank_dispatches_no_backend():
    """B=0 regression (alongside the zero-length-ingest spies): a bank
    with no rows must short-circuit before any backend dispatch even for
    a NON-empty stream — every key is out of range by definition."""
    plan = ExecutionPlan(backend="spy_counting_jnp")
    bank = SketchBank(
        jnp.zeros((0, CFG.m), jnp.uint8), jnp.zeros((0, 2), jnp.uint32), CFG
    )
    _SPY_CALLS["n"] = 0
    keys, items = _chunk(32, 4, seed=51)
    assert bank.update_many(keys, items, plan) is bank
    assert _SPY_CALLS["n"] == 0
    # the functional entry point short-circuits identically
    from repro.sketch import update_bank_registers

    regs = update_bank_registers(bank.registers, keys, items, CFG, plan)
    assert _SPY_CALLS["n"] == 0 and regs.shape == (0, CFG.m)


def test_hybrid_observe_empty_dispatches_no_backend():
    from repro.sketch import HybridWindowedBank

    plan = ExecutionPlan(backend="spy_counting_jnp")
    win = HybridWindowedBank.empty(2, 3, CFG, threshold=8)
    _SPY_CALLS["n"] = 0
    empty = jnp.zeros((0,), jnp.int32)
    assert win.observe(empty, empty, plan) is win
    assert _SPY_CALLS["n"] == 0


# ----------------------------------------------------------------------------
# RHLW v2 interop fuzz: v1<->v2 mixed rings must raise, never mis-parse
# ----------------------------------------------------------------------------


def test_v1_parser_rejects_v2_ring_and_v1_ring_with_v2_bucket():
    from repro.sketch import HybridWindowedBank

    win = _ring_from_chunks(2, 3, [_chunk(500, 3, seed=61)])
    v1 = win.to_bytes()
    hybrid = HybridWindowedBank.empty(2, 3, CFG, threshold=8).observe(
        *_chunk(500, 3, seed=61)
    )
    v2 = hybrid.to_bytes()
    # the dense parser points v2 rings at the hybrid one
    with pytest.raises(ValueError, match="version 2.*HybridWindowedBank"):
        WindowedBank.from_bytes(v2)
    # a v1 ring whose first bucket payload is spliced with v2 bucket bytes
    # fails the fixed-size layout checks (length or bucket version)
    v2_bucket = hybrid.buckets[0].to_bytes()
    spliced = v1[:40] + v2_bucket + v1[40 + len(v2_bucket) :]
    with pytest.raises(ValueError):
        WindowedBank.from_bytes(spliced[: len(v1)])
    # a v2 ring truncated anywhere (including inside a bucket payload)
    for frac in (0.05, 0.3, 0.6, 0.95):
        with pytest.raises(ValueError):
            HybridWindowedBank.from_bytes(v2[: int(len(v2) * frac)])
    with pytest.raises(ValueError):
        HybridWindowedBank.from_bytes(v2 + b"\x00")


def test_v2_ring_accepts_embedded_v1_dense_bucket():
    """The length-prefixed v2 frame may legitimately carry a v1 dense
    bucket blob (dense blobs still parse, version-gated); swapping one in
    must round-trip, not raise."""
    import struct as _struct

    from repro.sketch import HybridWindowedBank, update_many as _um

    keys, items = _chunk(400, 3, seed=62)
    hybrid = HybridWindowedBank.empty(2, 3, CFG, threshold=8).observe(keys, items)
    dense_bucket = _um(SketchBank.empty(3, CFG), keys, items)
    blob = hybrid.to_bytes()
    # rebuild the frame with bucket 0 replaced by the v1 dense payload
    off = 28 + 2 * 4
    out = [blob[:off]]
    v1_payload = dense_bucket.to_bytes()
    for w in range(2):
        (blen,) = _struct.unpack_from("<Q", blob, off)
        off += 8
        payload = blob[off : off + blen]
        off += blen
        if w == 0:
            payload = v1_payload
        out.append(_struct.pack("<Q", len(payload)))
        out.append(payload)
    back = HybridWindowedBank.from_bytes(b"".join(out))
    np.testing.assert_array_equal(
        np.asarray(back.buckets[0].to_dense().registers),
        np.asarray(dense_bucket.registers),
    )
