"""HLL Algorithm-1 behaviour: accuracy bands, corrections, lattice laws."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.sketch import ExecutionPlan, HyperLogLog, hll, update_registers
from repro.sketch import exact as exactlib
from repro.sketch.hll import HLLConfig

CFG64 = HLLConfig(p=14, hash_bits=64)
CFG32 = HLLConfig(p=14, hash_bits=32)


def _rand_items(n, seed=0):
    return np.random.default_rng(seed).integers(0, 2**31, n, dtype=np.int32)


# ----------------------------------------------------------------------------
# accuracy
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("p,H", [(14, 32), (14, 64), (16, 32), (16, 64)])
def test_accuracy_within_band(p, H):
    """Paper Fig. 1: error stays within a few sigma outside the LC transition."""
    cfg = HLLConfig(p=p, hash_bits=H)
    n = 40 * cfg.m  # well past the 5/2*m transition zone
    items = _rand_items(n, seed=p * H)
    est = hll.cardinality(jnp.asarray(items), cfg)
    ex = exactlib.exact_distinct(items)
    assert abs(est - ex) / ex < 5 * hll.standard_error(cfg)


def test_small_range_uses_linear_counting():
    """n << m: estimate must be the LC value and be very accurate."""
    cfg = CFG64
    items = _rand_items(500, seed=1)
    regs = hll.update(hll.init_registers(cfg), jnp.asarray(items), cfg)
    est = hll.estimate(regs, cfg)
    v = int(np.count_nonzero(np.asarray(regs) == 0))
    assert est == pytest.approx(cfg.m * math.log(cfg.m / v))
    assert abs(est - 500) / 500 < 0.03


def test_large_range_correction_32bit():
    """H=32 with nearly-saturated registers triggers the 2^32 correction."""
    cfg = HLLConfig(p=14, hash_bits=32)
    # synthetic registers deep enough that E > 2^32/30
    regs = np.full(cfg.m, 18, np.uint8)
    e = hll.estimate(jnp.asarray(regs), cfg)
    raw = hll.alpha(cfg.m) * cfg.m * cfg.m / (cfg.m * 2.0**-18)
    assert raw > 2**32 / 30
    assert e == pytest.approx(-(2.0**32) * math.log(1 - raw / 2**32))
    # 64-bit hash: same registers, no large-range correction applied
    cfg64 = HLLConfig(p=14, hash_bits=64)
    assert hll.estimate(jnp.asarray(regs), cfg64) == pytest.approx(raw)


def test_device_estimator_matches_host():
    cfg = CFG64
    for n in (100, 5_000, 300_000):
        regs = hll.update(
            hll.init_registers(cfg), jnp.asarray(_rand_items(n, seed=n)), cfg
        )
        host = hll.estimate(regs, cfg)
        dev = float(hll.estimate_device(regs, cfg))
        assert abs(dev - host) / host < 1e-4


def test_memory_footprint_table2():
    """Paper Tab. II: footprints for (p,H) in {14,16}x{32,64}."""
    kib = lambda cfg: cfg.memory_footprint_bits / 8 / 1024
    assert kib(HLLConfig(p=14, hash_bits=32)) == 10
    assert kib(HLLConfig(p=14, hash_bits=64)) == 12
    assert kib(HLLConfig(p=16, hash_bits=32)) == 40
    assert kib(HLLConfig(p=16, hash_bits=64)) == 48
    assert HLLConfig(p=14, hash_bits=32).register_bits == 5
    assert HLLConfig(p=16, hash_bits=64).register_bits == 6


def test_max_rank_eq2():
    assert HLLConfig(p=16, hash_bits=64).max_rank == 49
    assert HLLConfig(p=14, hash_bits=32).max_rank == 19


# ----------------------------------------------------------------------------
# lattice / merge laws (the basis for the paper's multi-pipeline fold)
# ----------------------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(
    st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200),
    st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200),
)
def test_merge_equals_union(xs, ys):
    cfg = HLLConfig(p=8, hash_bits=64)
    a = hll.update(hll.init_registers(cfg), jnp.asarray(xs, jnp.int32), cfg)
    b = hll.update(hll.init_registers(cfg), jnp.asarray(ys, jnp.int32), cfg)
    both = hll.update(
        hll.init_registers(cfg), jnp.asarray(xs + ys, jnp.int32), cfg
    )
    np.testing.assert_array_equal(np.asarray(hll.merge(a, b)), np.asarray(both))


@settings(deadline=None, max_examples=20)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200))
def test_update_idempotent_and_permutation_invariant(xs):
    cfg = HLLConfig(p=8, hash_bits=32)
    arr = jnp.asarray(xs, jnp.int32)
    once = hll.update(hll.init_registers(cfg), arr, cfg)
    twice = hll.update(once, arr, cfg)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
    perm = jnp.asarray(list(reversed(xs)), jnp.int32)
    p_regs = hll.update(hll.init_registers(cfg), perm, cfg)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(p_regs))


def test_monotone_in_data():
    cfg = CFG64
    items = _rand_items(10_000, seed=5)
    r1 = hll.update(hll.init_registers(cfg), jnp.asarray(items[:5000]), cfg)
    r2 = hll.update(r1, jnp.asarray(items[5000:]), cfg)
    assert (np.asarray(r2) >= np.asarray(r1)).all()


# ----------------------------------------------------------------------------
# multi-pipeline (paper Fig. 3)
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("pipelines", [1, 2, 4, 8, 16])
def test_pipelined_equals_single(pipelines):
    """k pipelines + merge-buckets fold == one pipeline, bit-for-bit."""
    cfg = HLLConfig(p=12, hash_bits=64)
    items = jnp.asarray(_rand_items(1 << 14, seed=9))
    single = hll.update(hll.init_registers(cfg), items, cfg)
    multi = update_registers(
        hll.init_registers(cfg), items, cfg,
        ExecutionPlan(backend="jnp", pipelines=pipelines),
    )
    np.testing.assert_array_equal(np.asarray(single), np.asarray(multi))


def test_sketch_carrier_merge():
    cfg = HLLConfig(p=10, hash_bits=64)
    a = HyperLogLog.empty(cfg).update(jnp.asarray(_rand_items(1000, 1)))
    b = HyperLogLog.empty(cfg).update(jnp.asarray(_rand_items(1000, 2)))
    ab = a | b
    assert ab.count == 2000
    assert (np.asarray(ab.registers) >= np.asarray(a.registers)).all()


def test_update_sharded_matches_local():
    """Device-merged sketch == single-device sketch on the same stream."""
    cfg = HLLConfig(p=10, hash_bits=64)
    items = jnp.asarray(_rand_items(1 << 12, seed=11))
    devs = jax.devices()
    mesh = jax.make_mesh((len(devs),), ("data",))
    plan = ExecutionPlan(backend="jnp", placement="mesh", mesh=mesh, pipelines=1)
    out = update_registers(hll.init_registers(cfg), items, cfg, plan)
    ref = hll.update(hll.init_registers(cfg), items, cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ----------------------------------------------------------------------------
# baselines
# ----------------------------------------------------------------------------


def test_linear_counting_standalone():
    cfg = HLLConfig(p=14, hash_bits=32)
    items = _rand_items(3000, seed=13)
    bm = exactlib.linear_counting_registers(jnp.asarray(items), cfg)
    est = exactlib.linear_counting_estimate(bm, cfg.m)
    ex = exactlib.exact_distinct(items)
    assert abs(est - ex) / ex < 0.05


def test_sublinear_memory_motivation():
    """Paper §I: sketch memory constant vs naive linear growth."""
    cfg = HLLConfig(p=16, hash_bits=64)
    assert cfg.memory_footprint_bits / 8 == 48 * 1024
    assert exactlib.naive_distinct_mem_bytes(10**9) > 1000 * cfg.memory_footprint_bits
