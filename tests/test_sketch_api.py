"""The unified repro.sketch API: plan equivalence, carrier, serialization.

Acceptance property for the API redesign: every registered
(backend, placement, pipelines) ExecutionPlan produces registers
bit-identical to the single-pipeline jnp reference on the same stream —
including streams whose length divides nothing (uniform padding, never an
error).  Plus: the overflow-safe item counter, to_bytes/from_bytes, set
algebra on the carrier, and the deprecated shims.
"""

import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.sketch import (
    DEFAULT_PIPELINES,
    ExecutionPlan,
    HLLConfig,
    HyperLogLog,
    available_backends,
    example_plans,
    hll,
    reference_plan,
    update_registers,
)
from repro.sketch.carrier import _counter_add

CFG = HLLConfig(p=10, hash_bits=64)  # p <= 12 so every backend is eligible


def _items(n, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).integers(0, 2**31, n, dtype=np.int32)
    )


def _mesh():
    return jax.make_mesh((jax.device_count(),), ("data",))


def _plan_id(plan):
    return f"{plan.backend}-{plan.placement}-k{plan.pipelines}"


PLANS = example_plans(mesh=_mesh())


# ----------------------------------------------------------------------------
# plan equivalence (the acceptance property)
# ----------------------------------------------------------------------------


def test_all_backends_registered():
    assert set(available_backends()) >= {"jnp", "pallas", "pallas_pipelined"}


@pytest.mark.parametrize("plan", PLANS, ids=_plan_id)
@pytest.mark.parametrize("n", [1, 4096, 4099])  # 4099 is prime: pads everywhere
def test_every_plan_matches_reference(plan, n):
    items = _items(n, seed=n)
    ref = update_registers(
        hll.init_registers(CFG), items, CFG, reference_plan()
    )
    got = update_registers(hll.init_registers(CFG), items, CFG, plan)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_non_divisible_stream_pads_instead_of_raising():
    """The old update_pipelined raised on n % k != 0; the new API must not."""
    items = _items(1001, seed=3)
    for k in (2, 4, DEFAULT_PIPELINES, 16):
        got = update_registers(
            hll.init_registers(CFG), items, CFG,
            ExecutionPlan(backend="jnp", pipelines=k),
        )
        ref = hll.update(hll.init_registers(CFG), items, CFG)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        update_registers(
            hll.init_registers(CFG), _items(16), CFG,
            ExecutionPlan(backend="vhdl"),
        )
    with pytest.raises(ValueError, match="placement"):
        ExecutionPlan(placement="fpga")
    with pytest.raises(ValueError, match="mesh"):
        ExecutionPlan(placement="mesh")


@settings(deadline=None, max_examples=10)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=300))
def test_plan_equivalence_property(xs):
    items = jnp.asarray(xs, jnp.int32)
    ref = hll.update(hll.init_registers(CFG), items, CFG)
    for plan in PLANS:
        got = update_registers(hll.init_registers(CFG), items, CFG, plan)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ----------------------------------------------------------------------------
# HyperLogLog carrier
# ----------------------------------------------------------------------------


def test_carrier_is_a_pytree_and_jits():
    sk = HyperLogLog.of(_items(1000), CFG)
    leaves = jax.tree_util.tree_leaves(sk)
    assert len(leaves) == 2  # registers + counter limbs; cfg is static

    @jax.jit
    def bump(s, items):
        return s.update(items)

    out = bump(sk, _items(500, seed=9))
    assert isinstance(out, HyperLogLog) and out.cfg == CFG
    assert out.count == 1500


def test_counter_is_overflow_safe_past_int32():
    """int32 overflowed at 2.1e9 items; the limb counter must not."""
    near_wrap = jnp.asarray(np.array([0, 0xFFFFFFFF], np.uint32))
    sk = HyperLogLog(hll.init_registers(CFG), near_wrap, CFG)
    assert sk.count == 2**32 - 1
    sk = sk.update(_items(3))
    assert sk.count == 2**32 + 2  # crossed the 32-bit boundary exactly
    # and limb arithmetic keeps carrying well past any int32/uint32 range
    big = _counter_add(sk.n_items, (200 * 10**9))
    assert (int(big[0]) << 32 | int(big[1])) == 2**32 + 2 + 200 * 10**9


def test_merge_checks_config_and_adds_counters():
    a = HyperLogLog.of(_items(100, 1), CFG)
    b = HyperLogLog.of(_items(200, 2), CFG)
    ab = a | b
    assert ab.count == 300
    with pytest.raises(ValueError, match="configs"):
        a.merge(HyperLogLog.empty(HLLConfig(p=12, hash_bits=64)))
    with pytest.raises(ValueError, match="configs"):
        a.jaccard(HyperLogLog.empty(HLLConfig(p=10, hash_bits=32)))


def test_carrier_set_algebra_matches_module_functions():
    from repro.sketch import setops

    a = HyperLogLog.of(jnp.arange(0, 60_000, dtype=jnp.int32), CFG)
    b = HyperLogLog.of(jnp.arange(30_000, 90_000, dtype=jnp.int32), CFG)
    assert a.union_estimate(b) == setops.union_estimate(
        a.registers, b.registers, CFG
    )
    assert a.intersection_estimate(b) == setops.intersection_estimate(a, b, CFG)
    assert 0.0 <= a.jaccard(b) <= 1.0


# ----------------------------------------------------------------------------
# serialization
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("p,H", [(10, 32), (10, 64), (16, 64)])
def test_bytes_roundtrip(p, H):
    cfg = HLLConfig(p=p, hash_bits=H, seed=7)
    sk = HyperLogLog.of(_items(5000, seed=p * H), cfg)
    blob = sk.to_bytes()
    assert len(blob) == 24 + cfg.m
    back = HyperLogLog.from_bytes(blob)
    assert back.cfg == cfg
    assert back.count == sk.count == 5000
    np.testing.assert_array_equal(
        np.asarray(back.registers), np.asarray(sk.registers)
    )
    assert back.estimate() == sk.estimate()


def test_bytes_rejects_garbage():
    with pytest.raises(ValueError, match="truncated"):
        HyperLogLog.from_bytes(b"xx")
    with pytest.raises(ValueError, match="magic"):
        HyperLogLog.from_bytes(b"NOPE" + bytes(20 + CFG.m))
    blob = HyperLogLog.empty(CFG).to_bytes()
    with pytest.raises(ValueError, match="payload"):
        HyperLogLog.from_bytes(blob[:-1])


def test_serialized_sketches_merge_across_boundaries():
    """The wire format carries everything a remote merge needs."""
    a = HyperLogLog.of(_items(4000, 1), CFG)
    b = HyperLogLog.of(_items(4000, 2), CFG)
    remote = HyperLogLog.from_bytes(a.to_bytes()) | HyperLogLog.from_bytes(
        b.to_bytes()
    )
    local = a | b
    np.testing.assert_array_equal(
        np.asarray(remote.registers), np.asarray(local.registers)
    )
    assert remote.count == local.count == 8000


# ----------------------------------------------------------------------------
# deprecated shims stay importable and equivalent
# ----------------------------------------------------------------------------


def test_raw_kernel_modules_import_standalone():
    """repro.kernels.* must be importable as a process's first import
    (regression: the sketch<->kernels cycle broke this)."""
    import os
    import subprocess
    import sys

    r = subprocess.run(
        [sys.executable, "-c",
         "import repro.kernels.hash_rank, repro.kernels.hll_fused, "
         "repro.kernels.bucket_fold, repro.kernels.ref"],
        capture_output=True, text=True, env=dict(os.environ),
    )
    assert r.returncode == 0, r.stderr


def test_legacy_shims_warn_and_match():
    items = _items(2048, seed=11)
    ref = hll.update(hll.init_registers(CFG), items, CFG)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        import repro.core.hll as legacy_hll
        import repro.core.sketch as legacy_sketch
        from repro.core import setops as legacy_setops  # noqa: F401
        from repro.kernels import ops as legacy_ops
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)

    assert legacy_hll.HLLConfig is HLLConfig
    np.testing.assert_array_equal(
        np.asarray(legacy_hll.update(hll.init_registers(CFG), items, CFG)),
        np.asarray(ref),
    )
    np.testing.assert_array_equal(
        np.asarray(
            legacy_sketch.update_pipelined(
                hll.init_registers(CFG), items, CFG, pipelines=4
            )
        ),
        np.asarray(ref),
    )
    np.testing.assert_array_equal(
        np.asarray(
            legacy_ops.pipelined_update(
                hll.init_registers(CFG), items, CFG, 4, interpret=True
            )
        ),
        np.asarray(ref),
    )
