"""Pure-python reference model + adapters for the differential harness.

The oracle is a dict-of-sets model of everything the bank subsystem
promises: per-row sets of observed item values (the TRUE distinct counts),
exact per-row observation counters, §9 key-routing drop rules, window
epochs as a bounded deque of per-row sets, and merge as set union.  It
never touches jax, so any disagreement localizes to the implementation.

``run_ops`` drives an op sequence through (oracle, system-under-test)
pairs.  The SUT adapters wrap each storage carrier behind one uniform
surface:

  update(keys, items)   keyed ingest (out-of-range keys included)
  merge(keys, items)    build a sibling carrier from a second stream and
                        fold it in (flat carriers only)
  advance(steps)        open new epochs (windowed carriers only)
  roundtrip()           serialize -> deserialize, state must survive
  peek()                a read at an adversarial point: hybrid carriers
                        settle their append buffer here (forcing the
                        deferred dedup mid-sequence), everything else
                        no-ops — the oracle is unaffected by definition
  estimates(estimator)  (B,) float estimates over the live window
  canonical()           a tuple of numpy arrays that must be BIT-IDENTICAL
                        across every registered backend for the same op
                        sequence (registers, counters, and for hybrid
                        carriers the per-row mode flags)

Op sequences are plain tuples so the same grammar serves the
deterministic fixed-seed sweeps and the hypothesis strategies in
tests/test_differential.py.
"""

from collections import Counter

import numpy as np
import jax.numpy as jnp

from repro.sketch import (
    CMConfig,
    CountMinBank,
    ExecutionPlan,
    HybridBank,
    HybridWindowedBank,
    SketchBank,
    WindowedBank,
    WindowedCountMinBank,
)


class ReferenceModel:
    """Dict-of-sets oracle for (windowed) multi-tenant cardinality."""

    def __init__(self, rows, window=None):
        self.rows = rows
        self.window = window
        self.epoch_sets = [self._fresh_sets()]
        self.epoch_counts = [np.zeros(rows, np.int64)]

    def _fresh_sets(self):
        return [set() for _ in range(self.rows)]

    def update(self, keys, items):
        cur_sets = self.epoch_sets[-1]
        cur_counts = self.epoch_counts[-1]
        for k, x in zip(np.asarray(keys), np.asarray(items)):
            k = int(k)
            if 0 <= k < self.rows:  # §9: out-of-range keys drop silently
                cur_sets[k].add(int(x))
                cur_counts[k] += 1

    def merge(self, other):
        assert self.window is None and other.window is None
        for r in range(self.rows):
            self.epoch_sets[-1][r] |= other.epoch_sets[-1][r]
        self.epoch_counts[-1] += other.epoch_counts[-1]

    def advance(self, steps=1):
        assert self.window is not None
        for _ in range(steps):
            self.epoch_sets.append(self._fresh_sets())
            self.epoch_counts.append(np.zeros(self.rows, np.int64))
            if len(self.epoch_sets) > self.window:
                self.epoch_sets.pop(0)
                self.epoch_counts.pop(0)

    def true_cardinalities(self):
        """(B,) exact distinct counts over the live window."""
        out = np.zeros(self.rows, np.int64)
        for r in range(self.rows):
            live = set()
            for sets in self.epoch_sets:
                live |= sets[r]
            out[r] = len(live)
        return out

    def observed(self):
        """(B,) exact observation counts over the live window."""
        return np.sum(self.epoch_counts, axis=0).astype(np.uint64)


class CounterReferenceModel:
    """Dict-of-Counters oracle for (windowed) multi-tenant frequencies.

    The exact twin of :class:`ReferenceModel` for the count-min family:
    per-row Counters of observed values (the TRUE frequencies), exact
    observation counters, the same §9 drop rules, window epochs as a
    bounded deque of Counter lists, merge as Counter addition.
    ``true_counts(probe)`` and ``top_k(k)`` are the ground truths the
    count-min queries and Topkapi recovery are held against.
    """

    def __init__(self, rows, window=None):
        self.rows = rows
        self.window = window
        self.epoch_counters = [self._fresh()]
        self.epoch_counts = [np.zeros(rows, np.int64)]

    def _fresh(self):
        return [Counter() for _ in range(self.rows)]

    def update(self, keys, items):
        cur = self.epoch_counters[-1]
        cur_counts = self.epoch_counts[-1]
        for k, x in zip(np.asarray(keys), np.asarray(items)):
            k = int(k)
            if 0 <= k < self.rows:  # §9: out-of-range keys drop silently
                cur[k][int(x)] += 1
                cur_counts[k] += 1

    def merge(self, other):
        assert self.window is None and other.window is None
        for r in range(self.rows):
            self.epoch_counters[-1][r] += other.epoch_counters[-1][r]
        self.epoch_counts[-1] += other.epoch_counts[-1]

    def advance(self, steps=1):
        assert self.window is not None
        for _ in range(steps):
            self.epoch_counters.append(self._fresh())
            self.epoch_counts.append(np.zeros(self.rows, np.int64))
            if len(self.epoch_counters) > self.window:
                self.epoch_counters.pop(0)
                self.epoch_counts.pop(0)

    def live_counters(self):
        """(B,) Counters of the live window (all epochs folded)."""
        out = [Counter() for _ in range(self.rows)]
        for epoch in self.epoch_counters:
            for r in range(self.rows):
                out[r] += epoch[r]
        return out

    def true_counts(self, probe):
        """(B, n) exact frequencies of ``probe`` over the live window."""
        live = self.live_counters()
        probe = np.asarray(probe)
        out = np.zeros((self.rows, probe.size), np.int64)
        for r in range(self.rows):
            for j, v in enumerate(probe):
                out[r, j] = live[r][int(v)]
        return out

    def top_k(self, k):
        """Per-row true top-k value sets (count-desc, ties value-desc)."""
        live = self.live_counters()
        return [
            [
                v
                for v, _ in sorted(
                    c.items(), key=lambda kv: (-kv[1], -kv[0])
                )[:k]
            ]
            for c in live
        ]

    def true_cardinalities(self):
        """(B,) exact distinct counts over the live window."""
        return np.array([len(c) for c in self.live_counters()], np.int64)

    def observed(self):
        """(B,) exact observation counts over the live window."""
        return np.sum(self.epoch_counts, axis=0).astype(np.uint64)


# ----------------------------------------------------------------------------
# systems under test
# ----------------------------------------------------------------------------


class DenseBankSUT:
    """The dense (B, m) SketchBank under a given ExecutionPlan."""

    windowed = False

    def __init__(self, rows, cfg, plan=None, threshold=None):
        self.cfg = cfg
        self.plan = plan
        self.bank = SketchBank.empty(rows, cfg)

    def update(self, keys, items):
        self.bank = self.bank.update_many(
            jnp.asarray(keys), jnp.asarray(items), self.plan
        )

    def merge(self, keys, items):
        other = SketchBank.empty(len(self.bank), self.cfg).update_many(
            jnp.asarray(keys), jnp.asarray(items), self.plan
        )
        self.bank = self.bank.merge(other)

    def roundtrip(self):
        self.bank = SketchBank.from_bytes(self.bank.to_bytes())

    def estimates(self, estimator=None):
        return np.asarray(self.bank.estimate_many(estimator, plan=self.plan))

    def counts(self):
        return self.bank.counts

    def canonical(self):
        return (
            np.asarray(self.bank.registers),
            self.bank.counts,
        )


class HybridBankSUT:
    """The sparse/dense HybridBank; threshold picks sparse vs mixed."""

    windowed = False

    def __init__(self, rows, cfg, plan=None, threshold=None):
        self.cfg = cfg
        self.plan = plan
        self.threshold = threshold
        self.bank = HybridBank.empty(rows, cfg, threshold)

    def update(self, keys, items):
        self.bank = self.bank.update_many(
            jnp.asarray(keys), jnp.asarray(items), self.plan
        )

    def merge(self, keys, items):
        other = HybridBank.empty(
            len(self.bank), self.cfg, self.threshold
        ).update_many(jnp.asarray(keys), jnp.asarray(items), self.plan)
        self.bank = self.bank.merge(other)

    def roundtrip(self):
        self.bank = HybridBank.from_bytes(self.bank.to_bytes())

    def peek(self):
        # settle the append buffer mid-sequence: the deferred dedup must
        # be invisible no matter where a read interleaves with ingest
        self.bank = self.bank.compact()

    def estimates(self, estimator=None):
        return np.asarray(self.bank.estimate_many(estimator, plan=self.plan))

    def counts(self):
        return self.bank.counts

    def canonical(self):
        return (
            np.asarray(self.bank.to_dense().registers),
            self.bank.counts,
            self.bank.modes,
        )


class EagerHybridBankSUT(HybridBankSUT):
    """Pre-append-buffer semantics: compact after EVERY update/merge.

    The regression anchor for the deferred-dedup path: a deferred
    HybridBankSUT run over the same ops must land bit-identical to this
    wrapper, which restores the old eager per-batch dedup behavior.
    """

    def update(self, keys, items):
        super().update(keys, items)
        self.bank = self.bank.compact()

    def merge(self, keys, items):
        super().merge(keys, items)
        self.bank = self.bank.compact()


class DenseWindowSUT:
    """The dense (W, B, m) WindowedBank ring."""

    windowed = True

    def __init__(self, window, rows, cfg, plan=None, threshold=None):
        self.cfg = cfg
        self.plan = plan
        self.ring = WindowedBank.empty(window, rows, cfg)

    def update(self, keys, items):
        self.ring = self.ring.observe(
            jnp.asarray(keys), jnp.asarray(items), self.plan
        )

    def advance(self, steps=1):
        self.ring = self.ring.advance(steps)

    def roundtrip(self):
        self.ring = WindowedBank.from_bytes(self.ring.to_bytes())

    def estimates(self, estimator=None):
        return np.asarray(
            self.ring.estimate_window(plan=self.plan, estimator=estimator)
        )

    def counts(self):
        return self.ring.window_counts()

    def canonical(self):
        return (
            np.asarray(self.ring._fold_registers(self.ring.window, self.plan)),
            self.ring.window_counts(),
            np.asarray(self.ring.epochs),
        )


class HybridWindowSUT:
    """The hybrid ring: sparse buckets, promotion surviving advance()."""

    windowed = True

    def __init__(self, window, rows, cfg, plan=None, threshold=None):
        self.cfg = cfg
        self.plan = plan
        self.ring = HybridWindowedBank.empty(window, rows, cfg, threshold)

    def update(self, keys, items):
        self.ring = self.ring.observe(
            jnp.asarray(keys), jnp.asarray(items), self.plan
        )

    def advance(self, steps=1):
        self.ring = self.ring.advance(steps)

    def roundtrip(self):
        self.ring = HybridWindowedBank.from_bytes(self.ring.to_bytes())

    def estimates(self, estimator=None):
        return np.asarray(
            self.ring.estimate_window(plan=self.plan, estimator=estimator)
        )

    def counts(self):
        return self.ring.window_counts()

    def canonical(self):
        fold = self.ring.fold_window()
        return (
            np.asarray(fold.to_dense().registers),
            self.ring.window_counts(),
            np.asarray(self.ring.epochs),
            fold.modes,
        )


class CountMinSUT:
    """The flat (B, d, w) CountMinBank under a given ExecutionPlan."""

    windowed = False

    def __init__(self, rows, cfg: CMConfig, plan=None, threshold=None):
        self.cfg = cfg
        self.plan = plan
        self.bank = CountMinBank.empty(rows, cfg)

    def update(self, keys, items):
        self.bank = self.bank.update_many(
            jnp.asarray(keys), jnp.asarray(items), self.plan
        )

    def merge(self, keys, items):
        other = CountMinBank.empty(len(self.bank), self.cfg).update_many(
            jnp.asarray(keys), jnp.asarray(items), self.plan
        )
        self.bank = self.bank.merge(other)

    def roundtrip(self):
        self.bank = CountMinBank.from_bytes(self.bank.to_bytes())

    def query(self, probe):
        return np.asarray(self.bank.query(jnp.asarray(probe), self.plan))

    def topk(self, k):
        return self.bank.topk(k)

    def counts(self):
        return self.bank.counts

    def canonical(self):
        return (
            np.asarray(self.bank.counters),
            np.asarray(self.bank.labels),
            np.asarray(self.bank.label_counts),
            self.bank.counts,
        )


class WindowedCountMinSUT:
    """The (W, B, d, w) WindowedCountMinBank ring."""

    windowed = True

    def __init__(self, window, rows, cfg: CMConfig, plan=None, threshold=None):
        self.cfg = cfg
        self.plan = plan
        self.ring = WindowedCountMinBank.empty(window, rows, cfg)

    def update(self, keys, items):
        self.ring = self.ring.observe(
            jnp.asarray(keys), jnp.asarray(items), self.plan
        )

    def advance(self, steps=1):
        self.ring = self.ring.advance(steps)

    def roundtrip(self):
        self.ring = WindowedCountMinBank.from_bytes(self.ring.to_bytes())

    def query(self, probe):
        return np.asarray(
            self.ring.query_window(jnp.asarray(probe), plan=self.plan)
        )

    def topk(self, k):
        return self.ring.topk_window(k, plan=self.plan)

    def counts(self):
        return self.ring.window_counts()

    def canonical(self):
        fold = self.ring.fold_window(plan=self.plan)
        return (
            np.asarray(fold.counters),
            np.asarray(fold.labels),
            np.asarray(fold.label_counts),
            self.ring.window_counts(),
            np.asarray(self.ring.epochs),
        )


# ----------------------------------------------------------------------------
# op sequences
# ----------------------------------------------------------------------------


# stream lengths come from a fixed palette so the jitted sort-merge and
# scatter kernels compile once per shape instead of once per op
STREAM_SIZES = (16, 64, 128, 320)


def gen_stream(rng, rows, n, hot_frac=0.2, oob_frac=0.05, value_space=None):
    """A Zipf-ish keyed stream with a sprinkle of out-of-range keys."""
    hot = max(1, int(rows * hot_frac))
    hot_keys = rng.integers(0, hot, n)
    cold_keys = rng.integers(0, rows, n)
    keys = np.where(rng.random(n) < 0.8, hot_keys, cold_keys).astype(np.int32)
    oob = rng.random(n) < oob_frac
    keys = np.where(oob, rng.choice([-3, -1, rows, rows + 7], n), keys)
    if value_space is None:
        value_space = int(rng.choice([50, 500, 2**20]))
    items = rng.integers(0, value_space, n, dtype=np.int32)
    return keys.astype(np.int32), items


def gen_ops(rng, rows, n_ops, windowed):
    """A deterministic op sequence over the shared grammar."""
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.50:
            n = int(rng.choice(STREAM_SIZES))
            ops.append(("update", *gen_stream(rng, rows, n)))
        elif r < 0.65:
            if windowed:
                ops.append(("advance", int(rng.integers(1, 3))))
            else:
                n = int(rng.choice(STREAM_SIZES[:2]))
                ops.append(("merge", *gen_stream(rng, rows, n)))
        elif r < 0.78:
            ops.append(("roundtrip",))
        elif r < 0.90:
            # force a compaction at an adversarial point (hybrid carriers)
            ops.append(("peek",))
        else:
            ops.append(("estimate",))
    ops.append(("estimate",))
    return ops


def run_ops(ops, sut, oracle, on_estimate=None):
    """Drive one op sequence; ``on_estimate(sut, oracle)`` checks bands."""
    for op in ops:
        kind = op[0]
        if kind == "update":
            sut.update(op[1], op[2])
            oracle.update(op[1], op[2])
        elif kind == "merge":
            sut.merge(op[1], op[2])
            side = type(oracle)(oracle.rows)
            side.update(op[1], op[2])
            oracle.merge(side)
        elif kind == "advance":
            sut.advance(op[1])
            oracle.advance(op[1])
        elif kind == "roundtrip":
            sut.roundtrip()
        elif kind == "peek":
            # oracle no-op: a read cannot change what was observed
            getattr(sut, "peek", lambda: None)()
        elif kind == "estimate":
            if on_estimate is not None:
                on_estimate(sut, oracle)
        else:  # pragma: no cover - grammar bug
            raise AssertionError(f"unknown op {kind!r}")
    return sut


def assert_within_band(estimates, true, m, sigma_mult=3.0):
    """|est - true| <= sigma_mult * (1.04/sqrt(m)) * true + small-count slack.

    The slack term 3*sqrt(true+1) covers the low-cardinality regime where
    the relative-sigma band collapses below hash-collision granularity.
    """
    estimates = np.asarray(estimates, np.float64)
    true = np.asarray(true, np.float64)
    tol = sigma_mult * (1.04 / np.sqrt(m)) * true + 3.0 * np.sqrt(true + 1.0)
    err = np.abs(estimates - true)
    worst = int(np.argmax(err - tol))
    assert (err <= tol).all(), (
        f"row {worst}: estimate {estimates[worst]} vs true {true[worst]} "
        f"(err {err[worst]:.2f} > tol {tol[worst]:.2f})"
    )


def make_plans(backends):
    """One local plan per registered bank backend (the differential axis)."""
    return {name: ExecutionPlan(backend=name) for name in backends}


def make_sharded_plans(backends):
    """One row-sharded plan per backend over this process's devices.

    The §16 differential axis: every op sequence driven under one of
    these plans must land bit-identical to the same sequence under
    ``make_plans`` — the sharded placement may change WHERE a register
    lives mid-flight, never what any read returns.
    """
    import jax

    from repro.launch.mesh import make_auto_mesh

    mesh = make_auto_mesh((jax.device_count(),), ("data",))
    return {name: ExecutionPlan(backend=name).with_sharding(mesh) for name in backends}


def assert_cm_bounds(estimates, true, total, width, depth):
    """Count-min sandwich: true <= est <= true + slack(stream, w).

    The lower bound is exact (counters only ever over-count); the upper
    bound uses the classical 2n/w expected collision mass per cell with a
    generous deterministic multiplier, plus small-stream slack, so fixed
    seeds stay far inside it.
    """
    estimates = np.asarray(estimates, np.int64)
    true = np.asarray(true, np.int64)
    assert (estimates >= true).all(), "count-min under-counted a probe"
    slack = 8.0 * (np.asarray(total, np.float64)[:, None] / width) + 16.0
    over = estimates - true
    assert (over <= slack).all(), (
        f"count-min overestimate {over.max()} exceeded the "
        f"{slack.max():.1f} collision-mass band (w={width}, d={depth})"
    )
