"""Checkpoint/restart + elastic resume + fault-tolerance invariants."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.sketch import hll
from repro.sketch import HLLConfig
from repro.data.pipeline import DataConfig, batch_at_step
from repro.optim.adamw import OptimizerConfig
from repro.train.step import TrainConfig, init_train_state
from repro.train.loop import LoopConfig, train


def _tiny():
    arch = get_arch("smollm-360m").reduced()
    cfg = TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=30),
        sketch=HLLConfig(p=8, hash_bits=32),
    )
    data = DataConfig(vocab_size=arch.vocab_size, global_batch=2, seq_len=32)
    return arch, cfg, data


def test_save_restore_roundtrip(tmp_path):
    arch, cfg, _ = _tiny()
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)
    ckpt.save(state, str(tmp_path), 5)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored = ckpt.restore(state, str(tmp_path), 5)
    for a, b in zip(
        jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    arch, cfg, _ = _tiny()
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)
    handle = ckpt.save(state, str(tmp_path), 7, async_write=True)
    handle.join()
    assert ckpt.latest_step(str(tmp_path)) == 7
    ckpt.restore(state, str(tmp_path), 7)


def test_restart_resumes_exactly(tmp_path):
    """Train 10 steps with ckpt@5, kill, resume: must equal uninterrupted run."""
    arch, cfg, data = _tiny()
    loop_a = LoopConfig(total_steps=10, ckpt_every=100, ckpt_dir=None, log_every=100)
    # uninterrupted 10 steps
    state_full, _ = train(arch, cfg, data, loop_a, log_fn=lambda s: None)

    # interrupted: 5 steps, checkpoint, then resume to 10
    d = str(tmp_path / "ck")
    loop_b = LoopConfig(total_steps=5, ckpt_every=5, ckpt_dir=d,
                        async_ckpt=False, log_every=100)
    train(arch, cfg, data, loop_b, log_fn=lambda s: None)
    loop_c = LoopConfig(total_steps=10, ckpt_every=100, ckpt_dir=d,
                        async_ckpt=False, log_every=100)
    state_resumed, _ = train(arch, cfg, data, loop_c, log_fn=lambda s: None)

    a = np.asarray(state_full["params"]["embed"], np.float32)
    b = np.asarray(state_resumed["params"]["embed"], np.float32)
    np.testing.assert_allclose(a, b, atol=1e-6)
    assert int(state_resumed["step"]) == 10


def test_crash_safe_write(tmp_path):
    """A temp dir from a crashed write must not be visible as a checkpoint."""
    arch, cfg, _ = _tiny()
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)
    os.makedirs(tmp_path / ".tmp_step_99")  # simulated crash debris
    ckpt.save(state, str(tmp_path), 3)
    assert ckpt.latest_step(str(tmp_path)) == 3  # 99 not visible


def test_structure_mismatch_rejected(tmp_path):
    arch, cfg, _ = _tiny()
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)
    ckpt.save(state, str(tmp_path), 1)
    with pytest.raises((ValueError, KeyError)):
        ckpt.restore({"just": jnp.zeros(3)}, str(tmp_path), 1)


def test_elastic_resume_resharding(tmp_path):
    """Restore onto a different device layout (elastic rescale path)."""
    arch, cfg, _ = _tiny()
    state = init_train_state(jax.random.PRNGKey(0), arch, cfg)
    ckpt.save(state, str(tmp_path), 2)
    from repro.launch.mesh import make_auto_mesh
    mesh = make_auto_mesh((jax.device_count(),), ("data",))
    shardings = jax.tree.map(
        lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), state
    )
    restored = ckpt.restore(state, str(tmp_path), 2, shardings=shardings)
    np.testing.assert_array_equal(
        np.asarray(restored["sketch"]), np.asarray(state["sketch"])
    )


def test_sketch_replay_immune():
    """Fault-tolerance invariant: re-aggregating a replayed batch is a no-op
    on the sketch (max-lattice idempotence) — the recovery path cannot skew
    cardinality telemetry."""
    cfg = HLLConfig(p=8, hash_bits=32)
    data = DataConfig(vocab_size=5000, global_batch=2, seq_len=64)
    regs = hll.init_registers(cfg)
    batch = batch_at_step(data, jnp.asarray(3))
    once = hll.update(regs, batch["tokens"], cfg)
    replay = hll.update(once, batch["tokens"], cfg)  # crash/restart replay
    np.testing.assert_array_equal(np.asarray(once), np.asarray(replay))
