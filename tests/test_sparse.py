"""HybridBank: sparse rows, dense promotion, RHLB/RHLW v2, density stats.

Acceptance properties for the sparse subsystem (DESIGN.md §12):

* hybrid ingest under EVERY registered bank backend materializes to
  registers bit-identical to dense ingestion of the same keyed stream
  (promotion included), with the §9 drop/counter rules intact;
* rows promote exactly when their distinct-bucket count crosses the
  threshold, promoted registers are bit-identical to dense-from-scratch,
  and the boundary (threshold-1 / threshold / threshold+1) round-trips
  through RHLB v2 and estimates identically to a dense row — per backend;
* the v2 wire formats reject garbage (truncation anywhere, mode-flag
  flips, unsorted/oversized pair lists, v1<->v2 confusion) instead of
  mis-parsing.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.sketch import (
    ExecutionPlan,
    HLLConfig,
    HybridBank,
    HybridWindowedBank,
    SketchBank,
    WindowedBank,
    available_bank_backends,
    available_estimators,
    default_threshold,
    hll,
    update_many,
)
from repro.sketch.sparse import MODE_DENSE, MODE_SPARSE

CFG = HLLConfig(p=8, hash_bits=64)  # m=256: small enough for pallas paths


def _stream(n, rows, seed=0, space=2**31):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, rows, n, dtype=np.int32))
    items = jnp.asarray(rng.integers(0, space, n, dtype=np.int32))
    return keys, items


def _skewed_stream(n, rows, seed=0, hot=3):
    """Most traffic on ``hot`` rows; the rest stay nearly empty."""
    rng = np.random.default_rng(seed)
    keys = np.where(
        rng.random(n) < 0.9,
        rng.integers(0, hot, n),
        rng.integers(hot, rows, n),
    ).astype(np.int32)
    items = rng.integers(0, 2**31, n, dtype=np.int32)
    return jnp.asarray(keys), jnp.asarray(items)


def _items_with_distinct_buckets(k, cfg=CFG, seed=0):
    """Items hashing to exactly ``k`` distinct buckets (greedy pick)."""
    rng = np.random.default_rng(seed)
    chosen, seen = [], set()
    while len(chosen) < k:
        cand = rng.integers(0, 2**31, 4 * cfg.m, dtype=np.int32)
        idx, _ = hll.hash_index_rank(jnp.asarray(cand), cfg)
        for item, b in zip(cand, np.asarray(idx)):
            if int(b) not in seen:
                seen.add(int(b))
                chosen.append(int(item))
                if len(chosen) == k:
                    break
    return np.asarray(chosen, np.int32)


# ----------------------------------------------------------------------------
# ingest equivalence (per backend) + routing rules
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("backend", available_bank_backends())
def test_hybrid_ingest_matches_dense_per_backend(backend):
    rows, n = 19, 3001
    plan = ExecutionPlan(backend=backend)
    keys, items = _skewed_stream(n, rows, seed=5)
    dense = update_many(SketchBank.empty(rows, CFG), keys, items, plan)
    hb = HybridBank.empty(rows, CFG, threshold=16)
    for c in np.array_split(np.arange(n), 4):  # chunked: promotions mid-way
        hb = hb.update_many(keys[jnp.asarray(c)], items[jnp.asarray(c)], plan)
    np.testing.assert_array_equal(
        np.asarray(hb.to_dense().registers), np.asarray(dense.registers)
    )
    np.testing.assert_array_equal(hb.counts, dense.counts)
    assert hb.dense_rows > 0 and hb.dense_rows < rows  # genuinely mixed


@pytest.mark.parametrize("backend", available_bank_backends())
def test_hybrid_out_of_range_keys_dropped_not_leaked(backend):
    rows, n = 11, 2001
    keys, items = _stream(n, rows, seed=7)
    bad = np.asarray(keys).copy()
    bad[::5] = -2
    bad[::7] = rows + 3
    plan = ExecutionPlan(backend=backend)
    dense = update_many(SketchBank.empty(rows, CFG), jnp.asarray(bad), items, plan)
    hb = HybridBank.empty(rows, CFG).update_many(jnp.asarray(bad), items, plan)
    np.testing.assert_array_equal(
        np.asarray(hb.to_dense().registers), np.asarray(dense.registers)
    )
    in_range = bad[(bad >= 0) & (bad < rows)]
    np.testing.assert_array_equal(
        hb.counts, np.bincount(in_range, minlength=rows)
    )


def test_chunked_ingest_is_order_invariant():
    rows, n = 13, 2000
    keys, items = _skewed_stream(n, rows, seed=11)
    one = HybridBank.empty(rows, CFG, threshold=16).update_many(keys, items)
    perm = np.random.default_rng(0).permutation(n)
    shuffled = HybridBank.empty(rows, CFG, threshold=16)
    for c in np.array_split(perm, 7):
        shuffled = shuffled.update_many(keys[jnp.asarray(c)], items[jnp.asarray(c)])
    np.testing.assert_array_equal(
        np.asarray(one.to_dense().registers),
        np.asarray(shuffled.to_dense().registers),
    )
    np.testing.assert_array_equal(one.modes, shuffled.modes)
    np.testing.assert_array_equal(one.counts, shuffled.counts)


def test_estimates_bit_identical_to_dense_all_estimators():
    rows = 31
    keys, items = _skewed_stream(2500, rows, seed=3)
    dense = update_many(SketchBank.empty(rows, CFG), keys, items)
    hb = HybridBank.empty(rows, CFG, threshold=32).update_many(keys, items)
    assert (hb.modes == MODE_SPARSE).any() and (hb.modes == MODE_DENSE).any()
    for est in (None,) + tuple(available_estimators()):
        np.testing.assert_array_equal(
            np.asarray(hb.estimate_many(est)),
            np.asarray(dense.estimate_many(est)),
            err_msg=f"estimator {est}",
        )
    # the LC fast path and the histogram path agree with each other too
    np.testing.assert_array_equal(
        np.asarray(hb.estimate_many("original")),
        np.asarray(hb.estimate_many("original", lc_fast=False)),
    )
    # exact host estimates agree row by row
    for b in (0, rows // 2, rows - 1):
        assert hb.estimate(b) == dense.estimate(b)


# ----------------------------------------------------------------------------
# promotion boundary (threshold-1 / threshold / threshold+1), per backend
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("backend", available_bank_backends())
@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_promotion_boundary_roundtrips_and_matches_dense(backend, delta):
    t = 16
    k = t + delta
    items = jnp.asarray(_items_with_distinct_buckets(k, seed=k))
    keys = jnp.zeros(k, jnp.int32)
    plan = ExecutionPlan(backend=backend)
    hb = HybridBank.empty(3, CFG, threshold=t).update_many(keys, items, plan)
    # crossing means strictly exceeding the threshold
    want_mode = MODE_DENSE if k > t else MODE_SPARSE
    assert hb.modes[0] == want_mode and (hb.modes[1:] == MODE_SPARSE).all()
    dense = update_many(SketchBank.empty(3, CFG), keys, items, plan)
    np.testing.assert_array_equal(
        np.asarray(hb.to_dense().registers), np.asarray(dense.registers)
    )
    if k > t:  # promoted registers are bit-identical to dense-from-scratch
        np.testing.assert_array_equal(
            np.asarray(hb.dense[0]), np.asarray(dense.registers[0])
        )
    back = HybridBank.from_bytes(hb.to_bytes())  # RHLB v2 round-trip
    assert back.threshold == t
    np.testing.assert_array_equal(back.modes, hb.modes)
    np.testing.assert_array_equal(back.counts, hb.counts)
    np.testing.assert_array_equal(
        np.asarray(back.to_dense().registers),
        np.asarray(hb.to_dense().registers),
    )
    for est in available_estimators():
        np.testing.assert_array_equal(
            np.asarray(back.estimate_many(est)),
            np.asarray(dense.estimate_many(est)),
            err_msg=f"estimator {est} at threshold{delta:+d}",
        )
        assert back.estimate(0, est) == dense.estimate(0, est)


def test_promotion_is_sticky_and_merge_keeps_it_infectious():
    t = 8
    hot = jnp.asarray(_items_with_distinct_buckets(t + 1, seed=1))
    a = HybridBank.empty(2, CFG, threshold=t).update_many(
        jnp.zeros(t + 1, jnp.int32), hot
    )
    assert a.modes.tolist() == [MODE_DENSE, MODE_SPARSE]
    # a tiny follow-up batch cannot demote the promoted row
    a = a.update_many(jnp.zeros(1, jnp.int32), jnp.asarray([123], jnp.int32))
    assert a.modes.tolist() == [MODE_DENSE, MODE_SPARSE]
    b = HybridBank.empty(2, CFG, threshold=t).update_many(
        jnp.ones(3, jnp.int32), jnp.arange(3, dtype=jnp.int32)
    )
    merged = a | b
    assert merged.modes.tolist() == [MODE_DENSE, MODE_SPARSE]
    np.testing.assert_array_equal(merged.counts, a.counts + b.counts)
    # sparse + sparse whose union crosses the threshold promotes
    half1 = jnp.asarray(_items_with_distinct_buckets(t, seed=2))
    half2 = jnp.asarray(_items_with_distinct_buckets(t, seed=3))
    u = HybridBank.empty(1, CFG, threshold=t).update_many(
        jnp.zeros(t, jnp.int32), half1
    ).merge(
        HybridBank.empty(1, CFG, threshold=t).update_many(
            jnp.zeros(t, jnp.int32), half2
        )
    )
    assert u.modes[0] == MODE_DENSE  # 16 distinct buckets > t=8


def test_merge_mismatches_raise():
    a = HybridBank.empty(4, CFG, threshold=8)
    with pytest.raises(ValueError, match="different sizes"):
        a.merge(HybridBank.empty(5, CFG, threshold=8))
    with pytest.raises(ValueError, match="different configs"):
        a.merge(HybridBank.empty(4, HLLConfig(p=9, hash_bits=64), threshold=8))
    with pytest.raises(ValueError, match="thresholds"):
        a.merge(HybridBank.empty(4, CFG, threshold=16))


# ----------------------------------------------------------------------------
# capacity adaptation + density introspection
# ----------------------------------------------------------------------------


def test_capacity_adapts_and_density_reports_the_win():
    rows = 64
    hb = HybridBank.empty(rows, CFG)
    assert hb.capacity == 0 and hb.nbytes < rows * CFG.m
    keys, items = _skewed_stream(4000, rows, seed=13)
    hb = hb.update_many(keys, items)
    d = hb.density()
    assert d["rows"] == rows and d["dense_rows"] == hb.dense_rows
    assert 0 < d["occupancy_mean"] < 1
    assert d["nbytes"] == hb.nbytes
    assert d["reduction"] > 1.5  # skewed traffic: hybrid must actually win
    # capacity tracks the largest sparse row, not the hot promoted rows
    assert hb.capacity <= hb.threshold
    assert hb.capacity >= int(np.asarray(hb.sparse_len).max())
    # dense SketchBank exposes the same introspection schema
    dd = update_many(SketchBank.empty(rows, CFG), keys, items).density()
    assert set(dd) == set(d) and dd["reduction"] == 1.0


def test_to_hybrid_and_from_dense_roundtrip():
    rows = 12
    keys, items = _skewed_stream(1500, rows, seed=17)
    dense = update_many(SketchBank.empty(rows, CFG), keys, items)
    hb = dense.to_hybrid(threshold=16)
    np.testing.assert_array_equal(
        np.asarray(hb.to_dense().registers), np.asarray(dense.registers)
    )
    np.testing.assert_array_equal(hb.counts, dense.counts)
    # forced dense rows stay dense even when nearly empty
    forced = dense.to_hybrid(threshold=16, dense_rows=np.ones(rows, bool))
    assert (forced.modes == MODE_DENSE).all()
    with pytest.raises(ValueError, match="mask"):
        dense.to_hybrid(dense_rows=np.ones(rows + 1, bool))


def test_row_and_to_sketches_match_dense():
    rows = 6
    keys, items = _skewed_stream(900, rows, seed=19)
    dense = update_many(SketchBank.empty(rows, CFG), keys, items)
    hb = HybridBank.empty(rows, CFG, threshold=16).update_many(keys, items)
    for i in range(-rows, rows):
        np.testing.assert_array_equal(
            np.asarray(hb.row(i).registers), np.asarray(dense.row(i).registers)
        )
        assert hb.row(i).count == dense.row(i).count
    with pytest.raises(IndexError, match="out of range"):
        hb.row(rows)
    assert len(hb.to_sketches()) == rows


def test_threshold_validation():
    with pytest.raises(ValueError, match="threshold"):
        HybridBank.empty(4, CFG, threshold=0)
    with pytest.raises(ValueError, match="threshold"):
        HybridBank.empty(4, CFG, threshold=CFG.m)  # > m // 2: LC guarantee
    with pytest.raises(ValueError, match="at least one row"):
        HybridBank.empty(0, CFG)
    assert HybridBank.empty(4, CFG).threshold == default_threshold(CFG)
    with pytest.raises(ValueError, match="sparse_threshold"):
        ExecutionPlan(sparse_threshold=0)
    assert ExecutionPlan(sparse_threshold=7).sparse_threshold == 7


# ----------------------------------------------------------------------------
# B=0 and empty-stream short-circuits
# ----------------------------------------------------------------------------


def test_hybrid_empty_stream_and_zero_rows_short_circuit():
    hb = HybridBank.empty(4, CFG)
    empty = jnp.zeros((0,), jnp.int32)
    assert hb.update_many(empty, empty) is hb
    with pytest.raises(ValueError, match="same length"):
        hb.update_many(jnp.zeros((2,), jnp.int32), empty)
    zero = HybridBank(
        jnp.zeros((0, 0), jnp.int32),
        jnp.zeros((0,), jnp.int32),
        jnp.zeros((0, CFG.m), hll.REGISTER_DTYPE),
        jnp.zeros((0,), jnp.int32),
        jnp.zeros((0, 2), jnp.uint32),
        CFG,
        8,
    )
    assert zero.update_many(jnp.zeros(5, jnp.int32), jnp.arange(5)) is zero
    assert zero.estimate_many().shape == (0,)


# ----------------------------------------------------------------------------
# RHLB v2 wire format: round-trip + garbage rejection
# ----------------------------------------------------------------------------


def _mixed_bank(rows=9, n=1200, threshold=16, seed=23):
    keys, items = _skewed_stream(n, rows, seed=seed)
    return HybridBank.empty(rows, CFG, threshold).update_many(keys, items)


def test_v2_roundtrip_mixed_modes():
    hb = _mixed_bank()
    assert (hb.modes == MODE_SPARSE).any() and (hb.modes == MODE_DENSE).any()
    back = HybridBank.from_bytes(hb.to_bytes())
    np.testing.assert_array_equal(back.modes, hb.modes)
    np.testing.assert_array_equal(back.counts, hb.counts)
    np.testing.assert_array_equal(
        np.asarray(back.to_dense().registers),
        np.asarray(hb.to_dense().registers),
    )
    np.testing.assert_array_equal(
        np.asarray(back.sparse_len), np.asarray(hb.sparse_len)
    )


def test_v1_dense_blob_parses_as_all_dense_hybrid():
    rows = 5
    keys, items = _stream(800, rows, seed=29)
    dense = update_many(SketchBank.empty(rows, CFG), keys, items)
    hb = HybridBank.from_bytes(dense.to_bytes())  # version-gated v1 parse
    assert (hb.modes == MODE_DENSE).all()
    np.testing.assert_array_equal(
        np.asarray(hb.to_dense().registers), np.asarray(dense.registers)
    )
    np.testing.assert_array_equal(hb.counts, dense.counts)


def test_sketchbank_rejects_v2_with_pointer():
    blob = _mixed_bank().to_bytes()
    with pytest.raises(ValueError, match="HybridBank.from_bytes"):
        SketchBank.from_bytes(blob)


@pytest.mark.parametrize("frac", [0.0, 0.05, 0.2, 0.45, 0.7, 0.9, 0.999])
def test_v2_rejects_truncation_anywhere(frac):
    """Cuts through the header, counts, mode flags, a dense row, and —
    crucially — inside a sparse pair list must all raise, never mis-parse."""
    blob = _mixed_bank().to_bytes()
    cut = int(len(blob) * frac)
    with pytest.raises(ValueError):
        HybridBank.from_bytes(blob[:cut])
    with pytest.raises(ValueError):
        HybridBank.from_bytes(blob + b"\x00")


def test_v2_rejects_cut_inside_pair_list():
    hb = HybridBank.empty(2, CFG, threshold=16).update_many(
        jnp.zeros(8, jnp.int32),
        jnp.asarray(_items_with_distinct_buckets(8, seed=31)),
    )
    blob = hb.to_bytes()
    header = 20 + 4 + 2 * 8 + 2  # header + threshold + counts + modes
    cut = header + 2 + 4  # inside row 0's pair list (8 pairs x 3 bytes)
    assert cut < len(blob)
    with pytest.raises(ValueError, match="cut short|payload"):
        HybridBank.from_bytes(blob[:cut])


def test_v2_rejects_mode_flag_flips():
    hb = _mixed_bank()
    rows = len(hb)
    blob = bytearray(hb.to_bytes())
    modes_off = 20 + 4 + rows * 8
    flip = int(np.argmax(hb.modes == MODE_SPARSE))
    blob[modes_off + flip] = MODE_DENSE  # sparse row re-labeled dense
    with pytest.raises(ValueError):
        HybridBank.from_bytes(bytes(blob))
    blob[modes_off + flip] = 7  # not a mode at all
    with pytest.raises(ValueError, match="mode flag"):
        HybridBank.from_bytes(bytes(blob))


def test_v2_rejects_corrupt_pair_lists():
    t = 16
    hb = HybridBank.empty(1, CFG, threshold=t).update_many(
        jnp.zeros(4, jnp.int32),
        jnp.asarray(_items_with_distinct_buckets(4, seed=37)),
    )
    blob = bytearray(hb.to_bytes())
    payload = 20 + 4 + 8 + 1  # header + threshold + count + mode
    # npairs beyond the declared threshold
    bad = bytearray(blob)
    bad[payload : payload + 2] = (t + 1).to_bytes(2, "little")
    with pytest.raises(ValueError, match="threshold|cut short"):
        HybridBank.from_bytes(bytes(bad))
    # unsorted buckets (swap the first two pairs)
    bad = bytearray(blob)
    first = bytes(bad[payload + 2 : payload + 5])
    bad[payload + 2 : payload + 5] = bad[payload + 5 : payload + 8]
    bad[payload + 5 : payload + 8] = first
    with pytest.raises(ValueError, match="increasing"):
        HybridBank.from_bytes(bytes(bad))
    # rank 0 is not a value a present bucket can hold
    bad = bytearray(blob)
    bad[payload + 4] = 0
    with pytest.raises(ValueError, match="rank"):
        HybridBank.from_bytes(bytes(bad))
    # rank beyond max_rank
    bad = bytearray(blob)
    bad[payload + 4] = CFG.max_rank + 1
    with pytest.raises(ValueError, match="rank"):
        HybridBank.from_bytes(bytes(bad))


# ----------------------------------------------------------------------------
# hybrid windowed ring: sparse buckets, promotion across advance, RHLW v2
# ----------------------------------------------------------------------------


def test_window_promotion_survives_advance():
    t = 8
    win = HybridWindowedBank.empty(3, 2, CFG, threshold=t)
    hot = jnp.asarray(_items_with_distinct_buckets(t + 1, seed=41))
    win = win.observe(jnp.zeros(t + 1, jnp.int32), hot)
    assert win.buckets[win.cursor].modes[0] == MODE_DENSE
    promoted_regs = np.asarray(win.buckets[win.cursor].dense[0])
    win = win.advance()  # the promoted bucket ages but keeps its mode
    aged = win.buckets[(win.cursor - 1) % win.window]
    assert aged.modes[0] == MODE_DENSE
    np.testing.assert_array_equal(np.asarray(aged.dense[0]), promoted_regs)
    # the NEW current bucket starts sparse again
    assert (win.buckets[win.cursor].modes == MODE_SPARSE).all()
    # ...and the fold still sees the promoted epoch until it expires
    assert win.fold_window().modes[0] == MODE_DENSE
    win = win.advance(win.window)  # slide the promoted epoch out
    assert win.window_counts().sum() == 0
    assert (win.fold_window().modes == MODE_SPARSE).all()


def test_hybrid_window_matches_dense_ring():
    window, rows = 3, 10
    wh = HybridWindowedBank.empty(window, rows, CFG, threshold=16)
    wd = WindowedBank.empty(window, rows, CFG)
    rng = np.random.default_rng(43)
    for e in range(5):
        if e:
            wh, wd = wh.advance(), wd.advance()
        keys = jnp.asarray(rng.integers(0, rows, 400, dtype=np.int32))
        items = jnp.asarray(rng.integers(0, 2**31, 400, dtype=np.int32))
        wh, wd = wh.observe(keys, items), wd.observe(keys, items)
    assert wh.epoch == wd.epoch
    for last_k in (1, 2, 3):
        np.testing.assert_array_equal(
            np.asarray(wh.fold_window(last_k).to_dense().registers),
            np.asarray(wd._fold_registers(last_k, None)),
        )
        np.testing.assert_array_equal(
            wh.window_counts(last_k), wd.window_counts(last_k)
        )
    with pytest.raises(ValueError, match="last_k"):
        wh.estimate_window(0)
    d = wh.density()
    assert d["window"] == window and d["rows"] == rows


def test_rhlw_v2_roundtrip_and_v1_interop():
    window, rows = 3, 4
    win = HybridWindowedBank.empty(window, rows, CFG, threshold=8)
    rng = np.random.default_rng(47)
    for e in range(4):
        if e:
            win = win.advance()
        win = win.observe(
            jnp.asarray(rng.integers(0, rows, 300, dtype=np.int32)),
            jnp.asarray(rng.integers(0, 2**31, 300, dtype=np.int32)),
        )
    blob = win.to_bytes()
    back = HybridWindowedBank.from_bytes(blob)
    assert back.cursor == win.cursor and back.epoch == win.epoch
    np.testing.assert_array_equal(back.epochs, win.epochs)
    np.testing.assert_array_equal(back.window_counts(), win.window_counts())
    np.testing.assert_array_equal(
        np.asarray(back.fold_window().to_dense().registers),
        np.asarray(win.fold_window().to_dense().registers),
    )
    # a v1 dense ring parses into an all-dense hybrid ring, version-gated
    wd = WindowedBank.empty(window, rows, CFG).observe(
        jnp.asarray(rng.integers(0, rows, 200, dtype=np.int32)),
        jnp.asarray(rng.integers(0, 2**31, 200, dtype=np.int32)),
    )
    h1 = HybridWindowedBank.from_bytes(wd.to_bytes())
    np.testing.assert_array_equal(
        np.asarray(h1.fold_window().to_dense().registers),
        np.asarray(wd._fold_registers(window, None)),
    )
    # ...while the dense parser refuses the v2 ring with a pointer
    with pytest.raises(ValueError, match="HybridWindowedBank"):
        WindowedBank.from_bytes(blob)


# ----------------------------------------------------------------------------
# deferred dedup: append buffer, pressure flush, settled reads (DESIGN.md §12)
# ----------------------------------------------------------------------------


def test_appends_defer_until_read_then_settle():
    keys, items = _stream(500, 8, seed=3)
    hb = HybridBank.empty(8, CFG).update_many(keys, items)
    assert hb.pending_pairs == 500  # raw appends, no dedup yet
    assert int(np.asarray(hb.pair_len).sum()) == 0  # settled state untouched
    # counters are eager: exact before any compaction
    np.testing.assert_array_equal(
        hb.counts, np.bincount(np.asarray(keys), minlength=8)
    )
    settled = hb.compact()
    assert settled.pending is None
    assert hb.pending_pairs == 500  # the original instance is immutable
    assert settled is hb.compact()  # idempotent AND cached per instance
    eager = HybridBank.empty(8, CFG).update_many(keys, items).compact()
    np.testing.assert_array_equal(
        np.asarray(settled.pair_buf), np.asarray(eager.pair_buf)
    )
    np.testing.assert_array_equal(
        np.asarray(settled.pair_len), np.asarray(eager.pair_len)
    )


@pytest.mark.parametrize(
    "surface", ["estimate", "serialize", "merge", "to_dense", "density", "row"]
)
def test_pending_settles_at_every_read_surface(surface):
    """Deferred-dedup banks read bit-identical to eager per-batch dedup."""
    rows = 11
    keys, items = _skewed_stream(2000, rows, seed=7)
    deferred = HybridBank.empty(rows, CFG, threshold=16)
    eager = HybridBank.empty(rows, CFG, threshold=16)
    for c in np.array_split(np.arange(2000), 5):
        ci = jnp.asarray(c)
        deferred = deferred.update_many(keys[ci], items[ci])
        eager = eager.update_many(keys[ci], items[ci]).compact()
    assert deferred.pending_pairs > 0 and eager.pending_pairs == 0
    if surface == "estimate":
        for est in available_estimators():
            np.testing.assert_array_equal(
                np.asarray(deferred.estimate_many(est)),
                np.asarray(eager.estimate_many(est)),
            )
    elif surface == "serialize":
        assert deferred.to_bytes() == eager.to_bytes()
    elif surface == "merge":
        ok, oi = _stream(300, rows, seed=9)
        other = HybridBank.empty(rows, CFG, threshold=16).update_many(ok, oi)
        assert other.pending_pairs > 0  # merge settles BOTH sides
        a = deferred.merge(other)
        b = eager.merge(other.compact())
        np.testing.assert_array_equal(
            np.asarray(a.to_dense().registers),
            np.asarray(b.to_dense().registers),
        )
        np.testing.assert_array_equal(a.modes, b.modes)
    elif surface == "to_dense":
        np.testing.assert_array_equal(
            np.asarray(deferred.to_dense().registers),
            np.asarray(eager.to_dense().registers),
        )
    elif surface == "density":
        assert deferred.density() == eager.density()
    elif surface == "row":
        for i in range(rows):
            np.testing.assert_array_equal(
                np.asarray(deferred.row(i).registers),
                np.asarray(eager.row(i).registers),
            )


def test_flush_pressure_fires_exactly_at_the_floor(monkeypatch):
    from repro.sketch import sparse as sparse_mod

    monkeypatch.setattr(sparse_mod, "_FLUSH_MIN_PAIRS", 64)
    monkeypatch.setattr(sparse_mod, "_FLUSH_FACTOR", 2)
    hb = HybridBank.empty(4, CFG)
    k1, i1 = _stream(63, 4, seed=1)
    hb = hb.update_many(k1, i1)
    assert hb.pending is not None and hb.pending_pairs == 63  # under the floor
    k2, i2 = _stream(1, 4, seed=2)
    hb = hb.update_many(k2, i2)  # lands exactly AT the floor: >= fires
    assert hb.pending is None and hb.pending_pairs == 0
    # second window: the floor is now max(MIN, FACTOR * live pairs)
    live = int(np.asarray(hb.pair_len).sum())
    gate = max(64, 2 * live)
    k3, i3 = _stream(gate - 1, 4, seed=3)
    hb = hb.update_many(k3, i3)
    assert hb.pending is not None  # one under the amortized floor
    k4, i4 = _stream(1, 4, seed=4)
    hb = hb.update_many(k4, i4)
    assert hb.pending is None  # crossing it compacts inside update_many


@pytest.mark.parametrize("backend", available_bank_backends())
@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_promotion_decided_at_compaction_from_buffered_pairs(backend, delta):
    t = 16
    k = t + delta
    items = jnp.asarray(_items_with_distinct_buckets(k, seed=100 + k))
    keys = jnp.zeros(k, jnp.int32)
    plan = ExecutionPlan(backend=backend)
    hb = HybridBank.empty(2, CFG, threshold=t)
    for i in range(k):  # one item per batch: every pair rides the buffer
        hb = hb.update_many(keys[i : i + 1], items[i : i + 1], plan)
    assert hb.pending_pairs == k
    assert int(np.asarray(hb.slot_map).max()) == -1  # not promoted yet
    want = MODE_DENSE if k > t else MODE_SPARSE
    assert hb.modes[0] == want  # settles; promotion decided at compaction
    dense = update_many(SketchBank.empty(2, CFG), keys, items, plan)
    np.testing.assert_array_equal(
        np.asarray(hb.to_dense().registers), np.asarray(dense.registers)
    )


def test_dense_destined_items_do_not_buffer():
    t = 8
    hot = jnp.asarray(_items_with_distinct_buckets(t + 1, seed=2))
    hb = HybridBank.empty(2, CFG, threshold=t).update_many(
        jnp.zeros(t + 1, jnp.int32), hot
    )
    hb = hb.compact()
    assert hb.modes[0] == MODE_DENSE and hb.pending is None
    # further traffic to the promoted row goes straight to the registers
    more = jnp.asarray(_items_with_distinct_buckets(5, seed=3))
    hb2 = hb.update_many(jnp.zeros(5, jnp.int32), more)
    assert hb2.pending is None and hb2.pending_pairs == 0


def test_cell_space_guard_shares_one_message():
    big = HybridBank.empty(1 << 23, CFG)  # 2^23 * 256 = 2^31 sort cells
    keys = jnp.zeros(4, jnp.int32)
    items = jnp.arange(4, dtype=jnp.int32)
    msg = r"bank cell space B\*m = 8388608\*256 overflows int32 sort cells"
    with pytest.raises(ValueError, match=msg) as via_update:
        big.update_many(keys, items)
    with pytest.raises(ValueError, match=msg) as via_merge:
        big.merge(big)
    # one shared guard: update_many and merge raise the identical message
    assert str(via_update.value) == str(via_merge.value)


def test_sparse_backend_registry_and_fallback():
    from repro.sketch import (
        available_sparse_backends,
        dedup_pairs,
        get_sparse_backend,
    )

    assert {"jnp", "pallas", "pallas_pipelined"} <= set(
        available_sparse_backends()
    )
    with pytest.raises(ValueError, match="no sparse dedup path"):
        get_sparse_backend("nope")
    # a bank-only backend (no sparse entry) falls back to the jnp dedup
    row = jnp.asarray([0, 1, -1, 0], jnp.int32)
    bucket = jnp.asarray([3, 5, 0, 3], jnp.int32)
    rank = jnp.asarray([2, 7, 1, 4], jnp.int32)
    got = dedup_pairs(row, bucket, rank, 2, CFG, ExecutionPlan(backend="jnp"))
    assert int(np.asarray(got.distinct).sum()) == 2


@pytest.mark.parametrize("backend", ["pallas", "pallas_pipelined"])
def test_sparse_scatter_kernel_matches_jnp_dedup(backend):
    """The Pallas dedup (interpret off-TPU) == the jnp reference, exactly."""
    from repro.sketch import dedup_pairs

    rows = 16
    rng = np.random.default_rng(12)
    n = 640
    row = jnp.asarray(
        np.where(
            rng.random(n) < 0.1,
            rng.choice([-2, rows + 1], n),
            rng.integers(0, rows, n),
        ).astype(np.int32)
    )
    bucket = jnp.asarray(rng.integers(0, CFG.m, n, dtype=np.int32))
    rank = jnp.asarray(rng.integers(1, 50, n, dtype=np.int32))
    ref = dedup_pairs(row, bucket, rank, rows, CFG, ExecutionPlan())
    got = dedup_pairs(
        row, bucket, rank, rows, CFG, ExecutionPlan(backend=backend)
    )
    assert got.cells is not None
    np.testing.assert_array_equal(np.asarray(got.distinct), np.asarray(ref.distinct))
    if ref.cells is not None:
        np.testing.assert_array_equal(np.asarray(got.cells), np.asarray(ref.cells))
