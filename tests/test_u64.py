"""Property tests: uint32-limb 64-bit arithmetic vs numpy uint64 ground truth."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.sketch import u64 as u64lib

U64S = st.integers(min_value=0, max_value=2**64 - 1)


def _pack(vals):
    a = np.asarray(vals, dtype=np.uint64)
    return u64lib.U64(
        jnp.asarray((a >> np.uint64(32)).astype(np.uint32)),
        jnp.asarray((a & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
    )


def _unpack(x):
    return (np.asarray(x.hi, dtype=np.uint64) << np.uint64(32)) | np.asarray(
        x.lo, dtype=np.uint64
    )


@settings(deadline=None, max_examples=50)
@given(st.lists(U64S, min_size=1, max_size=8), st.lists(U64S, min_size=1, max_size=8))
def test_add_mul_xor_match_numpy(xs, ys):
    n = min(len(xs), len(ys))
    a, b = np.asarray(xs[:n], np.uint64), np.asarray(ys[:n], np.uint64)
    A, B = _pack(a), _pack(b)
    np.testing.assert_array_equal(_unpack(u64lib.add(A, B)), a + b)
    np.testing.assert_array_equal(_unpack(u64lib.mul(A, B)), a * b)
    np.testing.assert_array_equal(_unpack(u64lib.xor(A, B)), a ^ b)


@settings(deadline=None, max_examples=30)
@given(st.lists(U64S, min_size=1, max_size=8), st.integers(min_value=1, max_value=63))
def test_shifts_and_rot_match_numpy(xs, n):
    a = np.asarray(xs, np.uint64)
    A = _pack(a)
    np.testing.assert_array_equal(_unpack(u64lib.shr(A, n)), a >> np.uint64(n))
    np.testing.assert_array_equal(_unpack(u64lib.shl(A, n)), a << np.uint64(n))
    rot = (a << np.uint64(n)) | (a >> np.uint64(64 - n))
    np.testing.assert_array_equal(_unpack(u64lib.rotl(A, n)), rot)


@settings(deadline=None, max_examples=50)
@given(st.lists(st.integers(min_value=0, max_value=2**32 - 1), min_size=1, max_size=32))
def test_clz32_exact(xs):
    x = np.asarray(xs, np.uint32)
    got = np.asarray(u64lib.clz32(jnp.asarray(x)))
    exp = np.asarray([32 if v == 0 else 32 - int(v).bit_length() for v in xs])
    np.testing.assert_array_equal(got, exp)


@settings(deadline=None, max_examples=50)
@given(st.lists(U64S, min_size=1, max_size=32))
def test_clz64_exact(xs):
    got = np.asarray(u64lib.clz(_pack(np.asarray(xs, np.uint64))))
    exp = np.asarray([64 if v == 0 else 64 - int(v).bit_length() for v in xs])
    np.testing.assert_array_equal(got, exp)


def test_clz_edge_cases():
    xs = np.asarray([0, 1, 2, 0x7FFFFFFF, 0x80000000, 0xFFFFFFFF], np.uint32)
    got = np.asarray(u64lib.clz32(jnp.asarray(xs)))
    np.testing.assert_array_equal(got, [32, 31, 30, 1, 0, 0])


def test_shift_bounds_raise():
    A = _pack([1])
    with pytest.raises(ValueError):
        u64lib.shr(A, 0)
    with pytest.raises(ValueError):
        u64lib.shl(A, 64)
