"""Jitted training step: loss + grads + AdamW + the HLL datapath tap.

The sketch update rides inside the same jit as the model step — the tokens
are already on device, the segment-max partials shard with the batch, and
the (m,)-register merge is one all-reduce-max fused into the step's
collective schedule.  That is the paper's NIC trick on a training pod:
cardinality telemetry at zero marginal datapath cost (measured < 0.1% of
step FLOPs for every assigned arch).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.sketch import DEFAULT_ESTIMATOR, HLLConfig, estimators, hll
from repro.sketch.dispatch import datapath_tap
from repro.models import transformer
from repro.optim import adamw
from repro.optim.adamw import OptimizerConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    sketch: HLLConfig = HLLConfig(p=16, hash_bits=64)
    # phase-4 finalizer for the in-step device estimate and the loop's
    # exact host finalization (repro.sketch.estimators registry)
    sketch_estimator: str = DEFAULT_ESTIMATOR
    aux_weight: float = 0.01  # MoE load-balance loss weight
    sketch_enabled: bool = True
    # gradient accumulation: microbatches processed sequentially per step.
    # Caps live activation memory at (B / grad_accum) sequences' worth of
    # layer-boundary residuals — the knob that fits the 32k/80-layer train
    # cells into 16 GB/chip (see EXPERIMENTS.md §Dry-run).
    grad_accum: int = 1


def init_train_state(key, arch: ArchConfig, cfg: TrainConfig) -> dict:
    params = transformer.init_params(key, arch)
    return {
        "params": params,
        "opt": adamw.init_state(params),
        "step": jnp.zeros((), jnp.int32),
        "sketch": hll.init_registers(cfg.sketch),
    }


def train_step(
    state: dict, batch: dict, arch: ArchConfig, cfg: TrainConfig
) -> Tuple[dict, dict]:
    def loss(params, mb):
        return transformer.loss_fn(params, mb, arch, cfg.aux_weight)

    grad_fn = jax.value_and_grad(loss, has_aux=True)
    if cfg.grad_accum <= 1:
        (loss_val, parts), grads = grad_fn(state["params"], batch)
    else:
        n = cfg.grad_accum
        micro = {
            k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
            if k != "positions" or not arch.mrope
            else v.reshape((3, n, v.shape[1] // n) + v.shape[2:]).swapaxes(0, 1)
            for k, v in batch.items()
        }

        def accum(carry, mb):
            loss_acc, parts_acc, grads_acc = carry
            (l, p), g = grad_fn(state["params"], mb)
            return (
                loss_acc + l / n,
                jax.tree.map(lambda a, b: a + b / n, parts_acc, p),
                jax.tree.map(lambda a, b: a + b / n, grads_acc, g),
            ), None

        zeros_like_f32 = lambda t: jax.tree.map(
            lambda x: jnp.zeros(x.shape, x.dtype), t
        )
        init = (
            jnp.zeros((), jnp.float32),
            {"nll": jnp.zeros(()), "aux": jnp.zeros(())},
            zeros_like_f32(state["params"]),
        )
        (loss_val, parts, grads), _ = jax.lax.scan(accum, init, micro)
    params, opt, opt_metrics = adamw.update(
        state["params"], grads, state["opt"], cfg.optimizer
    )

    regs = state["sketch"]
    if cfg.sketch_enabled:
        regs = datapath_tap(regs, batch["tokens"], cfg.sketch)
    distinct = estimators.estimate_device(
        regs, cfg.sketch, estimator=cfg.sketch_estimator
    )

    new_state = {
        "params": params,
        "opt": opt,
        "step": state["step"] + 1,
        "sketch": regs,
    }
    metrics = {
        "loss": loss_val,
        "nll": parts["nll"],
        "aux": parts["aux"],
        "distinct_tokens": distinct,
        **opt_metrics,
    }
    return new_state, metrics


def make_jitted_step(
    arch: ArchConfig,
    cfg: TrainConfig,
    mesh=None,
    state_shardings=None,
    batch_shardings=None,
):
    """jit(train_step) with donated state and optional explicit shardings."""
    fn = functools.partial(train_step, arch=arch, cfg=cfg)
    kwargs = {}
    if state_shardings is not None:
        kwargs["in_shardings"] = (state_shardings, batch_shardings)
        kwargs["out_shardings"] = (state_shardings, None)
    return jax.jit(fn, donate_argnums=(0,), **kwargs)
