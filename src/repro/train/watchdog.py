"""Straggler / hang mitigation for synchronous SPMD training.

In a synchronous-SPMD job one slow or wedged worker stalls every step
(collectives block).  The framework's mitigation layers:

  1. DETECT — ``StepWatchdog`` tracks a robust running estimate of step
     time (median + MAD) and flags steps beyond ``k_mad`` deviations; a
     hard ``timeout_factor`` classifies a wedge.
  2. BOUND THE BLAST RADIUS — steps are small quanta (grad-accum keeps the
     per-step wall time minutes, not hours) and checkpoints are cheap and
     async (checkpoint/ckpt.py), so restart loses at most ckpt_every steps.
  3. RECOVER — the driver-side policy object says what to do: keep going
     (transient), snapshot now (degrading), or abort-for-restart (wedged;
     the cluster manager restarts the job, train/loop.py resumes from the
     latest checkpoint, and the step-indexed data pipeline replays exactly
     the lost steps).  The HLL sketch is replay-immune by construction.

Nothing here inspects other hosts — in SPMD every host observes the same
stall because every host waits on the same collective, so local step-time
is the globally-correct signal.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List

from repro.obs.tracing import Stopwatch


class Verdict(enum.Enum):
    OK = "ok"
    SLOW = "slow"  # straggling: snapshot soon
    WEDGED = "wedged"  # abort and restart from checkpoint


@dataclasses.dataclass
class StepWatchdog:
    """Robust step-time anomaly detector (median + MAD)."""

    warmup_steps: int = 5  # compile/first-steps excluded from stats
    k_mad: float = 6.0  # SLOW threshold: median + k * MAD
    timeout_factor: float = 10.0  # WEDGED threshold: factor over median
    min_timeout_s: float = 1.0

    _durations: List[float] = dataclasses.field(default_factory=list)
    _watch: Stopwatch = dataclasses.field(default_factory=Stopwatch)
    slow_count: int = 0
    wedged_count: int = 0

    def step_begin(self) -> None:
        self._watch.start()

    def _stats(self):
        xs = sorted(self._durations)
        n = len(xs)
        med = xs[n // 2]
        mad = sorted(abs(x - med) for x in xs)[n // 2]
        return med, max(mad, med * 0.01)

    def step_end(self) -> Verdict:
        assert self._watch.running, "step_begin not called"
        dt = self._watch.stop()

        if len(self._durations) < self.warmup_steps:
            self._durations.append(dt)
            return Verdict.OK

        med, mad = self._stats()
        verdict = Verdict.OK
        if dt > max(self.timeout_factor * med, self.min_timeout_s):
            self.wedged_count += 1
            verdict = Verdict.WEDGED
        elif dt > med + self.k_mad * mad:
            self.slow_count += 1
            verdict = Verdict.SLOW
        else:
            # only healthy steps update the baseline (stragglers must not
            # poison the estimate)
            self._durations.append(dt)
            if len(self._durations) > 256:
                self._durations.pop(0)
        return verdict

    def deadline_s(self) -> float:
        """Current hard-timeout for external watchers (collective timeout)."""
        if len(self._durations) < self.warmup_steps:
            return float("inf")
        med, _ = self._stats()
        return max(self.timeout_factor * med, self.min_timeout_s)
