"""Training driver: restartable loop with async checkpoints + HLL telemetry.

Synchronous-SPMD fault model: a lost worker kills the step; recovery is
restart-from-latest (at most ``ckpt_every`` steps lost).  The data pipeline
is a pure function of the step index, so a restarted (or *rescaled*) job
consumes exactly the remaining stream — and the HLL sketch, being a
max-lattice, is immune to the replayed boundary batch (re-aggregating a
batch is a no-op).  See checkpoint/ckpt.py for the elastic-resume path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.obs.tracing import Stopwatch
from repro.configs.base import ArchConfig
from repro.sketch import estimators
from repro.data.pipeline import DataConfig, batch_at_step
from repro.train.step import TrainConfig, init_train_state, make_jitted_step
from repro.train.watchdog import StepWatchdog, Verdict


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    async_ckpt: bool = True
    log_every: int = 10


def train(
    arch: ArchConfig,
    train_cfg: TrainConfig,
    data_cfg: DataConfig,
    loop_cfg: LoopConfig,
    seed: int = 0,
    log_fn: Callable[[str], None] = print,
):
    """Run (or resume) training; returns (final_state, history)."""
    key = jax.random.PRNGKey(seed)
    state = jax.jit(
        lambda k: init_train_state(k, arch, train_cfg)
    )(key)

    start = 0
    pending_write = None
    if loop_cfg.ckpt_dir:
        last = ckpt.latest_step(loop_cfg.ckpt_dir)
        if last is not None:
            state = ckpt.restore(state, loop_cfg.ckpt_dir, last)
            start = int(state["step"])
            log_fn(f"[loop] resumed from step {start}")

    step_fn = make_jitted_step(arch, train_cfg)
    watchdog = StepWatchdog()
    history = []
    wall = Stopwatch()
    wall.start()
    for step in range(start, loop_cfg.total_steps):
        watchdog.step_begin()  # window covers data fetch too (data stalls
        batch = batch_at_step(data_cfg, jnp.asarray(step, jnp.int32))
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        verdict = watchdog.step_end()
        if verdict is not Verdict.OK and loop_cfg.ckpt_dir:
            # straggler policy: snapshot immediately so a restart loses
            # nothing; a WEDGED verdict in production also aborts the job
            # for the cluster manager to reschedule.
            log_fn(f"[watchdog] step {step + 1}: {verdict.value} "
                   f"(deadline {watchdog.deadline_s():.1f}s) — snapshotting")
            if pending_write is not None:
                pending_write.join()
            pending_write = ckpt.save(
                state, loop_cfg.ckpt_dir, step + 1,
                async_write=loop_cfg.async_ckpt,
            )
        if (step + 1) % loop_cfg.log_every == 0 or step + 1 == loop_cfg.total_steps:
            m = {k: float(v) for k, v in metrics.items()}
            dt = wall.elapsed() / (step - start + 1)
            history.append({"step": step + 1, **m})
            log_fn(
                f"[step {step + 1:5d}] loss={m['loss']:.4f} "
                f"nll={m['nll']:.4f} lr={m['lr']:.2e} "
                f"distinct={m['distinct_tokens']:.0f} "
                f"({dt * 1e3:.0f} ms/step)"
            )
        if loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0:
            if pending_write is not None:
                pending_write.join()
            pending_write = ckpt.save(
                state, loop_cfg.ckpt_dir, step + 1,
                async_write=loop_cfg.async_ckpt,
            )
    if pending_write is not None:
        pending_write.join()
    if loop_cfg.ckpt_dir:
        ckpt.save(state, loop_cfg.ckpt_dir, loop_cfg.total_steps)

    # exact host-side sketch finalization (paper phase 4), dispatched
    # through the estimator registry
    distinct = estimators.estimate(
        state["sketch"], train_cfg.sketch,
        estimator=train_cfg.sketch_estimator,
    )
    log_fn(
        f"[loop] exact-finalized distinct-token estimate "
        f"({train_cfg.sketch_estimator}): {distinct:.0f}"
    )
    return state, history
