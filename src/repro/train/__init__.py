"""Training substrate: state, jitted step, restartable loop."""
