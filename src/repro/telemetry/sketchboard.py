"""StreamSketch: the paper's sketch as a first-class telemetry feature.

Wraps named ``HyperLogLog`` carriers so a training/serving job can track
several cardinalities at once (distinct tokens, distinct users/request ids,
distinct (token, expert) routing pairs for MoE collapse detection — DESIGN.md
§4) — each one is 48 KiB of state and one all-reduce-max per merge,
regardless of stream size.

``report()`` finalizes the whole board through the batched estimator path
(DESIGN.md §8): the registers stack into one (B, m) bank and a single
jitted ``estimate_many`` dispatch produces every float32 estimate at once,
instead of a python loop of per-sketch finalizations.  ``report(exact=True)``
(and per-stream ``estimate()``) keep the exact host finalizer for
authoritative readings; both dispatch through the pluggable estimator
registry, defaulting to the board plan's ``estimator``.

Every stream's updates run under one ``ExecutionPlan``, so a board can be
switched from the local jnp path to Pallas pipelines or a device mesh —
or to a different estimator — without touching call sites.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.sketch import (
    DEFAULT_ESTIMATOR,
    ExecutionPlan,
    HyperLogLog,
    estimate_many,
)
from repro.sketch.hll import HLLConfig


@dataclasses.dataclass
class StreamSketch:
    cfg: HLLConfig
    plan: Optional[ExecutionPlan] = None  # None = default jnp plan
    sketches: Dict[str, HyperLogLog] = dataclasses.field(default_factory=dict)

    def _estimator(self, estimator: Optional[str]) -> str:
        if estimator is not None:
            return estimator
        return (
            self.plan.estimator if self.plan is not None else DEFAULT_ESTIMATOR
        )

    def stream(self, name: str) -> HyperLogLog:
        if name not in self.sketches:
            self.sketches[name] = HyperLogLog.empty(self.cfg)
        return self.sketches[name]

    def observe(self, name: str, items: jnp.ndarray) -> None:
        self.sketches[name] = self.stream(name).update(items, self.plan)

    def merge_from(self, other: "StreamSketch") -> None:
        if other.cfg != self.cfg:
            raise ValueError(
                f"cannot merge boards with different configs: "
                f"{self.cfg} vs {other.cfg}"
            )
        for name, sk in other.sketches.items():
            self.sketches[name] = self.stream(name).merge(sk)

    def estimate(self, name: str, estimator: Optional[str] = None) -> float:
        """Exact host-side estimate for one stream."""
        return self.stream(name).estimate(self._estimator(estimator))

    def serialize(self) -> Dict[str, bytes]:
        """Dense per-stream blobs (HyperLogLog.to_bytes) for shipping."""
        return {name: sk.to_bytes() for name, sk in self.sketches.items()}

    @classmethod
    def deserialize(
        cls,
        blobs: Dict[str, bytes],
        cfg: Optional[HLLConfig] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> "StreamSketch":
        """Rebuild a board from serialize() output.

        ``cfg`` is only required for a board serialized before its first
        observe() (no streams to recover the config from); when given, it
        must match the config recovered from the blobs — a mismatch raises
        instead of silently adopting the blob config.
        """
        sketches = {n: HyperLogLog.from_bytes(b) for n, b in blobs.items()}
        if sketches:
            recovered = next(iter(sketches.values())).cfg
            for name, sk in sketches.items():
                if sk.cfg != recovered:
                    raise ValueError(
                        f"blob {name!r} config {sk.cfg} disagrees with the "
                        f"other streams on this board"
                    )
            if cfg is not None and cfg != recovered:
                raise ValueError(
                    f"cfg mismatch: blobs were serialized with {recovered}, "
                    f"deserialize was asked for {cfg}"
                )
            cfg = recovered
        elif cfg is None:
            raise ValueError("empty board: pass cfg= to deserialize it")
        return cls(cfg=cfg, plan=plan, sketches=sketches)

    def report(
        self, exact: bool = False, estimator: Optional[str] = None
    ) -> Dict[str, dict]:
        """Per-stream estimates; batched device finalization by default."""
        estimator = self._estimator(estimator)
        names = list(self.sketches)
        if exact or not names:
            estimates = [
                self.sketches[n].estimate(estimator) for n in names
            ]
        else:
            bank = jnp.stack([self.sketches[n].registers for n in names])
            estimates = [
                float(e)
                for e in np.asarray(estimate_many(bank, self.cfg, estimator))
            ]
        out = {}
        for name, est in zip(names, estimates):
            sk = self.sketches[name]
            out[name] = {
                "estimate": est,
                "items_seen": sk.count,
                "duplication": (sk.count / est) if est > 0 else float("nan"),
                "stderr_expected": sk.standard_error,
            }
        return out
