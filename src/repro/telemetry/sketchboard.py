"""StreamSketch: the paper's sketch as a first-class telemetry feature.

Wraps named ``HyperLogLog`` carriers so a training/serving job can track
several cardinalities at once (distinct tokens, distinct users/request ids,
distinct (token, expert) routing pairs for MoE collapse detection — DESIGN.md
§4) — each one is 48 KiB of state and one all-reduce-max per merge,
regardless of stream size.

Ingest is **buffered and bank-batched** (DESIGN.md §9): ``observe()`` only
appends the items to a per-stream buffer; at flush time every buffered
stream's registers stack into one ``SketchBank`` and a single keyed
``update_many`` dispatch (key = stream row) aggregates everything at once —
one fused scatter-max instead of one dispatch per observe call.  Flushes
happen automatically once ``flush_items`` items are pending and before any
read (estimate / report / serialize / merge_from / stream), so results are
always bit-identical to unbuffered per-stream updates (the max-lattice makes
batching invisible).

``report()`` finalizes the whole board through the batched estimator path
(DESIGN.md §8): the registers stack into one (B, m) bank and a single
jitted ``estimate_many`` dispatch produces every float32 estimate at once,
instead of a python loop of per-sketch finalizations.  ``report(exact=True)``
(and per-stream ``estimate()``) keep the exact host finalizer for
authoritative readings; both dispatch through the pluggable estimator
registry, defaulting to the board plan's ``estimator``.

``window=W`` switches the board to WINDOWED mode (DESIGN.md §11): streams
become rows of one ``WindowedBank`` ring, ``advance()`` slides the window
by one epoch, and every read — ``report()``, ``estimate()``, ``stream()``
— answers over the last W epochs instead of all time (``report()`` is one
fused ring fold + one batched estimate_many).  The flush-before-read
contract is unchanged; flat-board ``serialize``/``merge_from`` are
replaced by ``window_bytes()`` (the RHLW blob) because epochs on
different boards are not aligned.

Every stream's updates run under one ``ExecutionPlan``, so a board can be
switched from the local jnp path to Pallas pipelines or a device mesh —
or to a different estimator — without touching call sites.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.sketch import (
    DEFAULT_ESTIMATOR,
    DEFAULT_PLAN,
    ExecutionPlan,
    HyperLogLog,
    SketchBank,
    WindowedBank,
    estimate_many,
    get_bank_backend,
    update_many,
)
from repro.sketch.hll import HLLConfig
from repro.sketch.hll import standard_error as hll_standard_error


@dataclasses.dataclass
class StreamSketch:
    cfg: HLLConfig
    plan: Optional[ExecutionPlan] = None  # None = default jnp plan
    sketches: Dict[str, HyperLogLog] = dataclasses.field(default_factory=dict)
    # buffered keyed ingest: flush once this many items are pending
    flush_items: int = 1 << 20
    # W > 0 switches the board to windowed mode (DESIGN.md §11): streams
    # become rows of one WindowedBank ring and every read answers over the
    # sliding W-epoch window instead of all time
    window: Optional[int] = None
    _pending: Dict[str, List[jnp.ndarray]] = dataclasses.field(
        default_factory=dict, repr=False
    )
    _pending_items: int = dataclasses.field(default=0, repr=False)
    _wbank: Optional[WindowedBank] = dataclasses.field(default=None, repr=False)
    _wrows: Dict[str, int] = dataclasses.field(default_factory=dict, repr=False)
    # the full-window fold, memoized between ring mutations so per-stream
    # reads (stream()/estimate()) over many streams cost ONE fold, not B
    _wfold_cache: Optional[SketchBank] = dataclasses.field(
        default=None, repr=False
    )

    def __post_init__(self):
        if self.window is not None and self.window < 1:
            raise ValueError(
                f"window needs at least one bucket, got {self.window}"
            )

    def _estimator(self, estimator: Optional[str]) -> str:
        if estimator is not None:
            return estimator
        return (
            self.plan.estimator if self.plan is not None else DEFAULT_ESTIMATOR
        )

    def stream(self, name: str) -> HyperLogLog:
        """The named sketch, current through any buffered observations.

        In windowed mode this is a read-only SNAPSHOT of the stream's
        sliding window (ring fold + exact windowed counter); mutate the
        board through ``observe``/``advance``, not the snapshot.
        """
        if name in self._pending:
            self.flush()
        if self.window is not None:
            if name not in self._wrows:
                self._wrows[name] = len(self._wrows)
            row = self._wrows[name]
            if self._wbank is None or row >= self._wbank.rows:
                return HyperLogLog.empty(self.cfg)
            return self._window_fold().row(row)
        if name not in self.sketches:
            self.sketches[name] = HyperLogLog.empty(self.cfg)
        return self.sketches[name]

    def _window_fold(self) -> SketchBank:
        """The live window collapsed to a flat bank (row = stream).

        Memoized until the next ring mutation (flush/advance/grow), so a
        loop of per-stream reads folds the ring once, like report() does.
        """
        if self._wfold_cache is None:
            self._wfold_cache = self._wbank.fold_window(plan=self.plan)
        return self._wfold_cache

    def observe(self, name: str, items: jnp.ndarray) -> None:
        """Buffer ``items`` for ``name``; aggregation happens at flush."""
        if self.window is not None:
            if name not in self._wrows:
                self._wrows[name] = len(self._wrows)
        elif name not in self.sketches:
            self.sketches[name] = HyperLogLog.empty(self.cfg)
        # murmur3 hashes the 32-bit pattern (it casts to uint32), so
        # normalizing the buffer dtype here cannot change any register
        flat = jnp.asarray(items).reshape(-1).astype(jnp.uint32)
        if flat.size == 0:
            return
        self._pending.setdefault(name, []).append(flat)
        self._pending_items += int(flat.size)
        if self._pending_items >= self.flush_items:
            self.flush()

    def flush(self) -> None:
        """Drain the buffer: ONE keyed update_many over the pending streams.

        Pending streams stack into a SketchBank (row = stream), every
        buffered array concatenates into one keyed stream, and a single
        fused dispatch (DESIGN.md §9) replaces what used to be one
        ``update()`` per observe call.  Bit-identical to the unbuffered
        path: scatter-max commutes with any batching of the stream.
        """
        if not self._pending:
            return
        names = list(self._pending)
        if self.window is not None:
            # windowed boards land the whole buffer in the CURRENT time
            # bucket of the ring with the same single keyed dispatch
            keys = jnp.concatenate(
                [
                    jnp.full((a.size,), self._wrows[name], jnp.int32)
                    for name in names
                    for a in self._pending[name]
                ]
            )
            items = jnp.concatenate(
                [a for name in names for a in self._pending[name]]
            )
            rows = len(self._wrows)
            if self._wbank is None:
                self._wbank = WindowedBank.empty(self.window, rows, self.cfg)
            elif rows > self._wbank.rows:
                self._wbank = self._wbank.with_rows(rows)
            self._wbank = self._wbank.observe(keys, items, self.plan)
            self._wfold_cache = None
            self._pending.clear()
            self._pending_items = 0
            return
        try:
            get_bank_backend((self.plan or DEFAULT_PLAN).backend)
        except ValueError:
            # a plugin backend registered only for single sketches keeps
            # working: fall back to one per-stream update over the
            # concatenated buffer (still one dispatch per stream)
            for name in names:
                chunk = jnp.concatenate(self._pending[name])
                self.sketches[name] = self.sketches[name].update(
                    chunk, self.plan
                )
            self._pending.clear()
            self._pending_items = 0
            return
        keys = jnp.concatenate(
            [
                jnp.full((a.size,), row, jnp.int32)
                for row, name in enumerate(names)
                for a in self._pending[name]
            ]
        )
        items = jnp.concatenate(
            [a for name in names for a in self._pending[name]]
        )
        bank = SketchBank.from_sketches([self.sketches[n] for n in names])
        bank = update_many(bank, keys, items, self.plan)
        for row, name in enumerate(names):
            self.sketches[name] = bank.row(row)
        self._pending.clear()
        self._pending_items = 0

    def advance(self, steps: int = 1) -> None:
        """Windowed mode: open ``steps`` new epochs (flushes first, so
        everything observed so far belongs to the bucket being closed)."""
        self._require_window("advance")
        self.flush()
        self._ensure_wbank()
        self._wbank = self._wbank.advance(steps)
        self._wfold_cache = None

    def advance_to(self, epoch: int) -> None:
        """Windowed mode: jump the ring forward to absolute ``epoch``."""
        self._require_window("advance_to")
        self.flush()
        self._ensure_wbank()
        self._wbank = self._wbank.advance_to(epoch)
        self._wfold_cache = None

    def window_bytes(self) -> bytes:
        """Windowed mode: the whole ring as one RHLW blob (DESIGN.md §11).

        Row-to-name mapping travels separately (``window_rows()``); the
        wire format carries ring state only.
        """
        self._require_window("window_bytes")
        self.flush()
        self._ensure_wbank()
        return self._wbank.to_bytes()

    def window_rows(self) -> tuple:
        """Stream names in bank-row order (row i holds names[i])."""
        self._require_window("window_rows")
        return tuple(sorted(self._wrows, key=self._wrows.get))

    def _require_window(self, op: str) -> None:
        if self.window is None:
            raise ValueError(f"{op}() needs a windowed board (window=W)")

    def merge_from(self, other: "StreamSketch") -> None:
        if self.window is not None or other.window is not None:
            raise ValueError(
                "windowed boards do not merge: epochs on different boards "
                "are not aligned; ship RHLW blobs (window_bytes) instead"
            )
        if other.cfg != self.cfg:
            raise ValueError(
                f"cannot merge boards with different configs: "
                f"{self.cfg} vs {other.cfg}"
            )
        self.flush()
        other.flush()
        for name, sk in other.sketches.items():
            self.sketches[name] = self.stream(name).merge(sk)

    def estimate(self, name: str, estimator: Optional[str] = None) -> float:
        """Exact host-side estimate for one stream.

        On a windowed board this is the stream's SLIDING-WINDOW distinct
        count (last W epochs), not an all-time figure.
        """
        return self.stream(name).estimate(self._estimator(estimator))

    def serialize(self) -> Dict[str, bytes]:
        """Dense per-stream blobs (HyperLogLog.to_bytes) for shipping."""
        if self.window is not None:
            raise ValueError(
                "windowed boards serialize the whole ring: use window_bytes()"
            )
        self.flush()
        return {name: sk.to_bytes() for name, sk in self.sketches.items()}

    @classmethod
    def deserialize(
        cls,
        blobs: Dict[str, bytes],
        cfg: Optional[HLLConfig] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> "StreamSketch":
        """Rebuild a board from serialize() output.

        ``cfg`` is only required for a board serialized before its first
        observe() (no streams to recover the config from); when given, it
        must match the config recovered from the blobs — a mismatch raises
        instead of silently adopting the blob config.
        """
        sketches = {n: HyperLogLog.from_bytes(b) for n, b in blobs.items()}
        if sketches:
            recovered = next(iter(sketches.values())).cfg
            for name, sk in sketches.items():
                if sk.cfg != recovered:
                    raise ValueError(
                        f"blob {name!r} config {sk.cfg} disagrees with the "
                        f"other streams on this board"
                    )
            if cfg is not None and cfg != recovered:
                raise ValueError(
                    f"cfg mismatch: blobs were serialized with {recovered}, "
                    f"deserialize was asked for {cfg}"
                )
            cfg = recovered
        elif cfg is None:
            raise ValueError("empty board: pass cfg= to deserialize it")
        return cls(cfg=cfg, plan=plan, sketches=sketches)

    def _board_registers(self) -> tuple:
        """(names, stacked (B, m) uint8 registers) of the live board.

        Windowed boards read the memoized ring fold, so this costs at
        most one fold regardless of how often density() is asked.
        """
        if self.window is not None:
            names = self.window_rows()
            if not names:
                return (), np.zeros((0, self.cfg.m), np.uint8)
            self._ensure_wbank()
            folded = self._window_fold()
            regs = np.asarray(folded.registers)[
                [self._wrows[n] for n in names]
            ]
            return names, regs
        names = tuple(self.sketches)
        if not names:
            return (), np.zeros((0, self.cfg.m), np.uint8)
        return names, np.stack(
            [np.asarray(self.sketches[n].registers) for n in names]
        )

    def density(self) -> Dict[str, object]:
        """Per-board register-density stats (DESIGN.md §12).

        Reports how full each stream's registers are, how many streams
        are sparse-eligible (occupancy at or under the board plan's
        ``sparse_threshold``, defaulting to m // 4 like the carrier), and
        what the board would cost under the hybrid sparse layout vs the
        dense carriers it holds — the signal for moving a fleet to
        ``HybridBank`` storage.
        """
        self.flush()
        names, regs = self._board_registers()
        m = self.cfg.m
        occ = (regs > 0).sum(axis=1)
        thr = self.plan.sparse_threshold if self.plan is not None else None
        if thr is None:
            thr = max(1, m // 4)
        # sparse rows cost ~4 bytes/pair + fixed per-row bookkeeping (§12)
        hybrid = int(np.where(occ > thr, m, 4 * occ + 16).sum())
        return {
            "streams": len(names),
            "occupancy": {n: float(occ[i] / m) for i, n in enumerate(names)},
            "occupancy_mean": float(occ.mean() / m) if len(names) else 0.0,
            "sparse_eligible": int((occ <= thr).sum()),
            "dense_nbytes": int(len(names) * m),
            "hybrid_nbytes_estimate": hybrid,
        }

    def report(
        self,
        exact: bool = False,
        estimator: Optional[str] = None,
        density: bool = False,
    ) -> Dict[str, dict]:
        """Per-stream estimates; batched device finalization by default.

        Windowed boards report ROLLING distinct counts over the sliding
        W-epoch window (one fused ring fold + one batched estimate_many);
        ``items_seen``/``duplication`` likewise cover only the live
        window.  Same row schema as flat boards.  ``density=True`` adds a
        ``register_occupancy`` column per stream (board-level stats live
        in :meth:`density`).
        """
        self.flush()
        estimator = self._estimator(estimator)
        if self.window is not None:
            out = self._report_window(exact, estimator)
        else:
            out = self._report_flat(exact, estimator)
        if density:
            occ = self.density()["occupancy"]
            for name, row in out.items():
                row["register_occupancy"] = occ[name]
        return out

    def _report_flat(self, exact: bool, estimator: str) -> Dict[str, dict]:
        names = list(self.sketches)
        if exact or not names:
            estimates = [
                self.sketches[n].estimate(estimator) for n in names
            ]
        else:
            bank = jnp.stack([self.sketches[n].registers for n in names])
            estimates = [
                float(e)
                for e in np.asarray(estimate_many(bank, self.cfg, estimator))
            ]
        out = {}
        for name, est in zip(names, estimates):
            sk = self.sketches[name]
            out[name] = {
                "estimate": est,
                "items_seen": sk.count,
                "duplication": (sk.count / est) if est > 0 else float("nan"),
                "stderr_expected": sk.standard_error,
            }
        return out

    def _ensure_wbank(self) -> None:
        """Materialize/grow the ring for every registered stream row."""
        rows = max(1, len(self._wrows))
        if self._wbank is None:
            self._wbank = WindowedBank.empty(self.window, rows, self.cfg)
            self._wfold_cache = None
        elif rows > self._wbank.rows:
            self._wbank = self._wbank.with_rows(rows)
            self._wfold_cache = None

    def _report_window(self, exact: bool, estimator: str) -> Dict[str, dict]:
        names = self.window_rows()
        if not names:
            return {}
        self._ensure_wbank()
        # ONE (cached) ring fold; finalization is one batched estimate_many
        # or, for exact=True, the host finalizer per row — same split as
        # the flat board path above
        folded = self._window_fold()
        if exact:
            estimates = [
                folded.estimate(self._wrows[n], estimator) for n in names
            ]
        else:
            ests = np.asarray(folded.estimate_many(estimator))
            estimates = [float(ests[self._wrows[n]]) for n in names]
        counts = folded.counts
        stderr = hll_standard_error(self.cfg)
        out = {}
        for name, est in zip(names, estimates):
            seen = int(counts[self._wrows[name]])
            out[name] = {
                "estimate": est,
                "items_seen": seen,
                "duplication": (seen / est) if est > 0 else float("nan"),
                "stderr_expected": stderr,
            }
        return out
