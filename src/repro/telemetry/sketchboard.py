"""StreamSketch: the paper's sketch as a first-class telemetry feature.

Wraps named ``HyperLogLog`` carriers so a training/serving job can track
several cardinalities at once (distinct tokens, distinct users/request ids,
distinct (token, expert) routing pairs for MoE collapse detection — DESIGN.md
§4) — each one is 48 KiB of state and one all-reduce-max per merge,
regardless of stream size.  The exact host-side estimate finalizes a report,
mirroring the paper's constant-time computation phase.

Every stream's updates run under one ``ExecutionPlan``, so a board can be
switched from the local jnp path to Pallas pipelines or a device mesh
without touching call sites.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp

from repro.sketch import ExecutionPlan, HyperLogLog
from repro.sketch.hll import HLLConfig


@dataclasses.dataclass
class StreamSketch:
    cfg: HLLConfig
    plan: Optional[ExecutionPlan] = None  # None = default jnp plan
    sketches: Dict[str, HyperLogLog] = dataclasses.field(default_factory=dict)

    def stream(self, name: str) -> HyperLogLog:
        if name not in self.sketches:
            self.sketches[name] = HyperLogLog.empty(self.cfg)
        return self.sketches[name]

    def observe(self, name: str, items: jnp.ndarray) -> None:
        self.sketches[name] = self.stream(name).update(items, self.plan)

    def merge_from(self, other: "StreamSketch") -> None:
        for name, sk in other.sketches.items():
            self.sketches[name] = self.stream(name).merge(sk)

    def estimate(self, name: str) -> float:
        return self.stream(name).estimate()

    def serialize(self) -> Dict[str, bytes]:
        """Dense per-stream blobs (HyperLogLog.to_bytes) for shipping."""
        return {name: sk.to_bytes() for name, sk in self.sketches.items()}

    @classmethod
    def deserialize(
        cls,
        blobs: Dict[str, bytes],
        cfg: Optional[HLLConfig] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> "StreamSketch":
        """Rebuild a board from serialize() output.

        ``cfg`` is only required for a board serialized before its first
        observe() (no streams to recover the config from).
        """
        sketches = {n: HyperLogLog.from_bytes(b) for n, b in blobs.items()}
        if sketches:
            cfg = next(iter(sketches.values())).cfg
        elif cfg is None:
            raise ValueError("empty board: pass cfg= to deserialize it")
        return cls(cfg=cfg, plan=plan, sketches=sketches)

    def report(self) -> Dict[str, dict]:
        return {
            name: {
                "estimate": sk.estimate(),
                "items_seen": sk.count,
                "duplication": sk.duplication(),
                "stderr_expected": sk.standard_error,
            }
            for name, sk in self.sketches.items()
        }
