"""StreamSketch: the paper's sketch as a first-class telemetry feature.

Wraps named ``HyperLogLog`` carriers so a training/serving job can track
several cardinalities at once (distinct tokens, distinct users/request ids,
distinct (token, expert) routing pairs for MoE collapse detection — DESIGN.md
§4) — each one is 48 KiB of state and one all-reduce-max per merge,
regardless of stream size.

Ingest is **buffered and bank-batched** (DESIGN.md §9): ``observe()`` only
appends the items to a per-stream buffer; at flush time every buffered
stream's registers stack into one ``SketchBank`` and a single keyed
``update_many`` dispatch (key = stream row) aggregates everything at once —
one fused scatter-max instead of one dispatch per observe call.  Flushes
happen automatically once ``flush_items`` items are pending and before any
read (estimate / report / serialize / merge_from / stream), so results are
always bit-identical to unbuffered per-stream updates (the max-lattice makes
batching invisible).

``report()`` finalizes the whole board through the batched estimator path
(DESIGN.md §8): the registers stack into one (B, m) bank and a single
jitted ``estimate_many`` dispatch produces every float32 estimate at once,
instead of a python loop of per-sketch finalizations.  ``report(exact=True)``
(and per-stream ``estimate()``) keep the exact host finalizer for
authoritative readings; both dispatch through the pluggable estimator
registry, defaulting to the board plan's ``estimator``.

``window=W`` switches the board to WINDOWED mode (DESIGN.md §11): streams
become rows of one ``WindowedBank`` ring, ``advance()`` slides the window
by one epoch, and every read — ``report()``, ``estimate()``, ``stream()``
— answers over the last W epochs instead of all time (``report()`` is one
fused ring fold + one batched estimate_many).  The flush-before-read
contract is unchanged; flat-board ``serialize``/``merge_from`` are
replaced by ``window_bytes()`` (the RHLW blob) because epochs on
different boards are not aligned.

``window_levels=L`` (windowed boards only) swaps the dense ring for a
``MultiResWindowedBank`` exponential histogram (DESIGN.md §14): the
newest ``window`` epochs stay at full resolution and older buckets
pairwise-merge, stretching the answerable horizon to
``window * (2**L - 1)`` epochs at O(window·L) storage.  Reads answer
over the whole horizon (rounded up to bucket edges at the tail) through
the same carrier surface, so every board path is unchanged.  Not
combinable with ``track_topk`` — count-min rings have no multi-res
carrier.

``track_topk=CMConfig(...)`` adds heavy-hitter tracking (DESIGN.md §13):
the same buffered keyed stream that feeds the HLL bank also feeds one
``CountMinBank`` (row = stream) through the same flush dispatch, and
``topk(name, k)`` / ``report(topk=k)`` answer "which items dominate this
stream" alongside the distinct counts.  On a windowed board the counters
ride a ``WindowedCountMinBank`` ring that advances in lockstep with the
HLL ring, so top-k answers cover the same sliding window as the
cardinalities.

Every stream's updates run under one ``ExecutionPlan``, so a board can be
switched from the local jnp path to Pallas pipelines or a device mesh —
or to a different estimator — without touching call sites.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.sketch import (
    CMConfig,
    CountMinBank,
    DEFAULT_ESTIMATOR,
    DEFAULT_PLAN,
    ExecutionPlan,
    HyperLogLog,
    MultiResWindowedBank,
    SketchBank,
    WindowedBank,
    WindowedCountMinBank,
    estimate_many,
    get_bank_backend,
    get_cm_backend,
    update_many,
)
from repro.sketch.hll import HLLConfig
from repro.sketch.hll import standard_error as hll_standard_error


@dataclasses.dataclass
class StreamSketch:
    cfg: HLLConfig
    plan: Optional[ExecutionPlan] = None  # None = default jnp plan
    sketches: Dict[str, HyperLogLog] = dataclasses.field(default_factory=dict)
    # buffered keyed ingest: flush once this many items are pending
    flush_items: int = 1 << 20
    # W > 0 switches the board to windowed mode (DESIGN.md §11): streams
    # become rows of one WindowedBank ring and every read answers over the
    # sliding W-epoch window instead of all time
    window: Optional[int] = None
    # L > 0 upgrades the windowed ring to the multi-resolution
    # exponential histogram (DESIGN.md §14): `window` becomes the
    # full-resolution base and the horizon stretches to
    # window * (2**L - 1) epochs at O(window * L) slots
    window_levels: Optional[int] = None
    # a CMConfig adds heavy-hitter tracking (DESIGN.md §13): the flush
    # dispatch also feeds one CountMinBank (row = stream) and topk()/
    # report(topk=k) answer which items dominate each stream
    track_topk: Optional[CMConfig] = None
    _pending: Dict[str, List[jnp.ndarray]] = dataclasses.field(
        default_factory=dict, repr=False
    )
    _pending_items: int = dataclasses.field(default=0, repr=False)
    _wbank: Optional[WindowedBank] = dataclasses.field(default=None, repr=False)
    _wrows: Dict[str, int] = dataclasses.field(default_factory=dict, repr=False)
    # the full-window fold, memoized between ring mutations so per-stream
    # reads (stream()/estimate()) over many streams cost ONE fold, not B
    _wfold_cache: Optional[SketchBank] = dataclasses.field(
        default=None, repr=False
    )
    # heavy-hitter state: the flat bank (row = stream, flat boards), the
    # ring (windowed boards, advanced in lockstep with _wbank), the flat
    # board's name -> row map, and the memoized window fold
    _cmbank: Optional[CountMinBank] = dataclasses.field(default=None, repr=False)
    _cmwin: Optional[WindowedCountMinBank] = dataclasses.field(
        default=None, repr=False
    )
    _cm_rows: Dict[str, int] = dataclasses.field(default_factory=dict, repr=False)
    _cmfold_cache: Optional[CountMinBank] = dataclasses.field(
        default=None, repr=False
    )

    def __post_init__(self):
        if self.window is not None and self.window < 1:
            raise ValueError(
                f"window needs at least one bucket, got {self.window}"
            )
        if self.window_levels is not None:
            if self.window is None:
                raise ValueError(
                    "window_levels needs a windowed board (window=W)"
                )
            if self.window_levels < 1:
                raise ValueError(
                    f"window_levels needs at least one level, "
                    f"got {self.window_levels}"
                )
            if self.track_topk is not None:
                raise ValueError(
                    "window_levels cannot combine with track_topk: the "
                    "count-min ring has no multi-resolution carrier"
                )

    def _estimator(self, estimator: Optional[str]) -> str:
        if estimator is not None:
            return estimator
        return (
            self.plan.estimator if self.plan is not None else DEFAULT_ESTIMATOR
        )

    def stream(self, name: str) -> HyperLogLog:
        """The named sketch, current through any buffered observations.

        In windowed mode this is a read-only SNAPSHOT of the stream's
        sliding window (ring fold + exact windowed counter); mutate the
        board through ``observe``/``advance``, not the snapshot.
        """
        if name in self._pending:
            self.flush()
        if self.window is not None:
            if name not in self._wrows:
                self._wrows[name] = len(self._wrows)
            row = self._wrows[name]
            if self._wbank is None or row >= self._wbank.rows:
                return HyperLogLog.empty(self.cfg)
            return self._window_fold().row(row)
        if name not in self.sketches:
            self.sketches[name] = HyperLogLog.empty(self.cfg)
        return self.sketches[name]

    def _window_fold(self) -> SketchBank:
        """The live window collapsed to a flat bank (row = stream).

        Memoized until the next ring mutation (flush/advance/grow), so a
        loop of per-stream reads folds the ring once, like report() does.
        """
        if self._wfold_cache is None:
            self._wfold_cache = self._wbank.fold_window(plan=self.plan)
        return self._wfold_cache

    def observe(self, name: str, items: jnp.ndarray) -> None:
        """Buffer ``items`` for ``name``; aggregation happens at flush."""
        if self.window is not None:
            if name not in self._wrows:
                self._wrows[name] = len(self._wrows)
        elif name not in self.sketches:
            self.sketches[name] = HyperLogLog.empty(self.cfg)
        # murmur3 hashes the 32-bit pattern (it casts to uint32), so
        # normalizing the buffer dtype here cannot change any register
        flat = jnp.asarray(items).reshape(-1).astype(jnp.uint32)
        if flat.size == 0:
            return
        self._pending.setdefault(name, []).append(flat)
        self._pending_items += int(flat.size)
        if self._pending_items >= self.flush_items:
            self.flush()

    def flush(self) -> None:
        """Drain the buffer: ONE keyed update_many over the pending streams.

        Pending streams stack into a SketchBank (row = stream), every
        buffered array concatenates into one keyed stream, and a single
        fused dispatch (DESIGN.md §9) replaces what used to be one
        ``update()`` per observe call.  Bit-identical to the unbuffered
        path: scatter-max commutes with any batching of the stream.
        (``HybridBank`` carriers layer their own second-stage buffer on
        top: sparse-destined pairs ride the bank's deferred append log
        past this flush and settle on the first read — DESIGN.md §12.)
        """
        if not self._pending:
            return
        if self.track_topk is not None:
            # the count-min twin ingests the SAME buffered keyed stream
            # first, while the buffer is still intact
            self._flush_topk()
        names = list(self._pending)
        if self.window is not None:
            # windowed boards land the whole buffer in the CURRENT time
            # bucket of the ring with the same single keyed dispatch
            keys = jnp.concatenate(
                [
                    jnp.full((a.size,), self._wrows[name], jnp.int32)
                    for name in names
                    for a in self._pending[name]
                ]
            )
            items = jnp.concatenate(
                [a for name in names for a in self._pending[name]]
            )
            rows = len(self._wrows)
            if self._wbank is None:
                self._wbank = self._new_wbank(rows)
            elif rows > self._wbank.rows:
                self._wbank = self._wbank.with_rows(rows)
            self._wbank = self._wbank.observe(keys, items, self.plan)
            self._wfold_cache = None
            self._pending.clear()
            self._pending_items = 0
            return
        try:
            get_bank_backend((self.plan or DEFAULT_PLAN).backend)
        except ValueError:
            # a plugin backend registered only for single sketches keeps
            # working: fall back to one per-stream update over the
            # concatenated buffer (still one dispatch per stream)
            for name in names:
                chunk = jnp.concatenate(self._pending[name])
                self.sketches[name] = self.sketches[name].update(
                    chunk, self.plan
                )
            self._pending.clear()
            self._pending_items = 0
            return
        keys = jnp.concatenate(
            [
                jnp.full((a.size,), row, jnp.int32)
                for row, name in enumerate(names)
                for a in self._pending[name]
            ]
        )
        items = jnp.concatenate(
            [a for name in names for a in self._pending[name]]
        )
        bank = SketchBank.from_sketches([self.sketches[n] for n in names])
        bank = update_many(bank, keys, items, self.plan)
        for row, name in enumerate(names):
            self.sketches[name] = bank.row(row)
        self._pending.clear()
        self._pending_items = 0

    def advance(self, steps: int = 1) -> None:
        """Windowed mode: open ``steps`` new epochs (flushes first, so
        everything observed so far belongs to the bucket being closed)."""
        self._require_window("advance")
        self.flush()
        self._ensure_wbank()
        self._wbank = self._wbank.advance(steps)
        self._wfold_cache = None
        if self._cmwin is not None:
            # the count-min ring slides in lockstep, so top-k answers
            # cover the same epochs as the cardinalities
            self._cmwin = self._cmwin.advance(steps)
            self._cmfold_cache = None

    def advance_to(self, epoch: int) -> None:
        """Windowed mode: jump the ring forward to absolute ``epoch``."""
        self._require_window("advance_to")
        self.flush()
        self._ensure_wbank()
        self._wbank = self._wbank.advance_to(epoch)
        self._wfold_cache = None
        if self._cmwin is not None:
            self._cmwin = self._cmwin.advance_to(epoch)
            self._cmfold_cache = None

    # ------------------------------------------------------------------
    # heavy hitters (track_topk boards; DESIGN.md §13)
    # ------------------------------------------------------------------

    def _cm_plan(self) -> Optional[ExecutionPlan]:
        """The board plan if its backend has a count-min path, else None.

        A plugin backend registered only for the HLL axes keeps working:
        its board falls back to the reference jnp count-min dispatch, the
        same degradation contract as the flat-flush bank fallback above.
        """
        try:
            get_cm_backend((self.plan or DEFAULT_PLAN).backend)
        except ValueError:
            return None
        return self.plan

    def _flush_topk(self) -> None:
        """Feed the buffered keyed stream into the count-min twin."""
        names = list(self._pending)
        rowmap = self._wrows if self.window is not None else self._cm_rows
        for name in names:
            if name not in rowmap:
                rowmap[name] = len(rowmap)
        keys = jnp.concatenate(
            [
                jnp.full((a.size,), rowmap[name], jnp.int32)
                for name in names
                for a in self._pending[name]
            ]
        )
        items = jnp.concatenate(
            [a for name in names for a in self._pending[name]]
        )
        rows = len(rowmap)
        plan = self._cm_plan()
        if self.window is not None:
            if self._cmwin is None:
                self._cmwin = WindowedCountMinBank.empty(
                    self.window, rows, self.track_topk
                )
            elif rows > self._cmwin.rows:
                self._cmwin = self._cmwin.with_rows(rows)
            self._cmwin = self._cmwin.observe(keys, items, plan)
        else:
            if self._cmbank is None:
                self._cmbank = CountMinBank.empty(rows, self.track_topk)
            elif rows > len(self._cmbank):
                self._cmbank = self._cmbank.with_rows(rows)
            self._cmbank = self._cmbank.update_many(keys, items, plan)
        self._cmfold_cache = None

    def _cm_read_bank(self) -> Optional[CountMinBank]:
        """The flat count-min bank current through any window fold."""
        if self.window is None:
            return self._cmbank
        if self._cmwin is None:
            return None
        if self._cmfold_cache is None:
            self._cmfold_cache = self._cmwin.fold_window(plan=self._cm_plan())
        return self._cmfold_cache

    def _require_topk(self, op: str) -> None:
        if self.track_topk is None:
            raise ValueError(
                f"{op}() needs a heavy-hitter board (track_topk=CMConfig(...))"
            )

    def topk(self, name: str, k: int = 10) -> List[tuple]:
        """The stream's top-k heavy items as [(item, est_count), ...].

        Items come back as the uint32 values observe() normalized to;
        counts are count-min upper bounds.  On a windowed board the
        answer covers the sliding W-epoch window, like every other read.
        Streams this board has never seen report [].
        """
        self._require_topk("topk")
        self.flush()
        rowmap = self._wrows if self.window is not None else self._cm_rows
        bank = self._cm_read_bank()
        if bank is None or name not in rowmap or rowmap[name] >= len(bank):
            return []
        vals, cnts = bank.topk(k)
        row = rowmap[name]
        return [
            (int(np.uint32(v)), int(c))
            for v, c in zip(vals[row], cnts[row])
            if c > 0
        ]

    def window_bytes(self) -> bytes:
        """Windowed mode: the whole ring as one RHLW blob (DESIGN.md §11).

        Row-to-name mapping travels separately (``window_rows()``); the
        wire format carries ring state only.
        """
        self._require_window("window_bytes")
        self.flush()
        self._ensure_wbank()
        return self._wbank.to_bytes()

    def window_rows(self) -> tuple:
        """Stream names in bank-row order (row i holds names[i])."""
        self._require_window("window_rows")
        return tuple(sorted(self._wrows, key=self._wrows.get))

    def _require_window(self, op: str) -> None:
        if self.window is None:
            raise ValueError(f"{op}() needs a windowed board (window=W)")

    def merge_from(self, other: "StreamSketch") -> None:
        if self.window is not None or other.window is not None:
            raise ValueError(
                "windowed boards do not merge: epochs on different boards "
                "are not aligned; ship RHLW blobs (window_bytes) instead"
            )
        if other.cfg != self.cfg:
            raise ValueError(
                f"cannot merge boards with different configs: "
                f"{self.cfg} vs {other.cfg}"
            )
        if self.track_topk != other.track_topk:
            raise ValueError(
                f"cannot merge boards with different track_topk configs: "
                f"{self.track_topk} vs {other.track_topk}"
            )
        self.flush()
        other.flush()
        for name, sk in other.sketches.items():
            self.sketches[name] = self.stream(name).merge(sk)
        if self.track_topk is not None and other._cmbank is not None:
            # align the other board's rows to this board's name -> row map,
            # then fold with ONE mergeable count-min merge (Topkapi rule)
            for name in other._cm_rows:
                if name not in self._cm_rows:
                    self._cm_rows[name] = len(self._cm_rows)
            rows = len(self._cm_rows)
            if self._cmbank is None:
                self._cmbank = CountMinBank.empty(rows, self.track_topk)
            elif rows > len(self._cmbank):
                self._cmbank = self._cmbank.with_rows(rows)
            dst = np.array(
                [self._cm_rows[n] for n in other._cm_rows], dtype=np.int64
            )
            src = np.array(list(other._cm_rows.values()), dtype=np.int64)
            aligned = CountMinBank.empty(rows, self.track_topk)

            def place(theirs):
                theirs = np.asarray(theirs)
                out = np.zeros((rows,) + theirs.shape[1:], theirs.dtype)
                out[dst] = theirs[src]
                return jnp.asarray(out)

            aligned = dataclasses.replace(
                aligned,
                counters=place(other._cmbank.counters),
                labels=place(other._cmbank.labels),
                label_counts=place(other._cmbank.label_counts),
                n_items=place(other._cmbank.n_items),
            )
            self._cmbank = self._cmbank.merge(aligned)
            self._cmfold_cache = None

    def estimate(self, name: str, estimator: Optional[str] = None) -> float:
        """Exact host-side estimate for one stream.

        On a windowed board this is the stream's SLIDING-WINDOW distinct
        count (last W epochs), not an all-time figure.
        """
        return self.stream(name).estimate(self._estimator(estimator))

    def serialize(self) -> Dict[str, bytes]:
        """Dense per-stream blobs (HyperLogLog.to_bytes) for shipping."""
        if self.window is not None:
            raise ValueError(
                "windowed boards serialize the whole ring: use window_bytes()"
            )
        self.flush()
        return {name: sk.to_bytes() for name, sk in self.sketches.items()}

    @classmethod
    def deserialize(
        cls,
        blobs: Dict[str, bytes],
        cfg: Optional[HLLConfig] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> "StreamSketch":
        """Rebuild a board from serialize() output.

        ``cfg`` is only required for a board serialized before its first
        observe() (no streams to recover the config from); when given, it
        must match the config recovered from the blobs — a mismatch raises
        instead of silently adopting the blob config.
        """
        sketches = {n: HyperLogLog.from_bytes(b) for n, b in blobs.items()}
        if sketches:
            recovered = next(iter(sketches.values())).cfg
            for name, sk in sketches.items():
                if sk.cfg != recovered:
                    raise ValueError(
                        f"blob {name!r} config {sk.cfg} disagrees with the "
                        f"other streams on this board"
                    )
            if cfg is not None and cfg != recovered:
                raise ValueError(
                    f"cfg mismatch: blobs were serialized with {recovered}, "
                    f"deserialize was asked for {cfg}"
                )
            cfg = recovered
        elif cfg is None:
            raise ValueError("empty board: pass cfg= to deserialize it")
        return cls(cfg=cfg, plan=plan, sketches=sketches)

    def _board_registers(self) -> tuple:
        """(names, stacked (B, m) uint8 registers) of the live board.

        Windowed boards read the memoized ring fold, so this costs at
        most one fold regardless of how often density() is asked.
        """
        if self.window is not None:
            names = self.window_rows()
            if not names:
                return (), np.zeros((0, self.cfg.m), np.uint8)
            self._ensure_wbank()
            folded = self._window_fold()
            regs = np.asarray(folded.registers)[
                [self._wrows[n] for n in names]
            ]
            return names, regs
        names = tuple(self.sketches)
        if not names:
            return (), np.zeros((0, self.cfg.m), np.uint8)
        return names, np.stack(
            [np.asarray(self.sketches[n].registers) for n in names]
        )

    def density(self) -> Dict[str, object]:
        """Per-board register-density stats (DESIGN.md §12).

        Reports how full each stream's registers are, how many streams
        are sparse-eligible (occupancy at or under the board plan's
        ``sparse_threshold``, defaulting to m // 4 like the carrier), and
        what the board would cost under the hybrid sparse layout vs the
        dense carriers it holds — the signal for moving a fleet to
        ``HybridBank`` storage.
        """
        self.flush()
        names, regs = self._board_registers()
        m = self.cfg.m
        occ = (regs > 0).sum(axis=1)
        thr = self.plan.sparse_threshold if self.plan is not None else None
        if thr is None:
            thr = max(1, m // 4)
        # sparse rows cost ~4 bytes/pair + fixed per-row bookkeeping (§12)
        hybrid = int(np.where(occ > thr, m, 4 * occ + 16).sum())
        return {
            "streams": len(names),
            "occupancy": {n: float(occ[i] / m) for i, n in enumerate(names)},
            "occupancy_mean": float(occ.mean() / m) if len(names) else 0.0,
            "sparse_eligible": int((occ <= thr).sum()),
            "dense_nbytes": int(len(names) * m),
            "hybrid_nbytes_estimate": hybrid,
        }

    def report(
        self,
        exact: bool = False,
        estimator: Optional[str] = None,
        density: bool = False,
        topk: Optional[int] = None,
    ) -> Dict[str, dict]:
        """Per-stream estimates; batched device finalization by default.

        Windowed boards report ROLLING distinct counts over the sliding
        W-epoch window (one fused ring fold + one batched estimate_many);
        ``items_seen``/``duplication`` likewise cover only the live
        window.  Same row schema as flat boards.  ``density=True`` adds a
        ``register_occupancy`` column per stream (board-level stats live
        in :meth:`density`).  ``topk=k`` adds a ``topk`` column — the
        stream's k heaviest items as [(item, est_count), ...] from ONE
        batched recovery over the whole board (heavy-hitter boards only).
        """
        if topk is not None:
            self._require_topk("report(topk=k)")
        self.flush()
        estimator = self._estimator(estimator)
        if self.window is not None:
            out = self._report_window(exact, estimator)
        else:
            out = self._report_flat(exact, estimator)
        if density:
            occ = self.density()["occupancy"]
            for name, row in out.items():
                row["register_occupancy"] = occ[name]
        if topk is not None:
            bank = self._cm_read_bank()
            rowmap = self._wrows if self.window is not None else self._cm_rows
            vals, cnts = (
                bank.topk(topk)
                if bank is not None
                else (np.zeros((0, topk)), np.zeros((0, topk)))
            )
            for name, row in out.items():
                r = rowmap.get(name)
                if r is None or bank is None or r >= len(bank):
                    row["topk"] = []
                    continue
                row["topk"] = [
                    (int(np.uint32(v)), int(c))
                    for v, c in zip(vals[r], cnts[r])
                    if c > 0
                ]
        return out

    def _report_flat(self, exact: bool, estimator: str) -> Dict[str, dict]:
        names = list(self.sketches)
        if exact or not names:
            estimates = [
                self.sketches[n].estimate(estimator) for n in names
            ]
        else:
            bank = jnp.stack([self.sketches[n].registers for n in names])
            estimates = [
                float(e)
                for e in np.asarray(estimate_many(bank, self.cfg, estimator))
            ]
        out = {}
        for name, est in zip(names, estimates):
            sk = self.sketches[name]
            out[name] = {
                "estimate": est,
                "items_seen": sk.count,
                "duplication": (sk.count / est) if est > 0 else float("nan"),
                "stderr_expected": sk.standard_error,
            }
        return out

    def _new_wbank(self, rows: int):
        """The board's window carrier: the dense ring, or the
        exponential histogram when ``window_levels`` is set."""
        if self.window_levels is not None:
            return MultiResWindowedBank.empty(
                self.window, rows, self.cfg, levels=self.window_levels
            )
        return WindowedBank.empty(self.window, rows, self.cfg)

    def _ensure_wbank(self) -> None:
        """Materialize/grow the ring for every registered stream row."""
        rows = max(1, len(self._wrows))
        if self._wbank is None:
            self._wbank = self._new_wbank(rows)
            self._wfold_cache = None
        elif rows > self._wbank.rows:
            self._wbank = self._wbank.with_rows(rows)
            self._wfold_cache = None

    def _report_window(self, exact: bool, estimator: str) -> Dict[str, dict]:
        names = self.window_rows()
        if not names:
            return {}
        self._ensure_wbank()
        # ONE (cached) ring fold; finalization is one batched estimate_many
        # or, for exact=True, the host finalizer per row — same split as
        # the flat board path above
        folded = self._window_fold()
        if exact:
            estimates = [
                folded.estimate(self._wrows[n], estimator) for n in names
            ]
        else:
            ests = np.asarray(folded.estimate_many(estimator))
            estimates = [float(ests[self._wrows[n]]) for n in names]
        counts = folded.counts
        stderr = hll_standard_error(self.cfg)
        out = {}
        for name, est in zip(names, estimates):
            seen = int(counts[self._wrows[name]])
            out[name] = {
                "estimate": est,
                "items_seen": seen,
                "duplication": (seen / est) if est > 0 else float("nan"),
                "stderr_expected": stderr,
            }
        return out
