"""StreamSketch: the paper's sketch as a first-class telemetry feature.

Wraps HLL registers with named streams so a training/serving job can track
several cardinalities at once (distinct tokens, distinct users/request ids,
distinct (token, expert) routing pairs for MoE collapse detection) — each
one is 48 KiB of state and one all-reduce-max per merge, regardless of
stream size.  The exact host-side estimate (core.hll.estimate) finalizes a
report, mirroring the paper's constant-time computation phase.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core import hll
from repro.core.hll import HLLConfig


@dataclasses.dataclass
class StreamSketch:
    cfg: HLLConfig
    registers: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def stream(self, name: str) -> jnp.ndarray:
        if name not in self.registers:
            self.registers[name] = hll.init_registers(self.cfg)
            self.counts[name] = 0
        return self.registers[name]

    def observe(self, name: str, items: jnp.ndarray) -> None:
        regs = self.stream(name)
        self.registers[name] = hll.update(regs, items, self.cfg)
        self.counts[name] += int(items.size)

    def merge_from(self, other: "StreamSketch") -> None:
        for name, regs in other.registers.items():
            mine = self.stream(name)
            self.registers[name] = jnp.maximum(mine, regs)
            self.counts[name] += other.counts.get(name, 0)

    def estimate(self, name: str) -> float:
        return hll.estimate(self.stream(name), self.cfg)

    def report(self) -> Dict[str, dict]:
        out = {}
        for name in self.registers:
            est = self.estimate(name)
            seen = self.counts[name]
            out[name] = {
                "estimate": est,
                "items_seen": seen,
                "duplication": (seen / est) if est > 0 else float("nan"),
                "stderr_expected": hll.standard_error(self.cfg),
            }
        return out
