"""First-class streaming-statistics layer (the paper's sketch on the datapath)."""
