"""Deprecated shim — the HLL implementation moved to ``repro.sketch.hll``.

Kept importable so pre-redesign callers keep working; new code should use
``repro.sketch`` (see DESIGN.md §1).
"""

import warnings

warnings.warn(
    "repro.core.hll is deprecated; import from repro.sketch instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.sketch.hll import *  # noqa: F401,F403,E402
from repro.sketch.hll import (  # noqa: F401,E402
    HLLConfig,
    REGISTER_DTYPE,
    alpha,
    cardinality,
    estimate,
    estimate_device,
    hash_index_rank,
    init_registers,
    merge,
    standard_error,
    update,
)
