"""Deprecated shim — set algebra moved to ``repro.sketch.setops``.

The functions now also accept ``HyperLogLog`` carriers directly; prefer the
methods on ``repro.sketch.HyperLogLog`` (union_estimate / jaccard / ...).
"""

import warnings

warnings.warn(
    "repro.core.setops is deprecated; use repro.sketch (HyperLogLog set "
    "algebra) instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.sketch.setops import (  # noqa: F401,E402
    difference_estimate,
    intersection_estimate,
    jaccard_estimate,
    union_estimate,
)
