"""Deprecated shim — moved to ``repro.sketch.murmur3``."""

import warnings

warnings.warn(
    "repro.core.murmur3 is deprecated; import repro.sketch.murmur3 instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.sketch.murmur3 import *  # noqa: F401,F403,E402
