"""Deprecated shim package — the HLL library moved to ``repro.sketch``.

Every submodule (hll, sketch, setops, murmur3, u64, exact) remains
importable and re-exports from its new home with a DeprecationWarning.
"""

from repro.core.hll import (  # noqa: F401
    HLLConfig,
    alpha,
    cardinality,
    estimate,
    estimate_device,
    hash_index_rank,
    init_registers,
    merge,
    standard_error,
    update,
)
from repro.core.sketch import (  # noqa: F401
    Sketch,
    datapath_tap,
    update_pipelined,
    update_sharded,
)
