"""Core HLL library: the paper's contribution as composable JAX modules."""

from repro.core.hll import (  # noqa: F401
    HLLConfig,
    alpha,
    cardinality,
    estimate,
    estimate_device,
    hash_index_rank,
    init_registers,
    merge,
    standard_error,
    update,
)
from repro.core.sketch import (  # noqa: F401
    Sketch,
    datapath_tap,
    update_pipelined,
    update_sharded,
)
