"""Deprecated shim — moved to ``repro.sketch.exact``."""

import warnings

warnings.warn(
    "repro.core.exact is deprecated; import repro.sketch.exact instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.sketch.exact import *  # noqa: F401,F403,E402
