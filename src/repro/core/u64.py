"""Deprecated shim — moved to ``repro.sketch.u64``."""

import warnings

warnings.warn(
    "repro.core.u64 is deprecated; import repro.sketch.u64 instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.sketch.u64 import *  # noqa: F401,F403,E402
from repro.sketch.u64 import MASK16, MASK32, U64  # noqa: F401,E402
