"""Deprecated shim — the sketch engine moved to ``repro.sketch``.

``update_pipelined`` / ``update_sharded`` / ``datapath_tap`` now route
through the ExecutionPlan dispatch in ``repro.sketch.dispatch``; the old
``Sketch`` carrier is superseded by ``repro.sketch.HyperLogLog`` (which adds
the overflow-safe counter, set algebra and serialization).  One behavioral
unification: streams that do not divide ``pipelines`` are padded uniformly
instead of raising (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp

warnings.warn(
    "repro.core.sketch is deprecated; use repro.sketch (HyperLogLog / "
    "ExecutionPlan) instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.sketch import hll  # noqa: E402
from repro.sketch.backends import update_pipelined  # noqa: F401,E402
from repro.sketch.dispatch import datapath_tap, update_registers  # noqa: F401,E402
from repro.sketch.hll import HLLConfig  # noqa: F401,E402
from repro.sketch.plan import ExecutionPlan  # noqa: E402


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Sketch:
    """Legacy carrier (int32 counter) — use repro.sketch.HyperLogLog."""

    registers: jnp.ndarray  # (m,) uint8
    n_items: jnp.ndarray  # () int32 counter; overflows at 2.1e9 items

    @staticmethod
    def init(cfg: HLLConfig) -> "Sketch":
        return Sketch(hll.init_registers(cfg), jnp.zeros((), jnp.int32))


def update(sketch: Sketch, items: jnp.ndarray, cfg: HLLConfig) -> Sketch:
    regs = hll.update(sketch.registers, items, cfg)
    return Sketch(regs, sketch.n_items + items.size)


def merge(a: Sketch, b: Sketch) -> Sketch:
    return Sketch(jnp.maximum(a.registers, b.registers), a.n_items + b.n_items)


def update_sharded(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    pipelines: int = 1,
) -> jnp.ndarray:
    """Sketch a device-sharded stream; merge partials with all-reduce-max."""
    plan = ExecutionPlan(
        backend="jnp", placement="mesh", mesh=mesh,
        data_axes=tuple(data_axes), pipelines=pipelines,
    )
    return update_registers(registers, items, cfg, plan)
