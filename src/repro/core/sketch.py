"""Multi-pipeline / multi-device sketch engine — the paper's Fig. 3 on a pod.

The paper scales throughput by slicing the input stream over k identical
aggregation pipelines and folding the partial sketches bucket-by-bucket with
max.  On TPU the same structure exists at three levels:

  lane level    k sub-sketches per device updated from disjoint stream slices
                (``update_pipelined``) — the literal analogue of Fig. 3;
  device level  each device of the ('pod','data') axes sketches its own data
                shard inside the jitted step (``update_sharded`` under
                shard_map) and partials merge with an all-reduce-MAX;
  pod level     the same all-reduce-max spans the 'pod' axis — a sketch is
                mergeable across pods for free.

Because max is associative, commutative and idempotent, replayed batches
(fault recovery), duplicated shards (elastic re-scaling) and stragglers can
never corrupt the sketch — see DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hll
from repro.core.hll import HLLConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Sketch:
    """Carrier pytree: registers + item counter (counter is exact, cheap)."""

    registers: jnp.ndarray  # (m,) uint8
    n_items: jnp.ndarray  # () int64-ish counter (int32 pair avoided: f64-free)

    @staticmethod
    def init(cfg: HLLConfig) -> "Sketch":
        return Sketch(hll.init_registers(cfg), jnp.zeros((), jnp.int32))


def update(sketch: Sketch, items: jnp.ndarray, cfg: HLLConfig) -> Sketch:
    regs = hll.update(sketch.registers, items, cfg)
    return Sketch(regs, sketch.n_items + items.size)


def merge(a: Sketch, b: Sketch) -> Sketch:
    return Sketch(jnp.maximum(a.registers, b.registers), a.n_items + b.n_items)


@partial(jax.jit, static_argnames=("cfg", "pipelines"))
def update_pipelined(
    registers: jnp.ndarray, items: jnp.ndarray, cfg: HLLConfig, pipelines: int = 8
) -> jnp.ndarray:
    """Fig. 3 on one device: slice the stream over k pipelines, fold with max.

    Items are sliced blockwise ("processed where they arrive, no active
    reassignment"); each slice aggregates into its own register array and the
    k partials fold bucket-by-bucket.  Functionally identical to a single
    pipeline — property-tested in tests/test_hll.py.
    """
    flat = items.reshape(-1)
    n = flat.shape[0]
    if n % pipelines != 0:
        raise ValueError(f"items ({n}) must divide pipelines ({pipelines})")
    slices = flat.reshape(pipelines, n // pipelines)
    idx, rank = hll.hash_index_rank(slices, cfg)
    # per-pipeline partial sketches: offset bucket ids per pipeline then one
    # segment_max over k*m segments (single fused scatter).
    offsets = (jnp.arange(pipelines, dtype=jnp.int32) * cfg.m)[:, None]
    seg = (idx + offsets).reshape(-1)
    partial_regs = jax.ops.segment_max(
        rank.reshape(-1), seg, num_segments=pipelines * cfg.m
    )
    partial_regs = jnp.maximum(partial_regs, 0).astype(hll.REGISTER_DTYPE)
    folded = jnp.max(partial_regs.reshape(pipelines, cfg.m), axis=0)
    return jnp.maximum(registers, folded)


# ----------------------------------------------------------------------------
# Device-parallel sketching (shard_map)
# ----------------------------------------------------------------------------


def update_sharded(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    pipelines: int = 1,
) -> jnp.ndarray:
    """Sketch a device-sharded stream; merge partials with all-reduce-max.

    ``items`` is sharded along its leading dim over ``data_axes``; every
    device aggregates its local shard (optionally with k local pipelines)
    and a single lax.pmax over the data axes folds the partial sketches —
    the paper's Merge-buckets module expressed as one collective.
    Registers come back replicated.
    """
    axes = tuple(data_axes)

    def local(regs: jnp.ndarray, local_items: jnp.ndarray) -> jnp.ndarray:
        if pipelines > 1:
            out = update_pipelined(regs, local_items, cfg, pipelines)
        else:
            out = hll.update(regs, local_items, cfg)
        return jax.lax.pmax(out, axes)

    in_specs = (P(), P(axes))
    return jax.shard_map(
        local, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )(registers, items)


def datapath_tap(
    registers: jnp.ndarray, token_ids: jnp.ndarray, cfg: HLLConfig
) -> jnp.ndarray:
    """Sketch-on-the-datapath inside a jitted step (NIC analogue, DESIGN §2).

    Called from train_step/serve_step on tokens already resident on device;
    under pjit the segment_max partials and the replicated-output max-reduce
    are inserted by SPMD partitioning automatically.  Costs O(tokens) VPU
    ops + one (m,)-sized all-reduce — negligible next to model FLOPs.
    """
    return hll.update(registers, token_ids, cfg)
