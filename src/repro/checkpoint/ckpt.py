"""Checkpoint/restart with async writes and elastic resharding on restore.

Format: one directory per step — ``step_<n>/leaf_<i>.npy`` + manifest.json
(leaf count, shapes, dtypes, keypaths).  Restore is template-based: the
caller supplies the live state pytree (from init) and gets back arrays
placed onto the requested shardings — which may belong to a *different*
mesh than the one that wrote the checkpoint (elastic rescale: the host
arrays are resharded by device_put; the HLL sketch registers merge by max
if partials from a previous topology are replayed, so telemetry survives
rescaling exactly — DESIGN.md §6).

Fault-tolerance contract used by train/loop.py:
  * save every N steps (async: the host copy is snapshotted synchronously,
    the disk write happens on a worker thread; the step loop never blocks
    on I/O),
  * on (re)start, ``latest_step`` + ``restore`` resume params, optimizer,
    data cursor and sketch — a preempted pod loses at most N steps,
  * writes go to a temp dir renamed into place, so a crash mid-write can
    never corrupt the latest checkpoint.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save(state, directory: str, step: int, async_write: bool = False):
    """Checkpoint a pytree. Returns a join() handle when async."""
    leaves_with_paths, _ = jax.tree_util.tree_flatten_with_path(state)
    host_leaves = [
        (_keystr(p), np.asarray(jax.device_get(l))) for p, l in leaves_with_paths
    ]

    def write():
        tmp = os.path.join(directory, f".tmp_step_{step}")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (keypath, arr) in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            manifest["leaves"].append(
                {"i": i, "key": keypath, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_", 1)[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and d.split("_", 1)[1].isdigit()
    ]
    return max(steps) if steps else None


def restore(
    template, directory: str, step: int, shardings=None
) -> Any:
    """Load ``step`` into the structure of ``template``.

    ``shardings``: optional matching pytree of jax.sharding.Sharding — the
    elastic-resume path places each host array directly onto the (possibly
    different) target mesh.
    """
    final = os.path.join(directory, f"step_{step}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    if len(manifest["leaves"]) != len(leaves_with_paths):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, template has "
            f"{len(leaves_with_paths)} — incompatible structures"
        )
    by_key = {m["key"]: m for m in manifest["leaves"]}

    loaded = []
    for path, tmpl_leaf in leaves_with_paths:
        key = _keystr(path)
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(final, f"leaf_{meta['i']}.npy"))
        if tuple(arr.shape) != tuple(np.shape(tmpl_leaf)):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != template "
                f"{np.shape(tmpl_leaf)}"
            )
        loaded.append(arr)

    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
        loaded = [
            jax.device_put(a, s) for a, s in zip(loaded, shard_leaves)
        ]
    else:
        loaded = [jax.device_put(a) for a in loaded]
    return jax.tree_util.tree_unflatten(treedef, loaded)
