"""Sharded checkpoint/restart with async writes and elastic resharding."""
