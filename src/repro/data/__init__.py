"""Deterministic synthetic data pipeline with HLL datapath tap."""
