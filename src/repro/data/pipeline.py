"""Deterministic synthetic token pipeline with an HLL tap on the datapath.

Counter-based generation: batch ``step`` is a pure function of
(seed, step, shape) via Murmur3 over flat counters — stateless, restartable
from a checkpointed step index, identical across hosts (each host slices its
shard), and cheap enough to run on-device.  This is the training-pod
equivalent of the paper's NIC datapath: the stream is hashed *as it is
produced*, so cardinality telemetry (vocabulary coverage, duplicate rates)
is free relative to the step.

Distributions:
  * ``zipf``    — log-uniform over the vocab (natural-language-like head/tail)
  * ``uniform`` — uniform over the vocab
  * ``unique``  — globally unique ids (sketch stress / Fig. 1 benchmarks)
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.sketch import murmur3


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    distribution: str = "zipf"  # zipf | uniform | unique


@partial(jax.jit, static_argnames=("cfg",))
def batch_at_step(cfg: DataConfig, step: jnp.ndarray) -> dict:
    """Materialize the global batch for an arbitrary step index."""
    n = cfg.global_batch * cfg.seq_len
    base = step.astype(jnp.uint32) * jnp.uint32(n)
    counters = base + jnp.arange(n + 1, dtype=jnp.uint32)
    h = murmur3.murmur3_32(counters, seed=cfg.seed)

    if cfg.distribution == "unique":
        tokens_full = counters % jnp.uint32(cfg.vocab_size)
    elif cfg.distribution == "uniform":
        tokens_full = h % jnp.uint32(cfg.vocab_size)
    else:  # zipf-ish: log-uniform inverse CDF
        u = h.astype(jnp.float32) / jnp.float32(2**32)
        logv = u * jnp.log(jnp.float32(cfg.vocab_size))
        tokens_full = jnp.minimum(
            jnp.exp(logv).astype(jnp.uint32), jnp.uint32(cfg.vocab_size - 1)
        )

    tokens_full = tokens_full.astype(jnp.int32)
    tokens = tokens_full[:n].reshape(cfg.global_batch, cfg.seq_len)
    # next-token targets; the +1 counter continues the stream
    targets = tokens_full[1 : n + 1].reshape(cfg.global_batch, cfg.seq_len)
    return {"tokens": tokens, "targets": targets}


def host_shard(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Slice the per-host batch shard (disjoint across hosts by batch dim)."""
    def slc(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per : (host_id + 1) * per]

    return jax.tree.map(slc, batch)


def stream_chunks(
    cfg: DataConfig, n_chunks: int, start_step: int = 0
):
    """Iterator of (step, batch) — the streaming feed for sketch benchmarks."""
    for s in range(start_step, start_step + n_chunks):
        yield s, batch_at_step(cfg, jnp.asarray(s, jnp.int32))
