"""Process-global metrics: Counter / Gauge / Histogram with a no-op default.

The runtime counterpart of the paper's resource tables (DESIGN.md §15):
dispatch counts and wall time per registry axis/backend, sparse-compaction
state-machine counters, window-cache hit rates, batch-size and latency
histograms — the numbers the mesh-sharded serve path (ROADMAP item 3) will
report through.

Everything here is host-side Python state (ints, floats, bin lists) behind
one lock; no jax array is ever stored.  Two invariants keep the module
safe to leave compiled into every hot seam:

* **No-op default.**  Metrics are disabled until :func:`enable` is called;
  every record site checks one module flag first, so the disabled path is
  a single attribute load + function call (gated ≤3% median on
  ``SketchBank.update_many`` by ``benchmarks/bench_obs.py``).
* **Trace hygiene.**  No record site runs under an active jax trace:
  :func:`recording` reuses the PR-8 gate (``jax.core.trace_state_clean()``,
  the same check ``WindowedBank._concrete`` makes before touching hidden
  host state).  Tracing a jitted caller therefore neither leaks tracers
  into the registry nor double-books work the compiled executable replays
  without running Python again.
"""

from __future__ import annotations

import bisect
import functools
import json
import math
import threading
import time
from typing import Callable, Dict, Optional

import jax

__all__ = [
    "enable",
    "disable",
    "enabled",
    "recording",
    "inc",
    "gauge",
    "observe",
    "counter_value",
    "timed",
    "seam",
    "wrap_backend",
    "snapshot",
    "to_json",
    "reset",
]

_LOCK = threading.Lock()
_ENABLED = False

_COUNTERS: Dict[str, float] = {}
_GAUGES: Dict[str, float] = {}
_HISTS: Dict[str, "_Hist"] = {}

# Log-scaled bins shared by every histogram: 4 bins/decade from 1e-7 to
# 1e9, wide enough for sub-µs seam timings and 10^9-item batch sizes on
# the same scale.  ~65 edges -> one small int list per histogram.
_EDGES = tuple(10.0 ** (e / 4.0) for e in range(-28, 37))

# Hooks installed by repro.obs.tracing at import (avoids an import cycle):
# seam timers also emit Chrome-trace events while a capture is active.
_trace_active: Callable[[], bool] = lambda: False
_trace_emit: Callable[..., None] = lambda name, t0, dur, args=None: None


def _install_trace_hook(active: Callable[[], bool], emit: Callable) -> None:
    global _trace_active, _trace_emit
    _trace_active, _trace_emit = active, emit


class _Hist:
    """Log-binned histogram: count/sum/min/max + percentile estimates."""

    __slots__ = ("count", "total", "vmin", "vmax", "bins")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.bins = [0] * (len(_EDGES) + 1)

    def add(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value
        self.bins[bisect.bisect_right(_EDGES, value)] += 1

    def percentile(self, q: float) -> float:
        """Bin-interpolated q-quantile (geometric midpoint within a bin)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        for i, n in enumerate(self.bins):
            acc += n
            if acc >= target and n:
                lo = _EDGES[i - 1] if i > 0 else self.vmin
                hi = _EDGES[i] if i < len(_EDGES) else self.vmax
                lo = max(min(lo, self.vmax), self.vmin)
                hi = min(max(hi, self.vmin), self.vmax)
                if lo > 0.0 and hi > 0.0:
                    return math.sqrt(lo * hi)
                return 0.5 * (lo + hi)
        return self.vmax

    def summary(self) -> dict:
        if self.count == 0:
            return {
                "count": 0,
                "sum": 0.0,
                "mean": 0.0,
                "min": 0.0,
                "max": 0.0,
                "p50": 0.0,
                "p90": 0.0,
                "p99": 0.0,
            }
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.total / self.count,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(0.50),
            "p90": self.percentile(0.90),
            "p99": self.percentile(0.99),
        }


# ---------------------------------------------------------------------------
# enable / gate


def enable() -> None:
    """Turn recording on (state is kept; call :func:`reset` to clear)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def recording() -> bool:
    """True when a record site should record.

    Order matters: the module flag short-circuits first so the disabled
    path never pays the jax call; under an active trace the site is
    skipped entirely (trace hygiene, DESIGN.md §15).
    """
    return _ENABLED and jax.core.trace_state_clean()


def reset() -> None:
    """Clear every counter/gauge/histogram (enabled flag untouched)."""
    with _LOCK:
        _COUNTERS.clear()
        _GAUGES.clear()
        _HISTS.clear()


# ---------------------------------------------------------------------------
# record sites


def inc(name: str, value: float = 1) -> None:
    """Add ``value`` to counter ``name`` (no-op unless :func:`recording`)."""
    if not recording():
        return
    with _LOCK:
        _COUNTERS[name] = _COUNTERS.get(name, 0) + value


def gauge(name: str, value: float) -> None:
    """Set gauge ``name`` to ``value`` (last write wins)."""
    if not recording():
        return
    with _LOCK:
        _GAUGES[name] = float(value)


def observe(name: str, value: float) -> None:
    """Record ``value`` into histogram ``name``."""
    if not recording():
        return
    with _LOCK:
        hist = _HISTS.get(name)
        if hist is None:
            hist = _HISTS[name] = _Hist()
        hist.add(value)


def counter_value(name: str) -> float:
    """Current value of counter ``name`` (0 if never incremented)."""
    with _LOCK:
        return _COUNTERS.get(name, 0)


# ---------------------------------------------------------------------------
# timers


class _NullTimer:
    """Shared do-nothing context manager: the disabled fast path."""

    __slots__ = ()
    elapsed_s = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullTimer()


class _Timer:
    __slots__ = ("_counter", "_hist", "_trace", "_t0", "elapsed_s")

    def __init__(self, counter, hist, trace):
        self._counter = counter
        self._hist = hist
        self._trace = trace
        self.elapsed_s = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        self.elapsed_s = dur
        if self._counter is not None or self._hist is not None:
            with _LOCK:
                if self._counter is not None:
                    _COUNTERS[self._counter] = _COUNTERS.get(self._counter, 0) + 1
                if self._hist is not None:
                    hist = _HISTS.get(self._hist)
                    if hist is None:
                        hist = _HISTS[self._hist] = _Hist()
                    hist.add(dur)
        if self._trace is not None:
            _trace_emit(self._trace, self._t0, dur)
        return False


def timed(name: str) -> "_Timer":
    """Context manager feeding histogram ``name`` with wall seconds."""
    if not recording():
        return _NULL
    return _Timer(None, name, None)


def seam(axis: str, backend: str) -> "_Timer":
    """Timer for one dispatch seam: ``dispatch.{axis}.{backend}``.

    Records a ``.calls`` counter and a ``.seconds`` histogram when metrics
    are enabled, and a Chrome-trace event while a trace capture is active
    — both gated off under an active jax trace.  Seconds are host dispatch
    wall time (includes compilation on first call; excludes device
    completion unless the caller blocks).
    """
    live_m = _ENABLED
    live_t = _trace_active()
    if not (live_m or live_t):
        return _NULL
    if not jax.core.trace_state_clean():
        return _NULL
    key = f"dispatch.{axis}.{backend}"
    return _Timer(
        key + ".calls" if live_m else None,
        key + ".seconds" if live_m else None,
        f"{axis}[{backend}]" if live_t else None,
    )


def wrap_backend(axis: str, name: str, fn: Callable) -> Callable:
    """Wrap a registry backend so every real dispatch is counted + timed.

    Applied once at registration (``repro.sketch.plan.register_*``), so
    the per-dispatch cost when disabled is one extra frame + flag check.
    Empty-stream short-circuits never reach the backend, so they are
    never counted — the spy-backend contract (tests/test_obs.py).
    """

    @functools.wraps(fn)
    def dispatch(*args, **kwargs):
        if not (_ENABLED or _trace_active()):
            return fn(*args, **kwargs)
        with seam(axis, name):
            return fn(*args, **kwargs)

    dispatch.__sketch_backend__ = fn
    return dispatch


# ---------------------------------------------------------------------------
# export


def snapshot() -> dict:
    """Plain-dict snapshot of every metric (stable schema, json-ready)."""
    with _LOCK:
        return {
            "enabled": _ENABLED,
            "counters": dict(_COUNTERS),
            "gauges": dict(_GAUGES),
            "histograms": {k: h.summary() for k, h in _HISTS.items()},
        }


def to_json(indent: Optional[int] = 2) -> str:
    return json.dumps(snapshot(), indent=indent, sort_keys=True)
