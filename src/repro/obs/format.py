"""One formatting vocabulary for launch report lines (DESIGN.md §15).

serve.py's board/bank/heavy/window report lines used to print raw floats
with whatever precision each f-string happened to pick, and truncated
top-k listings with an unlabeled ``...`` row.  Every human-facing number
now routes through these helpers — the same ones the periodic
``[metrics]`` report line uses — so precision and labels stay consistent
across surfaces.  Pure string munging: no jax, no metrics state.
"""

from __future__ import annotations

import math

__all__ = [
    "fmt_count",
    "fmt_float",
    "fmt_pct",
    "fmt_seconds",
    "fmt_rate",
    "fmt_bytes",
    "per_second",
    "kv_line",
    "truncated_note",
    "metrics_report_line",
]


def fmt_count(x: float) -> str:
    """Integer quantities: thousands separators, no decimals.

    Non-finite values render as ``inf``/``-inf``/``nan`` instead of
    raising from ``round()`` — a zero-elapsed throughput on a fast
    machine must degrade a report line, never crash the launcher.
    """
    x = float(x)
    if not math.isfinite(x):
        return str(x)
    return f"{round(x):,}"


def per_second(count: float, elapsed_s: float) -> float:
    """A rate that tolerates zero/near-zero timer spans.

    ``span``/``Stopwatch`` measure with ``perf_counter``, whose
    resolution can quantize a tiny timed region to exactly 0.0 — the
    naive ``count / elapsed`` then dies with ZeroDivisionError.  Zero
    work in zero time is 0.0; finite work in zero time is ``inf``,
    which every ``fmt_*`` helper renders safely.
    """
    count = float(count)
    elapsed_s = float(elapsed_s)
    if elapsed_s <= 0.0:
        return 0.0 if count == 0.0 else math.inf
    return count / elapsed_s


def fmt_float(x: float, digits: int = 1) -> str:
    return f"{float(x):.{digits}f}"


def fmt_pct(x: float, digits: int = 1) -> str:
    """A 0..1 ratio as a percentage."""
    return f"{float(x):.{digits}%}"


def fmt_seconds(s: float) -> str:
    """Auto-scaled wall time: 12µs / 3.4ms / 1.2s."""
    s = float(s)
    if s < 1e-3:
        return f"{s * 1e6:.0f}µs"
    if s < 1.0:
        return f"{s * 1e3:.1f}ms"
    return f"{s:.2f}s"


def fmt_rate(x: float, unit: str) -> str:
    """Throughput: '12,345 tok/s'."""
    return f"{fmt_count(x)} {unit}/s"


def fmt_bytes(n: float) -> str:
    n = float(n)
    for scale, suffix in ((1 << 30, "GiB"), (1 << 20, "MiB"), (1 << 10, "KiB")):
        if n >= scale:
            return f"{n / scale:.1f}{suffix}"
    return f"{fmt_count(n)}B"


def kv_line(label: str, pairs, indent: str = "  ") -> str:
    """'  label: k=v k=v' — the shared report-line shape."""
    body = " ".join(f"{k}={v}" for k, v in pairs)
    return f"{indent}{label}: {body}"


def truncated_note(shown: int, total: int, noun: str, indent: str = "    "):
    """Labeled truncation row: '    ... +4 more requests (of 8 total)'."""
    return f"{indent}... +{total - shown} more {noun} (of {total} total)"


def metrics_report_line(snap: dict) -> str:
    """One-line digest of a metrics snapshot for periodic serve reports."""
    counters = snap.get("counters", {})
    hists = snap.get("histograms", {})
    parts = []
    req = hists.get("serve.request.seconds")
    if req and req["count"]:
        p50, p99 = fmt_seconds(req["p50"]), fmt_seconds(req["p99"])
        parts.append(f"req p50={p50} p99={p99}")
    dispatches = sum(
        v
        for k, v in counters.items()
        if k.startswith("dispatch.") and k.endswith(".calls")
    )
    parts.append(f"dispatches={fmt_count(dispatches)}")
    compactions = counters.get("sparse.flush.pressure", 0) + counters.get(
        "sparse.flush.read", 0
    )
    parts.append(f"compactions={fmt_count(compactions)}")
    hits = counters.get("window.fold_cache.hits", 0)
    misses = counters.get("window.fold_cache.misses", 0)
    if hits + misses:
        parts.append(f"window-cache hit={fmt_pct(hits / (hits + misses))}")
    return "[metrics] " + " ".join(parts)
