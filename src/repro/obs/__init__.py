"""repro.obs — runtime observability: metrics registry + span tracing.

DESIGN.md §15.  ``metrics`` holds the process-global Counter/Gauge/
Histogram registry (a no-op until ``metrics.enable()``); ``tracing``
provides the ``span()`` context manager and Chrome-trace capture
(``start_trace()`` → ``write_trace(path)`` → load in Perfetto);
``format`` is the shared report-line vocabulary.

Importing this package wires the tracing hook into the metrics seam
timers, so dispatch seams appear in trace captures automatically.
"""

from repro.obs import format, metrics, tracing

__all__ = ["format", "metrics", "tracing"]
