"""Nested span tracing → Chrome-trace-event JSON, plus shared timer helpers.

``span("name")`` is the one timing idiom for launch/train/bench code
(replacing the hand-rolled ``perf_counter`` pairs): it always measures
``elapsed_s``; while a capture started by :func:`start_trace` is active it
also appends a Chrome ``"X"`` (complete) event, and ``metric=`` feeds the
duration into a metrics histogram when metrics are enabled.  Nesting needs
no bookkeeping — Perfetto reconstructs the stack from overlapping
``ts``/``dur`` ranges per thread.

:func:`chrome_trace` / :func:`write_trace` emit the ``{"traceEvents":
[...]}`` JSON that Perfetto (https://ui.perfetto.dev) and
``chrome://tracing`` load directly.  The event buffer is host-side only;
span bodies that run under an active jax trace record nothing (same
hygiene gate as the metrics registry, DESIGN.md §15).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

import jax

from repro.obs import metrics as _metrics

__all__ = [
    "span",
    "Stopwatch",
    "start_trace",
    "stop_trace",
    "active",
    "chrome_trace",
    "write_trace",
]

_LOCK = threading.Lock()
_EVENTS: list = []
_ACTIVE = False
_T0 = 0.0


def start_trace() -> None:
    """Begin a capture: clears the buffer and timestamps events from now."""
    global _ACTIVE, _T0
    with _LOCK:
        _EVENTS.clear()
        _T0 = time.perf_counter()
        _ACTIVE = True


def stop_trace() -> list:
    """End the capture; returns the buffered events (buffer is kept)."""
    global _ACTIVE
    with _LOCK:
        _ACTIVE = False
        return list(_EVENTS)


def active() -> bool:
    return _ACTIVE


def _emit(name: str, t0: float, dur_s: float, args: Optional[dict] = None):
    if not _ACTIVE or not jax.core.trace_state_clean():
        return
    event = {
        "name": name,
        "ph": "X",
        "ts": (t0 - _T0) * 1e6,
        "dur": dur_s * 1e6,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
    }
    if args:
        event["args"] = {k: str(v) for k, v in args.items()}
    with _LOCK:
        if _ACTIVE:
            _EVENTS.append(event)


# dispatch-seam timers (metrics.seam / wrap_backend) emit through us too,
# so a --trace capture shows backend dispatches under the outer spans
_metrics._install_trace_hook(active, _emit)


class span:
    """Context-manager timer; emits a Chrome event while a trace is active.

    ``with span("prefill") as t: ...`` then read ``t.elapsed_s``.  Pass
    ``metric="serve.request.seconds"`` to also feed a metrics histogram
    (no-op unless metrics are enabled); extra keyword arguments land in
    the event's ``args`` payload.
    """

    __slots__ = ("name", "metric", "args", "elapsed_s", "_t0")

    def __init__(self, name: str, *, metric: Optional[str] = None, **args):
        self.name = name
        self.metric = metric
        self.args = args or None
        self.elapsed_s = 0.0

    def __enter__(self) -> "span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.elapsed_s = time.perf_counter() - self._t0
        _emit(self.name, self._t0, self.elapsed_s, self.args)
        if self.metric is not None:
            _metrics.observe(self.metric, self.elapsed_s)
        return False


class Stopwatch:
    """Explicit ``start()``/``stop()`` timer for split begin/end seams.

    The watchdog-style idiom where begin and end live in different calls
    (so a context manager cannot span them).  ``stop()`` returns elapsed
    seconds and disarms; ``elapsed()`` peeks without disarming.
    """

    __slots__ = ("_t0",)

    def __init__(self):
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    @property
    def running(self) -> bool:
        return self._t0 is not None

    def elapsed(self) -> float:
        assert self._t0 is not None, "start() not called"
        return time.perf_counter() - self._t0

    def stop(self) -> float:
        dt = self.elapsed()
        self._t0 = None
        return dt


def chrome_trace() -> dict:
    """The capture as a Chrome-trace dict (Perfetto-loadable as JSON)."""
    with _LOCK:
        events = list(_EVENTS)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_trace(path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(), f)
    return path
