"""Config registry: --arch <id> resolution for every assigned architecture."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, MoEConfig  # noqa: F401
from repro.configs.shapes import (  # noqa: F401
    SHAPES,
    ShapeConfig,
    is_cell_supported,
    skip_reason,
)

_ARCH_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "rwkv6-3b": "rwkv6_3b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "smollm-360m": "smollm_360m",
    "qwen3-32b": "qwen3_32b",
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def all_archs() -> Dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}
