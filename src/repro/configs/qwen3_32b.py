"""qwen3-32b [hf:Qwen/Qwen3; hf]: qk_norm, GQA kv=8, explicit head_dim."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,  # qwen3 projects to n_heads * 128 != d_model
    qk_norm=True,
    rope_theta=1_000_000.0,
)
