"""qwen2-vl-72b [arXiv:2409.12191; hf]: M-RoPE, dynamic-resolution VLM.

Backbone only — the vision tower is a stub: input_specs() provides
precomputed patch embeddings merged into the token sequence, plus the
(temporal, h, w) position-id triple that M-RoPE consumes.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mrope=True,
    rope_theta=1_000_000.0,
    frontend_stub_len=256,  # precomputed image patch embeddings
)
