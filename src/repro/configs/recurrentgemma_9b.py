"""recurrentgemma-9b (Griffin) [arXiv:2402.19427]: RG-LRU + local attn 1:2."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,  # 12 x (rec, rec, attn) + 2 rec
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,  # MQA
    d_ff=12288,
    vocab_size=256000,
    tie_embeddings=True,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    rope_theta=10_000.0,
)
