"""mixtral-8x7b [arXiv:2401.04088; hf]: 8-expert top-2 MoE, GQA, SWA."""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,  # per-expert FFN hidden dim
    vocab_size=32000,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336, sharding="tp"),
    sliding_window=4096,
    rope_theta=1_000_000.0,
)
