"""olmoe-1b-7b [arXiv:2409.02060; hf]: 64-expert top-8 MoE, MHA."""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,  # per-expert FFN hidden dim
    vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024, sharding="ep"),
    rope_theta=10_000.0,
)
