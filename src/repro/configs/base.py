"""Architecture config schema for the 10 assigned archs (+ the paper's own).

Every field mirrors the published configuration; ``reduced()`` returns the
same-family smoke-test twin (small widths/layers/vocab) used by CPU tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    # 'ep': shard experts over the model axis; 'tp': shard expert hidden dim
    sharding: str = "ep"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # token mixer: 'attention' | 'rwkv6' | pattern-based hybrid
    mixer: str = "attention"
    # repeating layer pattern for hybrids, e.g. ('rec', 'rec', 'attn');
    # None means all layers identical.
    block_pattern: Optional[Tuple[str, ...]] = None
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # SWA for all attention layers
    local_window: Optional[int] = None  # hybrid local-attention window
    mrope: bool = False  # qwen2-vl 3-section rotary
    rwkv_head_dim: int = 64
    # 0 = per-token scan (paper-faithful recurrence); >0 = GLA-style chunked
    # formulation with this chunk length (see EXPERIMENTS.md §Perf)
    rwkv_chunk_size: int = 0
    conv_width: int = 4  # RG-LRU temporal conv
    tie_embeddings: bool = False
    # int8 KV cache (per-token/head scales) — halves decode-cache memory and
    # read traffic; see serve/kvquant.py and EXPERIMENTS.md §Perf.
    kv_quant: bool = False
    norm_eps: float = 1e-6
    # modality frontend stub: number of precomputed embedding positions the
    # input carries (0 = pure token stream)
    frontend_stub_len: int = 0

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.block_pattern is not None and self.n_layers < len(self.block_pattern):
            raise ValueError("n_layers smaller than one block pattern")

    # ----- derived quantities used by roofline / tests -----------------------

    @property
    def attention_params_per_layer(self) -> int:
        q = self.d_model * self.n_heads * self.head_dim
        kv = 2 * self.d_model * self.n_kv_heads * self.head_dim
        o = self.n_heads * self.head_dim * self.d_model
        return q + kv + o

    @property
    def mlp_params_per_layer(self) -> int:
        if self.moe is not None:
            per_expert = 3 * self.d_model * self.moe.d_expert
            router = self.d_model * self.moe.num_experts
            return per_expert * self.moe.num_experts + router
        return 3 * self.d_model * self.d_ff  # SwiGLU: gate, up, down

    def param_count(self) -> int:
        """Total parameters (exact for the layer stack + embeddings)."""
        from repro.models import registry  # local import to avoid cycle

        return registry.param_count(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        from repro.models import registry

        return registry.param_count(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """Smoke-test twin: same family/features, tiny dims."""
        moe = None
        if self.moe is not None:
            moe = MoEConfig(
                num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_expert=64,
                capacity_factor=2.0,
                sharding=self.moe.sharding,
            )
        n_kv = min(self.n_kv_heads, 2)
        heads = max(4, n_kv)
        pattern = self.block_pattern
        n_layers = len(pattern) + 1 if pattern else 2
        return dataclasses.replace(
            self,
            rwkv_head_dim=128 // heads,  # keep n_heads * rwkv_head_dim == d_model
            n_layers=n_layers,
            d_model=128,
            n_heads=heads,
            n_kv_heads=n_kv,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            moe=moe,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            local_window=min(self.local_window, 64) if self.local_window else None,
            frontend_stub_len=min(self.frontend_stub_len, 16),
        )
