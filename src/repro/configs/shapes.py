"""The four assigned input-shape cells (LM transformer shapes).

``decode_*`` / ``long_*`` lower serve_step (one new token against a KV cache
of seq_len); ``train_*`` / ``prefill_*`` lower train_step / prefill.
``long_500k`` requires sub-quadratic attention — the runnable set per arch is
decided by ``is_cell_supported`` (skips recorded in DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def has_subquadratic_path(arch: ArchConfig) -> bool:
    """True if per-token decode cost is bounded independent of context length."""
    if arch.mixer == "rwkv6":
        return True  # O(1) recurrent state
    if arch.block_pattern is not None:
        # hybrid: every attention layer must be local/windowed
        return arch.local_window is not None
    return arch.sliding_window is not None  # SWA bounds the KV


def is_cell_supported(arch: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return has_subquadratic_path(arch)
    return True


def skip_reason(arch: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    if is_cell_supported(arch, shape):
        return None
    return (
        f"{arch.name} is pure full attention (no sub-quadratic path); "
        f"long_500k decode requires bounded per-token cost — see DESIGN.md §5"
    )
