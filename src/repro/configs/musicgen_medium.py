"""musicgen-medium [arXiv:2306.05284; hf]: decoder-only over EnCodec tokens.

Backbone only — the EnCodec frontend is a stub: input_specs() provides
precomputed frame embeddings alongside the codebook token ids.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    rope_theta=10_000.0,
    frontend_stub_len=64,  # precomputed conditioning frame embeddings
)
