"""rwkv6-3b (Finch) [arXiv:2404.05892; hf]: attention-free, data-dep decay."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    mixer="rwkv6",
    rwkv_head_dim=64,
    # chunked (GLA-style) time-mix by default: 57x memory-term reduction over
    # the per-token recurrence at identical math — EXPERIMENTS.md §Perf A.
    # Set to 0 for the paper-faithful per-token scan baseline.
    rwkv_chunk_size=64,
)
