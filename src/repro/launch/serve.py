"""Serving launcher: --arch selection, prefill + batched decode + telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \\
        --requests 8 --prompt-len 64 --gen-len 32 [--reduced] \\
        [--placement sharded] [--metrics-out metrics.json]

Same step functions the decode dry-run compiles; on a pod the KV-cache
sequence axis shards over 'model' per sharding/specs.cache_specs.

The sketch-telemetry ingest runs the production serve path (DESIGN.md
§16): every request SUBMITS its token stream to a coalescing queue and
the merged batch lands as ONE ``update_many`` per tick
(repro/serve/coalesce.py); ``--placement sharded`` splits the bank's
tenant-row axis over the process's devices with block-local key routing,
bit-identical to local placement.  The sliding-window ring is shared
across requests through ``SharedWindowRing`` so the §14 incremental fold
state amortizes across the fleet instead of rebuilding per request.

``--metrics-out`` turns on the repro.obs metrics registry for the run
(DESIGN.md §15): per-request read latency histograms (p50/p99), items/s
and density gauges, dispatch counts per registry axis/backend, sparse
compaction counters, coalescer tick sizes, and window-cache hit rates
land in one snapshot JSON, with a periodic ``[metrics]`` report line
every ``--report-every`` requests (0 = no periodic lines, snapshot at
exit only).  Without it the registry stays in its no-op default.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.obs import metrics, tracing
from repro.obs.format import (
    fmt_bytes,
    fmt_count,
    fmt_float,
    fmt_pct,
    fmt_rate,
    kv_line,
    metrics_report_line,
    per_second,
    truncated_note,
)
from repro.sketch import (
    CMConfig,
    CountMinBank,
    DEFAULT_ESTIMATOR,
    ExecutionPlan,
    HLLConfig,
    HybridBank,
    MultiResWindowedBank,
    WindowedBank,
    available_estimators,
)
from repro.launch.mesh import make_auto_mesh
from repro.models import transformer
from repro.serve import engine
from repro.serve.coalesce import CoalescingQueue, SharedWindowRing
from repro.telemetry.sketchboard import StreamSketch


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--estimator", default=DEFAULT_ESTIMATOR,
                    choices=available_estimators(),
                    help="phase-4 finalizer for the telemetry board")
    ap.add_argument("--window-epochs", type=int, default=4,
                    help="ring buckets for the sliding request window")
    ap.add_argument("--window-levels", type=int, default=0,
                    help=">0 swaps the dense window ring for the "
                         "multi-resolution exponential histogram "
                         "(DESIGN.md §14): --window-epochs full-resolution "
                         "buckets per level, horizon stretched to "
                         "W*(2**L - 1) epochs")
    ap.add_argument("--sparse-threshold", type=int, default=None,
                    help="distinct-bucket promotion threshold for the "
                         "hybrid per-request bank (default: m // 4)")
    ap.add_argument("--topk", type=int, default=5,
                    help="heavy-hitter tokens to report per request stream "
                         "(0 disables the count-min telemetry)")
    ap.add_argument("--cm-depth", type=int, default=4,
                    help="count-min depth rows for --topk tracking")
    ap.add_argument("--cm-width", type=int, default=1024,
                    help="count-min counters per depth row for --topk")
    ap.add_argument("--placement", default="local",
                    choices=("local", "sharded"),
                    help="'sharded' splits the telemetry banks' tenant-row "
                         "axis over this process's devices with block-local "
                         "key routing (DESIGN.md §16); bit-identical to "
                         "'local'")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable the metrics registry (DESIGN.md §15) and "
                         "write the snapshot JSON here at exit")
    ap.add_argument("--report-every", type=int, default=4,
                    help="print a [metrics] line every N requests (needs "
                         "--metrics-out); 0 disables the periodic lines and "
                         "only the exit snapshot is written")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    args = ap.parse_args()

    if args.metrics_out:
        metrics.enable()
        metrics.reset()

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    params = transformer.init_params(jax.random.PRNGKey(args.seed), arch)
    # the plan's estimator rides to board.report(), which finalizes all
    # streams with one batched estimate_many dispatch; --topk adds the
    # count-min twin so the same flush also tracks heavy-hitter tokens
    cm_cfg = (
        CMConfig(depth=args.cm_depth, width=args.cm_width, seed=args.seed)
        if args.topk > 0
        else None
    )
    board = StreamSketch(
        HLLConfig(p=12, hash_bits=64),
        plan=ExecutionPlan(
            estimator=args.estimator, sparse_threshold=args.sparse_threshold
        ),
        track_topk=cm_cfg,
    )
    # the board's single-sketch streams have no row axis; the multi-tenant
    # banks below ingest and finalize under the serve placement (§16)
    ingest_plan = board.plan
    if args.placement == "sharded":
        ingest_plan = board.plan.with_sharding(
            make_auto_mesh((jax.device_count(),), ("data",))
        )

    B, S, T = args.requests, args.prompt_len, args.gen_len
    prompts = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1), (B, S), 0, arch.vocab_size
    )
    batch = {"tokens": prompts}
    if arch.mrope:
        batch["positions"] = transformer.default_positions(arch, B, S)
    if arch.frontend_stub_len:
        batch["frontend_embeds"] = (
            jax.random.normal(
                jax.random.PRNGKey(args.seed + 2),
                (B, arch.frontend_stub_len, arch.d_model),
            ).astype(jnp.bfloat16)
            * 0.02
        )

    with tracing.span("serve.prefill", metric="serve.prefill.seconds") as pre:
        logits, cache = engine.prefill(params, batch, arch, kv_len=S + T + 1)
        first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    with tracing.span("serve.decode", metric="serve.decode.seconds") as dec:
        out, _ = engine.decode_loop(
            params, cache, first, jnp.asarray(S, jnp.int32), arch, steps=T
        )
        jax.block_until_ready(out)

    board.observe("prompt_tokens", prompts)
    board.observe("generated_tokens", out)
    # per_second guards the zero/near-zero elapsed a --smoke-sized run can
    # produce: "inf tok/s" on a report line instead of ZeroDivisionError
    print(
        f"{args.arch}: "
        f"prefill {fmt_rate(per_second(B * S, pre.elapsed_s), 'tok')}, "
        f"decode {fmt_rate(per_second(B * T, dec.elapsed_s), 'tok')}"
    )
    metrics.gauge(
        "serve.items_per_s",
        per_second(B * (S + T), pre.elapsed_s + dec.elapsed_s),
    )
    report = board.report(
        density=True, topk=args.topk if args.topk > 0 else None
    )
    for name, row in report.items():
        print(kv_line(f"sketch[{name}]", [
            ("distinct~", fmt_count(row["estimate"])),
            ("seen", fmt_count(row["items_seen"])),
            ("dup", fmt_float(row["duplication"], 2)),
            ("occ", fmt_pct(row["register_occupancy"])),
        ]))
        if args.topk > 0:
            hits = ", ".join(f"{v}x{c}" for v, c in row["topk"])
            print(f"    top-{args.topk} tokens: {hits}")
    bd = board.density()
    metrics.gauge("serve.board.occupancy_mean", bd["occupancy_mean"])
    print(kv_line("board density", [
        ("sparse-eligible", f"{bd['sparse_eligible']}/{bd['streams']}"),
        ("occupancy", fmt_pct(bd["occupancy_mean"])),
        ("hybrid~", fmt_bytes(bd["hybrid_nbytes_estimate"])),
        ("dense", fmt_bytes(bd["dense_nbytes"])),
    ]))

    # per-request distinct-token telemetry: one HybridBank row per request.
    # Each request SUBMITS its (prompt + generated) stream to the
    # coalescing queue — cheap host appends — and the whole fleet lands as
    # ONE hybrid-routed update_many tick (DESIGN.md §9, §12, §16); requests
    # with few distinct tokens stay in the sparse COO layout and the bank
    # reports its own storage win.  Sparse-destined pairs ride the deferred
    # append buffer until estimate_many()/density() below settle the bank —
    # the first read IS the flush seam, no explicit compact() call needed.
    # The bank shares the board's config so both readings stay comparable.
    bank = HybridBank.empty(
        B, board.cfg, threshold=board.plan.sparse_threshold
    )
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    req_keys = jnp.broadcast_to(rows, prompts.shape)
    gen_keys = jnp.broadcast_to(rows, out.shape)
    queue = CoalescingQueue()
    prompts_np, out_np = np.asarray(prompts), np.asarray(out)
    for r in range(B):
        queue.submit_row(r, np.concatenate([prompts_np[r], out_np[r]]))
    bank = queue.flush_into(bank, ingest_plan)
    per_req = np.asarray(bank.estimate_many(args.estimator, plan=ingest_plan))
    bank_d = bank.density()
    metrics.gauge("serve.bank.density_reduction", bank_d["reduction"])
    print(kv_line(f"bank[{B} requests] distinct tokens/request", [
        ("min", fmt_count(per_req.min())),
        ("mean", fmt_count(per_req.mean())),
        ("max", fmt_count(per_req.max())),
    ]) + " (one hybrid update_many pass)")
    print(kv_line("bank density", [
        ("promoted", f"{bank_d['dense_rows']}/{bank_d['rows']}"),
        ("occupancy", fmt_pct(bank_d["occupancy_mean"])),
        ("reduction", f"{fmt_float(bank_d['reduction'], 1)}x"),
    ]))

    # per-request heavy hitters (DESIGN.md §13): one CountMinBank row per
    # request stream, every (prompt + generated) token routed by request
    # index with ONE fused d-hash scatter-add, then a single batched
    # Topkapi recovery answers "top-k tokens per request stream" — the
    # frequency twin of the distinct-count bank above.
    if args.topk > 0:
        hh = CountMinBank.empty(B, cm_cfg)
        hh = hh.update_many(
            jnp.concatenate([req_keys.reshape(-1), gen_keys.reshape(-1)]),
            jnp.concatenate([prompts.reshape(-1), out.reshape(-1)]),
            board.plan,
        )
        vals, cnts = hh.topk(args.topk)
        shown = min(B, 4)
        print(kv_line(f"heavy[{B} requests] top-{args.topk} tokens/request", [
            ("d", args.cm_depth),
            ("w", args.cm_width),
            ("bank", fmt_bytes(hh.nbytes)),
        ]))
        for r in range(shown):
            hits = ", ".join(
                f"{v}x{c}" for v, c in zip(vals[r], cnts[r]) if c > 0
            )
            print(f"    request {r}: {hits}")
        if B > shown:
            print(truncated_note(shown, B, "requests"))

    # sliding-window telemetry (DESIGN.md §11): a WindowedBank ring over
    # decode time — the prompt lands in epoch 0, each decode slice opens a
    # new epoch, and the rolling per-request distinct count is ONE fused
    # ring fold + one batched estimate_many per reading.  With W buckets
    # the prompt epoch slides out once --window-epochs slices have landed,
    # which is exactly the "distinct tokens in the last k slices" question
    # a traffic dashboard asks.
    W = args.window_epochs
    ring_key = ("serve", args.window_levels, W, B, board.cfg)
    if args.window_levels > 0:
        # multi-res mode (DESIGN.md §14): same carrier surface, but the
        # horizon stretches to W*(2**L - 1) epochs at O(W*L) slots — the
        # prompt epoch coarsens into merged buckets instead of expiring
        win = SharedWindowRing.get_or_create(
            ring_key,
            lambda: MultiResWindowedBank.empty(
                W, B, board.cfg, levels=args.window_levels
            ),
        )
    else:
        win = SharedWindowRing.get_or_create(
            ring_key, lambda: WindowedBank.empty(W, B, board.cfg)
        )
    win = win.observe(req_keys, prompts, ingest_plan)
    slices = np.array_split(out_np, W, axis=1)
    for chunk in slices:
        if chunk.shape[1] == 0:
            # --gen-len < --window-epochs: array_split pads the tail with
            # token-less slices.  Rotating on them would expire the prompt
            # epoch after fewer than W REAL decode slices (and coarsen
            # empty multi-res buckets), so empty slices do not advance.
            continue
        win = win.advance()
        keys = jnp.broadcast_to(rows, chunk.shape)
        win = win.observe(keys, jnp.asarray(chunk), ingest_plan)
    # publish the advanced ring so later requests (and re-entries in this
    # process) share the §14 decomposed fold state instead of refolding
    win = SharedWindowRing.swap(ring_key, win)
    rolling = np.asarray(win.estimate_window(plan=ingest_plan,
                                             estimator=args.estimator))
    newest = np.asarray(win.estimate_window(1, ingest_plan, args.estimator))
    span = win.window  # horizon for the EH carrier, W for the dense ring
    print(kv_line(f"window[{span} epochs] rolling distinct/request", [
        ("min", fmt_count(rolling.min())),
        ("mean", fmt_count(rolling.mean())),
        ("max", fmt_count(rolling.max())),
        ("newest-mean", fmt_count(newest.mean())),
    ]))
    if args.window_levels > 0:
        d = win.density()
        print(kv_line("multi-res ring", [
            ("slots", d["slots"]),
            ("horizon", f"{d['horizon']} epochs"),
            ("reduction", f"{fmt_float(d['reduction'], 1)}x"),
        ]))

    # per-request read-path latency (DESIGN.md §15): each request's
    # dashboard read — rolling window estimate + its distinct count —
    # timed into the serve.request.seconds histogram.  Repeated window
    # reads hit the per-instance fold cache, which is exactly what the
    # window.fold_cache hit/miss counters in the snapshot make visible.
    for r in range(B):
        with tracing.span(
            "serve.request", metric="serve.request.seconds", request=r
        ):
            est = win.estimate_window(plan=ingest_plan,
                                      estimator=args.estimator)
            _reading = (float(np.asarray(est)[r]), float(per_req[r]))
        if (
            metrics.enabled()
            and args.report_every > 0
            and (r + 1) % args.report_every == 0
        ):
            print(metrics_report_line(metrics.snapshot()))

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(metrics.to_json())
        print(f"  metrics snapshot written to {args.metrics_out}")


if __name__ == "__main__":
    main()
