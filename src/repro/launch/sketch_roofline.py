import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline of the paper's own pipeline: HLL sketch update on the pod mesh.

The paper's Fig. 4 measures sketch throughput against an I/O bound (PCIe /
100 GbE).  On the pod the corresponding bound is HBM: a perfect sketch
engine reads the token stream once (4 bytes/item) and touches nothing else,
so ideal memory term = N*4 / (chips * 819 GB/s).  This driver lowers the
sharded update on the production mesh, runs the scan-aware HLO analyzer and
reports how close each variant gets to that ideal:

    PYTHONPATH=src python -m repro.launch.sketch_roofline

Variants (the §Perf iteration axis for the paper-representative cell):
  scatter     one segment_max per device (CPU-baseline structure)
  pipelined4/8/16  k per-device sub-sketches + max-fold (paper Fig. 3)
  hash32      32-bit hash (paper Fig. 4b: width-insensitive off CPU)
"""

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sketch import ExecutionPlan, HLLConfig, hll, update_registers
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, n_chips

N_ITEMS = 1 << 28  # 268M tokens/step across the pod (~1 GiB stream)


def lower_variant(name: str, mesh, cfg: HLLConfig, pipelines: int):
    chips = n_chips(mesh)
    items = jax.ShapeDtypeStruct((N_ITEMS,), jnp.int32)
    regs = jax.ShapeDtypeStruct((cfg.m,), hll.REGISTER_DTYPE)

    # shard the stream over EVERY mesh axis — the sketch has no TP dimension,
    # all 256 chips are stream lanes (the paper's k pipelines, k=chips*k_loc)
    all_axes = tuple(mesh.axis_names)
    plan = ExecutionPlan(
        backend="jnp", placement="mesh", mesh=mesh, data_axes=all_axes,
        pipelines=pipelines,
    )

    def fn_all(r, x):
        return update_registers(r, x, cfg, plan)

    with mesh:
        lowered = jax.jit(
            fn_all,
            in_shardings=(
                NamedSharding(mesh, P()),
                NamedSharding(mesh, P(all_axes)),
            ),
            out_shardings=NamedSharding(mesh, P()),
        ).lower(regs, items)
    compiled = lowered.compile()
    an = hlo_analysis.analyze(compiled.as_text())
    ideal_s = (N_ITEMS * 4 / chips) / hlo_analysis.HBM_BW
    terms = hlo_analysis.roofline_terms(an, n_chips=1)
    frac = ideal_s / max(terms[terms["dominant"]], 1e-12)
    return {
        "variant": name,
        "pipelines": pipelines,
        "hash_bits": cfg.hash_bits,
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"],
        "ideal_memory_s": ideal_s,
        "roofline_fraction": frac,
        "collectives_by_kind": terms["collectives_by_kind"],
        "hlo_bytes_per_item_per_chip": an.bytes / (N_ITEMS / n_chips(mesh)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/perf/sketch_roofline.json")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    results = []
    for name, cfg, k in [
        ("scatter", HLLConfig(p=16, hash_bits=64), 1),
        ("pipelined4", HLLConfig(p=16, hash_bits=64), 4),
        ("pipelined8", HLLConfig(p=16, hash_bits=64), 8),
        ("pipelined16", HLLConfig(p=16, hash_bits=64), 16),
        ("hash32", HLLConfig(p=16, hash_bits=32), 1),
    ]:
        r = lower_variant(name, mesh, cfg, k)
        results.append(r)
        print(
            f"[sketch] {name:12s} dominant={r['dominant']:12s} "
            f"bound={r[r['dominant']]:.6f}s ideal={r['ideal_memory_s']:.6f}s "
            f"frac={r['roofline_fraction']:.3f} "
            f"bytes/item={r['hlo_bytes_per_item_per_chip']:.1f}",
            flush=True,
        )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)


if __name__ == "__main__":
    main()
