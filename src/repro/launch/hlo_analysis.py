"""Scan-aware roofline accounting from optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of its
trip count (verified in EXPERIMENTS.md §Dry-run), which silently drops
~n_layers x the FLOPs of any scan-over-layers model and every chunked-
attention / recurrence inner loop.  This module re-derives the three
roofline quantities directly from ``compiled.as_text()``:

  * flops            — dot/convolution FLOPs from operand/output shapes
  * bytes            — per-instruction operand+output HBM traffic (post-
                       fusion approximation: fused interiors are free)
  * collective bytes — operand sizes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute,
                       bucketed by kind

with every quantity multiplied through the call graph: fusions/calls x1,
while bodies x trip count (extracted from the loop-condition constant —
XLA lowers lax.scan/fori to ``induction < constant(N)``).  Nested loops
multiply.  Validated against cost_analysis on loop-free graphs and against
analytic 6ND in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# name = <everything>; opcode found as the first word directly followed by '('
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-zA-Z][\w\-]*)\(")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str  # output shape string
    opcode: str
    rest: str  # full text after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    is_entry: bool = False


def parse_computations(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if current is None:
            if stripped.endswith("{") and ("(" in stripped or stripped.startswith("ENTRY")):
                m = _COMP_START_RE.match(stripped)
                if m:
                    current = Computation(
                        name=m.group(1),
                        instructions=[],
                        is_entry=stripped.startswith("ENTRY"),
                    )
            continue
        if stripped == "}":
            comps[current.name] = current
            current = None
            continue
        mn = _NAME_RE.match(stripped)
        if mn:
            name, body = mn.groups()
            mo = _OPCODE_RE.search(body)
            if mo:
                shape = body[: mo.start()].strip()
                opcode = mo.group(1)
                rest = body[mo.end() :]
                current.instructions.append(Instruction(name, shape, opcode, rest))
    return comps


def _dot_flops(instr: Instruction, symbols: Dict[str, str]) -> int:
    """2 * prod(output) * contracted_size for dot ops."""
    _, out_dims = _shape_dims(instr.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    operands = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
    if not operands:
        return 0
    lhs_shape = symbols.get(operands[0], "")
    _, lhs_dims = _shape_dims(lhs_shape)
    contracted = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contracted *= lhs_dims[int(idx)]
    out = 1
    for d in out_dims:
        out *= d
    return 2 * out * contracted


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota",
}


def _instr_bytes(instr: Instruction, symbols: Dict[str, str]) -> int:
    if instr.opcode in _SKIP_BYTES_OPS:
        return 0
    total = _shape_bytes(instr.shape)  # output write
    operand_str = instr.rest.split("), ")[0]
    for op in _OPERAND_RE.findall(operand_str):
        total += _shape_bytes(symbols.get(op, ""))
    return total


def _collective_bytes(instr: Instruction, symbols: Dict[str, str]) -> int:
    operand_str = instr.rest.split("), ")[0]
    total = 0
    for op in _OPERAND_RE.findall(operand_str):
        total += _shape_bytes(symbols.get(op, ""))
    return total


def _trip_count(cond: Computation, comps: Dict[str, "Computation"]) -> int:
    """Max integer constant in the loop condition (XLA: induction < N).

    Constants may live directly in the condition or inside a fusion it
    calls (wrapped_compare); search one level deep.
    """
    best = 1

    def scan_comp(c: Computation):
        nonlocal best
        for instr in c.instructions:
            if instr.opcode == "constant":
                m = re.match(r"(\d+)\)", instr.rest)
                if m:
                    best = max(best, int(m.group(1)))
            for sub in re.findall(r"(?:calls=|to_apply=)%?([\w\.\-]+)", instr.rest):
                subc = comps.get(sub)
                if subc is not None:
                    for si in subc.instructions:
                        if si.opcode == "constant":
                            m = re.match(r"(\d+)\)", si.rest)
                            if m:
                                best = max(best, int(m.group(1)))

    scan_comp(cond)
    return best


@dataclasses.dataclass
class Analysis:
    flops: float
    bytes: float
    collective_bytes: float
    collectives_by_kind: Dict[str, float]
    n_while_loops: int
    trip_counts: Dict[str, int]


def analyze(hlo_text: str, trip_hints: Optional[Dict[str, int]] = None) -> Analysis:
    comps = parse_computations(hlo_text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    trip_hints = trip_hints or {}
    trips: Dict[str, int] = {}
    n_while = 0
    memo: Dict[str, Tuple[float, float, float, Dict[str, float]]] = {}

    def walk(comp: Computation):
        nonlocal n_while
        if comp.name in memo:
            return memo[comp.name]
        flops = 0.0
        byts = 0.0
        coll = 0.0
        by_kind: Dict[str, float] = {}
        symbols = {i.name: i.shape for i in comp.instructions}
        # parameters appear as instructions with opcode 'parameter' — covered.
        for instr in comp.instructions:
            op = instr.opcode
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                b = _collective_bytes(instr, symbols)
                coll += b
                by_kind[base] = by_kind.get(base, 0.0) + b
                byts += _instr_bytes(instr, symbols)
                continue
            if op in ("dot", "convolution"):
                flops += _dot_flops(instr, symbols)
                byts += _instr_bytes(instr, symbols)
                continue
            if op == "while":
                n_while += 1
                mb = re.search(r"body=%?([\w\.\-]+)", instr.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", instr.rest)
                body = comps.get(mb.group(1)) if mb else None
                cond = comps.get(mc.group(1)) if mc else None
                trip = trip_hints.get(
                    mb.group(1) if mb else "",
                    _trip_count(cond, comps) if cond else 1,
                )
                trips[mb.group(1) if mb else instr.name] = trip
                if body is not None:
                    bf, bb, bc, bk = walk(body)
                    flops += trip * bf
                    byts += trip * bb
                    coll += trip * bc
                    for k, v in bk.items():
                        by_kind[k] = by_kind.get(k, 0.0) + trip * v
                if cond is not None:
                    cf, cb, cc, _ = walk(cond)
                    flops += trip * cf
                    byts += trip * cb
                continue
            # nested calls: fusion / call / conditional / custom-call
            called = re.findall(r"(?:calls=|to_apply=)%?([\w\.\-]+)", instr.rest)
            for cname in called:
                sub = comps.get(cname)
                if sub is not None and sub.name != comp.name:
                    sf, _, sc, sk = walk(sub)
                    flops += sf  # inner dots count; inner bytes are fused
                    coll += sc
                    for k, v in sk.items():
                        by_kind[k] = by_kind.get(k, 0.0) + v
            byts += _instr_bytes(instr, symbols)
        memo[comp.name] = (flops, byts, coll, by_kind)
        return memo[comp.name]

    flops, byts, coll, by_kind = walk(entry)
    return Analysis(
        flops=flops,
        bytes=byts,
        collective_bytes=coll,
        collectives_by_kind=by_kind,
        n_while_loops=n_while,
        trip_counts=trips,
    )


# ----------------------------------------------------------------------------
# roofline terms (TPU v5e constants from the assignment)
# ----------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(
    analysis: Analysis, n_chips: int, model_flops: Optional[float] = None
) -> dict:
    """The three §Roofline terms (seconds) + dominant + usefulness ratio.

    flops/bytes from the analyzer are whole-program (all chips); the
    per-chip roofline divides by the chip count.
    """
    compute_s = analysis.flops / (n_chips * PEAK_FLOPS_BF16)
    memory_s = analysis.bytes / (n_chips * HBM_BW)
    collective_s = analysis.collective_bytes / (n_chips * ICI_BW)
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    out = {
        **terms,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "collectives_by_kind": analysis.collectives_by_kind,
        "hlo_flops": analysis.flops,
        "hlo_bytes": analysis.bytes,
        "collective_bytes": analysis.collective_bytes,
    }
    if model_flops is not None:
        out["model_flops"] = model_flops
        out["useful_flop_ratio"] = (
            model_flops / analysis.flops if analysis.flops else float("nan")
        )
        # fraction of the roofline actually achieved if the dominant term
        # were the runtime: useful work time / bound time
        ideal_s = model_flops / (n_chips * PEAK_FLOPS_BF16)
        out["roofline_fraction"] = ideal_s / terms[dominant] if terms[dominant] else 0.0
    return out
