"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods; the 'pod'
axis is pure data parallelism (gradient all-reduce + sketch max-reduce cross
pod), 'model' stays intra-pod where ICI is fastest.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_auto_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the installed jax
    supports them (jax.sharding.AxisType landed after 0.4.x; on older
    releases every axis is Auto already, so the kwarg is simply dropped)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False, tp: int = 16):
    """tp != 16 is a §Perf variant: same 256 chips/pod, different DP x TP
    factorization (data = 256 // tp).  The assignment baseline is tp=16."""
    data = 256 // tp
    shape = (2, data, tp) if multi_pod else (data, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many devices the test process has."""
    return make_auto_mesh(shape, axes)


def n_chips(mesh) -> int:
    return mesh.devices.size
