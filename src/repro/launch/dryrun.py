import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import anywhere in the process
(jax locks the device count at first init), which is why this module must
only be run as a script / fresh process:

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

Per cell it produces a JSON artifact with:
  * compile success on the (16,16) single-pod AND (2,16,16) multi-pod mesh
  * compiled.memory_analysis() — bytes per device (proves it fits)
  * compiled.cost_analysis()  — raw XLA numbers (scan bodies counted once!)
  * scan-aware HLO analysis    — corrected flops / bytes / collective bytes
    (launch/hlo_analysis.py) and the three §Roofline terms.

Post-SPMD HLO is the per-device program, so analyzer outputs are per-chip;
MODEL_FLOPS is divided by the chip count for the usefulness ratio.
"""

import argparse
import dataclasses
import json
import traceback
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ARCH_IDS, SHAPES, get_arch, is_cell_supported, skip_reason,
)
from repro.obs import tracing
from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, n_chips
from repro.models import common, registry, transformer
from repro.serve import engine
from repro.sharding import ctx as shardctx
from repro.sharding import specs as shardspecs
from repro.train.step import TrainConfig, init_train_state, train_step


# ----------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ----------------------------------------------------------------------------


def input_specs(arch: ArchConfig, shape: ShapeConfig):
    """Aval dict for the cell's step function."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "train":
            batch["targets"] = jax.ShapeDtypeStruct((b, s), i32)
        if arch.mrope:
            batch["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
        if arch.frontend_stub_len:
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, arch.frontend_stub_len, arch.d_model), common.ACT_DTYPE
            )
        return batch
    # decode: one new token against a kv_len cache
    cache = jax.eval_shape(lambda: engine.init_cache(arch, b, s))
    return {
        "token": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache,
    }


def _state_shardings(state_avals, arch, mesh):
    param_specs = shardspecs.param_specs(
        state_avals["params"], arch,
        data_size=mesh.shape.get("data", 1),
        model_size=mesh.shape.get("model", 1),
    )

    def named(tree):
        return jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree)

    return {
        "params": named(param_specs),
        "opt": {
            "mu": named(param_specs),
            "nu": named(param_specs),
            "count": NamedSharding(mesh, P()),
            "ef": None,
        },
        "step": NamedSharding(mesh, P()),
        "sketch": NamedSharding(mesh, P()),
    }


def _batch_shardings(batch_avals, arch, mesh, global_batch):
    return {
        k: NamedSharding(mesh, shardspecs.batch_spec(arch, mesh, global_batch, k))
        for k in batch_avals
    }


# ----------------------------------------------------------------------------
# per-cell lowering
# ----------------------------------------------------------------------------


def pick_grad_accum(arch: ArchConfig, shape: ShapeConfig, n_dp: int) -> int:
    """Smallest power-of-two microbatching that bounds layer-boundary
    residuals to ~3 GB/device (the activation term of the 16 GB budget)."""
    if shape.kind != "train":
        return 1
    b_loc = max(1, shape.global_batch // n_dp)
    resid = arch.n_layers * b_loc * shape.seq_len * arch.d_model * 2  # bf16
    mu = 1
    while (
        resid / mu > 3e9
        and mu * 2 <= b_loc
        and shape.global_batch % (mu * 2) == 0
        and (shape.global_batch // (mu * 2)) % n_dp == 0
    ):
        mu *= 2
    return mu


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
               overrides: Optional[dict] = None, tp: int = 16,
               grad_accum: int = 0):
    """Lower + compile one cell. Returns (compiled, meta)."""
    arch = get_arch(arch_id)
    if overrides:
        arch = dataclasses.replace(arch, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, tp=tp)
    chips = n_chips(mesh)

    dp = shardspecs.data_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    cfg = TrainConfig(
        grad_accum=grad_accum or pick_grad_accum(arch, shape, n_dp)
    )
    hints = shardctx.ActivationHints(
        batch_axes=dp if shape.global_batch % n_dp == 0 else (),
        model_axis="model",
        seq_parallel=bool(int(os.environ.get("REPRO_SEQ_PARALLEL", "0"))),
    )

    with mesh, shardctx.use_hints(hints):
        if shape.kind == "train":
            state_avals = jax.eval_shape(
                lambda k: init_train_state(k, arch, cfg),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            )
            batch_avals = input_specs(arch, shape)
            state_sh = _state_shardings(state_avals, arch, mesh)
            batch_sh = _batch_shardings(batch_avals, arch, mesh, shape.global_batch)
            fn = partial(train_step, arch=arch, cfg=cfg)
            lowered = jax.jit(
                fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_avals, batch_avals)
        elif shape.kind == "prefill":
            params_avals = jax.eval_shape(
                lambda k: transformer.init_params(k, arch),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            )
            batch_avals = input_specs(arch, shape)
            params_sh = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp),
                shardspecs.param_specs(
                    params_avals, arch,
                    data_size=mesh.shape.get("data", 1),
                    model_size=mesh.shape.get("model", 1),
                ),
            )
            batch_sh = _batch_shardings(batch_avals, arch, mesh, shape.global_batch)

            def prefill_fn(params, batch):
                logits, _, states = transformer.forward(
                    params, batch, arch, collect_state=True
                )
                return logits[:, -1, :], states

            lowered = jax.jit(
                prefill_fn, in_shardings=(params_sh, batch_sh)
            ).lower(params_avals, batch_avals)
        else:  # decode
            params_avals = jax.eval_shape(
                lambda k: transformer.init_params(k, arch),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            )
            ins = input_specs(arch, shape)
            params_sh = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp),
                shardspecs.param_specs(
                    params_avals, arch,
                    data_size=mesh.shape.get("data", 1),
                    model_size=mesh.shape.get("model", 1),
                ),
            )
            cache_sh = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp),
                shardspecs.cache_specs(ins["cache"], arch, mesh, shape.global_batch),
            )
            tok_sh = NamedSharding(
                mesh, shardspecs.batch_spec(arch, mesh, shape.global_batch, "token")
            )
            fn = partial(engine.decode_step, arch=arch)
            lowered = jax.jit(
                fn,
                in_shardings=(params_sh, cache_sh, tok_sh, NamedSharding(mesh, P())),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(params_avals, ins["cache"], ins["token"], ins["pos"])

    compiled = lowered.compile()
    return compiled, {"chips": chips, "kind": shape.kind}


def _memory_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {"unavailable": True}
    if ma is None:
        return {"unavailable": True}
    for field in (
        "temp_size_in_bytes", "argument_size_in_bytes",
        "output_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    if "temp_size_in_bytes" in out and "argument_size_in_bytes" in out:
        out["peak_bytes_per_device_est"] = (
            out["temp_size_in_bytes"]
            + out["argument_size_in_bytes"]
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def run_cell(
    arch_id: str, shape_name: str, multi_pod: bool, out_dir: Optional[str],
    overrides: Optional[dict] = None, tag: str = "", tp: int = 16,
    grad_accum: int = 0,
) -> dict:
    arch = get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh_tag = ("pod2x16x16" if multi_pod else "pod16x16") + tag
    record = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
        "kind": shape.kind, "status": "ok", "overrides": overrides or {},
    }
    if not is_cell_supported(arch, shape):
        record["status"] = "skipped"
        record["skip_reason"] = skip_reason(arch, shape)
        _write(record, out_dir)
        return record

    try:
        with tracing.span("dryrun.compile", cell=f"{arch_id}/{shape_name}") as sp:
            compiled, meta = lower_cell(arch_id, shape_name, multi_pod,
                                        overrides, tp, grad_accum)
        chips = meta["chips"]
        record["compile_s"] = round(sp.elapsed_s, 1)
        record["memory_analysis"] = _memory_dict(compiled)
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, list):  # older jax returns [per-device dict]
                ca = ca[0]
            record["cost_analysis_raw"] = {
                "flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
            }
        except Exception:
            record["cost_analysis_raw"] = {"unavailable": True}

        analysis = hlo_analysis.analyze(compiled.as_text())
        model_flops = registry.model_flops_per_token(arch, shape.kind) * (
            shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        )
        terms = hlo_analysis.roofline_terms(
            analysis, n_chips=1, model_flops=model_flops / chips
        )
        record["roofline"] = {
            k: (v if not isinstance(v, float) else float(v))
            for k, v in terms.items()
        }
        record["hlo"] = {
            "n_while_loops": analysis.n_while_loops,
            "trip_counts": analysis.trip_counts,
        }
        record["model_flops_global"] = model_flops
        record["chips"] = chips
    except Exception as e:  # a failing cell is a bug — record it loudly
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    _write(record, out_dir)
    return record


def _write(record: dict, out_dir: Optional[str]):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=2, default=str)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="arch field override key=value (int/float/str)")
    ap.add_argument("--tag", default="", help="suffix for the artifact name")
    ap.add_argument("--tp", type=int, default=16,
                    help="TP degree (256//tp becomes DP) — §Perf variant")
    ap.add_argument("--grad-accum", type=int, default=0,
                    help="override microbatch count (0 = auto)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a, s in cells:
        for mp in meshes:
            tag = ("pod2x16x16" if mp else "pod16x16") + args.tag
            path = os.path.join(args.out, f"{a}__{s}__{tag}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] skip existing {a} {s} {tag}")
                continue
            rec = run_cell(a, s, mp, args.out, overrides or None, args.tag,
                           args.tp, args.grad_accum)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (
                    f" dominant={r['dominant']} bound={r['bound_s']:.4f}s "
                    f"useful={r.get('useful_flop_ratio', 0):.3f}"
                )
            elif status == "error":
                extra = " " + rec["error"][:160]
            print(f"[dryrun] {a:18s} {s:12s} {tag:10s} {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
