"""Assemble EXPERIMENTS.md tables from the dry-run JSON artifacts.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List


def load(dir_: str) -> List[dict]:
    return [json.load(open(f)) for f in sorted(glob.glob(os.path.join(dir_, "*.json")))]


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}GiB"


def roofline_table(recs: List[dict], mesh: str = "pod16x16") -> str:
    rows = [
        "| arch | shape | status | compute_s | memory_s | collective_s | "
        "dominant | MODEL/HLO | roofline frac | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | skipped (full attention @500k) "
                "| - | - | - | - | - | - | - |"
            )
            continue
        rf = r["roofline"]
        mem = r.get("memory_analysis", {}).get("peak_bytes_per_device_est")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant'].replace('_s','')} "
            f"| {rf.get('useful_flop_ratio', float('nan')):.3f} "
            f"| {rf.get('roofline_fraction', float('nan')):.4f} "
            f"| {fmt_bytes(mem)} |"
        )
    return "\n".join(rows)


def dryrun_table(recs: List[dict]) -> str:
    rows = [
        "| arch | shape | 16x16 | 2x16x16 | compile_s (single/multi) |",
        "|---|---|---|---|---|",
    ]
    by_key = {}
    for r in recs:
        by_key.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = r
    for (a, s), pair in sorted(by_key.items()):
        s1 = pair.get("pod16x16", {})
        s2 = pair.get("pod2x16x16", {})
        c1 = s1.get("compile_s", "-")
        c2 = s2.get("compile_s", "-")
        rows.append(
            f"| {a} | {s} | {s1.get('status','-')} | {s2.get('status','-')} "
            f"| {c1}/{c2} |"
        )
    return "\n".join(rows)


def interesting_cells(recs: List[dict]) -> dict:
    """Pick hillclimb candidates: worst roofline frac, most collective-bound."""
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "pod16x16"]
    worst = min(ok, key=lambda r: r["roofline"].get("roofline_fraction", 1))
    coll = max(
        ok,
        key=lambda r: r["roofline"]["collective_s"]
        / max(r["roofline"]["bound_s"], 1e-12),
    )
    return {"worst_fraction": worst, "most_collective": coll}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run status (both meshes)\n")
    print(dryrun_table(recs))
    print(f"\n## Roofline ({args.mesh})\n")
    print(roofline_table(recs, args.mesh))
    picks = interesting_cells(recs)
    print("\n## Hillclimb candidates")
    for k, r in picks.items():
        print(f"- {k}: {r['arch']} {r['shape']} "
              f"(frac={r['roofline'].get('roofline_fraction'):.4f}, "
              f"dominant={r['roofline']['dominant']})")


if __name__ == "__main__":
    main()
