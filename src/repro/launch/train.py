"""Training launcher: --arch/--shape selection, mesh-aware, restartable.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
        --steps 100 --ckpt-dir /tmp/ck [--reduced] [--tp 4] [--compress-grads]

On this CPU container use --reduced (default).  On a real pod, drop
--reduced and the FSDP/TP shardings from sharding/specs.py apply through
the same step function the dry-run compiles; the launcher is identical —
only the device fleet differs (jax.distributed.initialize is invoked when
JAX_COORDINATOR is set, one process per host).
"""

from __future__ import annotations

import argparse
import os

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.sketch import (
    DEFAULT_ESTIMATOR,
    HLLConfig,
    available_estimators,
)
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptimizerConfig
from repro.train.loop import LoopConfig, train
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--sketch-p", type=int, default=14)
    ap.add_argument("--estimator", default=DEFAULT_ESTIMATOR,
                    choices=available_estimators(),
                    help="phase-4 finalizer for the sketch telemetry")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()  # multi-host pod entry

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()

    cfg = TrainConfig(
        optimizer=OptimizerConfig(
            lr=args.lr,
            warmup_steps=max(1, args.steps // 10),
            total_steps=args.steps,
            compress_grads=args.compress_grads,
        ),
        sketch=HLLConfig(p=args.sketch_p, hash_bits=64),
        sketch_estimator=args.estimator,
        grad_accum=args.grad_accum,
    )
    data = DataConfig(
        vocab_size=arch.vocab_size,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
    )
    loop = LoopConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
    )
    train(arch, cfg, data, loop)


if __name__ == "__main__":
    main()
