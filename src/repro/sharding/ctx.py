"""Activation-sharding hints: explicit constraints where propagation fails.

XLA's SPMD propagation loses the 'model' sharding at uneven reshapes
(e.g. (B,S,960)@model -> (B,S,15,64): 60 channels/device cannot tile 15
heads), silently *replicating* whole attention/RWKV mixers across the model
axis — measured as a 20x HLO-vs-model FLOP blowup on smollm train_4k
(EXPERIMENTS.md §Perf iteration 1).  Models therefore place
with_sharding_constraint at the head/channel-forming reshapes, resolved
through the hints below so the same model code runs unsharded on CPU tests
(hints unset -> no-op) and on any mesh the launcher picks.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ActivationHints:
    batch_axes: Tuple[str, ...]  # () to leave batch unsharded
    model_axis: Optional[str]  # None to leave features unsharded
    # Korthikanti-style sequence parallelism: the residual stream between
    # layers is sharded over the model axis on its sequence dim, so the
    # layer-boundary activations scan-grad stores shrink by the TP degree.
    # XLA inserts the all-gather/reduce-scatter pair at the TP matmuls.
    seq_parallel: bool = False


_HINTS: Optional[ActivationHints] = None


def set_hints(hints: Optional[ActivationHints]) -> None:
    global _HINTS
    _HINTS = hints


def get_hints() -> Optional[ActivationHints]:
    return _HINTS


class use_hints:
    """Context manager for scoped hints (used by the dry-run launcher)."""

    def __init__(self, hints: Optional[ActivationHints]):
        self.hints = hints
        self.prev = None

    def __enter__(self):
        global _HINTS
        self.prev = _HINTS
        _HINTS = self.hints
        return self.hints

    def __exit__(self, *exc):
        global _HINTS
        _HINTS = self.prev
        return False


def constrain(x, dims: Tuple[Optional[str], ...]):
    """Apply with_sharding_constraint resolved from hints.

    dims entries: 'batch' | 'model' | None, one per array dim.
    No-op when hints are unset (single-device tests) or when the requested
    axis is absent from the hints.
    """
    h = _HINTS
    if h is None:
        return x
    spec = []
    for d in dims:
        if d == "batch" and h.batch_axes:
            spec.append(h.batch_axes if len(h.batch_axes) > 1 else h.batch_axes[0])
        elif d == "model" and h.model_axis:
            spec.append(h.model_axis)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
