"""Partition rules: FSDP/TP/EP/sequence-parallel specs."""
