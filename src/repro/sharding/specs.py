"""Partition rules: FSDP over 'data', TP over 'model', DP over 'pod'.

Parameters
  Stacked per-stage weights carry a leading layer axis — FSDP shards it over
  'data' (ZeRO-3: every device holds 1/16 of every layer's weights and
  optimizer state; all-gather on use, reduce-scatter on grads — inserted by
  the SPMD partitioner).  Tensor-parallel 'model' sharding follows the
  standard Megatron pattern: column-parallel in-projections, row-parallel
  out-projections, experts over 'model' when the expert count divides it
  (EP), expert-hidden otherwise.  Uneven head counts (smollm's 15, phi4's
  24) are allowed — XLA pads the shard.

Activations
  Batch shards over ('pod','data'); heads / expert / vocab dims follow the
  params via propagation.  Decode KV caches shard their *sequence* axis over
  'model' (sequence-parallel flash-decode): any GQA ratio works, including
  MQA, because heads stay local — see serve/engine.py.

The HLL sketch registers are replicated (P()); the per-shard partial
sketches merge through an all-reduce-MAX that SPMD inserts because
segment_max's output is requested replicated — the paper's Fig. 3 fold.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

DATA_AXES = ("pod", "data")  # batch axes (pod may be absent on single-pod)
FSDP_AXIS = "data"
TP_AXIS = "model"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


# ----------------------------------------------------------------------------
# parameter specs
# ----------------------------------------------------------------------------

# leaf-name -> (tp_dim_from_right_of_unstacked, row_parallel)
_TP_RULES = {
    # attention
    "wq": ("col",),
    "wk": ("col",),
    "wv": ("col",),
    "wo": ("row",),
    "wg": ("col",),
    # swiglu / rwkv channel
    "gate": ("col",),
    "up": ("col",),
    "down": ("row",),
    "wk_cm": ("col",),
    # rglru
    "w_x": ("col",),
    "w_gate": ("col",),
    "w_a": ("col",),
    "w_i": ("col",),
    "w_out": ("row",),
    # rwkv decay lora (d, rank)/(rank, d): keep replicated (tiny)
}


def _add_fsdp(dims: list, shape, data_size: int) -> list:
    """Place the FSDP 'data' axis on the largest free dim it divides.

    pjit in_shardings demand exact divisibility (a 22-layer stack cannot
    shard over data=16), so the axis goes to the biggest divisible dim —
    usually the stacked-layer dim, else a weight matrix dim — or nowhere.
    """
    candidates = sorted(
        (i for i in range(len(dims)) if dims[i] is None),
        key=lambda i: -shape[i],
    )
    for i in candidates:
        if shape[i] % data_size == 0 and shape[i] >= data_size:
            dims[i] = FSDP_AXIS
            break
    return dims


def _param_spec(
    path: Tuple, leaf, arch: ArchConfig, data_size: int, model_size: int
) -> P:
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    leaf_name = str(names[-1])
    shape = tuple(leaf.shape)
    ndim = leaf.ndim
    dims: list = [None] * ndim

    def tp(dim_idx: int):
        """Apply TP to a dim if it divides the model axis."""
        if shape[dim_idx] % model_size == 0 and shape[dim_idx] >= model_size:
            dims[dim_idx] = TP_AXIS

    if leaf_name == "embed":
        tp(0)  # vocab-parallel
        return P(*dims)
    if leaf_name == "lm_head":
        tp(1)
        return P(*dims)
    if ndim <= 1:
        return P(*dims)

    stacked = any(str(n).startswith("stage") for n in names)
    off = 1 if stacked else 0
    inner = ndim - off
    moe = arch.moe
    in_moe = moe is not None and leaf_name in ("gate", "up", "down", "router")

    if in_moe and leaf_name != "router" and inner == 3:
        if moe.sharding == "ep" and moe.num_experts % model_size == 0:
            tp(off + 0)  # experts over 'model' (EP)
        elif leaf_name == "down":  # (E, f, d): expert-hidden TP
            tp(off + 1)
        else:  # (E, d, f)
            tp(off + 2)
    elif not in_moe:
        rule = _TP_RULES.get(leaf_name)
        if rule and inner == 2:
            tp(off + (1 if rule[0] == "col" else 0))

    return P(*_add_fsdp(dims, shape, data_size))


def param_specs(params_tree, arch: ArchConfig, data_size: int = 16,
                model_size: int = 16):
    """PartitionSpec pytree matching the model param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(path, leaf, arch, data_size, model_size),
        params_tree,
    )


def param_shardings(params_tree, arch: ArchConfig, mesh: Mesh):
    specs = param_specs(
        params_tree, arch,
        data_size=mesh.shape.get(FSDP_AXIS, 1),
        model_size=mesh.shape.get(TP_AXIS, 1),
    )
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ----------------------------------------------------------------------------
# batch / cache specs
# ----------------------------------------------------------------------------


def batch_spec(arch: ArchConfig, mesh: Mesh, global_batch: int, key: str) -> P:
    dp = data_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    bdim = dp if global_batch % n_dp == 0 else None  # tiny batches replicate
    if key == "positions" and arch.mrope:
        return P(None, bdim, None)
    if key == "frontend_embeds":
        return P(bdim, None, None)
    if key in ("token", "pos_scalar"):
        return P(bdim) if key == "token" else P()
    return P(bdim, None)  # tokens / targets / positions (B, S)


def batch_specs(arch: ArchConfig, mesh: Mesh, global_batch: int, batch_tree):
    return {
        k: batch_spec(arch, mesh, global_batch, k) for k in batch_tree
    }


def cache_specs(cache_tree, arch: ArchConfig, mesh: Mesh, global_batch: int):
    """Decode-cache specs: batch over data axes, KV sequence over 'model'.

    Every placement is divisibility-checked (pjit requirement); when a
    preferred dim does not divide, the next candidate dim is tried, else
    that dim stays replicated.
    """
    dp = data_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    bdim = dp if global_batch % n_dp == 0 else None
    tp_size = mesh.shape.get(TP_AXIS, 1)

    def spec(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        leaf_name = names[-1]
        shape = tuple(leaf.shape)
        if leaf_name.startswith("kv_pos"):
            return P(None)

        def tp_first(dims, candidates):
            for c in candidates:
                if shape[c] % tp_size == 0 and shape[c] >= tp_size:
                    dims[c] = TP_AXIS
                    return dims
            return dims

        if leaf_name in ("k", "v"):  # (L, B, W, Hkv, hd): seq over model
            dims = [None, bdim, None, None, None]
            return P(*tp_first(dims, [2, 4]))
        if leaf_name in ("k_scale", "v_scale"):  # (L, B, W, Hkv, 1)
            dims = [None, bdim, None, None, None]
            return P(*tp_first(dims, [2]))
        if leaf_name == "s":  # rwkv state (L, B, H, N, N)
            dims = [None, bdim, None, None, None]
            return P(*tp_first(dims, [2, 3]))  # heads, else key-dim
        if leaf_name == "conv":  # (L, B, w-1, d)
            dims = [None, bdim, None, None]
            return P(*tp_first(dims, [3]))
        if leaf_name == "h":  # (L, B, d)
            dims = [None, bdim, None]
            return P(*tp_first(dims, [2]))
        if leaf_name in ("x_prev", "cm_x_prev"):  # (L, B, d) replicated d
            return P(None, bdim, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)
