"""Serving layer: prefill, KV/recurrent caches, batched decode."""
