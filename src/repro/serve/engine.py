"""Serving engine: prefill + single-token decode for every architecture.

Cache layouts (per stage, stacked over the stage's layers for lax.scan):

  attn : ring-buffered K/V of width W = min(kv_len, window) plus a global
         slot->position map ``kv_pos`` (-1 = empty).  The ring makes SWA /
         local-attention decode O(window) — this is what qualifies mixtral
         and recurrentgemma for the long_500k cell: position p lives in slot
         p % W, so the buffer always holds exactly the positions the window
         may attend to.
  rec  : RG-LRU hidden state + trailing conv window.
  rwkv : per-head state matrix + the two token-shift activations.

Decode attention materializes (B, H, W) scores — tiny — against the cache;
under the production mesh the cache's W axis is sharded over 'model'
(sequence-parallel flash-decode; the partial-softmax collectives are
inserted by SPMD partitioning — see sharding/specs.py).
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention, common, moe as moe_lib, rglru, rwkv6
from repro.models import transformer
from repro.serve.kvquant import dequantize_kv, quantize_kv

NEG_INF = attention.NEG_INF


def cache_width(arch: ArchConfig, kind: str, kv_len: int) -> int:
    window = transformer._sublayer_window(kind, arch)
    return min(kv_len, window) if window else kv_len


# ----------------------------------------------------------------------------
# cache init
# ----------------------------------------------------------------------------


def init_cache(arch: ArchConfig, batch: int, kv_len: int):
    """Zeroed decode cache for a maximum context of ``kv_len`` tokens."""
    stages = []
    hd, hkv = arch.head_dim, arch.n_kv_heads
    h, n = arch.n_heads, arch.rwkv_head_dim
    d = arch.d_model
    for pattern, repeats in transformer.layer_stages(arch):
        stage: Dict[str, Any] = {}
        for j, kind in enumerate(pattern):
            if kind == "attn":
                w = cache_width(arch, kind, kv_len)
                if arch.kv_quant:
                    stage[f"sub{j}"] = {
                        "k": jnp.zeros((repeats, batch, w, hkv, hd), jnp.int8),
                        "v": jnp.zeros((repeats, batch, w, hkv, hd), jnp.int8),
                        "k_scale": jnp.zeros(
                            (repeats, batch, w, hkv, 1), jnp.bfloat16
                        ),
                        "v_scale": jnp.zeros(
                            (repeats, batch, w, hkv, 1), jnp.bfloat16
                        ),
                    }
                else:
                    stage[f"sub{j}"] = {
                        "k": jnp.zeros((repeats, batch, w, hkv, hd), common.ACT_DTYPE),
                        "v": jnp.zeros((repeats, batch, w, hkv, hd), common.ACT_DTYPE),
                    }
            elif kind == "rec":
                stage[f"sub{j}"] = {
                    "conv": jnp.zeros(
                        (repeats, batch, arch.conv_width - 1, d), common.ACT_DTYPE
                    ),
                    "h": jnp.zeros((repeats, batch, d), jnp.float32),
                }
            else:  # rwkv
                stage[f"sub{j}"] = {
                    "s": jnp.zeros((repeats, batch, h, n, n), jnp.float32),
                    "x_prev": jnp.zeros((repeats, batch, d), common.ACT_DTYPE),
                    "cm_x_prev": jnp.zeros((repeats, batch, d), common.ACT_DTYPE),
                }
        stages.append(stage)
    # slot -> position maps, one per distinct ring width
    pos_maps = {}
    for pattern, _ in transformer.layer_stages(arch):
        for kind in pattern:
            if kind == "attn":
                w = cache_width(arch, kind, kv_len)
                pos_maps[f"kv_pos_{w}"] = jnp.full((w,), -1, jnp.int32)
    return {"stages": stages, **pos_maps}


# ----------------------------------------------------------------------------
# prefill
# ----------------------------------------------------------------------------


def prefill(params, batch, arch: ArchConfig, kv_len: int):
    """Run the full prompt, returning (logits (B,S,V), populated cache)."""
    logits, _, states = transformer.forward(
        params, batch, arch, collect_state=True
    )
    b, s = batch["tokens"].shape
    cache = init_cache(arch, b, kv_len)

    for si, (pattern, repeats) in enumerate(transformer.layer_stages(arch)):
        for j, kind in enumerate(pattern):
            st = states[si][f"sub{j}"]
            tgt = cache["stages"][si][f"sub{j}"]
            if kind == "attn":
                w = cache_width(arch, kind, kv_len)
                take = min(s, w)
                pos = np.arange(s - take, s)
                slots = pos % w
                k_tail = st["k"][:, :, s - take :]
                v_tail = st["v"][:, :, s - take :]
                if arch.kv_quant:
                    kq, ks = quantize_kv(k_tail)
                    vq, vs = quantize_kv(v_tail)
                    tgt["k"] = tgt["k"].at[:, :, slots].set(kq)
                    tgt["v"] = tgt["v"].at[:, :, slots].set(vq)
                    tgt["k_scale"] = tgt["k_scale"].at[:, :, slots].set(ks)
                    tgt["v_scale"] = tgt["v_scale"].at[:, :, slots].set(vs)
                else:
                    tgt["k"] = tgt["k"].at[:, :, slots].set(k_tail)
                    tgt["v"] = tgt["v"].at[:, :, slots].set(v_tail)
                cache[f"kv_pos_{w}"] = cache[f"kv_pos_{w}"].at[slots].set(
                    jnp.asarray(pos, jnp.int32)
                )
            elif kind == "rec":
                tgt["conv"] = st["conv"]
                tgt["h"] = st["h"]
            else:
                tgt["s"] = st["s"]
                tgt["x_prev"] = st["x_prev"].astype(common.ACT_DTYPE)
                tgt["cm_x_prev"] = st["cm_x_prev"].astype(common.ACT_DTYPE)
    return logits, cache


# ----------------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------------


def _decode_attn(sub, cache, kv_pos, x, pos, arch: ArchConfig):
    """Single-token attention vs the ring cache. x (B, d) -> (B, d)."""
    b, d = x.shape
    hd, hkv = arch.head_dim, arch.n_kv_heads
    g = arch.n_heads // hkv
    h1 = x[:, None, :]  # (B, 1, d)
    q, k, v = attention.qkv_project(sub["mixer"], h1, arch)
    if arch.mrope:
        posvec = jnp.broadcast_to(pos[None, None], (3, b, 1)).astype(jnp.int32)
    else:
        posvec = jnp.broadcast_to(pos[None], (b, 1)).astype(jnp.int32)
    q, k = attention.apply_positions(q, k, posvec, arch)

    w = cache["k"].shape[1]
    slot = (pos % w).astype(jnp.int32)
    new_entries = {}
    if arch.kv_quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        ckq = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        cvq = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, slot, 0, 0))
        cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, slot, 0, 0))
        new_entries = {"k": ckq, "v": cvq, "k_scale": cks, "v_scale": cvs}
        ck = dequantize_kv(ckq, cks, x.dtype)
        cv = dequantize_kv(cvq, cvs, x.dtype)
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_entries = {"k": ck, "v": cv}

    qg = q.reshape(b, hkv, g, hd)
    scores = jnp.einsum(
        "bhgd,bwhd->bhgw", qg, ck, preferred_element_type=jnp.float32
    ) / np.sqrt(hd)
    valid = (kv_pos >= 0) & (kv_pos <= pos)
    valid = valid.at[slot].set(True)  # the token just written
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgw,bwhd->bhgd", p.astype(x.dtype), cv,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = out.reshape(b, arch.n_heads * hd) @ sub["mixer"]["wo"].astype(x.dtype)
    return out, new_entries


def _decode_sublayer(kind, sub, lcache, kv_pos_map, x, pos, arch):
    """One sublayer of decode; x (B, d). Returns (x, new_lcache)."""
    h = common.rms_norm(x, sub["norm1"], arch.norm_eps)
    new_cache = dict(lcache)
    if kind == "attn":
        w = lcache["k"].shape[1]
        mixed, kv_new = _decode_attn(sub, lcache, kv_pos_map[w], h, pos, arch)
        new_cache.update(kv_new)
    elif kind == "rec":
        st = rglru.RGLRUState(conv=lcache["conv"], h=lcache["h"])
        mixed, st_new = rglru.block_step(sub["mixer"], h, st, arch)
        new_cache.update(conv=st_new.conv, h=st_new.h)
    else:  # rwkv
        mixed, s_new = rwkv6.time_mix_step(
            sub["mixer"], h, lcache["x_prev"].astype(h.dtype), lcache["s"], arch
        )
        new_cache.update(s=s_new, x_prev=h.astype(common.ACT_DTYPE))
    x = x + mixed

    h2 = common.rms_norm(x, sub["norm2"], arch.norm_eps)
    if arch.moe is not None:
        ch, _, _ = moe_lib.moe_mixer(sub["channel"], h2[:, None, :], arch)
        ch = ch[:, 0]
    elif kind == "rwkv":
        ch = rwkv6.channel_mix(
            sub["channel"], h2[:, None, :],
            lcache["cm_x_prev"].astype(h2.dtype)[:, None, :],
        )[:, 0]
        new_cache.update(cm_x_prev=h2.astype(common.ACT_DTYPE))
    else:
        ch = common.swiglu(sub["channel"], h2)
    return x + ch, new_cache


def decode_step(params, cache, token: jnp.ndarray, pos: jnp.ndarray, arch):
    """One decode step. token (B,) int32, pos () int32 (batch-uniform).

    Returns (logits (B, V), new cache).
    """
    x = jnp.take(params["embed"], token, axis=0).astype(common.ACT_DTYPE)
    pos = pos.astype(jnp.int32)

    # slot->position maps advance once per step (shared by all layers)
    new_pos_maps = {}
    kv_pos_map = {}
    for key, arr in cache.items():
        if key.startswith("kv_pos_"):
            w = int(key.split("_")[-1])
            kv_pos_map[w] = arr
            new_pos_maps[key] = jax.lax.dynamic_update_slice(
                arr, pos[None], ((pos % w).astype(jnp.int32),)
            )

    new_stages = []
    for si, (pattern, repeats) in enumerate(transformer.layer_stages(arch)):
        stage_params = params[f"stage{si}"]
        stage_cache = cache["stages"][si]

        def body(xc, inp, _pattern=pattern):
            layer_params, layer_cache = inp
            new_lc = {}
            for j, kind in enumerate(_pattern):
                xc, nc = _decode_sublayer(
                    kind, layer_params[f"sub{j}"], layer_cache[f"sub{j}"],
                    kv_pos_map, xc, pos, arch,
                )
                new_lc[f"sub{j}"] = nc
            return xc, new_lc

        x, new_stage_cache = jax.lax.scan(body, x, (stage_params, stage_cache))
        new_stages.append(new_stage_cache)

    x = common.rms_norm(x, params["final_norm"], arch.norm_eps)
    head = (
        params["embed"].T if arch.tie_embeddings else params["lm_head"]
    ).astype(x.dtype)
    logits = (x @ head).astype(jnp.float32)
    return logits, {"stages": new_stages, **new_pos_maps}


@functools.partial(jax.jit, static_argnames=("arch", "steps"))
def decode_loop(params, cache, first_token, start_pos, arch, steps: int):
    """Greedy multi-step decode (serving example / tests)."""

    def body(carry, _):
        tok, pos, cache = carry
        logits, cache = decode_step(params, cache, tok, pos, arch)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, pos + 1, cache), nxt

    (_, _, cache), toks = jax.lax.scan(
        body, (first_token, start_pos, cache), None, length=steps
    )
    return jnp.moveaxis(toks, 0, 1), cache  # (B, steps)
