"""Request coalescing: many tenants' pending updates, ONE ingest per tick.

The paper's FPGA wins sustained line rate because ingest never waits on a
per-request round trip; the serving mirror of that (DESIGN.md §16) is a
coalescing queue in front of the bank.  Tenants ``submit()`` their keyed
token streams as they arrive — cheap host-side appends, no device work —
and a periodic tick ``drain()``s the queue into one merged (keys, items)
batch that lands with a single fused ``update_many`` dispatch.  N
per-tenant batches and their concatenation are bit-identical by the §6
lattice laws (register max is associative/commutative/idempotent, and the
exact counters add), so coalescing is pure batching: it can change WHEN a
register moves, never WHERE it lands (tests/test_serve_path.py).

Double-buffered host→device staging: ``drain(stage=True)`` device_puts
the merged batch through a two-slot ring.  jax transfers and kernel
dispatch are async, so while the device scatters tick N's batch the host
is already concatenating and staging tick N+1's into the other slot —
hashing overlaps scatter, the paper's ping-pong BRAM staging in XLA
terms.  The ring keeps a strong reference to both in-flight batches so
neither can be donated or collected before its scatter retires.
Host-orchestrated carriers (HybridBank's append buffer) consume the
merged batch on host instead via ``drain(stage=False)``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.obs import metrics as obs_metrics

__all__ = ["CoalescingQueue", "DoubleBuffer", "SharedWindowRing"]


class DoubleBuffer:
    """Two-slot host→device staging ring (ping-pong transfer buffers)."""

    def __init__(self, depth: int = 2):
        if depth < 2:
            raise ValueError(f"staging needs >= 2 slots, got {depth}")
        self._slots = [None] * depth
        self._tick = 0

    @property
    def depth(self) -> int:
        return len(self._slots)

    def stage(self, *host_arrays) -> Tuple[jax.Array, ...]:
        """Async-transfer ``host_arrays``; returns the device handles.

        Rotates through the slot ring, so the previous tick's buffers
        stay pinned while its scatter is still in flight and the slot
        being overwritten is always the oldest (already-retired) one.
        """
        staged = tuple(jax.device_put(a) for a in host_arrays)
        self._slots[self._tick % len(self._slots)] = staged
        self._tick += 1
        return staged


class CoalescingQueue:
    """Pending per-tenant updates, drained as one merged batch per tick."""

    def __init__(self, staging_depth: int = 2):
        self._chunks = []  # [(keys int32, items int32), ...] host-side
        self._staging = DoubleBuffer(staging_depth)
        self.ticks = 0

    def submit(self, keys, items) -> int:
        """Queue one tenant batch (host append, no device work); returns
        the number of items pending after the append."""
        keys = np.asarray(keys).reshape(-1).astype(np.int32, copy=False)
        items = np.asarray(items).reshape(-1)
        if keys.shape[0] != items.shape[0]:
            raise ValueError(
                f"keys ({keys.shape[0]}) and items ({items.shape[0]}) "
                f"must flatten to the same length"
            )
        if keys.shape[0]:
            self._chunks.append((keys, items))
            obs_metrics.inc("serve.coalesce.submitted")
        return self.pending_items()

    def submit_row(self, row: int, items) -> int:
        """``submit`` with every item routed to one tenant row."""
        items = np.asarray(items).reshape(-1)
        return self.submit(np.full(items.shape[0], row, np.int32), items)

    def pending_batches(self) -> int:
        return len(self._chunks)

    def pending_items(self) -> int:
        return sum(k.shape[0] for k, _ in self._chunks)

    def drain(self, stage: bool = True) -> Optional[Tuple]:
        """Pop everything pending as ONE merged (keys, items) batch.

        ``stage=True`` routes the merge through the double buffer and
        returns device handles (the fused-scatter path); ``stage=False``
        returns the host arrays for host-orchestrated carriers.  An
        empty queue returns None — a tick with no traffic must not
        dispatch anything.
        """
        if not self._chunks:
            return None
        chunks, self._chunks = self._chunks, []
        keys = np.concatenate([k for k, _ in chunks])
        items = np.concatenate([x for _, x in chunks])
        self.ticks += 1
        obs_metrics.inc("serve.coalesce.ticks")
        obs_metrics.observe("serve.coalesce.batches_per_tick", len(chunks))
        obs_metrics.observe("serve.coalesce.batch_items", keys.shape[0])
        if stage:
            return self._staging.stage(keys, items)
        return keys, items

    def flush_into(self, bank, plan=None):
        """Drain into ``bank`` with ONE ``update_many``; returns the new
        bank (unchanged when nothing is pending).  Device-stages unless
        the carrier ingests on host (a ``pending_pairs`` surface marks
        the HybridBank append-buffer family)."""
        host_carrier = hasattr(bank, "pending_pairs")
        merged = self.drain(stage=not host_carrier)
        if merged is None:
            return bank
        return bank.update_many(merged[0], merged[1], plan)


class SharedWindowRing:
    """Process-wide window rings shared across requests (DESIGN.md §16).

    The §14 fold decomposition and fold cache amortize per INSTANCE; a
    ring constructed per request pays the rebuild every time.  Serving
    code gets-or-creates one ring per (carrier, shape, config) key and
    writes functional updates back with ``swap``, so every request's
    read hits the same decomposed state.
    """

    _rings: dict = {}

    @classmethod
    def get_or_create(cls, key, factory):
        ring = cls._rings.get(key)
        if ring is None:
            ring = cls._rings[key] = factory()
            obs_metrics.inc("serve.window_ring.created")
        else:
            obs_metrics.inc("serve.window_ring.shared")
        return ring

    @classmethod
    def swap(cls, key, ring):
        """Publish an updated ring under ``key``; returns it."""
        cls._rings[key] = ring
        return ring

    @classmethod
    def reset(cls) -> None:
        """Drop every shared ring (tests and process teardown)."""
        cls._rings.clear()
