"""Continuous batching: per-slot decode positions + slot recycling.

`engine.decode_step` is batch-uniform (one shared position) — fine for
static batches, not for a serving system where requests arrive and finish
at different times.  This module lifts it to per-slot state:

  * ``decode_step_slots``: vmapped single-sequence decode — every batch
    slot carries its own position and its own ring-buffer slot map, so a
    slot can be at token 7 while its neighbour is at token 31000.
  * ``ContinuousBatcher``: admits queued requests into free slots, steps
    the whole batch at once, retires finished slots, recycles them for the
    next queued request — vLLM-style iteration-level scheduling expressed
    over the same jitted step.

Correctness invariant (tested): a request decoded in a mixed batch yields
exactly the logits it would get decoded alone.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.serve import engine


# ----------------------------------------------------------------------------
# per-slot decode (vmapped single-sequence step)
# ----------------------------------------------------------------------------


def _cache_batch_axes(cache):
    """in_axes pytree: batch is axis 1 for stage leaves (L, B, ...), and the
    kv_pos_* maps are per-slot (B, W) under the slotted layout -> axis 0."""

    def axes_of(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if any(n.startswith("kv_pos_") for n in names):
            return 0
        return 1

    return jax.tree_util.tree_map_with_path(axes_of, cache)


def slotted_cache(arch: ArchConfig, batch: int, kv_len: int):
    """Like engine.init_cache but with per-slot (B, W) position maps."""
    cache = engine.init_cache(arch, batch, kv_len)
    out = {}
    for k, v in cache.items():
        if k.startswith("kv_pos_"):
            out[k] = jnp.broadcast_to(v, (batch,) + v.shape).copy()
        else:
            out[k] = v
    return out


@partial(jax.jit, static_argnames=("arch",))
def decode_step_slots(params, cache, tokens: jnp.ndarray, pos: jnp.ndarray,
                      arch: ArchConfig):
    """Per-slot decode: tokens (B,), pos (B,) — independent positions.

    Implemented as vmap of the single-sequence engine.decode_step: params
    broadcast, every cache leaf mapped over its batch axis.  Returns
    (logits (B, V), new cache).
    """
    axes = _cache_batch_axes(cache)

    def single(cache_1, token_1, pos_1):
        # re-add the singleton batch dim the engine expects
        def add_b(path, leaf):
            names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
            if any(n.startswith("kv_pos_") for n in names):
                return leaf  # (W,) stays global for this slot
            return leaf[:, None]

        cache_b = jax.tree_util.tree_map_with_path(add_b, cache_1)
        logits, new_cache = engine.decode_step(
            params, cache_b, token_1[None], pos_1, arch
        )

        def drop_b(path, leaf):
            names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
            if any(n.startswith("kv_pos_") for n in names):
                return leaf
            return leaf[:, 0]

        return logits[0], jax.tree_util.tree_map_with_path(drop_b, new_cache)

    out_axes = (0, _cache_batch_axes(cache))
    return jax.vmap(single, in_axes=(axes, 0, 0), out_axes=out_axes)(
        cache, tokens, pos
    )


# ----------------------------------------------------------------------------
# iteration-level scheduler
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Admit/step/retire loop over a fixed slot count.

    Prefill is per-request (single-sequence) on admission; decode advances
    every live slot each iteration.  Token-budget variants (chunked prefill)
    would slot in at `admit` — out of scope here.
    """

    def __init__(self, params, arch: ArchConfig, n_slots: int, kv_len: int):
        self.params = params
        self.arch = arch
        self.n_slots = n_slots
        self.kv_len = kv_len
        self.cache = slotted_cache(arch, n_slots, kv_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros(n_slots, np.int32)
        self.next_token = np.zeros(n_slots, np.int32)
        self.queue: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    # ---- internals ----------------------------------------------------------

    def _write_slot(self, slot: int, cache_1, kv_pos, pos: int, token: int):
        def write(path, dst, src):
            names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
            if any(n.startswith("kv_pos_") for n in names):
                return dst
            return dst.at[:, slot].set(src[:, 0])

        self.cache = {
            k: (v.at[slot].set(kv_pos[k]) if k.startswith("kv_pos_") else v)
            for k, v in self.cache.items()
        }
        self.cache = dict(
            self.cache,
            stages=jax.tree_util.tree_map_with_path(
                write, self.cache["stages"], cache_1["stages"]
            ),
        )
        self.pos[slot] = pos
        self.next_token[slot] = token

    def admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None])}
                logits, cache_1 = engine.prefill(
                    self.params, batch, self.arch, kv_len=self.kv_len
                )
                first = int(jnp.argmax(logits[0, -1]))
                kv_pos = {
                    k: v for k, v in cache_1.items() if k.startswith("kv_pos_")
                }
                self._write_slot(
                    slot, cache_1, kv_pos, pos=len(req.prompt), token=first
                )
                req.generated.append(first)
                self.slot_req[slot] = req

    def step(self):
        """One decode iteration across all live slots."""
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return
        logits, self.cache = decode_step_slots(
            self.params, self.cache,
            jnp.asarray(self.next_token), jnp.asarray(self.pos), self.arch,
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for slot in live:
            req = self.slot_req[slot]
            self.pos[slot] += 1
            self.next_token[slot] = nxt[slot]
            req.generated.append(int(nxt[slot]))
            if len(req.generated) >= req.max_new or self.pos[slot] >= self.kv_len - 1:
                req.done = True
                self.slot_req[slot] = None  # retire -> slot recycled

    def run(self, max_iters: int = 10_000) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        reqs = list(self.queue)
        for _ in range(max_iters):
            self.admit()
            if not any(self.slot_req) and not self.queue:
                break
            self.step()
        for r in reqs:
            out[r.uid] = r.generated
        return out
