"""Int8 KV-cache quantization (per-token, per-head symmetric scales).

The decode_32k cache for qwen2-vl is 19.5 GiB/device in bf16 — over the
16 GiB v5e budget.  Quantizing K/V to int8 with a bf16 scale per
(token, head) halves the cache and its read traffic at decode; the scale
granularity keeps the attention error at the bf16 noise level (validated
in tests/test_kvquant.py against the bf16 path).

Layout: values int8 (..., W, Hkv, D), scales bf16 (..., W, Hkv, 1).
Dequantization fuses into the attention einsum's operand read.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., D) bf16/f32 -> (int8 values, bf16 scale over the last dim)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)
