"""Manual compressed all-reduce under shard_map (wire-format mechanics).

In the FSDP/pjit path XLA owns the gradient reduce-scatter; deploying int8
compression on the wire requires taking over that collective.  This module
proves the mechanics: an all-reduce over the data axes whose payload is int8
+ one f32 scale per shard — 4x fewer bytes than an f32 psum, ~2x fewer than
bf16.  Accuracy is preserved by the caller's error feedback (optim/adamw.py).

Implementation: quantize locally -> all_gather the (int8, scale) pairs over
the axis -> dequantize-and-sum locally.  all_gather moves exactly the
quantized bytes; the sum happens at full precision so there is no overflow,
unlike a naive int8 psum.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.optim.adamw import quantize_int8


def compressed_psum(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """All-reduce(sum) of f32 x over axis_name with int8 payload on the wire."""
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)  # (n_dev, ...) int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)  # (n_dev,) f32
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
    return jnp.sum(deq, axis=0)


def compressed_allreduce_bytes(x: jnp.ndarray, n_devices: int) -> dict:
    """Napkin accounting for EXPERIMENTS.md: payload bytes vs f32 psum."""
    n = x.size
    return {
        "f32_psum_bytes": 4 * n * 2 * (n_devices - 1) / n_devices,  # ring
        "int8_gather_bytes": (1 * n + 4) * (n_devices - 1),
        "ratio": 4.0,
    }


def make_compressed_grad_reducer(mesh, axes: Sequence[str]):
    """shard_map-wrapped mean-reduction of replicated-grad pytrees."""

    def reduce_tree(grads):
        def local(g):
            def one(leaf):
                summed = compressed_psum(leaf, axes)
                return summed / jnp.asarray(
                    jnp.prod(jnp.asarray([mesh.shape[a] for a in axes])),
                    jnp.float32,
                )

            return jax.tree.map(one, g)

        return shard_map(
            local, mesh=mesh, in_specs=(P(),), out_specs=P()
        )(grads)

    return reduce_tree
