"""Sharded AdamW + schedule + clipping + int8 error-feedback compression.

Self-contained (no optax).  Optimizer state mirrors the parameter pytree, so
the FSDP param specs apply verbatim (ZeRO: each device owns 1/16 of mu/nu).

Gradient compression: symmetric per-leaf int8 quantization with an error-
feedback residual (Seide et al. / EF-SGD style).  ``compress_grads`` is the
fidelity path used inside train_step; ``compressed_psum`` (optim/compress.py)
proves the wire-format mechanics under shard_map for the manual-collective
deployment mode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False  # int8 + error feedback


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t)
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {
        "mu": zeros(params),
        "nu": zeros(params),
        "count": jnp.zeros((), jnp.int32),
        "ef": None,  # error-feedback residuals, created lazily on compression
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [
        jnp.sum(jnp.square(l.astype(jnp.float32)))
        for l in jax.tree_util.tree_leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ----------------------------------------------------------------------------
# int8 error-feedback compression
# ----------------------------------------------------------------------------


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_error_feedback(grads, ef_residuals):
    """Quantize grads to int8, carrying quantization error to the next step."""
    if ef_residuals is None:
        ef_residuals = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads
        )

    def one(g, ef):
        corrected = g.astype(jnp.float32) + ef
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat = jax.tree.map(one, grads, ef_residuals)
    new_grads = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, new_ef


# ----------------------------------------------------------------------------
# update
# ----------------------------------------------------------------------------


def _is_matrix(path) -> bool:
    return True  # weight decay applied uniformly except norms/bias (1D)


def update(
    params, grads, opt_state, cfg: OptimizerConfig
) -> Tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads, grad_norm = clip_by_global_norm(grads, cfg.clip_norm)

    ef = opt_state.get("ef")
    if cfg.compress_grads:
        grads, ef = compress_with_error_feedback(grads, ef)

    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def one(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * gf
        nu = cfg.b2 * nu + (1 - cfg.b2) * gf * gf
        mhat = mu / b1c
        nhat = nu / b2c
        upd = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # no decay on norms / 1D params
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), mu, nu

    out = jax.tree.map(one, params, grads, opt_state["mu"], opt_state["nu"])
    istup = lambda t: isinstance(t, tuple)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=istup)
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=istup)
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=istup)
    new_state = {"mu": new_mu, "nu": new_nu, "count": count, "ef": ef}
    return new_params, new_state, {"lr": lr, "grad_norm": grad_norm}
