"""Sharded AdamW, schedules, gradient compression."""
