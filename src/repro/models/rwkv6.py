"""RWKV6 "Finch" token mixer: attention-free, data-dependent diagonal decay.

Structure follows arXiv:2404.05892: token-shift ddlerp with LoRA deltas,
per-channel data-dependent decay w_t = exp(-exp(d_t)), bonus u for the
current token, per-head state S in R^{N x N}, grouped head norm, and the
squared-ReLU channel mix.

The baseline prefill path is a per-token lax.scan over the recurrence
(state (B, H, N, N) updated once per token) — numerically exact and the
natural decode step, but HBM-bound at long sequence (the state is re-read
and re-written every token).  The chunked GLA-style formulation is the
§Perf hillclimb for this family (see EXPERIMENTS.md).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.sharding import ctx as shardctx

LORA_RANK = 32
DECAY_RANK = 64
MIX_NAMES = ("w", "k", "v", "r", "g")  # ddlerp targets


def init_params(key, arch: ArchConfig):
    d = arch.d_model
    keys = jax.random.split(key, 12)
    p = {
        "mix_base": jnp.zeros((5, d), common.PARAM_DTYPE) + 0.5,
        "mix_lora_a": jax.random.normal(keys[0], (5, d, LORA_RANK), common.PARAM_DTYPE)
        * 0.01,
        "mix_lora_b": jax.random.normal(keys[1], (5, LORA_RANK, d), common.PARAM_DTYPE)
        * 0.01,
        "wr": common.dense_init(keys[2], d, d),
        "wk": common.dense_init(keys[3], d, d),
        "wv": common.dense_init(keys[4], d, d),
        "wg": common.dense_init(keys[5], d, d),
        "wo": common.dense_init(keys[6], d, d),
        # decay: softplus-ish parameterization around slow decay
        "decay_base": jnp.zeros((d,), common.PARAM_DTYPE) - 0.5,
        "decay_lora_a": jax.random.normal(keys[7], (d, DECAY_RANK), common.PARAM_DTYPE)
        * 0.01,
        "decay_lora_b": jax.random.normal(keys[8], (DECAY_RANK, d), common.PARAM_DTYPE)
        * 0.01,
        "u": jax.random.normal(keys[9], (d,), common.PARAM_DTYPE) * 0.1,
        "ln_w": jnp.ones((d,), common.PARAM_DTYPE),
        "ln_b": jnp.zeros((d,), common.PARAM_DTYPE),
    }
    return p


def _ddlerp(params, x: jnp.ndarray, x_prev: jnp.ndarray):
    """Data-dependent token-shift interpolation -> dict of 5 mixed inputs."""
    sx = x_prev - x  # (B, S, d)
    dt = x.dtype
    base = params["mix_base"].astype(dt)  # (5, d)
    # shared LoRA trunk on the base-mixed input
    xxx = x + sx * base[0]
    out = {}
    for i, name in enumerate(MIX_NAMES):
        delta = jnp.tanh(xxx @ params["mix_lora_a"][i].astype(dt)) @ params[
            "mix_lora_b"
        ][i].astype(dt)
        out[name] = x + sx * (base[i] + delta)
    return out


def _projections(params, x: jnp.ndarray, arch: ArchConfig):
    """Full-sequence r/k/v/g/decay projections (B, S, H, N) + gate (B, S, d)."""
    b, s, d = x.shape
    h, n = arch.n_heads, arch.rwkv_head_dim
    dt = x.dtype
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mixed = _ddlerp(params, x, x_prev)
    bshn = ("batch", None, "model", None)
    r = shardctx.constrain(
        (mixed["r"] @ params["wr"].astype(dt)).reshape(b, s, h, n), bshn
    )
    k = shardctx.constrain(
        (mixed["k"] @ params["wk"].astype(dt)).reshape(b, s, h, n), bshn
    )
    v = shardctx.constrain(
        (mixed["v"] @ params["wv"].astype(dt)).reshape(b, s, h, n), bshn
    )
    g = jax.nn.silu((mixed["g"] @ params["wg"].astype(dt)).astype(jnp.float32))
    # data-dependent log-decay: lw = -exp(base + lora(x_w)) <= 0
    dd = params["decay_base"].astype(jnp.float32) + (
        jnp.tanh(mixed["w"] @ params["decay_lora_a"].astype(dt))
        @ params["decay_lora_b"].astype(dt)
    ).astype(jnp.float32)
    log_w = shardctx.constrain(
        -jnp.exp(jnp.clip(dd, -8.0, 8.0)).reshape(b, s, h, n), bshn
    )
    return r, k, v, g.astype(dt), log_w


def _head_norm(params, y: jnp.ndarray, arch: ArchConfig, eps: float = 64e-5):
    """GroupNorm with one group per head over (B, S, H, N)."""
    yf = y.astype(jnp.float32)
    mean = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + eps)
    b, s, h, n = y.shape
    yn = yn.reshape(b, s, h * n)
    return yn * params["ln_w"].astype(jnp.float32) + params["ln_b"].astype(
        jnp.float32
    )


def recurrence_step(
    state: jnp.ndarray,  # (B, H, N, N) f32
    r: jnp.ndarray,  # (B, H, N)
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_w: jnp.ndarray,  # (B, H, N)
    u: jnp.ndarray,  # (H, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One token of the RWKV6 recurrence. Returns (new_state, out (B,H,N))."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]  # (B, H, N, N)
    y = jnp.einsum("bhn,bhnv->bhv", rf, state + u[..., None] * kv)
    new_state = jnp.exp(log_w.astype(jnp.float32))[..., None] * state + kv
    return new_state, y


def time_mix(
    params, x: jnp.ndarray, arch: ArchConfig, state: jnp.ndarray = None
):
    """Full-sequence RWKV6 time mixing via per-token scan.

    Returns (out (B, S, d), final_state (B, H, N, N)).
    """
    b, s, d = x.shape
    h, n = arch.n_heads, arch.rwkv_head_dim
    r, k, v, g, log_w = _projections(params, x, arch)
    u = params["u"].astype(jnp.float32).reshape(h, n)
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)
    state = shardctx.constrain(state, ("batch", "model", None, None))

    def body(st, inp):
        rt, kt, vt, lwt = inp
        st_new, y = recurrence_step(st, rt, kt, vt, lwt, u)
        return st_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, log_w))
    state, ys = jax.lax.scan(body, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, n)  # (B, S, H, N)
    y = _head_norm(params, y, arch).astype(x.dtype) * g
    return y @ params["wo"].astype(x.dtype), state


def time_mix_chunked(
    params, x: jnp.ndarray, arch: ArchConfig, state: jnp.ndarray = None,
    chunk: int = 32,
):
    """Chunk-parallel RWKV6 (GLA-style) — §Perf hillclimb for this family.

    The per-token scan re-reads/writes the (B, H, N, N) state every token:
    HBM traffic ~2.6 MB/token/layer, measured 5119 s memory term on
    train_4k.  Chunking touches the state once per C tokens and turns the
    inner work into MXU matmuls:

      y_t   = (r_t * exp(Lex_t)) @ S_0                      [inter-chunk]
            + sum_{s<t} [sum_n r_t k_s exp(Lex_t - L_s)]_n v_s   [intra]
            + (r_t . (u * k_t)) v_t                         [bonus diag]
      S_C   = Diag(exp(L_C)) S_0 + sum_s (k_s * exp(L_C - L_s))^T v_s

    where L is the inclusive log-decay cumsum within the chunk and
    Lex = L - log_w the exclusive one.  Every exponent is a *relative*
    decay (<= 0), so the computation is stable for arbitrarily strong
    data-dependent decays — the pairwise exponent tensor (C, C, N) is
    materialized per chunk rather than factorized (exp(-L_s) alone can
    overflow).  Bit-compatible with time_mix (tests/test_rwkv_chunked.py).
    """
    b, s, d = x.shape
    h, n = arch.n_heads, arch.rwkv_head_dim
    c = min(chunk, s)
    if s % c != 0:
        return time_mix(params, x, arch, state)
    nc = s // c
    r, k, v, g, log_w = _projections(params, x, arch)
    u = params["u"].astype(jnp.float32).reshape(h, n)
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)
    state = shardctx.constrain(state, ("batch", "model", None, None))

    # (B, NC, C, H, N) f32 chunk views
    def chunked(t):
        return t.astype(jnp.float32).reshape(b, nc, c, h, n)

    rc, kc, vc, lwc = chunked(r), chunked(k), chunked(v), chunked(log_w)
    L = jnp.cumsum(lwc, axis=2)  # inclusive log-decay
    Lex = L - lwc  # exclusive
    Lend = L[:, :, -1:, :, :]  # (B, NC, 1, H, N)

    r_in = rc * jnp.exp(Lex)  # weights against S_0
    k_out = kc * jnp.exp(Lend - L)  # contribution weights into S_end
    # intra-chunk work happens INSIDE the chunk scan: the pairwise tensor
    # (B, C, C, H, N) is a per-step transient, never materialized across
    # the whole sequence (full-seq materialization measured 38 GiB/device
    # on train_4k — §Perf A iteration 4).
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)[None, :, :, None, None]

    def body(st, inp):
        rc_g, kc_g, v_g, r_in_g, k_out_g, wend_g, Lex_g, L_g = inp
        y_inter = jnp.einsum("bthn,bhnv->bthv", r_in_g, st)
        pair = Lex_g[:, :, None] - L_g[:, None, :]  # (B, C, C, H, N)
        A = jnp.sum(
            jnp.where(mask, rc_g[:, :, None] * kc_g[:, None, :] * jnp.exp(pair), 0.0),
            axis=-1,
        )  # (B, C, C, H)
        diag = jnp.einsum("bthn,hn,bthn->bth", rc_g, u, kc_g)
        y_intra = jnp.einsum("btsh,bshn->bthn", A, v_g) + diag[..., None] * v_g
        kv = jnp.einsum("bthn,bthv->bhnv", k_out_g, v_g)
        st_new = wend_g[:, 0, :, :, None] * st + kv
        return st_new, y_inter + y_intra

    xs = tuple(
        jnp.moveaxis(t, 1, 0)
        for t in (rc, kc, vc, r_in, k_out, jnp.exp(Lend), Lex, L)
    )
    state, ys = jax.lax.scan(jax.checkpoint(body), state, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, NC, C, H, N)
    y = y.reshape(b, s, h, n)
    y = _head_norm(params, y, arch).astype(x.dtype) * g
    return y @ params["wo"].astype(x.dtype), state


def time_mix_step(params, x_t, x_prev, state, arch: ArchConfig):
    """Single-token decode step.

    x_t: (B, d) current token activations; x_prev: (B, d) previous token
    (token-shift state); state: (B, H, N, N).
    Returns (out (B, d), new_state).
    """
    b, d = x_t.shape
    h, n = arch.n_heads, arch.rwkv_head_dim
    r, k, v, g, log_w = _projections_step(params, x_t, x_prev, arch)
    u = params["u"].astype(jnp.float32).reshape(h, n)
    state, y = recurrence_step(state, r, k, v, log_w, u)
    y = _head_norm(params, y[:, None, :, :].reshape(b, 1, h, n), arch)
    y = y.reshape(b, h * n).astype(x_t.dtype) * g
    return y @ params["wo"].astype(x_t.dtype), state


def _projections_step(params, x_t, x_prev, arch: ArchConfig):
    """Single-token variant of _projections using explicit shift state."""
    b, d = x_t.shape
    h, n = arch.n_heads, arch.rwkv_head_dim
    dt = x_t.dtype
    sx = x_prev - x_t
    base = params["mix_base"].astype(dt)
    xxx = x_t + sx * base[0]
    mixed = {}
    for i, name in enumerate(MIX_NAMES):
        delta = jnp.tanh(xxx @ params["mix_lora_a"][i].astype(dt)) @ params[
            "mix_lora_b"
        ][i].astype(dt)
        mixed[name] = x_t + sx * (base[i] + delta)
    r = (mixed["r"] @ params["wr"].astype(dt)).reshape(b, h, n)
    k = (mixed["k"] @ params["wk"].astype(dt)).reshape(b, h, n)
    v = (mixed["v"] @ params["wv"].astype(dt)).reshape(b, h, n)
    g = jax.nn.silu((mixed["g"] @ params["wg"].astype(dt)).astype(jnp.float32))
    dd = params["decay_base"].astype(jnp.float32) + (
        jnp.tanh(mixed["w"] @ params["decay_lora_a"].astype(dt))
        @ params["decay_lora_b"].astype(dt)
    ).astype(jnp.float32)
    log_w = -jnp.exp(jnp.clip(dd, -8.0, 8.0)).reshape(b, h, n)
    return r, k, v, g.astype(dt), log_w


# ----------------------------------------------------------------------------
# channel mix (squared-ReLU)
# ----------------------------------------------------------------------------


def init_channel_params(key, arch: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = arch.d_model, arch.d_ff
    return {
        "mix_k": jnp.zeros((d,), common.PARAM_DTYPE) + 0.5,
        "mix_r": jnp.zeros((d,), common.PARAM_DTYPE) + 0.5,
        "wk": common.dense_init(k1, d, f),
        "wr": common.dense_init(k2, d, d),
        "wv": common.dense_init(k3, f, d),
    }


def channel_mix(params, x: jnp.ndarray, x_prev: jnp.ndarray = None):
    """RWKV channel mixing: r = sigmoid, k = relu^2. Shapes (B, S, d)."""
    dt = x.dtype
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xk = x + (x_prev - x) * params["mix_k"].astype(dt)
    xr = x + (x_prev - x) * params["mix_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ params["wk"].astype(dt)))
    r = jax.nn.sigmoid((xr @ params["wr"].astype(dt)).astype(jnp.float32))
    return r.astype(dt) * (k @ params["wv"].astype(dt))
