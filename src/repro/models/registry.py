"""Model registry: param counting and arch-level helpers.

Param counts are derived from ``jax.eval_shape`` over the real initializer —
exact by construction, no hand-maintained formulas to drift.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer


@functools.lru_cache(maxsize=64)
def _param_shapes(arch: ArchConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        lambda k: transformer.init_params(k, arch), key
    )


def param_count(arch: ArchConfig, active_only: bool = False) -> int:
    shapes = _param_shapes(arch)
    total = sum(
        int(np.prod(leaf.shape)) for leaf in jax.tree_util.tree_leaves(shapes)
    )
    if active_only and arch.moe is not None:
        moe = arch.moe
        inactive_per_layer = (
            3 * arch.d_model * moe.d_expert * (moe.num_experts - moe.top_k)
        )
        total -= inactive_per_layer * arch.n_layers
    return total


def embedding_params(arch: ArchConfig) -> int:
    n = arch.vocab_size * arch.d_model
    return n if arch.tie_embeddings else 2 * n


def non_embedding_params(arch: ArchConfig, active_only: bool = False) -> int:
    return param_count(arch, active_only) - embedding_params(arch)


def model_flops_per_token(arch: ArchConfig, kind: str) -> float:
    """MODEL_FLOPS term for §Roofline.

    train: 6 * N (dense) or 6 * N_active (MoE) per token
    prefill/decode: 2 * N(_active) per token (forward only).
    Attention score FLOPs are excluded by convention (they are the
    'overhead' the usefulness ratio exposes).
    """
    n = param_count(arch, active_only=True) - (
        arch.vocab_size * arch.d_model  # input embedding gather is not a matmul
    )
    mult = 6.0 if kind == "train" else 2.0
    return mult * n
