"""GQA attention: chunked online-softmax (flash-style) in pure jnp.

Scores are never materialized at (S, S): a lax.scan over KV blocks carries
the running (max, sum-exp, accumulator) triple, so peak memory is
O(S * kv_block * heads_per_device) — this is what lets the 32k-sequence
cells fit the dry-run memory budget.  The scan body is checkpointed so the
backward pass recomputes block scores instead of stacking them.

Causal masking baseline computes all KV blocks and masks (predictable HLO
FLOPs, ~2x the useful triangle); the block-skipping variant is a §Perf
hillclimb (see EXPERIMENTS.md).

GQA: queries (B, S, H, D) grouped as (B, S, Hkv, G, D) against (B, S, Hkv, D)
keys/values — any H/Hkv ratio, including MQA (Hkv=1).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.sharding import ctx as shardctx

NEG_INF = -1e30


def init_params(key, arch: ArchConfig):
    kq, kk, kv, ko, kn1, kn2 = jax.random.split(key, 6)
    d, hd = arch.d_model, arch.head_dim
    p = {
        "wq": common.dense_init(kq, d, arch.n_heads * hd),
        "wk": common.dense_init(kk, d, arch.n_kv_heads * hd),
        "wv": common.dense_init(kv, d, arch.n_kv_heads * hd),
        "wo": common.dense_init(ko, arch.n_heads * hd, d),
    }
    if arch.qk_norm:
        p["q_norm"] = jnp.ones((hd,), common.PARAM_DTYPE)
        p["k_norm"] = jnp.ones((hd,), common.PARAM_DTYPE)
    return p


def _block_mask(
    q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: Optional[int]
) -> jnp.ndarray:
    """(..., Sq, Sk) bool: True where q may attend k (causal [+ window])."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    q_pos: jnp.ndarray,  # (B, Sq) int32
    k_pos: jnp.ndarray,  # (B, Sk) int32
    *,
    window: Optional[int] = None,
    kv_block: int = 512,
) -> jnp.ndarray:
    """Chunked causal(+windowed) attention; returns (B, Sq, H, D).

    GQA keys/values are repeated up to the full head count before the score
    einsum (Megatron-style KV replication within the TP group): the head
    axis then shards cleanly over 'model' for ANY head count, where the
    grouped (Hkv, G) formulation defeats SPMD propagation at the uneven
    reshape and silently replicates the whole mixer (§Perf iteration 1).
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    scale = 1.0 / np.sqrt(d)

    kv_block = min(kv_block, sk)
    if sk % kv_block != 0:
        raise ValueError(f"seq_len {sk} must divide kv_block {kv_block}")
    n_blocks = sk // kv_block

    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    bsh = ("batch", None, "model", None)
    q = shardctx.constrain(q, bsh)
    k = shardctx.constrain(k, bsh)
    v = shardctx.constrain(v, bsh)

    kb = k.reshape(b, n_blocks, kv_block, h, d)
    vb = v.reshape(b, n_blocks, kv_block, h, d)
    kpb = k_pos.reshape(b, n_blocks, kv_block)

    def body(carry, blk):
        m_run, l_run, acc = carry
        kj, vj, kp = blk  # (B, kvb, H, D), (B, kvb, H, D), (B, kvb)
        s = jnp.einsum(
            "bqhd,bkhd->bqhk", q, kj, preferred_element_type=jnp.float32
        ) * scale  # (B, Sq, H, kvb) f32
        mask = _block_mask(q_pos, kp, window)  # (B, Sq, kvb)
        s = jnp.where(mask[:, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bqhk,bkhd->bqhd",
            p.astype(q.dtype),
            vj,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, sq, h), NEG_INF, jnp.float32),
        jnp.zeros((b, sq, h), jnp.float32),
        jnp.zeros((b, sq, h, d), jnp.float32),
    )
    xs = (
        jnp.moveaxis(kb, 1, 0),
        jnp.moveaxis(vb, 1, 0),
        jnp.moveaxis(kpb, 1, 0),
    )
    (m_run, l_run, acc), _ = jax.lax.scan(jax.checkpoint(body), init, xs)
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return out.astype(q.dtype)


def qkv_project(params, x: jnp.ndarray, arch: ArchConfig):
    """x (B, S, d) -> q (B,S,H,D), k/v (B,S,Hkv,D) with optional qk-norm."""
    b, s, _ = x.shape
    hd = arch.head_dim
    dt = x.dtype
    bsh = ("batch", None, "model", None)
    q = shardctx.constrain(
        (x @ params["wq"].astype(dt)).reshape(b, s, arch.n_heads, hd), bsh
    )
    k = shardctx.constrain(
        (x @ params["wk"].astype(dt)).reshape(b, s, arch.n_kv_heads, hd), bsh
    )
    v = shardctx.constrain(
        (x @ params["wv"].astype(dt)).reshape(b, s, arch.n_kv_heads, hd), bsh
    )
    if arch.qk_norm:
        q = common.head_rms_norm(q, params["q_norm"], arch.norm_eps)
        k = common.head_rms_norm(k, params["k_norm"], arch.norm_eps)
    return q, k, v


def apply_positions(q, k, positions, arch: ArchConfig):
    """RoPE or M-RoPE on q and k.

    positions: (B, S) for RoPE, (3, B, S) for M-RoPE.
    """
    if arch.mrope:
        q = common.apply_mrope(q, positions, arch.rope_theta)
        k = common.apply_mrope(k, positions, arch.rope_theta)
    else:
        q = common.apply_rope(q, positions, arch.rope_theta)
        k = common.apply_rope(k, positions, arch.rope_theta)
    return q, k


def self_attention(
    params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    arch: ArchConfig,
    *,
    window: Optional[int] = None,
    kv_block: int = 512,
) -> jnp.ndarray:
    """Full-sequence causal self-attention (train / prefill path)."""
    q, k, v = qkv_project(params, x, arch)
    q, k = apply_positions(q, k, positions, arch)
    flat_pos = positions[0] if arch.mrope else positions  # mask uses temporal
    out = flash_attention(
        q, k, v, flat_pos, flat_pos, window=window, kv_block=kv_block
    )
    b, s, _, _ = out.shape
    return out.reshape(b, s, -1) @ params["wo"].astype(x.dtype)


def reference_attention(
    params, x, positions, arch: ArchConfig, *, window=None
) -> jnp.ndarray:
    """Naive O(S^2)-memory oracle used by tests to validate flash_attention."""
    q, k, v = qkv_project(params, x, arch)
    q, k = apply_positions(q, k, positions, arch)
    flat_pos = positions[0] if arch.mrope else positions
    b, s, h, d = q.shape
    hkv = arch.n_kv_heads
    qg = q.reshape(b, s, hkv, h // hkv, d)
    scores = jnp.einsum(
        "bqhgd,bkhd->bqhgk", qg, k, preferred_element_type=jnp.float32
    ) / np.sqrt(d)
    mask = _block_mask(flat_pos, flat_pos, window)
    scores = jnp.where(mask[:, :, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bqhgk,bkhd->bqhgd", p.astype(x.dtype), v,
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(b, s, h, d).astype(x.dtype)
    return out.reshape(b, s, -1) @ params["wo"].astype(x.dtype)
