"""Model zoo: 10-arch decoder backbone + mixers."""
