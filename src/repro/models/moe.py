"""Mixture-of-Experts channel mixer (olmoe 64e/top-8, mixtral 8e/top-2).

TPU-native capacity-based dispatch: routing is expressed as two one-hot
einsums (dispatch / combine tensors) so the expert FFNs run as dense batched
matmuls on the MXU — no gather/scatter on the hot path.  Experts shard over
the 'model' mesh axis when the expert count divides it (EP, olmoe), else the
per-expert hidden dim shards (TP-within-expert, mixtral).  Aux load-balance
loss follows Switch/ST-MoE.

Router-collapse telemetry: the (token, expert) assignment stream is exposed
for the HLL datapath tap (DESIGN.md §4) — distinct-pair cardinality dropping
far below tokens*top_k indicates collapse.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common


def init_params(key, arch: ArchConfig):
    moe = arch.moe
    kr, kg, ku, kd = jax.random.split(key, 4)
    d, e, f = arch.d_model, moe.num_experts, moe.d_expert
    scale_in, scale_out = d ** -0.5, f ** -0.5
    return {
        "router": common.dense_init(kr, d, e),
        "gate": jax.random.normal(kg, (e, d, f), common.PARAM_DTYPE) * scale_in,
        "up": jax.random.normal(ku, (e, d, f), common.PARAM_DTYPE) * scale_in,
        "down": jax.random.normal(kd, (e, f, d), common.PARAM_DTYPE) * scale_out,
    }


def moe_mixer(
    params, x: jnp.ndarray, arch: ArchConfig
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux_loss (), assignment (B,S,top_k) int32).

    Grouped dispatch: routing/capacity runs independently per group (one
    group per sequence), so the one-hot dispatch tensor is (G, Tg, E, Cg)
    with Cg = capacity per group — total cost LINEAR in tokens.  A single
    global capacity pool would make the dispatch einsum T*E*C ~ T^2
    (measured: 2.1 TiB/device on mixtral train_4k — EXPERIMENTS.md §Perf
    iteration 2); per-group capacity is the standard TPU MoE formulation
    (Switch/GShard groups) and also shards cleanly: groups follow the batch
    axes, experts follow 'model'.
    """
    moe = arch.moe
    b, s, d = x.shape
    n_tok = b * s
    e, k = moe.num_experts, moe.top_k
    tg = min(s, 4096)  # tokens per routing group
    n_groups = n_tok // tg
    capacity = int(moe.capacity_factor * tg * k / e)
    if tg <= 256:
        capacity = tg * k  # tiny groups (decode/tests): drop-free routing
    capacity = max(capacity, k)

    xt = x.reshape(n_groups, tg, d)
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)

    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each (token, choice) within its expert queue (per group)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (G, Tg, k, E)
    flat_onehot = onehot.reshape(n_groups, tg * k, e)
    pos_in_expert = jnp.cumsum(flat_onehot, axis=1) - flat_onehot
    pos_in_expert = jnp.sum(
        pos_in_expert.reshape(n_groups, tg, k, e) * onehot, axis=-1
    )  # (G, Tg, k)
    keep = pos_in_expert < capacity

    # dispatch (G, Tg, E, C) / combine weights
    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos_in_expert, capacity), capacity, dtype=x.dtype
    )  # (G, Tg, k, C); dropped tokens map nowhere
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum(
        "gtke,gtkc,gtk->gtec",
        onehot.astype(jnp.float32),
        pos_oh.astype(jnp.float32),
        gate_vals,
    ).astype(x.dtype)

    # expert compute: (G, E, C, d) batched SwiGLU
    xe = jnp.einsum("gtec,gtd->gecd", disp, xt)
    g = jnp.einsum("gecd,edf->gecf", xe, params["gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, params["up"].astype(x.dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("gecf,efd->gecd", act, params["down"].astype(x.dtype))
    out = jnp.einsum("gtec,gecd->gtd", comb, ye)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(
        onehot[:, :, 0].astype(jnp.float32), axis=(0, 1)
    )  # top-1
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    return out.reshape(b, s, d), aux, expert_idx.reshape(b, s, k)


def assignment_stream(token_ids: jnp.ndarray, expert_idx: jnp.ndarray) -> jnp.ndarray:
    """(token, expert) pairs packed into int32 words for the HLL tap.

    token_ids (B, S), expert_idx (B, S, k) -> (B*S*k,) int32 where the low 8
    bits carry the expert and the rest the token id — distinct-pair
    cardinality tracks router diversity.
    """
    t = token_ids[..., None].astype(jnp.int32)
    pairs = (t << 8) | expert_idx.astype(jnp.int32)
    return pairs.reshape(-1)
