"""Shared building blocks: norms, rotary embeddings (RoPE / M-RoPE), SwiGLU.

Everything is a pure function over parameter pytrees (plain dicts), bf16
activations with f32 accumulation/norm statistics, shaped for scan-over-
layers stacking (see models/transformer.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

ACT_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), PARAM_DTYPE) * scale)


def embed_init(key, vocab: int, dim: int):
    return jax.random.normal(key, (vocab, dim), PARAM_DTYPE) * 0.02


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def head_rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    """qk-norm (qwen3): RMS over head_dim of (..., heads, head_dim)."""
    return rms_norm(x, weight, eps)


# ----------------------------------------------------------------------------
# rotary embeddings
# ----------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for standard RoPE; (head_dim/2,) f32."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """Rotate (..., S, H, D) by per-position angles; positions (..., S)."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float, sections=(2, 1, 1)
) -> jnp.ndarray:
    """M-RoPE (qwen2-vl): rotary split into (temporal, h, w) sections.

    positions: (3, ..., S) int32 — one position stream per section.
    ``sections`` are relative shares of the head_dim/2 frequency slots,
    qwen2-vl uses (16, 24, 24)/64ths ~ here (2,1,1)/4ths of D/2.
    """
    d = x.shape[-1]
    half = d // 2
    total = sum(sections)
    splits = [half * s // total for s in sections]
    splits[-1] = half - sum(splits[:-1])
    inv = rope_frequencies(d, theta)  # (D/2,)

    # build per-slot positions by section
    pieces = []
    start = 0
    for sec_idx, width in enumerate(splits):
        pos = positions[sec_idx]  # (..., S)
        ang = pos[..., None].astype(jnp.float32) * inv[start : start + width]
        pieces.append(ang)
        start += width
    angles = jnp.concatenate(pieces, axis=-1)  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------------


def swiglu_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff),
        "up": dense_init(k2, d_model, d_ff),
        "down": dense_init(k3, d_ff, d_model),
    }


def swiglu(params, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    g = x @ params["gate"].astype(dt)
    u = x @ params["up"].astype(dt)
    return (jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u) @ params[
        "down"
    ].astype(dt)
