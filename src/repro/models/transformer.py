"""Decoder-only backbone assembly for all 10 assigned architectures.

One composable definition covers every family:

  dense / moe / audio / vlm : attention mixer (+SWA / M-RoPE / qk-norm)
  ssm (rwkv6)               : RWKV6 time-mix + squared-ReLU channel mix
  hybrid (recurrentgemma)   : (rec, rec, attn) pattern, RG-LRU + local attn

Layers are grouped into *stages* — (pattern, repeats) pairs — and each stage
runs as one lax.scan over stacked parameters with a checkpointed body, so
compile time and HLO size stay flat in depth (qwen2-vl's 80 layers compile
as fast as smollm's 32).  Hybrids scan over whole patterns; leftover layers
form a trailing mini-stage.

Modality frontends (audio frames / vision patches) are stubs by assignment:
``frontend_embeds`` enter as precomputed (B, stub_len, d) activations that
overwrite the leading token embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, common, moe as moe_lib, rglru, rwkv6
from repro.sharding import ctx as shardctx


# ----------------------------------------------------------------------------
# stage structure
# ----------------------------------------------------------------------------


def layer_stages(arch: ArchConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """[(sublayer pattern, repeats)] covering exactly n_layers layers."""
    if arch.block_pattern is None:
        kind = "rwkv" if arch.mixer == "rwkv6" else "attn"
        return [((kind,), arch.n_layers)]
    pat = tuple(arch.block_pattern)
    full = arch.n_layers // len(pat)
    rem = arch.n_layers - full * len(pat)
    stages: List[Tuple[Tuple[str, ...], int]] = [(pat, full)]
    if rem:
        stages.append((tuple(pat[:rem]), 1))
    return stages


def _sublayer_window(kind: str, arch: ArchConfig) -> Optional[int]:
    if arch.block_pattern is not None and kind == "attn":
        return arch.local_window
    return arch.sliding_window


# ----------------------------------------------------------------------------
# parameter init
# ----------------------------------------------------------------------------


def _init_sublayer(key, kind: str, arch: ArchConfig):
    km, kc, kn1, kn2 = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "norm1": jnp.ones((arch.d_model,), common.PARAM_DTYPE),
        "norm2": jnp.ones((arch.d_model,), common.PARAM_DTYPE),
    }
    if kind == "attn":
        p["mixer"] = attention.init_params(km, arch)
    elif kind == "rec":
        p["mixer"] = rglru.init_params(km, arch)
    elif kind == "rwkv":
        p["mixer"] = rwkv6.init_params(km, arch)
    else:
        raise ValueError(f"unknown sublayer kind {kind!r}")

    if arch.moe is not None:
        p["channel"] = moe_lib.init_params(kc, arch)
    elif kind == "rwkv":
        p["channel"] = rwkv6.init_channel_params(kc, arch)
    else:
        p["channel"] = common.swiglu_init(kc, arch.d_model, arch.d_ff)
    return p


def init_params(key, arch: ArchConfig):
    """Full model params; per-stage sublayer params stacked for scan."""
    keys = jax.random.split(key, 4 + len(layer_stages(arch)))
    params: Dict[str, Any] = {
        "embed": common.embed_init(keys[0], arch.vocab_size, arch.d_model),
        "final_norm": jnp.ones((arch.d_model,), common.PARAM_DTYPE),
    }
    if not arch.tie_embeddings:
        params["lm_head"] = common.dense_init(
            keys[1], arch.d_model, arch.vocab_size
        )
    for si, (pattern, repeats) in enumerate(layer_stages(arch)):
        stage_key = keys[3 + si]

        def init_one(k):
            sub_keys = jax.random.split(k, len(pattern))
            return {
                f"sub{j}": _init_sublayer(sub_keys[j], kind, arch)
                for j, kind in enumerate(pattern)
            }

        layer_keys = jax.random.split(stage_key, repeats)
        params[f"stage{si}"] = jax.vmap(init_one)(layer_keys)
    return params


# ----------------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------------


def _apply_sublayer(kind, sub, x, positions, arch, collect_state):
    """Pre-norm residual sublayer. Returns (x, aux_loss, state_or_None)."""
    h = common.rms_norm(x, sub["norm1"], arch.norm_eps)
    state = None
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        mixed = attention.self_attention(
            sub["mixer"], h, positions, arch,
            window=_sublayer_window(kind, arch),
        )
        if collect_state:
            q, k, v = attention.qkv_project(sub["mixer"], h, arch)
            _, k = attention.apply_positions(q, k, positions, arch)
            state = {"k": k, "v": v}
    elif kind == "rec":
        if collect_state:
            mixed, rec_state = rglru.block(sub["mixer"], h, arch, return_state=True)
            state = {"conv": rec_state.conv, "h": rec_state.h}
        else:
            mixed = rglru.block(sub["mixer"], h, arch)
    else:  # rwkv
        if arch.rwkv_chunk_size > 0:
            mixed, rwkv_state = rwkv6.time_mix_chunked(
                sub["mixer"], h, arch, chunk=arch.rwkv_chunk_size
            )
        else:
            mixed, rwkv_state = rwkv6.time_mix(sub["mixer"], h, arch)
        if collect_state:
            state = {"s": rwkv_state, "x_prev": h[:, -1]}
    x = x + mixed

    h2 = common.rms_norm(x, sub["norm2"], arch.norm_eps)
    if arch.moe is not None:
        ch, aux, _ = moe_lib.moe_mixer(sub["channel"], h2, arch)
    elif kind == "rwkv":
        ch = rwkv6.channel_mix(sub["channel"], h2)
        if collect_state:
            state = dict(state or {}, cm_x_prev=h2[:, -1])
    else:
        ch = common.swiglu(sub["channel"], h2)
    out = x + ch
    hints = shardctx.get_hints()
    if hints is not None and hints.seq_parallel:
        out = shardctx.constrain(out, ("batch", "model", None))
    return out, aux, state


def embed_tokens(params, batch, arch: ArchConfig) -> jnp.ndarray:
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(common.ACT_DTYPE)
    if arch.frontend_stub_len > 0 and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(common.ACT_DTYPE)
        stub = fe.shape[1]
        x = jnp.concatenate([fe, x[:, stub:]], axis=1)
    return x


def default_positions(arch: ArchConfig, batch_size: int, seq: int):
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch_size, seq))
    if arch.mrope:
        return jnp.broadcast_to(pos, (3, batch_size, seq))
    return pos


def forward(
    params, batch, arch: ArchConfig, *, collect_state: bool = False
):
    """Full-sequence forward.

    Returns (logits (B, S, V), aux_loss, states) — states is a per-stage
    list of stacked sublayer caches when collect_state (prefill), else None.
    """
    x = embed_tokens(params, batch, arch)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(arch, b, s)

    total_aux = jnp.zeros((), jnp.float32)
    all_states = [] if collect_state else None

    for si, (pattern, repeats) in enumerate(layer_stages(arch)):
        stage_params = params[f"stage{si}"]

        def body(carry, layer_params, _pattern=pattern):
            xc, aux = carry
            states = {}
            for j, kind in enumerate(_pattern):
                xc, aux_j, st = _apply_sublayer(
                    kind, layer_params[f"sub{j}"], xc, positions, arch,
                    collect_state,
                )
                aux = aux + aux_j
                if collect_state:
                    states[f"sub{j}"] = st
            return (xc, aux), states if collect_state else None

        (x, total_aux), stage_states = jax.lax.scan(
            jax.checkpoint(body), (x, total_aux), stage_params
        )
        if collect_state:
            all_states.append(stage_states)

    x = common.rms_norm(x, params["final_norm"], arch.norm_eps)
    head = (
        params["embed"].T if arch.tie_embeddings else params["lm_head"]
    ).astype(x.dtype)
    logits = x @ head
    return logits, total_aux, all_states


# ----------------------------------------------------------------------------
# loss
# ----------------------------------------------------------------------------


def loss_fn(params, batch, arch: ArchConfig, aux_weight: float = 0.01):
    logits, aux, _ = forward(params, batch, arch)
    targets = batch["targets"]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1
    ).squeeze(-1)
    nll = jnp.mean(logz - tgt_logit)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}
