"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: proj-in (x-branch + GeLU gate branch) -> causal depthwise conv1d
(width 4) -> RG-LRU diagonal gated recurrence -> gated proj-out.

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
diagonal linear scan -> computed with jax.lax.associative_scan (parallel
prefix) over the sequence: O(log S) depth, MXU/VPU friendly — the TPU-native
choice Griffin itself makes.  a_t = exp(c * r_t * log sigmoid(lambda)) with
c = 8 keeps log a_t <= 0 for stability.

Decode keeps (conv window, h) as the recurrent cache — O(1) per token, which
is what qualifies this arch for the long_500k cell.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.sharding import ctx as shardctx

C_FACTOR = 8.0


class RGLRUState(NamedTuple):
    conv: jnp.ndarray  # (B, conv_width-1, d) trailing inputs
    h: jnp.ndarray  # (B, d) recurrent state (f32)


def init_params(key, arch: ArchConfig):
    d = arch.d_model
    keys = jax.random.split(key, 6)
    return {
        "w_x": common.dense_init(keys[0], d, d),
        "w_gate": common.dense_init(keys[1], d, d),
        "conv_w": jax.random.normal(keys[2], (arch.conv_width, d), common.PARAM_DTYPE)
        * (1.0 / arch.conv_width),
        "conv_b": jnp.zeros((d,), common.PARAM_DTYPE),
        # recurrence gates
        "w_a": common.dense_init(keys[3], d, d),
        "w_i": common.dense_init(keys[4], d, d),
        # lambda parameterized so sigmoid(lambda) ~ 0.9..0.999
        "lam": jnp.linspace(2.0, 6.0, d).astype(common.PARAM_DTYPE),
        "w_out": common.dense_init(keys[5], d, d),
    }


def _gates(params, xc: jnp.ndarray):
    """Recurrence gate computation on conv output xc (..., d). f32."""
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32))
    log_a = C_FACTOR * r * jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed via exp/log1p for stability near a ~ 1
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * i * xf


def _causal_conv(params, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d over (B, S, d), width w (static unroll)."""
    w = params["conv_w"].shape[0]
    out = x * params["conv_w"][w - 1].astype(x.dtype)
    shifted = x
    for i in range(1, w):
        shifted = jnp.pad(shifted, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        out = out + shifted * params["conv_w"][w - 1 - i].astype(x.dtype)
    return out + params["conv_b"].astype(x.dtype)


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t via associative parallel prefix over axis 1."""

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def block(params, x: jnp.ndarray, arch: ArchConfig, *, return_state: bool = False):
    """Full-sequence recurrent block. x (B, S, d) -> (B, S, d).

    With ``return_state`` also returns the decode-resumable RGLRUState
    (trailing conv window + final hidden state).
    """
    dt = x.dtype
    bsd = ("batch", None, "model")
    gate = jax.nn.gelu(
        shardctx.constrain(x @ params["w_gate"].astype(dt), bsd).astype(jnp.float32)
    )
    xb = shardctx.constrain(x @ params["w_x"].astype(dt), bsd)
    xc = _causal_conv(params, xb)
    a, b = _gates(params, xc)
    a = shardctx.constrain(a, bsd)
    b = shardctx.constrain(b, bsd)
    h = rglru_scan(a, b)  # (B, S, d) f32
    out = (h * gate).astype(dt) @ params["w_out"].astype(dt)
    if not return_state:
        return out
    w = params["conv_w"].shape[0]
    state = RGLRUState(conv=xb[:, -(w - 1) :].astype(common.ACT_DTYPE), h=h[:, -1])
    return out, state


def block_step(
    params, x_t: jnp.ndarray, state: RGLRUState, arch: ArchConfig
) -> Tuple[jnp.ndarray, RGLRUState]:
    """Single-token decode step. x_t (B, d); returns (out, new_state)."""
    dt = x_t.dtype
    gate = jax.nn.gelu((x_t @ params["w_gate"].astype(dt)).astype(jnp.float32))
    xb = x_t @ params["w_x"].astype(dt)
    # conv over (state.conv ++ xb)
    w = params["conv_w"].shape[0]
    window = jnp.concatenate([state.conv, xb[:, None, :]], axis=1)  # (B, w, d)
    xc = jnp.einsum("bwd,wd->bd", window.astype(dt), params["conv_w"].astype(dt))
    xc = xc + params["conv_b"].astype(dt)
    a, b = _gates(params, xc)
    h = a * state.h + b  # (B, d) f32
    out = (h * gate).astype(dt) @ params["w_out"].astype(dt)
    return out, RGLRUState(conv=window[:, 1:], h=h)


def init_state(batch: int, arch: ArchConfig) -> RGLRUState:
    return RGLRUState(
        conv=jnp.zeros((batch, arch.conv_width - 1, arch.d_model), common.ACT_DTYPE),
        h=jnp.zeros((batch, arch.d_model), jnp.float32),
    )
