"""Version-compat shims for the installed jax.

The repo targets current jax APIs but must run on older releases (this
container ships 0.4.x): ``jax.shard_map`` and its ``check_vma`` kwarg landed
after 0.4.x, where the same function lives under ``jax.experimental`` with a
``check_rep`` kwarg.  Mesh axis-type compat lives in
``repro.launch.mesh.make_auto_mesh``.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _SHARD_MAP_KW = {"check_rep": False}


def shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with the replication/VMA check disabled, on any jax."""
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **_SHARD_MAP_KW
    )
