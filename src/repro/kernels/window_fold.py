"""Pallas TPU kernel: masked ring fold for a (W, B, m) windowed bank.

A sliding-window estimate over a ``WindowedBank`` is one reduction: fold
the live time buckets of the (W, B, m) ring into a scratch (B, m) bank by
bucket-wise max, then finalize with the batched estimator (DESIGN.md §11).
The FPGA sliding-window sketches this mirrors (arXiv:2504.16896) keep one
BRAM bank per time slice and OR/merge the live slices on query; the TPU
analogue folds the ring axis with the VPU.

The grid tiles the BANK over row blocks exactly the way ``bank_scatter``
does — each grid step owns ``row_block`` whole sketches whose
``row_block * m`` registers stay resident in a VMEM scratch accumulator —
and sweeps the W ring slices in the inner grid dimension.  Expired buckets
(and suffix windows shorter than W) are neutralized by a (W,) mask: a
masked slice contributes rank 0, the identity of the bucket max, so every
suffix window is bit-identical to merging its buckets one by one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
# row_block * m VMEM-resident cells per grid step (the bank_scatter cap,
# applied to the fold side of the window).
MAX_BLOCK_CELLS = 1 << 12


def _window_kernel(mask_ref, ring_ref, out_ref, scratch_ref):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        scratch_ref[...] = jnp.zeros_like(scratch_ref)

    # masked slices fold as 0, the identity of the bucket max
    contrib = jnp.where(mask_ref[...] > 0, ring_ref[0], 0)
    scratch_ref[...] = jnp.maximum(scratch_ref[...], contrib)

    @pl.when(w == pl.num_programs(1) - 1)
    def _flush():
        out_ref[...] = scratch_ref[...]


@functools.partial(jax.jit, static_argnames=("m", "row_block", "interpret"))
def window_fold_max(
    ring: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    m: int,
    row_block: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fold a (W, B, m) int32 ring into (B, m) by masked bucket-wise max.

    ``ring`` is (W, B, m) int32 with B divisible by ``row_block``; ``mask``
    is (W,) int32 where nonzero marks a live bucket.  See
    ``sketch.backends.window_fold`` for the wrapper that owns padding,
    dtype casts, and block sizing.
    """
    if ring.ndim != 3:
        raise ValueError(f"ring must be (W, B, m), got {ring.shape}")
    window, bank_rows, got_m = ring.shape
    if got_m != m:
        raise ValueError(f"ring is (W, B, {got_m}), expected m={m}")
    if bank_rows % row_block != 0:
        raise ValueError(f"row_block ({row_block}) must divide B ({bank_rows})")
    if row_block * m > MAX_BLOCK_CELLS:
        raise ValueError(
            f"row_block*m = {row_block * m} exceeds the VMEM cell cap "
            f"{MAX_BLOCK_CELLS}; use the jnp fold for large banks"
        )
    if mask.shape != (window,):
        raise ValueError(f"mask must be ({window},), got {mask.shape}")

    row_blocks = bank_rows // row_block
    cells = row_block * m
    # the (W, row_blocks, cells) layout keeps every reshape outside the kernel
    ring3d = ring.reshape(window, row_blocks, cells)
    grid = (row_blocks, window)
    out = pl.pallas_call(
        _window_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda j, w: (w, 0)),
            pl.BlockSpec((1, 1, cells), lambda j, w: (w, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, cells), lambda j, w: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((row_blocks, cells), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, cells), jnp.int32)],
        interpret=interpret,
    )(mask.astype(jnp.int32).reshape(window, 1), ring3d)
    return out.reshape(bank_rows, m)


@functools.partial(jax.jit, static_argnames=("m", "row_block", "interpret"))
def window_merge_max(
    parts: jnp.ndarray,
    *,
    m: int,
    row_block: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fold a (K, B, m) int32 stack of fold fragments into (B, m) by max.

    The incremental-merge entry point of the prefix/suffix window
    decomposition (DESIGN.md §14): where ``window_fold_max`` sweeps W ring
    slices per query, the decomposed read path merges K fragments with K
    tiny and independent of W — the prefix-stack top, the running suffix
    accumulator, and the dirty head bucket.  A merge IS a W=K fold with
    every slice live, so this reuses the masked ring sweep with an
    all-ones mask and inherits its bit-identity to the bucket-by-bucket
    reference for free.
    """
    if parts.ndim != 3:
        raise ValueError(f"parts must be (K, B, m), got {parts.shape}")
    return window_fold_max(
        parts,
        jnp.ones((parts.shape[0],), jnp.int32),
        m=m,
        row_block=row_block,
        interpret=interpret,
    )
