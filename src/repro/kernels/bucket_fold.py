"""Pallas TPU kernel: the paper's "Merge buckets" module.

Folds k partial sketches (one per pipeline / lane-group / device) into one
register array by bucket-wise max — the complexity "of a fold" (paper §V-B).
Registers are streamed through VMEM in (k, block_m) tiles; the k-way max is
one VPU reduction per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_M = 2048


def _fold_kernel(partials_ref, out_ref):
    out_ref[...] = jnp.max(partials_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def bucket_fold(
    partials: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fold (k, m) int32 partial registers into (m,) by element-wise max.

    m must be a multiple of min(block_m, m); the block is clamped for small
    sketches.
    """
    if partials.ndim != 2:
        raise ValueError(f"partials must be (k, m), got {partials.shape}")
    k, m = partials.shape
    bm = min(block_m, m)
    if m % bm != 0:
        raise ValueError(f"m ({m}) must divide block_m ({bm})")

    grid = (m // bm,)
    return pl.pallas_call(
        _fold_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, bm), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), partials.dtype),
        interpret=interpret,
    )(partials)
