"""Pallas TPU kernel: fused Murmur3 hash + index/rank extraction.

The paper's pipeline front end (hash function -> index extractor -> leading
zero detector, Fig. 2) as one VPU kernel.  Hashes are never materialized to
HBM — each tile of input words is hashed in VMEM/VREGs and only the (idx,
rank) pair the aggregation needs is written back, the same locality the FPGA
dataflow gets from its stream handshake.

64-bit hashing uses the uint32-limb math from core/u64.py: TPU has no native
u64, so the 64-bit multiplies decompose into 16-bit partial products — the
DSP-slice mapping of the paper, re-expressed for 32-bit vector lanes.

Tiling: items are shaped (rows, 128); each grid step processes a
(block_rows, 128) tile.  128 lanes is the VPU vector width; block_rows is a
multiple of 8 (sublanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.sketch import hll
from repro.sketch.hll import HLLConfig

LANES = 128
DEFAULT_BLOCK_ROWS = 64  # 64 x 128 = 8192 items / grid step


def _hash_rank_kernel(items_ref, idx_ref, rank_ref, *, cfg: HLLConfig):
    """One tile: murmur3 -> split -> clz, all element-wise in VREGs."""
    items = items_ref[...]
    idx, rank = hll.hash_index_rank(items, cfg)
    idx_ref[...] = idx.astype(jnp.int32)
    rank_ref[...] = rank.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("cfg", "block_rows", "interpret")
)
def hash_rank(
    items: jnp.ndarray,
    cfg: HLLConfig,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    """Hash a (rows, 128) uint32/int32 array into (idx, rank) int32 arrays.

    rows must be a multiple of block_rows; use repro.sketch.backends.hash_rank for the
    padding/reshaping convenience wrapper over flat streams.
    """
    if items.ndim != 2 or items.shape[1] != LANES:
        raise ValueError(f"items must be (rows, {LANES}), got {items.shape}")
    rows = items.shape[0]
    if rows % block_rows != 0:
        raise ValueError(f"rows ({rows}) must divide block_rows ({block_rows})")

    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, LANES), jnp.int32)
    return pl.pallas_call(
        functools.partial(_hash_rank_kernel, cfg=cfg),
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(items.astype(jnp.uint32))
