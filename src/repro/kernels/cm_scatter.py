"""Pallas TPU kernels: keyed scatter-ADD into a (B, d, w) count-min bank.

The bank_scatter kernel folds a keyed HLL stream into a register bank with
a chunked one-hot compare-reduce over the block's flattened cell space;
this module is its additive mirror for the count-min family (DESIGN.md
§13).  A count-min ingest lands d increments per item — one per depth row,
at column ``r*w + idx_r`` of the row's flattened (d, w) counter slab — so
the wrapper repeats each stream element d times and this kernel sums the
resulting (key, cell, hit) stream into ``row_block`` whole counter slabs
held VMEM-resident for the entire sweep.

Where the max-lattice neutralizes padding with rank 0, the sum-lattice
neutralizes it with hit 0 (the additive identity): padding and foreign
keys arrive pre-masked to ``val = 0`` and aim at cell 0 as a no-op.
Counter arithmetic is int32 two's-complement, bit-identical to the uint32
wraparound of the jnp reference (the wrapper bitcasts in and out).

``cm_window_fold_sum`` is the fourth sibling of ``window_fold``: the same
masked ring fold over a (W, B, d*w) counter ring, with + replacing max
(an expired bucket contributes 0, the additive identity).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_BLOCK_ROWS = 8
DEFAULT_CHUNK = 128
# row_block * d * w VMEM-resident cells per grid step (the bank_scatter
# cap applied to count-min slabs: d=4, w=1024 fits exactly one row).
MAX_BLOCK_CELLS = 1 << 12


def _cm_kernel(
    keys_ref,
    col_ref,
    val_ref,
    counters_in_ref,
    out_ref,
    scratch_ref,
    *,
    cells_per_row: int,
    row_block: int,
    block_rows: int,
    chunk: int,
):
    jb = pl.program_id(0)  # bank row block
    step = pl.program_id(1)  # item tile

    @pl.when(step == 0)
    def _init():
        scratch_ref[...] = counters_in_ref[...]

    keys = keys_ref[...]  # (block_rows, LANES)
    local = keys - jb * row_block
    owned = (local >= 0) & (local < row_block)
    # hit 0 is the identity of the cell sum, so entries owned by other row
    # blocks (and padding, pre-masked to val 0 by the wrapper) are no-ops
    # aimed at cell 0.
    val = jnp.where(owned, val_ref[...], 0)
    col = jnp.where(owned, local * cells_per_row + col_ref[...], 0)

    tile = block_rows * LANES
    col_flat = col.reshape(tile)
    val_flat = val.reshape(tile)
    cells = row_block * cells_per_row
    cell_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, cells), 1)

    def body(i, _):
        cs = jax.lax.dynamic_slice(col_flat, (i * chunk,), (chunk,))
        vs = jax.lax.dynamic_slice(val_flat, (i * chunk,), (chunk,))
        onehot = jnp.where(cs[:, None] == cell_ids, vs[:, None], 0)
        contrib = jnp.sum(onehot, axis=0, keepdims=True)  # (1, cells)
        scratch_ref[...] = scratch_ref[...] + contrib
        return 0

    jax.lax.fori_loop(0, tile // chunk, body, 0)

    @pl.when(step == pl.num_programs(1) - 1)
    def _flush():
        out_ref[...] = scratch_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("cells_per_row", "row_block", "block_rows", "chunk", "interpret"),
)
def cm_scatter_add(
    counters: jnp.ndarray,
    keys: jnp.ndarray,
    col: jnp.ndarray,
    val: jnp.ndarray,
    *,
    cells_per_row: int,
    row_block: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Sum a precomputed (key, cell, hit) stream into a (B, d*w) bank.

    ``counters`` is (B, cells_per_row) int32 with B divisible by
    ``row_block``; ``keys``/``col``/``val`` are (rows, LANES) int32 tiles
    of the d-expanded stream (rows divisible by ``block_rows``).  Padding
    and foreign keys must arrive pre-masked to val 0 — see
    ``sketch.backends.cm_update`` for the wrapper that owns hashing,
    d-expansion, tiling, and masking.
    """
    bank_rows, got_cells = counters.shape
    if got_cells != cells_per_row:
        raise ValueError(
            f"counters are (B, {got_cells}), expected d*w={cells_per_row}"
        )
    if bank_rows % row_block != 0:
        raise ValueError(f"row_block ({row_block}) must divide B ({bank_rows})")
    if row_block * cells_per_row > MAX_BLOCK_CELLS:
        raise ValueError(
            f"row_block*d*w = {row_block * cells_per_row} exceeds the VMEM "
            f"cell cap {MAX_BLOCK_CELLS}; use the jnp scatter path instead"
        )
    if keys.shape != col.shape or keys.shape != val.shape:
        raise ValueError("keys/col/val tile shapes must match")
    rows = keys.shape[0]
    if keys.ndim != 2 or keys.shape[1] != LANES:
        raise ValueError(f"stream tiles must be (rows, {LANES}), got {keys.shape}")
    if rows % block_rows != 0:
        raise ValueError(f"block_rows ({block_rows}) must divide rows ({rows})")
    if (block_rows * LANES) % chunk != 0:
        raise ValueError("chunk must divide the item tile size")

    row_blocks = bank_rows // row_block
    cells = row_block * cells_per_row
    # the (row_blocks, cells) layout keeps every reshape outside the kernel
    cnt2d = counters.reshape(row_blocks, cells)
    grid = (row_blocks, rows // block_rows)
    stream_spec = pl.BlockSpec((block_rows, LANES), lambda j, i: (i, 0))
    bank_spec = pl.BlockSpec((1, cells), lambda j, i: (j, 0))
    out = pl.pallas_call(
        functools.partial(
            _cm_kernel,
            cells_per_row=cells_per_row,
            row_block=row_block,
            block_rows=block_rows,
            chunk=chunk,
        ),
        grid=grid,
        in_specs=[stream_spec, stream_spec, stream_spec, bank_spec],
        out_specs=bank_spec,
        out_shape=jax.ShapeDtypeStruct((row_blocks, cells), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, cells), jnp.int32)],
        interpret=interpret,
    )(
        keys.astype(jnp.int32),
        col.astype(jnp.int32),
        val.astype(jnp.int32),
        cnt2d,
    )
    return out.reshape(bank_rows, cells_per_row)


def _cm_fold_kernel(mask_ref, ring_ref, out_ref, scratch_ref):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        scratch_ref[...] = jnp.zeros_like(scratch_ref)

    # masked slices fold as 0, the identity of the cell sum
    contrib = jnp.where(mask_ref[...] > 0, ring_ref[0], 0)
    scratch_ref[...] = scratch_ref[...] + contrib

    @pl.when(w == pl.num_programs(1) - 1)
    def _flush():
        out_ref[...] = scratch_ref[...]


@functools.partial(
    jax.jit, static_argnames=("cells_per_row", "row_block", "interpret")
)
def cm_window_fold_sum(
    ring: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    cells_per_row: int,
    row_block: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fold a (W, B, d*w) int32 counter ring into (B, d*w) by masked sum.

    ``ring`` is (W, B, d*w) int32 with B divisible by ``row_block``;
    ``mask`` is (W,) int32 where nonzero marks a live bucket.  See
    ``sketch.backends.cm_window_fold`` for the wrapper that owns padding,
    bitcasts, and block sizing.
    """
    if ring.ndim != 3:
        raise ValueError(f"ring must be (W, B, d*w), got {ring.shape}")
    window, bank_rows, got_cells = ring.shape
    if got_cells != cells_per_row:
        raise ValueError(
            f"ring is (W, B, {got_cells}), expected d*w={cells_per_row}"
        )
    if bank_rows % row_block != 0:
        raise ValueError(f"row_block ({row_block}) must divide B ({bank_rows})")
    if row_block * cells_per_row > MAX_BLOCK_CELLS:
        raise ValueError(
            f"row_block*d*w = {row_block * cells_per_row} exceeds the VMEM "
            f"cell cap {MAX_BLOCK_CELLS}; use the jnp fold instead"
        )
    if mask.shape != (window,):
        raise ValueError(f"mask must be ({window},), got {mask.shape}")

    row_blocks = bank_rows // row_block
    cells = row_block * cells_per_row
    ring3d = ring.reshape(window, row_blocks, cells)
    grid = (row_blocks, window)
    out = pl.pallas_call(
        _cm_fold_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda j, w: (w, 0)),
            pl.BlockSpec((1, 1, cells), lambda j, w: (w, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, cells), lambda j, w: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((row_blocks, cells), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, cells), jnp.int32)],
        interpret=interpret,
    )(mask.astype(jnp.int32).reshape(window, 1), ring3d)
    return out.reshape(bank_rows, cells_per_row)
