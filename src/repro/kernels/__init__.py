"""Pallas TPU kernels for the paper's compute hot-spots (+ jnp oracles).

``repro.kernels.ops`` is a deprecated shim over ``repro.sketch.backends``;
it is resolved lazily here so that importing the kernel primitives
(hash_rank / hll_fused / bucket_fold / ref) never triggers its
DeprecationWarning or a circular import through repro.sketch.
"""

import importlib


def __getattr__(name):
    if name in ("ops", "ref"):
        return importlib.import_module(f"repro.kernels.{name}")
    raise AttributeError(f"module 'repro.kernels' has no attribute {name!r}")
