"""Pallas TPU kernels for the paper's compute hot-spots (+ jnp oracles)."""

from repro.kernels import ops, ref  # noqa: F401
