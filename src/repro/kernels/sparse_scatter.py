"""Pallas TPU kernel: dedup-scatter of sparse-destined items into COO row blocks.

Fourth sibling of ``bank_scatter``/``window_fold``/``cm_scatter``: the
HybridBank (DESIGN.md §12) defers sparse-row dedup into an append buffer and
compacts under pressure; this kernel is the compaction's scatter phase.  The
(row, bucket, rank) triple stream — existing COO pairs re-emitted as triples
plus the newly hashed append buffer — sweeps a grid tiled over *bank row
blocks*, exactly like ``bank_scatter`` tiles ingest, but the VMEM-resident
tile here is the row block's bucket -> max-rank pair map (dense-addressed so
the TPU's chunked one-hot compare-reduce can stand in for the random
read-modify-write port it does not have), initialized to zero instead of
carrying registers in.

At the final item tile the kernel flushes two outputs per row block: the
deduped pair tile itself (``row_block * m`` int32 cells; the host-side COO
compaction reads the surviving ``(bucket, max rank)`` pairs back out of it in
bucket order) and the per-row distinct-bucket counts (one in-VMEM popcount
over the tile), which is everything promotion detection needs — no second
pass over the stream.  Cost is O(items * row_block * m) VPU compares per row
block: the small-m trade again, so the cap mirrors ``MAX_BLOCK_CELLS``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_BLOCK_ROWS = 8
DEFAULT_CHUNK = 128
# row_block * m VMEM-resident pair cells per grid step (same budget as the
# bank_scatter accumulator).
MAX_BLOCK_CELLS = 1 << 12


def _sparse_kernel(
    keys_ref,
    idx_ref,
    rank_ref,
    pairs_ref,
    count_ref,
    scratch_ref,
    *,
    m: int,
    row_block: int,
    block_rows: int,
    chunk: int,
):
    jb = pl.program_id(0)  # bank row block
    step = pl.program_id(1)  # item tile

    @pl.when(step == 0)
    def _init():
        # unlike bank_scatter there are no incoming registers: the pair
        # tile starts empty and the stream alone decides the survivors
        scratch_ref[...] = jnp.zeros_like(scratch_ref)

    keys = keys_ref[...]  # (block_rows, LANES)
    local = keys - jb * row_block
    owned = (local >= 0) & (local < row_block)
    # rank 0 is the identity of the bucket max, so items owned by other row
    # blocks (and padding, pre-masked to rank 0 by the wrapper) are no-ops
    # aimed at cell 0.
    rank = jnp.where(owned, rank_ref[...], 0)
    col = jnp.where(owned, local * m + idx_ref[...], 0)

    tile = block_rows * LANES
    col_flat = col.reshape(tile)
    rank_flat = rank.reshape(tile)
    cell_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, row_block * m), 1)

    def body(i, _):
        cs = jax.lax.dynamic_slice(col_flat, (i * chunk,), (chunk,))
        rs = jax.lax.dynamic_slice(rank_flat, (i * chunk,), (chunk,))
        onehot = jnp.where(cs[:, None] == cell_ids, rs[:, None], 0)
        contrib = jnp.max(onehot, axis=0, keepdims=True)  # (1, row_block*m)
        scratch_ref[...] = jnp.maximum(scratch_ref[...], contrib)
        return 0

    jax.lax.fori_loop(0, tile // chunk, body, 0)

    @pl.when(step == pl.num_programs(1) - 1)
    def _flush():
        pairs_ref[...] = scratch_ref[...]
        tile2d = scratch_ref[...].reshape(row_block, m)
        count_ref[...] = jnp.sum(
            (tile2d > 0).astype(jnp.int32), axis=1
        ).reshape(1, row_block)


@functools.partial(
    jax.jit,
    static_argnames=("rows", "m", "row_block", "block_rows", "chunk", "interpret"),
)
def sparse_scatter_coo(
    keys: jnp.ndarray,
    idx: jnp.ndarray,
    rank: jnp.ndarray,
    *,
    rows: int,
    m: int,
    row_block: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> tuple:
    """Dedup a routed (key, bucket, rank) stream into per-row pair maps.

    ``keys``/``idx``/``rank`` are (tile_rows, LANES) int32 tiles of the
    triple stream (tile_rows divisible by ``block_rows``); ``rows`` is the
    bank's row count, divisible by ``row_block``.  Padding and foreign keys
    must arrive pre-masked to rank 0 — see ``sketch.backends.sparse_merge``
    for the wrapper that owns tiling and masking.  Returns the (rows, m)
    int32 max-rank cells and the (rows,) int32 distinct-bucket counts.
    """
    if rows % row_block != 0:
        raise ValueError(f"row_block ({row_block}) must divide rows ({rows})")
    if row_block * m > MAX_BLOCK_CELLS:
        raise ValueError(
            f"row_block*m = {row_block * m} exceeds the VMEM cell cap "
            f"{MAX_BLOCK_CELLS}; use the jnp dedup path for large banks"
        )
    if keys.shape != idx.shape or keys.shape != rank.shape:
        raise ValueError("keys/idx/rank tile shapes must match")
    if keys.ndim != 2 or keys.shape[1] != LANES:
        raise ValueError(
            f"stream tiles must be (rows, {LANES}), got {keys.shape}"
        )
    tile_rows = keys.shape[0]
    if tile_rows % block_rows != 0:
        raise ValueError(
            f"block_rows ({block_rows}) must divide tile rows ({tile_rows})"
        )
    if (block_rows * LANES) % chunk != 0:
        raise ValueError("chunk must divide the item tile size")

    row_blocks = rows // row_block
    cells = row_block * m
    grid = (row_blocks, tile_rows // block_rows)
    stream_spec = pl.BlockSpec((block_rows, LANES), lambda j, i: (i, 0))
    pairs, counts = pl.pallas_call(
        functools.partial(
            _sparse_kernel,
            m=m,
            row_block=row_block,
            block_rows=block_rows,
            chunk=chunk,
        ),
        grid=grid,
        in_specs=[stream_spec, stream_spec, stream_spec],
        out_specs=[
            pl.BlockSpec((1, cells), lambda j, i: (j, 0)),
            pl.BlockSpec((1, row_block), lambda j, i: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((row_blocks, cells), jnp.int32),
            jax.ShapeDtypeStruct((row_blocks, row_block), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, cells), jnp.int32)],
        interpret=interpret,
    )(
        keys.astype(jnp.int32),
        idx.astype(jnp.int32),
        rank.astype(jnp.int32),
    )
    return pairs.reshape(rows, m), counts.reshape(rows)
