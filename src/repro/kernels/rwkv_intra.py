"""Pallas TPU kernel: RWKV6 intra-chunk attention-like quadratic form.

After the chunked reformulation (models/rwkv6.py::time_mix_chunked), the
dominant remaining HBM traffic in the rwkv train cells is the intra-chunk
pairwise tensor: XLA materializes exp(Lex_t - L_s) as a (B, C, C, H, N)
f32 array per chunk (EXPERIMENTS.md §Perf A iter 2/3).  On TPU this kernel
keeps the whole quadratic form in VMEM per (batch-chunk, head) grid cell:

    A[t,s] = sum_n r[t,n] k[s,n] exp(Lex[t,n] - L[s,n])     (s < t)
    diag[t] = sum_n r[t,n] u[n] k[t,n]
    y[t]   = sum_{s<t} A[t,s] v[s] + diag[t] v[t]

VMEM footprint per cell: 5 x (C,N) inputs + one (C,C,N) transient + (C,C)
scores + (C,N) output — ~1.2 MiB at C=N=64, far under the 16 MiB budget.
HBM traffic drops to the (C,N) inputs/outputs only: 6*C*N*4 bytes per cell
vs the XLA path's additional 3*C*C*N*4 transient round-trip (a ~22x
reduction of the intra term at C=64).

Exponents are relative decays (<= 0) — numerically safe for arbitrarily
strong data-dependent decay, same as the jnp reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intra_kernel(r_ref, k_ref, v_ref, lex_ref, l_ref, u_ref, y_ref):
    r = r_ref[0]  # (C, N) f32
    k = k_ref[0]
    v = v_ref[0]
    lex = lex_ref[0]
    lcum = l_ref[0]
    u = u_ref[...]  # (1, N)

    c = r.shape[0]
    # pairwise relative decay, strictly-lower-triangular mask
    pair = lex[:, None, :] - lcum[None, :, :]  # (C, C, N), all <= 0 for s < t
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    mask = (s_idx < t_idx)[:, :, None]
    prod = jnp.where(mask, r[:, None, :] * k[None, :, :] * jnp.exp(pair), 0.0)
    a = jnp.sum(prod, axis=-1)  # (C, C)
    diag = jnp.sum(r * u * k, axis=-1)  # (C,)
    y = jax.lax.dot(a, v, preferred_element_type=jnp.float32)
    y_ref[0] = y + diag[:, None] * v


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv_intra(
    r: jnp.ndarray,  # (G, C, N) f32 — G = batch*chunks*heads grid cells
    k: jnp.ndarray,
    v: jnp.ndarray,
    lex: jnp.ndarray,
    lcum: jnp.ndarray,
    u: jnp.ndarray,  # (G, N) per-cell bonus (head-dependent)
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Intra-chunk output (G, C, N); grid over G, everything else in VMEM."""
    g, c, n = r.shape
    spec = pl.BlockSpec((1, c, n), lambda i: (i, 0, 0))
    uspec = pl.BlockSpec((1, n), lambda i: (i, 0))
    f32 = lambda t: t.astype(jnp.float32)
    return pl.pallas_call(
        _intra_kernel,
        grid=(g,),
        in_specs=[spec, spec, spec, spec, spec, uspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((g, c, n), jnp.float32),
        interpret=interpret,
    )(f32(r), f32(k), f32(v), f32(lex), f32(lcum), f32(u))


def rwkv_intra_ref(r, k, v, lex, lcum, u):
    """Pure-jnp oracle (the math time_mix_chunked computes inline)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    pair = lex[:, :, None, :] - lcum[:, None, :, :]  # (G, C, C, N)
    c = r.shape[1]
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)[None, :, :, None]
    a = jnp.sum(
        jnp.where(mask, rf[:, :, None] * kf[:, None, :] * jnp.exp(pair), 0.0),
        axis=-1,
    )
    diag = jnp.einsum("gtn,gn,gtn->gt", rf, u.astype(jnp.float32), kf)
    return jnp.einsum("gts,gsn->gtn", a, vf) + diag[..., None] * vf
