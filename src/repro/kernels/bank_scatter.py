"""Pallas TPU kernel: keyed scatter-max into a stacked (B, m) register bank.

The FPGA engine time-multiplexes one aggregation datapath over many flows:
each arriving word carries a flow key, and the bucket update lands in that
flow's BRAM slice (arXiv:2504.16896 applies the same trick to sketch banks).
The TPU analogue for a multi-tenant bank: the (key, bucket, rank) stream is
precomputed once (the hash_rank kernel), and this kernel folds it into the
bank with the grid tiled over *bank rows* — exactly how ``bucket_fold``
tiles the m axis of a single sketch, except the tile here is a block of
``row_block`` whole sketches whose ``row_block * m`` registers stay resident
in a VMEM scratch accumulator for the entire item sweep.

TPU has no random read-modify-write port, so the update is the same chunked
one-hot compare-reduce as ``hll_fused``, widened to the block's flattened
(row, bucket) cell space: an item owned by the current row block selects
cell ``(key - block_start) * m + bucket``; items owned by other blocks (and
padding) are neutralized by forcing their rank to 0, the identity of the
bucket max.  Cost is O(items * row_block * m) VPU compares per row block —
the small-m trade again, which is why the bank cap mirrors ``MAX_FUSED_P``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_BLOCK_ROWS = 8
DEFAULT_CHUNK = 128
# row_block * m VMEM-resident cells per grid step (the hll_fused m <= 4096
# trade, applied to a block of sketches instead of one).
MAX_BLOCK_CELLS = 1 << 12


def _bank_kernel(
    keys_ref,
    idx_ref,
    rank_ref,
    regs_in_ref,
    out_ref,
    scratch_ref,
    *,
    m: int,
    row_block: int,
    block_rows: int,
    chunk: int,
):
    jb = pl.program_id(0)  # bank row block
    step = pl.program_id(1)  # item tile

    @pl.when(step == 0)
    def _init():
        scratch_ref[...] = regs_in_ref[...]

    keys = keys_ref[...]  # (block_rows, LANES)
    local = keys - jb * row_block
    owned = (local >= 0) & (local < row_block)
    # rank 0 is the identity of the bucket max, so items owned by other row
    # blocks (and padding, pre-masked to rank 0 by the wrapper) are no-ops
    # aimed at cell 0.
    rank = jnp.where(owned, rank_ref[...], 0)
    col = jnp.where(owned, local * m + idx_ref[...], 0)

    tile = block_rows * LANES
    col_flat = col.reshape(tile)
    rank_flat = rank.reshape(tile)
    cell_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, row_block * m), 1)

    def body(i, _):
        cs = jax.lax.dynamic_slice(col_flat, (i * chunk,), (chunk,))
        rs = jax.lax.dynamic_slice(rank_flat, (i * chunk,), (chunk,))
        onehot = jnp.where(cs[:, None] == cell_ids, rs[:, None], 0)
        contrib = jnp.max(onehot, axis=0, keepdims=True)  # (1, row_block*m)
        scratch_ref[...] = jnp.maximum(scratch_ref[...], contrib)
        return 0

    jax.lax.fori_loop(0, tile // chunk, body, 0)

    @pl.when(step == pl.num_programs(1) - 1)
    def _flush():
        out_ref[...] = scratch_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("m", "row_block", "block_rows", "chunk", "interpret"),
)
def bank_scatter_max(
    registers: jnp.ndarray,
    keys: jnp.ndarray,
    idx: jnp.ndarray,
    rank: jnp.ndarray,
    *,
    m: int,
    row_block: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fold a precomputed (key, bucket, rank) stream into a (B, m) bank.

    ``registers`` is (B, m) int32 with B divisible by ``row_block``;
    ``keys``/``idx``/``rank`` are (rows, LANES) int32 tiles of the routed
    stream (rows divisible by ``block_rows``).  Padding and foreign keys
    must arrive pre-masked to rank 0 — see ``sketch.backends.bank_update``
    for the wrapper that owns tiling and masking.
    """
    bank_rows, got_m = registers.shape
    if got_m != m:
        raise ValueError(f"registers are (B, {got_m}), expected m={m}")
    if bank_rows % row_block != 0:
        raise ValueError(f"row_block ({row_block}) must divide B ({bank_rows})")
    if row_block * m > MAX_BLOCK_CELLS:
        raise ValueError(
            f"row_block*m = {row_block * m} exceeds the VMEM cell cap "
            f"{MAX_BLOCK_CELLS}; use the jnp scatter path for large banks"
        )
    if keys.shape != idx.shape or keys.shape != rank.shape:
        raise ValueError("keys/idx/rank tile shapes must match")
    rows = keys.shape[0]
    if keys.ndim != 2 or keys.shape[1] != LANES:
        raise ValueError(f"stream tiles must be (rows, {LANES}), got {keys.shape}")
    if rows % block_rows != 0:
        raise ValueError(f"block_rows ({block_rows}) must divide rows ({rows})")
    if (block_rows * LANES) % chunk != 0:
        raise ValueError("chunk must divide the item tile size")

    row_blocks = bank_rows // row_block
    cells = row_block * m
    # the (row_blocks, cells) layout keeps every reshape outside the kernel
    regs2d = registers.reshape(row_blocks, cells)
    grid = (row_blocks, rows // block_rows)
    stream_spec = pl.BlockSpec((block_rows, LANES), lambda j, i: (i, 0))
    bank_spec = pl.BlockSpec((1, cells), lambda j, i: (j, 0))
    out = pl.pallas_call(
        functools.partial(
            _bank_kernel,
            m=m,
            row_block=row_block,
            block_rows=block_rows,
            chunk=chunk,
        ),
        grid=grid,
        in_specs=[stream_spec, stream_spec, stream_spec, bank_spec],
        out_specs=bank_spec,
        out_shape=jax.ShapeDtypeStruct((row_blocks, cells), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, cells), jnp.int32)],
        interpret=interpret,
    )(
        keys.astype(jnp.int32),
        idx.astype(jnp.int32),
        rank.astype(jnp.int32),
        regs2d,
    )
    return out.reshape(bank_rows, m)
