"""Pallas TPU kernel: fully-fused HLL aggregation pipeline (small-p sketches).

The FPGA keeps the *entire* aggregation phase on-chip: hash units feed bucket
BRAM with an II=1 read-max-write loop.  The TPU analogue for sketches whose
register file fits VMEM comfortably (p <= 12, m <= 4096): a grid over input
tiles with the registers held in a VMEM scratch accumulator for the whole
sweep — input words stream HBM->VMEM once, hashes/ranks/updates never touch
HBM, and the registers are written back exactly once at the end.

TPU has no random read-modify-write port, so the bucket update is expressed
as a chunked one-hot compare-reduce: a (chunk, m) equality mask against the
bucket iota selects each item's rank into its bucket column and a max over
the chunk axis merges the chunk — "updates to the same counter arriving
during the read-modify-write cycle are merged" (paper §V-A.4), except here
the merge window is the whole chunk.  Cost is O(items * m) VPU compares,
which is the right trade only for small m; for p=16 the scatter-based path
in sketch/hll.py is used instead (see DESIGN.md §2).

Padding items are neutralized by forcing their rank to 0: registers are
non-negative and max(r, 0) is the identity, so a rank-0 update is a no-op
by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.sketch import hll
from repro.sketch.hll import HLLConfig

LANES = 128
DEFAULT_BLOCK_ROWS = 8
DEFAULT_CHUNK = 128
MAX_FUSED_P = 12


def _fused_kernel(
    n_valid_ref,
    items_ref,
    regs_in_ref,
    out_ref,
    scratch_ref,
    *,
    cfg: HLLConfig,
    block_rows: int,
    chunk: int,
):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        scratch_ref[...] = regs_in_ref[...]

    items = items_ref[...]  # (block_rows, LANES)
    idx, rank = hll.hash_index_rank(items, cfg)

    # neutralize padding: global row-major position >= n_valid -> rank 0
    tile = block_rows * LANES
    pos = jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 0) * LANES
    pos = pos + jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 1)
    pos = pos + step * tile
    rank = jnp.where(pos < n_valid_ref[0, 0], rank, 0)

    idx_flat = idx.reshape(tile)
    rank_flat = rank.reshape(tile)
    bucket_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, cfg.m), 1)

    def body(i, _):
        ids = jax.lax.dynamic_slice(idx_flat, (i * chunk,), (chunk,))
        rks = jax.lax.dynamic_slice(rank_flat, (i * chunk,), (chunk,))
        onehot = jnp.where(ids[:, None] == bucket_ids, rks[:, None], 0)
        contrib = jnp.max(onehot, axis=0, keepdims=True)  # (1, m)
        scratch_ref[...] = jnp.maximum(scratch_ref[...], contrib)
        return 0

    jax.lax.fori_loop(0, tile // chunk, body, 0)

    @pl.when(step == pl.num_programs(0) - 1)
    def _flush():
        out_ref[...] = scratch_ref[...]


@functools.partial(
    jax.jit, static_argnames=("cfg", "block_rows", "chunk", "interpret")
)
def hll_update_fused(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    n_valid: jnp.ndarray,
    cfg: HLLConfig,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Aggregate (rows, 128) items into (1, m) int32 registers, fully fused.

    ``n_valid`` is a (1, 1) int32 array: items at flat positions >= n_valid
    are padding and are ignored.  Use kernels.ops.hll_update for the
    flat-stream convenience wrapper.
    """
    if cfg.p > MAX_FUSED_P:
        raise ValueError(
            f"fused pipeline supports p <= {MAX_FUSED_P} (m <= "
            f"{1 << MAX_FUSED_P}); use the scatter path for p={cfg.p}"
        )
    if items.ndim != 2 or items.shape[1] != LANES:
        raise ValueError(f"items must be (rows, {LANES}), got {items.shape}")
    rows = items.shape[0]
    if rows % block_rows != 0:
        raise ValueError(f"rows ({rows}) must divide block_rows ({block_rows})")
    if (block_rows * LANES) % chunk != 0:
        raise ValueError("tile size must divide chunk")
    if registers.shape != (1, cfg.m):
        raise ValueError(f"registers must be (1, {cfg.m}), got {registers.shape}")

    grid = (rows // block_rows,)
    full_regs = pl.BlockSpec((1, cfg.m), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(
            _fused_kernel, cfg=cfg, block_rows=block_rows, chunk=chunk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # n_valid
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),  # items
            full_regs,  # current registers
        ],
        out_specs=full_regs,
        out_shape=jax.ShapeDtypeStruct((1, cfg.m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, cfg.m), jnp.int32)],
        interpret=interpret,
    )(n_valid.astype(jnp.int32), items.astype(jnp.uint32), registers)
