"""Deprecated shim — the kernel wrappers moved to ``repro.sketch.backends``.

Use ``repro.sketch.update_registers`` with ``ExecutionPlan(backend="pallas")``
or ``backend="pallas_pipelined"`` instead of calling these directly.  One
behavioral unification: ``pipelined_update`` now defaults to the package-wide
``DEFAULT_PIPELINES`` (8) rather than 4.
"""

import warnings

warnings.warn(
    "repro.kernels.ops is deprecated; use repro.sketch (ExecutionPlan "
    "backends 'pallas' / 'pallas_pipelined') instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.sketch.backends import (  # noqa: F401,E402
    LANES,
    bucket_fold,
    hash_rank,
    hll_update,
    pipelined_update,
)
