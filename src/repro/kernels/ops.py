"""Jit'd public wrappers around the Pallas kernels.

These absorb the tiling details (padding flat streams to (rows, 128) tiles,
dtype casts, small-sketch block clamping) so callers see the same API shape
as the pure-jnp reference path in repro.core.

``interpret`` defaults to True on CPU (this container) and False on TPU,
where the Mosaic-compiled kernel runs.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hll
from repro.core.hll import HLLConfig
from repro.kernels import bucket_fold as _fold
from repro.kernels import hash_rank as _hash
from repro.kernels import hll_fused as _fused

LANES = _hash.LANES


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to_tiles(flat: jnp.ndarray, tile_items: int) -> Tuple[jnp.ndarray, int]:
    """Pad a flat stream up to a whole number of (block_rows, 128) tiles."""
    n = flat.shape[0]
    padded = -(-n // tile_items) * tile_items
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(padded // LANES, LANES), n


def hash_rank(
    items: jnp.ndarray,
    cfg: HLLConfig,
    *,
    block_rows: int = _hash.DEFAULT_BLOCK_ROWS,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused murmur3+rank of a flat item stream -> (idx, rank) int32 arrays."""
    interpret = _default_interpret() if interpret is None else interpret
    flat = items.reshape(-1)
    tiled, n = _pad_to_tiles(flat, block_rows * LANES)
    idx, rank = _hash.hash_rank(
        tiled, cfg, block_rows=block_rows, interpret=interpret
    )
    return idx.reshape(-1)[:n], rank.reshape(-1)[:n]


def bucket_fold(
    partials: jnp.ndarray,
    *,
    block_m: int = _fold.DEFAULT_BLOCK_M,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fold (k, m) partial registers (any int dtype) -> (m,) by max."""
    interpret = _default_interpret() if interpret is None else interpret
    out = _fold.bucket_fold(
        partials.astype(jnp.int32), block_m=block_m, interpret=interpret
    )
    return out.astype(partials.dtype)


def hll_update(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    *,
    block_rows: int = _fused.DEFAULT_BLOCK_ROWS,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fully-fused aggregation of a flat stream into (m,) uint8 registers.

    Small-p sketches only (p <= 12); the p=16 production sketch uses the
    scatter path in core/hll.py — see the kernel docstring for why.
    """
    interpret = _default_interpret() if interpret is None else interpret
    flat = items.reshape(-1)
    tiled, n = _pad_to_tiles(flat, block_rows * LANES)
    n_valid = jnp.full((1, 1), n, jnp.int32)
    regs2d = registers.astype(jnp.int32).reshape(1, cfg.m)
    out = _fused.hll_update_fused(
        regs2d, tiled, n_valid, cfg, block_rows=block_rows, interpret=interpret
    )
    return out.reshape(cfg.m).astype(hll.REGISTER_DTYPE)


def pipelined_update(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    pipelines: int = 4,
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Paper Fig. 3 built from the kernels: k fused pipelines + fold kernel.

    Slices the stream across ``pipelines`` sub-sketches, aggregates each with
    the fused kernel, folds partials with the bucket_fold kernel, and merges
    into the running registers.
    """
    interpret = _default_interpret() if interpret is None else interpret
    flat = items.reshape(-1)
    n = flat.shape[0]
    per = -(-n // pipelines)
    partials = []
    for k in range(pipelines):
        part = flat[k * per : (k + 1) * per]  # static slice; last may be short
        partials.append(
            hll_update(
                jnp.zeros((cfg.m,), hll.REGISTER_DTYPE), part, cfg,
                interpret=interpret,
            )
        )
    folded = bucket_fold(jnp.stack(partials), interpret=interpret)
    return jnp.maximum(registers, folded)
