"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each function computes exactly what the corresponding kernel computes, with
no Pallas, no tiling, no padding — used by tests/test_kernels.py sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.sketch import hll
from repro.sketch.hll import HLLConfig


def hash_rank_ref(items: jnp.ndarray, cfg: HLLConfig):
    """Oracle for kernels.hash_rank: (idx int32, rank int32), shape of items."""
    idx, rank = hll.hash_index_rank(items.reshape(-1), cfg)
    return idx.reshape(items.shape), rank.reshape(items.shape)


def bucket_fold_ref(partials: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.bucket_fold: max over the pipeline axis (k, m)->(m,)."""
    return jnp.max(partials, axis=0)


def hll_update_fused_ref(
    registers: jnp.ndarray, items: jnp.ndarray, cfg: HLLConfig
) -> jnp.ndarray:
    """Oracle for kernels.hll_update_fused: full aggregation phase."""
    return hll.update(registers, items, cfg)
