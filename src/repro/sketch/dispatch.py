"""The single aggregation entry point: update_registers(regs, items, cfg, plan).

One call replaces the five historical surfaces (core.hll.update,
core.sketch.update_pipelined / update_sharded / datapath_tap and
kernels.ops.hll_update / pipelined_update): the ``ExecutionPlan`` chooses the
backend and placement, and every plan yields bit-identical registers on the
same stream (DESIGN.md §3).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.obs import metrics as obs_metrics
from repro.sketch import hll
from repro.sketch.hll import HLLConfig
from repro.sketch.plan import (
    DEFAULT_PLAN,
    ExecutionPlan,
    get_backend,
    get_sparse_backend,
)


def mesh_fold(plan: ExecutionPlan, registers, arrays, apply_fn):
    """The mesh placement rule, shared by sketch and bank dispatch.

    ``arrays`` is a tuple of equal-length flat streams (the item stream;
    or the key + item streams for a bank, DESIGN.md §9).  Each is sharded
    over ``plan.data_axes``; every device applies ``apply_fn(registers,
    *local_arrays)`` to its shard and one lax.pmax folds the partial
    register states — the paper's Merge-buckets module as a single
    collective.  Registers come back replicated.  Streams that do not
    divide the mesh axes are edge-padded: zero-padding would sketch
    phantom elements, while repeating a real element (or (key, item)
    pair) cannot move any register — the lattice is idempotent
    (DESIGN.md §6) — so no plan ever raises on stream length.
    """
    axes = plan.data_axes
    shards = 1
    for a in axes:
        shards *= plan.mesh.shape[a]
    n = arrays[0].shape[0]
    padded = -(-n // shards) * shards
    if padded != n:
        arrays = tuple(
            jnp.pad(x, (0, padded - n), mode="edge") for x in arrays
        )

    def local(regs, *local_arrays):
        return jax.lax.pmax(apply_fn(regs, *local_arrays), axes)

    in_specs = (P(),) + (P(axes),) * len(arrays)
    return shard_map(
        local, mesh=plan.mesh, in_specs=in_specs, out_specs=P()
    )(registers, *arrays)


def _shard_count(plan: ExecutionPlan) -> int:
    shards = 1
    for a in plan.data_axes:
        shards *= plan.mesh.shape[a]
    return shards


def _block_index(plan: ExecutionPlan):
    """This device's row-block index: the flattened position over
    ``plan.data_axes`` in the same row-major order ``P(axes)`` shards by."""
    idx = jax.lax.axis_index(plan.data_axes[0])
    for a in plan.data_axes[1:]:
        idx = idx * plan.mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _spec_at(axes, dim: int, rank: int):
    """A PartitionSpec sharding dimension ``dim`` of a rank-``rank`` array
    over ``axes``, replicating every other dimension."""
    entries = [None] * rank
    entries[dim] = axes
    return P(*entries)


def row_shard_fold(plan: ExecutionPlan, registers, keys, arrays, apply_fn):
    """The sharded placement rule for keyed bank ingest (DESIGN.md §16).

    ``registers`` is a (B, ...) bank whose ROW axis splits into contiguous
    blocks over ``plan.data_axes``; ``keys`` and the ``arrays`` streams are
    replicated to every device.  Each device re-bases the key stream into
    block-local coordinates (``key - block_start``) and applies
    ``apply_fn(block, local_keys, *local_arrays)``: keys owned by another
    device fall outside [0, block_rows) and the §9 drop rule discards
    them, so cross-device key ROUTING is the drop rule itself — no
    gather, scatter, or collective moves a register.  Row counts that do
    not divide the shard count pad with phantom rows (valid keys are
    < B by the same rule, so nothing can land in them) and slice back.
    The union of the blocks is exactly one local update: bit-identity to
    placement="local" holds by construction, not by a fold.
    """
    shards = _shard_count(plan)
    rows = registers.shape[0]
    padded = -(-rows // shards) * shards
    regs = registers
    if padded != rows:
        regs = jnp.pad(
            registers, [(0, padded - rows)] + [(0, 0)] * (registers.ndim - 1)
        )
    out = _sharded_fold_callable(
        apply_fn, plan, padded // shards, regs.ndim, len(arrays)
    )(regs, keys, *arrays)
    return out[:rows] if padded != rows else out


@functools.lru_cache(maxsize=512)
def _sharded_fold_callable(apply_fn, plan, block_rows, rank, n_arrays):
    """The jitted shard-mapped ingest for one (fn, plan, geometry) key.

    Eager ``shard_map`` re-traces on every call when handed a fresh
    closure, which turns the serve loop's once-per-tick dispatch into a
    once-per-tick recompile.  Caching here keeps the serve path's steady
    state at one compile per shape; it only works because call sites
    pass IDENTITY-STABLE apply functions (themselves lru_cached on the
    values they close over) rather than inline lambdas.
    """

    def local(block, ks, *rest):
        return apply_fn(block, ks - _block_index(plan) * block_rows, *rest)

    in_specs = (_spec_at(plan.data_axes, 0, rank),) + (P(),) * (1 + n_arrays)
    return jax.jit(
        shard_map(
            local,
            mesh=plan.mesh,
            in_specs=in_specs,
            out_specs=_spec_at(plan.data_axes, 0, rank),
        )
    )


def row_shard_apply(plan: ExecutionPlan, fn, arrays, in_dims, out_dim: int = 0):
    """Apply a ROW-INDEPENDENT map block-wise under the sharded placement.

    The read-side companion of :func:`row_shard_fold`: ``fn`` maps each
    array's row block to a per-row result (batched estimate finalization,
    window ring folds — anything with no cross-row dataflow), so running
    it per block and concatenating is bit-identical to the unsharded
    call.  ``in_dims[i]`` names the row dimension of ``arrays[i]`` (None
    replicates the whole array); the output's row dimension is
    ``out_dim``.  Non-divisible row counts pad with phantom zero rows —
    inert under every row-wise map here — and slice back.
    """
    shards = _shard_count(plan)
    rows = next(
        a.shape[d] for a, d in zip(arrays, in_dims) if d is not None
    )
    padded = -(-rows // shards) * shards
    staged = []
    for a, d in zip(arrays, in_dims):
        if d is not None and padded != rows:
            pad = [(0, 0)] * a.ndim
            pad[d] = (0, padded - rows)
            a = jnp.pad(a, pad)
        staged.append(a)
    out_rank = jax.eval_shape(fn, *staged).ndim  # abstract: no FLOPs
    out = _sharded_apply_callable(
        fn,
        plan,
        tuple(in_dims),
        out_dim,
        tuple(a.ndim for a in staged),
        out_rank,
    )(*staged)
    if padded != rows:
        out = jax.lax.slice_in_dim(out, 0, rows, axis=out_dim)
    return out


@functools.lru_cache(maxsize=512)
def _sharded_apply_callable(fn, plan, in_dims, out_dim, ranks, out_rank):
    """Jitted shard-mapped row map, cached like the fold companion."""
    in_specs = tuple(
        _spec_at(plan.data_axes, d, r) if d is not None else P()
        for d, r in zip(in_dims, ranks)
    )
    return jax.jit(
        shard_map(
            fn,
            mesh=plan.mesh,
            in_specs=in_specs,
            out_specs=_spec_at(plan.data_axes, out_dim, out_rank),
        )
    )


def cm_mesh_sum(plan: ExecutionPlan, counters, arrays, apply_fn):
    """The mesh placement rule for ADDITIVE sketch state (count-min).

    ``mesh_fold`` edge-pads non-divisible streams because repeating a
    (key, item) pair cannot move a max-lattice register — but under a sum
    it would double-count.  Here padding fills the key stream with -1
    instead, which the §9 drop rule discards on every backend.  Each
    device ingests its shard into a ZERO counter bank, one lax.psum folds
    the per-device deltas, and the delta lands on the incoming counters
    exactly once, outside the collective.
    """
    axes = plan.data_axes
    shards = 1
    for a in axes:
        shards *= plan.mesh.shape[a]
    n = arrays[0].shape[0]
    padded = -(-n // shards) * shards
    if padded != n:
        keys, rest = arrays[0], arrays[1:]
        arrays = (jnp.pad(keys, (0, padded - n), constant_values=-1),) + tuple(
            jnp.pad(x, (0, padded - n)) for x in rest
        )
    zeros = jnp.zeros(counters.shape, counters.dtype)

    def local(z, *local_arrays):
        return jax.lax.psum(apply_fn(z, *local_arrays), axes)

    in_specs = (P(),) + (P(axes),) * len(arrays)
    delta = shard_map(
        local, mesh=plan.mesh, in_specs=in_specs, out_specs=P()
    )(zeros, *arrays)
    return counters + delta


def update_registers(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    plan: Optional[ExecutionPlan] = None,
) -> jnp.ndarray:
    """Aggregate ``items`` into ``registers`` under ``plan`` (Phase 3).

    placement="local": the backend runs on the caller's device(s) as-is.
    placement="mesh":  ``items`` is flattened and sharded over
    ``plan.data_axes`` through :func:`mesh_fold` (per-device aggregation
    + one all-reduce-max; edge-padding for non-divisible streams).
    placement="sharded" degrades to the mesh rule here: a single sketch
    has no row axis to split, and stream-sharding is bit-identical to
    local by the same lattice laws (DESIGN.md §16).
    """
    plan = (DEFAULT_PLAN if plan is None else plan).validate()
    backend = get_backend(plan.backend)
    flat = items.reshape(-1)
    if flat.shape[0] == 0:
        # an empty stream cannot move a register: skip the dispatch entirely
        # (skips are counted so the no-dispatch contract stays observable)
        obs_metrics.inc("dispatch.update.skipped_empty")
        return registers
    obs_metrics.observe("update.batch_items", flat.shape[0])
    if plan.placement == "local":
        return backend(registers, items, cfg, plan)
    return mesh_fold(
        plan, registers, (flat,), lambda regs, x: backend(regs, x, cfg, plan)
    )


def dedup_pairs(
    row: jnp.ndarray,
    bucket: jnp.ndarray,
    rank: jnp.ndarray,
    rows: int,
    cfg: HLLConfig,
    plan: Optional[ExecutionPlan] = None,
):
    """Dedup a (row, bucket, rank) triple stream under ``plan`` (DESIGN.md §12).

    The HybridBank compaction's dispatch seam, mirroring
    :func:`update_registers`: the sparse-capable backend registered under
    ``plan.backend`` (jnp adaptive sort/scatter, or the sparse_scatter
    Pallas kernel) collapses the combined live-pair + append-buffer stream
    to each row's distinct bucket -> max-rank map and per-row distinct
    counts, returned as a :class:`repro.sketch.plan.SparseDedup`.  The
    dedup always runs on the caller's device regardless of ``placement`` —
    compaction consumes host-resident COO state, so there is no stream to
    shard (mesh plans shard the *ingest* phases instead).  A backend with
    no sparse registration (e.g. a custom bank backend) falls back to the
    jnp dedup: every sparse path is bit-identical by contract, so the
    fallback cannot change the compacted state.
    """
    plan = (DEFAULT_PLAN if plan is None else plan).validate()
    try:
        backend = get_sparse_backend(plan.backend)
    except ValueError:
        obs_metrics.inc("dispatch.sparse_dedup.fallback")
        backend = get_sparse_backend("jnp")
    return backend(row, bucket, rank, rows, cfg, plan)


def datapath_tap(
    registers: jnp.ndarray, token_ids: jnp.ndarray, cfg: HLLConfig
) -> jnp.ndarray:
    """Sketch-on-the-datapath inside a jitted step (NIC analogue, DESIGN.md §2).

    Called from train_step/serve_step on tokens already resident on device;
    under pjit the segment_max partials and the replicated-output max-reduce
    are inserted by SPMD partitioning automatically.  Costs O(tokens) VPU
    ops + one (m,)-sized all-reduce — negligible next to model FLOPs.
    Equivalent to ``update_registers`` with the single-pipeline jnp plan.
    """
    return hll.update(registers, token_ids, cfg)
