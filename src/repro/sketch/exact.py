"""Baselines the paper compares against / falls back to.

* ``exact_distinct``     — ground-truth distinct count (host, sort-based).
* ``linear_counting``    — the LC bitmap estimator HLL reverts to at small
                           cardinalities (Algorithm 1 line 15), standalone.
* ``naive_distinct_mem`` — memory a naive exact set would need (paper §I's
                           motivation: linear in cardinality).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.sketch import murmur3
from repro.sketch.hll import HLLConfig


def exact_distinct(items) -> int:
    """Ground-truth cardinality (host-side)."""
    return int(np.unique(np.asarray(items).reshape(-1)).size)


def linear_counting_registers(items: jnp.ndarray, cfg: HLLConfig) -> jnp.ndarray:
    """Occupancy bitmap over m = 2^p hash buckets (uint8 0/1)."""
    h = murmur3.murmur3_32(items.reshape(-1), cfg.seed)
    idx = (h >> (32 - cfg.p)).astype(jnp.int32)
    seg = jax.ops.segment_max(
        jnp.ones_like(idx), idx, num_segments=cfg.m, indices_are_sorted=False
    )
    return jnp.maximum(seg, 0).astype(jnp.uint8)


def linear_counting_estimate(bitmap, m: int) -> float:
    v = int(m - np.count_nonzero(np.asarray(bitmap)))
    if v == 0:
        return float("inf")  # bitmap saturated; LC undefined
    return m * math.log(m / v)


def naive_distinct_mem_bytes(cardinality: int, item_bytes: int = 4) -> int:
    """Memory of an exact hash-set, the paper's strawman (linear in n)."""
    # 2x load-factor overhead, item + bucket pointer
    return int(cardinality * (item_bytes + 8) * 2)
