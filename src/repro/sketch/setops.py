"""Approximate set algebra over HLL sketches (beyond-paper extension).

The paper stops at single-stream cardinality.  Production deployments
(the BigQuery use-case it cites) routinely need set operations, and the
max-lattice gives two of them almost for free:

  union        exact at sketch level: |A ∪ B| = estimate(merge(A, B))
  intersection inclusion-exclusion: |A ∩ B| = |A| + |B| - |A ∪ B|
               (error grows with the Jaccard disparity — reported alongside)
  difference   |A \\ B| = |A ∪ B| - |B|

Each operation consumes only the 48 KiB register arrays — no re-streaming —
and finalizes through the pluggable estimator registry (``estimator=``,
DESIGN.md §8).  Inclusion-exclusion compounds the error of *three*
estimates, so the bias-free ``ertl_improved``/``ertl_mle`` finalizers
measurably shrink intersection/Jaccard error versus the threshold-corrected
``original``, especially near the linear-counting transition.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.sketch import hll
from repro.sketch.hll import HLLConfig


def _registers(x) -> jnp.ndarray:
    """Accept either a raw (m,) register array or a HyperLogLog carrier."""
    return getattr(x, "registers", x)


def union_estimate(
    a, b, cfg: HLLConfig, estimator: Optional[str] = None
) -> float:
    return hll.estimate(
        hll.merge(_registers(a), _registers(b)), cfg, estimator=estimator
    )


def intersection_estimate(
    a, b, cfg: HLLConfig, estimator: Optional[str] = None
) -> Tuple[float, float]:
    """Returns (|A ∩ B| estimate, standard-error bound of the estimate).

    Inclusion-exclusion over three HLL estimates; the absolute error is
    bounded by the sum of the three absolute errors, so the *relative*
    error blows up for small intersections — the returned bound makes that
    explicit so callers can reject unreliable readings.
    """
    a, b = _registers(a), _registers(b)
    ea = hll.estimate(a, cfg, estimator=estimator)
    eb = hll.estimate(b, cfg, estimator=estimator)
    eu = union_estimate(a, b, cfg, estimator=estimator)
    inter = max(0.0, ea + eb - eu)
    sigma = hll.standard_error(cfg)
    err_abs = sigma * (ea + eb + eu)
    return inter, err_abs


def difference_estimate(
    a, b, cfg: HLLConfig, estimator: Optional[str] = None
) -> float:
    """|A \\ B| >= 0 via union."""
    return max(
        0.0,
        union_estimate(a, b, cfg, estimator=estimator)
        - hll.estimate(_registers(b), cfg, estimator=estimator),
    )


def jaccard_estimate(
    a, b, cfg: HLLConfig, estimator: Optional[str] = None
) -> float:
    # inclusion-exclusion from one union merge + three finalizations
    # (delegating to intersection_estimate would finalize the union twice)
    a, b = _registers(a), _registers(b)
    ea = hll.estimate(a, cfg, estimator=estimator)
    eb = hll.estimate(b, cfg, estimator=estimator)
    eu = union_estimate(a, b, cfg, estimator=estimator)
    if eu <= 0:
        return float("nan")
    return max(0.0, ea + eb - eu) / eu
