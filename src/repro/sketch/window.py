"""WindowedBank: time-bucketed bank rings with fused sliding-window estimates.

Every query the flat carriers answer is "distinct items since the beginning
of time"; production traffic analytics asks "distinct users in the last 60
seconds".  The sliding-window FPGA follow-up (arXiv:2504.16896) keeps one
BRAM sketch slice per time bucket and merges the live slices on query —
this module is that structure over :class:`repro.sketch.bank.SketchBank`
primitives: a window is a ring of W time-bucket banks, and a windowed
estimate is ONE fused masked max-fold across the ring axis followed by the
existing batched ``estimate_many`` (estimator registry, DESIGN.md §8).

Ring/rotation contract (DESIGN.md §11):

* ``registers`` is (W, B, m): W time buckets of a B-row bank sharing one
  static ``HLLConfig``; ``n_items`` is (W, B, 2) exact per-bucket-per-row
  uint32 limb counters.
* ``epochs`` labels each slot with the absolute time bucket it holds;
  slot s always holds an epoch congruent to s modulo W, and the slot at
  ``cursor`` holds the newest epoch.  ``advance()`` rotates the cursor and
  zero-fills the slot it enters; ``advance_to(t)`` jumps forward any
  distance, expiring every overwritten bucket, with no python loop.
* ``observe(keys, items, plan)`` ingests into the CURRENT bucket through
  the same fused bank scatter as ``SketchBank.update_many`` (key-routing
  and drop rules of DESIGN.md §9 apply unchanged).
* ``estimate_window(last_k, plan)`` masks the k newest live epochs, folds
  the ring with the window backend registered under ``plan.backend``
  (``register_window_backend`` in plan.py), and finalizes the scratch
  (B, m) bank with one batched ``estimate_many`` — never a python loop
  over buckets or rows.  Every registered fold is bit-identical to
  merging the live buckets one by one (tests/test_window.py).

Incremental maintenance (DESIGN.md §14): the dense ring additionally
carries a host-side prefix/suffix fold decomposition so the full-window
read costs O(1) in W instead of refolding the (W, B, m) ring per query.
``advance()`` threads the decomposition forward in O(1) amortized per
rotation (the prefix stack rebuilds only once per W rotations),
``observe()`` leaves it untouched (the dirty head bucket is read live at
merge time), and a per-instance ``last_k`` fold cache — the same
immutable-instance memoization as ``HybridBank.compact``'s settled view
(DESIGN.md §12) — serves repeated reads without touching the ring.  All
of it is invisible state: instances stay 4-leaf jit-traceable pytrees,
and every cached or incremental read is bit-identical to the cold full
fold because register max is an associative, commutative, idempotent
lattice (DESIGN.md §6).

``MultiResWindowedBank`` is the long-horizon construction option: an
exponential histogram keeping the newest epochs at full resolution and
pairwise-merging older ones, so a ``base * (2**levels - 1)``-epoch
horizon costs O(base * levels) bucket slots instead of one slot per
epoch (DESIGN.md §14).  Its fold rides the same
``register_window_backend`` axis over the O(log horizon) bucket stack.

``to_bytes``/``from_bytes`` is the RHLW wire format: a 28-byte window
header + W int32 epoch labels + W per-bucket RHLB payloads, with the same
garbage/truncation rejection contract as RHLL/RHLB (DESIGN.md §7, §9).
Version 2 is the hybrid sparse ring; version 3 the multi-resolution ring.
"""

from __future__ import annotations

import dataclasses
import functools
import struct
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.sketch import hll
from repro.sketch.bank import SketchBank, _sharded_estimate_fn
from repro.sketch.dispatch import row_shard_apply
from repro.sketch.hll import HLLConfig
from repro.sketch.plan import (
    DEFAULT_PLAN,
    ExecutionPlan,
    get_window_backend,
    get_window_merge_backend,
)

_WINDOW_HEADER = struct.Struct("<4sBBBBQIII")
# magic, ver, p, H, flags, seed, W, B, cursor
_WINDOW_MAGIC = b"RHLW"
_WINDOW_VERSION = 1
_EPOCH = np.dtype("<i4")


def _initial_epochs(window: int) -> np.ndarray:
    """Epoch labels of a fresh ring at epoch 0: slot s holds the unique
    epoch in (0 - W, 0] congruent to s mod W (negative = never filled)."""
    slots = np.arange(window, dtype=np.int64)
    return (0 - np.mod(0 - slots, window)).astype(_EPOCH)


def _check_last_k_value(last_k: Optional[int], window: int) -> int:
    """Shared ``last_k`` validation for every ring flavor (dense, hybrid,
    multi-resolution) — one helper so the bound check and its error
    message cannot drift between carriers (tests/test_window_incremental.py
    pins the messages identical)."""
    if last_k is None:
        return window
    if not 1 <= int(last_k) <= window:
        raise ValueError(f"last_k must be in [1, {window}], got {last_k}")
    return int(last_k)


def _ring_fold(backend, ring, mask, cfg, plan: ExecutionPlan):
    """One masked ring fold under ``plan``'s placement.

    Folds are per-row maps over the bank axis (dim 1 of the (W, B, m)
    ring), so placement="sharded" runs the SAME backend on each device's
    row block (DESIGN.md §16) — bit-identical to the flat fold by row
    independence; every other placement folds the replicated ring as-is.
    """
    if plan.placement == "sharded":
        # the mask rides along replicated (in_dim None) so the cached
        # apply fn closes only over hashables — dispatch memoizes the
        # jitted shard_map per fn identity, and a per-call lambda would
        # force a re-trace on every serve-loop read
        return row_shard_apply(
            plan, _sharded_masked_fn(backend, cfg, plan), (ring, mask), (1, None)
        )
    return backend(ring, mask, cfg, plan)


@functools.lru_cache(maxsize=128)
def _sharded_masked_fn(backend, cfg, plan: ExecutionPlan):
    """Identity-stable (ring-block, mask) fold for the sharded cache."""

    def apply(ring, mask):
        return backend(ring, mask, cfg, plan)

    return apply


def _parts_merge(parts, cfg, plan: ExecutionPlan):
    """Merge (K, B, m) fold fragments under ``plan``'s placement — the
    sharded mirror of :func:`_ring_fold` for the §14 incremental read."""
    merge = get_window_merge_backend(plan.backend)
    if plan.placement == "sharded":
        return row_shard_apply(
            plan, _sharded_merge_fn(merge, cfg, plan), (parts,), (1,)
        )
    return merge(parts, cfg, plan)


@functools.lru_cache(maxsize=128)
def _sharded_merge_fn(merge, cfg, plan: ExecutionPlan):
    """Identity-stable fragment merge for the sharded cache."""

    def apply(parts):
        return merge(parts, cfg, plan)

    return apply


def _finalize_many(folded, cfg, plan: ExecutionPlan, estimator):
    """Batched finalization of a folded (B, m) scratch bank under
    ``plan``'s placement: sharded plans finalize per row block (§16),
    everything else in one flat dispatch (§8)."""
    from repro.sketch import estimators as _estimators

    name = estimator or plan.estimator
    if plan.placement == "sharded":
        return row_shard_apply(plan, _sharded_estimate_fn(cfg, name), (folded,), (0,))
    return _estimators.estimate_many(folded, cfg, estimator=name)


def _pack_limbs(totals: np.ndarray) -> np.ndarray:
    """(B,) uint64 exact counts -> (B, 2) uint32 hi/lo limb pairs."""
    return np.stack(
        [
            (totals >> np.uint64(32)).astype(np.uint32),
            totals.astype(np.uint32),
        ],
        axis=-1,
    )


class _RingReads:
    """Window reads shared verbatim by the dense and hybrid rings.

    Both carriers expose the same ``counts`` / ``_live_mask`` surface, so
    the exact-counter suffix sum and the ``last_k`` validation live here
    once instead of being copied per class.
    """

    def _check_last_k(self, last_k: Optional[int]) -> int:
        return _check_last_k_value(last_k, self.window)

    def window_counts(self, last_k: Optional[int] = None) -> np.ndarray:
        """(B,) exact observation counts over the last ``last_k`` epochs."""
        mask = np.asarray(self._live_mask(self._check_last_k(last_k)))
        return self.counts[mask].sum(axis=0, dtype=np.uint64)


@dataclasses.dataclass(frozen=True)
class _SuffixFold:
    """The prefix/suffix decomposition of a ring's CLOSED buckets.

    Host-side, non-pytree state stashed on a ``WindowedBank`` instance's
    ``__dict__`` (never a dataclass field — instances stay 4-leaf
    pytrees).  With the closed buckets ordered oldest → newest as
    a_1..a_C (C = W - 1; the bucket at ``cursor`` is the dirty head and
    never enters the decomposition):

    * ``prefix`` is the (C, B, m) suffix-fold stack built at the last
      rebuild: ``prefix[i] = fold(a_{i+1} .. a_F)`` over the front
      segment a_1..a_F.  Only the top entry ``prefix[head]`` is ever
      read; a rotation expires the oldest front bucket by bumping
      ``head`` — an O(1) pop.
    * ``suffix`` is the (B, m) running fold of every closed bucket NEWER
      than the front segment; each rotation folds the just-closed head
      bucket into it — one O(B·m) max, W-independent.
    * ``epoch`` is the absolute epoch this state describes; a mismatch
      (stale threading) forces a rebuild instead of a wrong answer.

    Full-window read = merge(prefix[head], suffix, ring[cursor]) through
    the ``register_window_merge_backend`` axis.  When ``head`` drains
    past the stack the next rotation rebuilds the stack from the ring —
    one reverse-cummax scan, so rebuilds cost O(W) only once per W
    rotations: O(1) amortized (DESIGN.md §14).
    """

    prefix: jnp.ndarray  # (C, B, m) suffix folds of the front segment
    head: int  # first live prefix entry; == C means the front is drained
    suffix: jnp.ndarray  # (B, m) fold of closed buckets newer than the front
    epoch: int  # absolute epoch the decomposition is valid for


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WindowedBank(_RingReads):
    """A (W, B, m) ring of time-bucket banks as one frozen pytree.

    Reads are incrementally maintained (DESIGN.md §14): instances carry a
    hidden prefix/suffix fold decomposition plus a per-instance ``last_k``
    fold cache in ``__dict__`` (mirroring ``HybridBank.compact``'s settled
    view, DESIGN.md §12), so steady-state ``estimate_window`` costs O(1)
    in W while staying bit-identical to the full ring fold.  The hidden
    state is dropped — never copied — by ``dataclasses.replace``, jit
    boundaries, and ``from_bytes``, which is exactly the invalidation
    rule: a new instance re-derives or re-threads what it can prove valid.
    """

    registers: jnp.ndarray  # (W, B, m) uint8
    n_items: jnp.ndarray  # (W, B, 2) uint32 limb pairs per bucket row
    cursor: jnp.ndarray  # () int32: ring slot of the newest epoch
    epochs: jnp.ndarray  # (W,) int32: absolute epoch held by each slot
    cfg: HLLConfig = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(
        cls, window: int, rows: int, cfg: Optional[HLLConfig] = None
    ) -> "WindowedBank":
        cfg = cfg or HLLConfig()
        if window < 1:
            raise ValueError(f"a window needs at least one bucket, got {window}")
        if rows < 1:
            raise ValueError(f"a bank needs at least one row, got {rows}")
        return cls(
            jnp.zeros((window, rows, cfg.m), hll.REGISTER_DTYPE),
            jnp.zeros((window, rows, 2), jnp.uint32),
            jnp.zeros((), jnp.int32),
            jnp.asarray(_initial_epochs(window)),
            cfg,
        )

    def with_rows(self, rows: int) -> "WindowedBank":
        """Grow the bank axis to ``rows`` (new rows start empty)."""
        have = self.rows
        if rows < have:
            raise ValueError(f"cannot shrink a {have}-row window to {rows}")
        if rows == have:
            return self
        pad = ((0, 0), (0, rows - have), (0, 0))
        return dataclasses.replace(
            self,
            registers=jnp.pad(self.registers, pad),
            n_items=jnp.pad(self.n_items, pad),
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def window(self) -> int:
        return int(self.registers.shape[0])

    @property
    def rows(self) -> int:
        return int(self.registers.shape[1])

    def __len__(self) -> int:
        return self.rows

    @property
    def epoch(self) -> int:
        """The newest (current) absolute epoch — host-side read."""
        return int(self.epochs[self.cursor])

    @property
    def counts(self) -> np.ndarray:
        """(W, B) exact per-bucket-per-row observation counts as uint64."""
        limbs = np.asarray(self.n_items)
        hi = limbs[..., 0].astype(np.uint64)
        lo = limbs[..., 1].astype(np.uint64)
        return (hi << np.uint64(32)) | lo

    def _live_mask(self, last_k: int) -> jnp.ndarray:
        """(W,) bool: slots holding one of the ``last_k`` newest epochs."""
        newest = self.epochs[self.cursor]
        return self.epochs > newest - last_k

    # ------------------------------------------------------------------
    # incremental fold state (hidden, host-side; DESIGN.md §14)
    # ------------------------------------------------------------------

    def _concrete(self) -> bool:
        """True when the ring is host-readable (no jit tracers).

        Under a jit trace the hidden state machinery stands down entirely:
        tracers must never leak into instance ``__dict__``s, and the
        traced instance returned by jit is rebuilt from pytree leaves
        anyway, so it could not carry the state out.  The trace-state
        check matters even when every leaf is concrete: a closure-captured
        instance used inside someone else's jit binds its ops to the
        active trace, so any derived value (``self.epoch``, a fold) would
        still come back abstract.
        """
        return jax.core.trace_state_clean() and not any(
            isinstance(leaf, jax.core.Tracer)
            for leaf in (self.registers, self.n_items, self.cursor, self.epochs)
        )

    def _suffix_state(self) -> _SuffixFold:
        """The live decomposition — threaded forward by ``advance_to``,
        rebuilt from the ring when absent or stale."""
        state = self.__dict__.get("_inc")
        if state is None or state.epoch != self.epoch:
            state = self._rebuild_suffix()
            object.__setattr__(self, "_inc", state)
        return state

    def _rebuild_suffix(self) -> _SuffixFold:
        """One O(W) reverse-cummax scan over the closed buckets.

        ``prefix[i]`` folds closed buckets i..C-1 in age order, so popping
        the oldest is a pointer bump.  Runs once per W rotations in steady
        state (the amortization of DESIGN.md §14); expired slots were
        zero-filled by ``advance_to`` and fold as the rank-0 identity.
        """
        obs_metrics.inc("window.prefix_rebuilds")
        window, cursor = self.window, int(self.cursor)
        bank_shape = self.registers.shape[1:]
        if window == 1:
            prefix = jnp.zeros((0,) + bank_shape, self.registers.dtype)
        else:
            order = (cursor + 1 + np.arange(window - 1)) % window
            closed = self.registers[jnp.asarray(order, jnp.int32)]
            prefix = jax.lax.cummax(closed, axis=0, reverse=True)
        suffix = jnp.zeros(bank_shape, self.registers.dtype)
        return _SuffixFold(prefix, 0, suffix, self.epoch)

    def _thread_state(self, out: "WindowedBank", steps: int) -> None:
        """Carry the decomposition from ``self`` onto ``out`` after a
        rotation of ``steps`` epochs — O(1): fold the just-closed head
        bucket into the suffix accumulator and pop ``steps`` expired front
        buckets off the prefix stack.  Bails (leaving ``out`` stateless,
        to rebuild lazily) when the rotation outruns the stack."""
        state = self.__dict__.get("_inc")
        if steps <= 0:
            if state is not None and state.epoch == self.epoch:
                object.__setattr__(out, "_inc", state)
            return
        if state is None or state.epoch != self.epoch or steps >= self.window:
            return
        if steps > state.prefix.shape[0] - state.head:
            # the jump expires buckets already folded into the suffix
            # accumulator; max has no inverse, so rebuild from the ring
            return
        head_bucket = jax.lax.dynamic_index_in_dim(
            self.registers, self.cursor, 0, keepdims=False
        )
        object.__setattr__(
            out,
            "_inc",
            _SuffixFold(
                state.prefix,
                state.head + steps,
                jnp.maximum(state.suffix, head_bucket),
                self.epoch + steps,
            ),
        )

    # ------------------------------------------------------------------
    # ingestion (current bucket; paper phase 3)
    # ------------------------------------------------------------------

    def observe(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "WindowedBank":
        """Route each item to row ``keys[i]`` of the CURRENT time bucket.

        The current bucket IS a ``SketchBank``, so the ingest delegates to
        ``SketchBank.update_many`` wholesale — one fused bank scatter, and
        the §9 validation/drop/counter rules cannot drift from the flat
        path.  Empty streams return ``self`` without dispatching anything.
        """
        cur = SketchBank(
            jax.lax.dynamic_index_in_dim(
                self.registers, self.cursor, 0, keepdims=False
            ),
            jax.lax.dynamic_index_in_dim(self.n_items, self.cursor, 0, keepdims=False),
            self.cfg,
        )
        new = cur.update_many(keys, items, plan)
        if new is cur:  # the empty-stream short-circuit: nothing to write back
            return self
        out = dataclasses.replace(
            self,
            registers=jax.lax.dynamic_update_index_in_dim(
                self.registers, new.registers, self.cursor, 0
            ),
            n_items=jax.lax.dynamic_update_index_in_dim(
                self.n_items, new.n_items, self.cursor, 0
            ),
        )
        # the decomposition describes CLOSED buckets only; an observe
        # dirties just the head bucket (read live at merge time), so the
        # state threads through unchanged.  The fold cache does NOT: `out`
        # is a fresh instance, so its cache starts empty — exactly the
        # invalidation an ingest requires.
        if self._concrete():
            self._thread_state(out, 0)
        return out

    # ------------------------------------------------------------------
    # rotation (the sliding part of the window)
    # ------------------------------------------------------------------

    def advance(self, steps: int = 1) -> "WindowedBank":
        """Open ``steps`` new epochs, expiring the buckets they overwrite."""
        if steps < 1:
            raise ValueError(f"advance needs steps >= 1, got {steps}")
        return self.advance_to(self.epochs[self.cursor] + steps)

    def advance_to(self, epoch) -> "WindowedBank":
        """Rotate forward so ``epoch`` is current; the past never returns.

        Every slot whose label changes is zero-filled (its old bucket has
        slid out of the window); jumping W or more epochs expires the whole
        ring.  ``epoch`` at or before the current epoch is a no-op, so
        replaying an old timestamp cannot resurrect expired data.  All
        vectorized — no python loop over buckets.
        """
        target = jnp.maximum(jnp.asarray(epoch, jnp.int32), self.epochs[self.cursor])
        window = self.window
        slots = jnp.arange(window, dtype=jnp.int32)
        # the unique epoch in (target - W, target] congruent to s mod W
        new_epochs = target - jnp.mod(target - slots, window)
        stale = new_epochs > self.epochs  # slots being overwritten
        keep = ~stale[:, None, None]
        out = dataclasses.replace(
            self,
            registers=jnp.where(keep, self.registers, 0).astype(self.registers.dtype),
            n_items=jnp.where(keep, self.n_items, 0).astype(self.n_items.dtype),
            cursor=jnp.mod(target, window).astype(jnp.int32),
            epochs=new_epochs.astype(jnp.int32),
        )
        # O(1)-amortized incremental maintenance (DESIGN.md §14): fold the
        # just-closed head bucket into the suffix accumulator and pop the
        # expired front buckets.  Host-side only — a traced rotation
        # leaves the new instance stateless (reads rebuild lazily).
        if self._concrete() and not isinstance(target, jax.core.Tracer):
            self._thread_state(out, int(target) - self.epoch)
        return out

    # ------------------------------------------------------------------
    # estimation (paper phase 4, windowed)
    # ------------------------------------------------------------------

    def estimate_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
        estimator: Optional[str] = None,
    ) -> jnp.ndarray:
        """(B,) float32 distinct counts over the ``last_k`` newest epochs.

        ONE fused masked max-reduce over the ring axis (the window backend
        registered under ``plan.backend``) into a scratch (B, m) bank,
        then one batched ``estimate_many`` dispatch — never a python loop
        over buckets or rows.  The fold reads replicated ring state, so
        mesh plans fold locally (placement only moves ingest streams).
        """
        folded = self._fold_registers(self._check_last_k(last_k), plan)
        plan = DEFAULT_PLAN if plan is None else plan
        return _finalize_many(folded, self.cfg, plan, estimator)

    def _fold_registers(
        self, last_k: int, plan: Optional[ExecutionPlan]
    ) -> jnp.ndarray:
        """(B, m) fold of the ``last_k`` newest epochs — cached, and O(1)
        in W for the full window (DESIGN.md §14).

        The per-instance cache is the settled-view idiom of
        ``HybridBank.compact`` (§12): an instance is immutable, so its
        folds are too, and every mutation returns a NEW instance whose
        cache starts empty — invalidation by construction.  The key
        carries the plan's dispatch identity so distinct backends still
        exercise their own fold paths (the equivalence tests depend on
        that).  A full-window read merges the three decomposition
        fragments through the ``register_window_merge_backend`` axis
        instead of refolding the ring; suffix windows (last_k < W) fall
        back to the masked ring fold, cached the same way.
        """
        plan = (DEFAULT_PLAN if plan is None else plan).validate()
        backend = get_window_backend(plan.backend)
        if not self._concrete():
            return _ring_fold(
                backend, self.registers, self._live_mask(last_k), self.cfg, plan
            )
        cache = self.__dict__.setdefault("_fold_cache", {})
        key = (last_k, plan.backend, plan.pipelines, plan.placement)
        hit = cache.get(key)
        if hit is not None:
            obs_metrics.inc("window.fold_cache.hits")
            return hit
        obs_metrics.inc("window.fold_cache.misses")
        if last_k == self.window:
            regs = self._fold_incremental(plan)
        else:
            regs = _ring_fold(
                backend, self.registers, self._live_mask(last_k), self.cfg, plan
            )
        cache[key] = regs
        return regs

    def _fold_incremental(self, plan: ExecutionPlan) -> jnp.ndarray:
        """merge(prefix top, suffix accumulator, dirty head) — three (B, m)
        fragments, whatever W is.  Bit-identical to the masked ring fold:
        the fragments partition the live buckets (empty slots fold as the
        rank-0 identity) and register max is order-invisible (§6)."""
        state = self._suffix_state()
        if state.head < state.prefix.shape[0]:
            prefix_top = state.prefix[state.head]
        else:  # front segment fully drained (or W == 1): identity
            prefix_top = jnp.zeros(self.registers.shape[1:], self.registers.dtype)
        head_bucket = jax.lax.dynamic_index_in_dim(
            self.registers, self.cursor, 0, keepdims=False
        )
        parts = jnp.stack([prefix_top, state.suffix, head_bucket])
        return _parts_merge(parts, self.cfg, plan)

    def fold_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> SketchBank:
        """The ``last_k``-epoch suffix collapsed to a flat ``SketchBank``.

        Registers come from the (cached, incrementally maintained) ring
        fold; the exact per-row counters sum the live buckets' counts
        (host-side, exact to 2^64).
        """
        last_k = self._check_last_k(last_k)
        regs = self._fold_registers(last_k, plan)
        totals = self.window_counts(last_k)
        return SketchBank(regs, jnp.asarray(_pack_limbs(totals)), self.cfg)

    # ------------------------------------------------------------------
    # serialization (RHLW: window header + epochs + RHLB payloads)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        """28-byte window header + W int32 epochs + W RHLB bucket blobs."""
        header = _WINDOW_HEADER.pack(
            _WINDOW_MAGIC,
            _WINDOW_VERSION,
            self.cfg.p,
            self.cfg.hash_bits,
            0,
            self.cfg.seed,
            self.window,
            self.rows,
            int(self.cursor),
        )
        epochs = np.asarray(self.epochs, dtype=_EPOCH).tobytes()
        buckets = b"".join(
            SketchBank(self.registers[w], self.n_items[w], self.cfg).to_bytes()
            for w in range(self.window)
        )
        return header + epochs + buckets

    @classmethod
    def from_bytes(cls, data: bytes) -> "WindowedBank":
        if len(data) < _WINDOW_HEADER.size:
            raise ValueError(f"truncated window: {len(data)} bytes")
        magic, version, p, hash_bits, _flags, seed, window, rows, cursor = (
            _WINDOW_HEADER.unpack(data[: _WINDOW_HEADER.size])
        )
        if magic != _WINDOW_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized window")
        if version != _WINDOW_VERSION:
            hints = {
                2: "; version 2 is the hybrid sparse ring — parse it with "
                "HybridWindowedBank.from_bytes",
                3: "; version 3 is the multi-resolution ring — parse it "
                "with MultiResWindowedBank.from_bytes",
            }
            raise ValueError(
                f"unsupported window version {version}{hints.get(version, '')}"
            )
        if window < 1 or rows < 1:
            raise ValueError(f"window header claims {window} buckets x {rows} rows")
        if cursor >= window:
            raise ValueError(f"cursor {cursor} out of range for W={window}")
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        epochs_end = _WINDOW_HEADER.size + window * _EPOCH.itemsize
        bucket_size = 20 + rows * 8 + rows * cfg.m
        expected = epochs_end + window * bucket_size
        if len(data) != expected:
            # covers payloads cut mid-bucket and mid-row alike
            raise ValueError(
                f"window payload is {len(data)} bytes, expected {expected} "
                f"for W={window}, B={rows}, m={cfg.m}"
            )
        epochs = np.frombuffer(data[_WINDOW_HEADER.size : epochs_end], _EPOCH)
        epochs = epochs.astype(np.int64)
        _validate_epoch_ring(epochs, cursor, window)
        regs, limbs = [], []
        for w in range(window):
            start = epochs_end + w * bucket_size
            bucket = SketchBank.from_bytes(data[start : start + bucket_size])
            if bucket.cfg != cfg or len(bucket) != rows:
                raise ValueError(f"bucket {w} disagrees with the window header")
            regs.append(bucket.registers)
            limbs.append(bucket.n_items)
        return cls(
            jnp.stack(regs),
            jnp.stack(limbs),
            jnp.asarray(cursor, jnp.int32),
            jnp.asarray(epochs.astype(_EPOCH)),
            cfg,
        )


# ----------------------------------------------------------------------------
# hybrid (sparse-bucket) rings — DESIGN.md §12
# ----------------------------------------------------------------------------

_WINDOW_VERSION_SPARSE = 2
_BUCKET_LEN = struct.Struct("<Q")


def _validate_epoch_ring(epochs: np.ndarray, cursor: int, window: int) -> None:
    """The slot-congruence invariant shared by RHLW v1 and v2 parsers."""
    epochs = epochs.astype(np.int64)
    slots = np.arange(window, dtype=np.int64)
    if not (
        np.array_equal(np.mod(epochs, window), slots)
        and int(np.argmax(epochs)) == cursor
        and int(epochs.max() - epochs.min()) == window - 1
    ):
        raise ValueError("corrupt epoch labels: ring invariant violated")


@dataclasses.dataclass(frozen=True)
class HybridWindowedBank(_RingReads):
    """A ring of W sparse/dense ``HybridBank`` time buckets.

    The dense ``WindowedBank`` above carries a (W, B, m) block no matter
    how empty the tenants are; this ring carries one hybrid bank per time
    bucket instead, so near-empty rows cost COO pairs per epoch rather
    than m bytes per epoch.  The ring/rotation contract (epoch labels,
    cursor, expiry-on-overwrite, monotone ``advance_to``) is identical to
    ``WindowedBank``; promotion state is PER BUCKET and rides the slot as
    it ages — a bucket promoted while current stays dense until the slot
    is overwritten, so ``advance()`` never demotes or re-ingests anything.

    Like ``HybridBank``, the ring is host-orchestrated (bucket shapes
    change under promotion), so it is not a jit-traceable pytree; each
    bucket's ingest still runs the fused hybrid dispatch.  Window folds
    merge the live hybrid buckets pairwise (W is small — the fused ring
    fold of §11 stays the dense path's job) and finalize with one batched
    ``estimate_many``; merges and serialization settle each bucket's
    deferred append buffer first (``HybridBank.compact``), so every read
    of the ring observes fully deduped state.  ``to_bytes``/``from_bytes`` is RHLW v2: the window
    header with version=2, the epoch labels, then W length-prefixed RHLB
    v2 bucket payloads (v1 dense bucket payloads still parse,
    version-gated, matching ``HybridBank.from_bytes``).
    """

    buckets: tuple  # W HybridBanks, slot order
    cursor: int
    epochs: np.ndarray  # (W,) int32 absolute epoch per slot

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(
        cls,
        window: int,
        rows: int,
        cfg: Optional[HLLConfig] = None,
        threshold: Optional[int] = None,
    ) -> "HybridWindowedBank":
        from repro.sketch.sparse import HybridBank

        if window < 1:
            raise ValueError(f"a window needs at least one bucket, got {window}")
        return cls(
            tuple(
                HybridBank.empty(rows, cfg, threshold) for _ in range(window)
            ),
            0,
            _initial_epochs(window),
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def window(self) -> int:
        return len(self.buckets)

    @property
    def rows(self) -> int:
        return len(self.buckets[0])

    def __len__(self) -> int:
        return self.rows

    @property
    def cfg(self) -> HLLConfig:
        return self.buckets[0].cfg

    @property
    def threshold(self) -> int:
        return self.buckets[0].threshold

    @property
    def epoch(self) -> int:
        return int(self.epochs[self.cursor])

    @property
    def counts(self) -> np.ndarray:
        """(W, B) exact per-bucket-per-row observation counts as uint64."""
        return np.stack([b.counts for b in self.buckets])

    def density(self) -> dict:
        """Ring-wide storage stats: the §12 introspection summed over W."""
        per = [b.density() for b in self.buckets]
        nbytes = sum(d["nbytes"] for d in per)
        dense_nbytes = sum(d["dense_nbytes"] for d in per)
        return {
            "window": self.window,
            "rows": self.rows,
            "dense_rows": sum(d["dense_rows"] for d in per),
            "sparse_rows": sum(d["sparse_rows"] for d in per),
            "threshold": self.threshold,
            "occupancy_mean": float(
                np.mean([d["occupancy_mean"] for d in per])
            ),
            "nbytes": nbytes,
            "dense_nbytes": dense_nbytes,
            "reduction": dense_nbytes / nbytes if nbytes else 0.0,
        }

    def _live_mask(self, last_k: int) -> np.ndarray:
        newest = int(self.epochs[self.cursor])
        return np.asarray(self.epochs) > newest - last_k

    # ------------------------------------------------------------------
    # ingestion + rotation
    # ------------------------------------------------------------------

    def observe(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "HybridWindowedBank":
        """Hybrid-route each item into the CURRENT time bucket.

        Delegates to ``HybridBank.update_many`` wholesale (sparse/dense
        routing, promotion, §9 drop/counter rules — including the
        deferred append buffer: sparse-destined pairs accumulate raw in
        the current bucket's pending log and dedup only under capacity
        pressure or when a read settles the bucket, so per-epoch ingest
        stays O(append)); empty streams return ``self`` without
        dispatching anything.
        """
        cur = self.buckets[self.cursor]
        new = cur.update_many(keys, items, plan)
        if new is cur:  # the empty-stream short-circuit
            return self
        buckets = list(self.buckets)
        buckets[self.cursor] = new
        return dataclasses.replace(self, buckets=tuple(buckets))

    def advance(self, steps: int = 1) -> "HybridWindowedBank":
        if steps < 1:
            raise ValueError(f"advance needs steps >= 1, got {steps}")
        return self.advance_to(self.epoch + steps)

    def advance_to(self, epoch: int) -> "HybridWindowedBank":
        """Rotate forward; overwritten buckets expire (same rules as the
        dense ring: monotone, whole-ring expiry on jumps >= W)."""
        from repro.sketch.sparse import HybridBank

        target = max(int(epoch), self.epoch)
        window = self.window
        slots = np.arange(window, dtype=np.int64)
        new_epochs = target - np.mod(target - slots, window)
        stale = new_epochs > np.asarray(self.epochs, np.int64)
        fresh = lambda: HybridBank.empty(self.rows, self.cfg, self.threshold)
        buckets = tuple(
            fresh() if stale[s] else self.buckets[s] for s in range(window)
        )
        return dataclasses.replace(
            self,
            buckets=buckets,
            cursor=int(target % window),
            epochs=new_epochs.astype(_EPOCH),
        )

    # ------------------------------------------------------------------
    # estimation
    # ------------------------------------------------------------------

    def fold_window(self, last_k: Optional[int] = None):
        """The live ``last_k``-epoch suffix merged into one ``HybridBank``.

        Pairwise hybrid merges over at most W (small) live buckets;
        promotion stays infectious, so a row dense in ANY live bucket is
        dense in the fold.  Memoized per instance and per ``last_k`` —
        the same settled-view idiom as ``HybridBank.compact`` (DESIGN.md
        §12/§14): the ring is immutable, so its folds are too, and any
        mutation returns a fresh instance with an empty cache.
        """
        last_k = self._check_last_k(last_k)
        # under an active trace the merge ops would come back abstract;
        # caching them would leak dead tracers into later eager reads
        cacheable = jax.core.trace_state_clean()
        if cacheable:
            cache = self.__dict__.setdefault("_fold_cache", {})
            hit = cache.get(last_k)
            if hit is not None:
                obs_metrics.inc("window.fold_cache.hits")
                return hit
            obs_metrics.inc("window.fold_cache.misses")
        mask = self._live_mask(last_k)
        live = [self.buckets[s] for s in range(self.window) if mask[s]]
        out = live[0]
        for b in live[1:]:
            out = out.merge(b)
        if cacheable:
            cache[last_k] = out
        return out

    def estimate_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
        estimator: Optional[str] = None,
    ) -> jnp.ndarray:
        """(B,) float32 distinct counts over the ``last_k`` newest epochs."""
        plan = DEFAULT_PLAN if plan is None else plan
        return self.fold_window(last_k).estimate_many(
            estimator or plan.estimator
        )

    # ------------------------------------------------------------------
    # serialization (RHLW v2: length-prefixed hybrid bucket payloads)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        header = _WINDOW_HEADER.pack(
            _WINDOW_MAGIC,
            _WINDOW_VERSION_SPARSE,
            self.cfg.p,
            self.cfg.hash_bits,
            0,
            self.cfg.seed,
            self.window,
            self.rows,
            self.cursor,
        )
        out = [header, np.asarray(self.epochs, dtype=_EPOCH).tobytes()]
        for b in self.buckets:
            blob = b.to_bytes()
            out.append(_BUCKET_LEN.pack(len(blob)))
            out.append(blob)
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "HybridWindowedBank":
        from repro.sketch.sparse import HybridBank

        if len(data) < _WINDOW_HEADER.size:
            raise ValueError(f"truncated window: {len(data)} bytes")
        magic, version, p, hash_bits, _flags, seed, window, rows, cursor = (
            _WINDOW_HEADER.unpack(data[: _WINDOW_HEADER.size])
        )
        if magic != _WINDOW_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized window")
        if version == _WINDOW_VERSION:
            # dense rings still parse, version-gated: all-dense buckets
            dense = WindowedBank.from_bytes(data)
            buckets = tuple(
                SketchBank(
                    dense.registers[w], dense.n_items[w], dense.cfg
                ).to_hybrid(dense_rows=np.ones(dense.rows, bool))
                for w in range(dense.window)
            )
            return cls(
                buckets, int(dense.cursor), np.asarray(dense.epochs, _EPOCH)
            )
        if version != _WINDOW_VERSION_SPARSE:
            hint = (
                "; version 3 is the multi-resolution ring — parse it "
                "with MultiResWindowedBank.from_bytes"
                if version == _WINDOW_VERSION_MULTI
                else ""
            )
            raise ValueError(f"unsupported window version {version}{hint}")
        if window < 1 or rows < 1:
            raise ValueError(f"window header claims {window} buckets x {rows} rows")
        if cursor >= window:
            raise ValueError(f"cursor {cursor} out of range for W={window}")
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        epochs_end = _WINDOW_HEADER.size + window * _EPOCH.itemsize
        if len(data) < epochs_end:
            raise ValueError("truncated window: epoch labels cut short")
        epochs = np.frombuffer(data[_WINDOW_HEADER.size : epochs_end], _EPOCH)
        _validate_epoch_ring(epochs, cursor, window)
        off = epochs_end
        buckets, was_v1 = [], []
        for w in range(window):
            if len(data) < off + _BUCKET_LEN.size:
                raise ValueError(f"bucket {w}: length prefix cut short")
            (blen,) = _BUCKET_LEN.unpack_from(data, off)
            off += _BUCKET_LEN.size
            if len(data) < off + blen:
                raise ValueError(f"bucket {w}: payload cut short")
            payload = data[off : off + blen]
            bucket = HybridBank.from_bytes(payload)
            if bucket.cfg != cfg or len(bucket) != rows:
                raise ValueError(f"bucket {w} disagrees with the window header")
            buckets.append(bucket)
            # a version-gated v1 dense payload carries no threshold of its
            # own; it adopts the ring's below instead of vetoing it
            was_v1.append(len(payload) > 5 and payload[4] == 1)
            off += blen
        if off != len(data):
            raise ValueError(
                f"window payload is {len(data)} bytes, expected {off}"
            )
        v2_thresholds = {
            b.threshold for b, v1 in zip(buckets, was_v1) if not v1
        }
        if len(v2_thresholds) > 1:
            raise ValueError(
                f"bucket thresholds disagree across the ring: "
                f"{sorted(v2_thresholds)}"
            )
        if v2_thresholds:
            (ring_threshold,) = v2_thresholds
            buckets = [
                dataclasses.replace(b, threshold=ring_threshold)
                if v1
                else b
                for b, v1 in zip(buckets, was_v1)
            ]
        return cls(tuple(buckets), int(cursor), epochs.copy())


# ----------------------------------------------------------------------------
# multi-resolution rings (exponential histogram) — DESIGN.md §14
# ----------------------------------------------------------------------------

_WINDOW_VERSION_MULTI = 3
_MR_BASE = struct.Struct("<I")
_MR_BUCKET = struct.Struct("<iiI")  # start epoch, end epoch, logical size
_MR_MAX_LEVELS = 24  # keeps base * 2**levels (and every epoch label) in int32


@dataclasses.dataclass(frozen=True)
class _MRBucket:
    """One closed exponential-histogram bucket.

    ``start``/``end`` are the absolute epochs the bucket spans (label
    width may exceed ``size`` when empty epochs fell inside a merge);
    ``size`` is the logical level size — always a power of two: two
    size-s buckets merge into one size-2s bucket, never anything else.
    """

    start: int
    end: int
    size: int
    bank: SketchBank


@dataclasses.dataclass(frozen=True)
class MultiResWindowedBank:
    """An exponential-histogram window: O(base·levels) slots, long horizon.

    The dense ring pays one (B, m) bucket per epoch, so a million-epoch
    horizon is a million buckets.  This carrier keeps the newest epochs
    at full resolution and PAIRWISE-MERGES older ones (the classic
    exponential histogram, composing with the sliding-window FPGA
    sketches of arXiv:2504.16896): each resolution level holds at most
    ``base`` buckets of logical size 2^ℓ, ℓ < ``levels``; when a level
    overflows, its two oldest buckets merge into one bucket of the next
    level (register max + exact counter add — lossless for the union,
    since the register lattice is a true union).  A
    ``horizon = base * (2**levels - 1)`` epoch span therefore costs at
    most ``base * levels`` closed buckets.

    What is approximated: never the registers — only the window BOUNDARY.
    A query over the last k epochs folds every bucket that intersects it,
    so the answer covers a superset of the exact window, rounded up to
    bucket edges: at most one extra bucket of size ≤ 2^(levels-1) at the
    tail.  The newest epochs are exact (size-1 buckets), which is where
    sliding-window queries concentrate.

    Queries stack the O(log horizon) live buckets and fold them through
    the SAME ``register_window_backend`` axis as the dense ring, then
    finalize with one batched ``estimate_many`` — and are memoized per
    instance like every other window read (DESIGN.md §14).  Like the
    hybrid ring, this carrier is host-orchestrated (the bucket list
    changes shape under merges), not a jit-traceable pytree.

    ``to_bytes``/``from_bytes`` is RHLW version 3: the window header
    (flags byte = levels, W = total buckets, cursor field = current
    epoch), a uint32 ``base``, then per bucket a (start, end, size) label
    and a fixed-size RHLB payload, newest first, current bucket first.
    """

    current: SketchBank  # the open bucket at `epoch`
    closed: tuple  # _MRBuckets, NEWEST first, strictly older, non-overlapping
    epoch: int
    base: int  # max buckets per resolution level
    levels: int  # level sizes 1, 2, ..., 2**(levels-1)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(
        cls,
        base: int,
        rows: int,
        cfg: Optional[HLLConfig] = None,
        levels: int = 4,
    ) -> "MultiResWindowedBank":
        cfg = cfg or HLLConfig()
        if base < 1:
            raise ValueError(f"a window needs at least one bucket, got {base}")
        if rows < 1:
            raise ValueError(f"a bank needs at least one row, got {rows}")
        _check_mr_shape(base, levels)
        return cls(SketchBank.empty(rows, cfg), (), 0, base, levels)

    def with_rows(self, rows: int) -> "MultiResWindowedBank":
        """Grow the bank axis to ``rows`` (new rows start empty)."""
        have = self.rows
        if rows < have:
            raise ValueError(f"cannot shrink a {have}-row window to {rows}")
        if rows == have:
            return self
        grow = lambda bank: dataclasses.replace(
            bank,
            registers=jnp.pad(bank.registers, ((0, rows - have), (0, 0))),
            n_items=jnp.pad(bank.n_items, ((0, rows - have), (0, 0))),
        )
        return dataclasses.replace(
            self,
            current=grow(self.current),
            closed=tuple(
                dataclasses.replace(b, bank=grow(b.bank)) for b in self.closed
            ),
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def cfg(self) -> HLLConfig:
        return self.current.cfg

    @property
    def rows(self) -> int:
        return len(self.current)

    def __len__(self) -> int:
        return self.rows

    @property
    def horizon(self) -> int:
        """The answerable span in epochs: base * (2**levels - 1)."""
        return self.base * ((1 << self.levels) - 1)

    @property
    def window(self) -> int:
        """Alias of ``horizon`` — the bound ``last_k`` validates against,
        mirroring the dense ring's W (shared helper, shared message)."""
        return self.horizon

    @property
    def slots(self) -> int:
        """Buckets currently held (current + closed): O(base · levels)."""
        return 1 + len(self.closed)

    def _check_last_k(self, last_k: Optional[int]) -> int:
        return _check_last_k_value(last_k, self.window)

    def _live_buckets(self, last_k: int) -> list:
        """Closed buckets intersecting the last ``last_k`` epochs, newest
        first.  The current bucket is always live and not listed here."""
        floor = self.epoch - last_k
        return [b for b in self.closed if b.end > floor]

    def window_counts(self, last_k: Optional[int] = None) -> np.ndarray:
        """(B,) exact observation counts over the covered buckets.

        Covers the same rounded-up-to-bucket-edges span as the register
        fold, so counters and estimates always describe one window.
        """
        last_k = self._check_last_k(last_k)
        totals = self.current.counts.copy()
        for b in self._live_buckets(last_k):
            totals += b.bank.counts
        return totals

    def density(self) -> dict:
        """Slot/storage introspection: the multi-res counterpart of the
        ring carriers' density surface."""
        per_level = {}
        for b in self.closed:
            per_level[b.size] = per_level.get(b.size, 0) + 1
        nbytes = self.current.nbytes + sum(b.bank.nbytes for b in self.closed)
        dense_slots = min(self.horizon, self.epoch + 1)
        return {
            "horizon": self.horizon,
            "slots": self.slots,
            "rows": self.rows,
            "base": self.base,
            "levels": self.levels,
            "buckets_per_size": dict(sorted(per_level.items())),
            "nbytes": nbytes,
            "dense_ring_nbytes": dense_slots * self.current.nbytes,
            "reduction": (dense_slots * self.current.nbytes) / nbytes
            if nbytes
            else 0.0,
        }

    # ------------------------------------------------------------------
    # ingestion + rotation
    # ------------------------------------------------------------------

    def observe(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "MultiResWindowedBank":
        """Route each item to row ``keys[i]`` of the CURRENT epoch bucket
        (the same fused bank scatter as every other window carrier)."""
        new = self.current.update_many(keys, items, plan)
        if new is self.current:  # the empty-stream short-circuit
            return self
        return dataclasses.replace(self, current=new)

    def advance(self, steps: int = 1) -> "MultiResWindowedBank":
        if steps < 1:
            raise ValueError(f"advance needs steps >= 1, got {steps}")
        return self.advance_to(self.epoch + steps)

    def advance_to(self, epoch: int) -> "MultiResWindowedBank":
        """Rotate forward to ``epoch``, running the slot-merge schedule.

        The just-closed current bucket enters level 0; any level left
        holding more than ``base`` buckets merges its two oldest into the
        next level (top-level overflow drops the oldest bucket — it is at
        the horizon boundary by then, the standard exponential-histogram
        tail).  Skipped epochs insert nothing: empty epochs are implicit
        gaps in the labels, which is why a label's width can exceed its
        logical size.  Monotone like the rings — replaying an old epoch
        is a no-op — and buckets entirely past the horizon expire even
        when no merge touches them.
        """
        target = max(int(epoch), self.epoch)
        if target == self.epoch:
            return self
        closed = list(self.closed)
        if int(self.current.counts.sum()) > 0:
            closed.insert(
                0, _MRBucket(self.epoch, self.epoch, 1, self.current)
            )
            closed = _mr_carry(closed, self.base, self.levels)
        floor = target - self.horizon
        closed = [b for b in closed if b.end > floor]
        return dataclasses.replace(
            self,
            current=SketchBank.empty(self.rows, self.cfg),
            closed=tuple(closed),
            epoch=target,
        )

    # ------------------------------------------------------------------
    # estimation
    # ------------------------------------------------------------------

    def _fold_registers(
        self, last_k: int, plan: Optional[ExecutionPlan]
    ) -> jnp.ndarray:
        """(B, m) fold of every bucket covering the last ``last_k`` epochs.

        Stacks the O(log horizon) live buckets and folds the stack with
        the ring-fold backend registered under ``plan.backend`` — the EH
        rides the same ``register_window_backend`` axis as the dense
        ring, just with a logarithmic ring.  Memoized per instance
        (settled-view idiom, DESIGN.md §14).
        """
        plan = (DEFAULT_PLAN if plan is None else plan).validate()
        backend = get_window_backend(plan.backend)
        # same trace-state rule as the dense ring's cache: never memoize
        # values minted under someone else's jit trace
        cacheable = jax.core.trace_state_clean()
        if cacheable:
            cache = self.__dict__.setdefault("_fold_cache", {})
            key = (last_k, plan.backend, plan.pipelines, plan.placement)
            hit = cache.get(key)
            if hit is not None:
                obs_metrics.inc("window.fold_cache.hits")
                return hit
            obs_metrics.inc("window.fold_cache.misses")
        stack = jnp.stack(
            [self.current.registers]
            + [b.bank.registers for b in self._live_buckets(last_k)]
        )
        mask = jnp.ones((stack.shape[0],), bool)
        regs = _ring_fold(backend, stack, mask, self.cfg, plan)
        if cacheable:
            cache[key] = regs
        return regs

    def estimate_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
        estimator: Optional[str] = None,
    ) -> jnp.ndarray:
        """(B,) float32 distinct counts over (at least) the last ``last_k``
        epochs — rounded up to bucket edges at the tail, exact at the
        full-resolution head."""
        folded = self._fold_registers(self._check_last_k(last_k), plan)
        plan = DEFAULT_PLAN if plan is None else plan
        return _finalize_many(folded, self.cfg, plan, estimator)

    def fold_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> SketchBank:
        """The covered suffix collapsed to a flat ``SketchBank`` (same
        surface as the ring carriers, so StreamSketch reads are
        carrier-agnostic)."""
        last_k = self._check_last_k(last_k)
        regs = self._fold_registers(last_k, plan)
        totals = self.window_counts(last_k)
        return SketchBank(regs, jnp.asarray(_pack_limbs(totals)), self.cfg)

    # ------------------------------------------------------------------
    # serialization (RHLW v3)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        header = _WINDOW_HEADER.pack(
            _WINDOW_MAGIC,
            _WINDOW_VERSION_MULTI,
            self.cfg.p,
            self.cfg.hash_bits,
            self.levels,
            self.cfg.seed,
            self.slots,
            self.rows,
            self.epoch,
        )
        out = [header, _MR_BASE.pack(self.base)]
        labelled = [(self.epoch, self.epoch, 1, self.current)] + [
            (b.start, b.end, b.size, b.bank) for b in self.closed
        ]
        for start, end, size, bank in labelled:
            out.append(_MR_BUCKET.pack(start, end, size))
            out.append(bank.to_bytes())
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "MultiResWindowedBank":
        if len(data) < _WINDOW_HEADER.size + _MR_BASE.size:
            raise ValueError(f"truncated window: {len(data)} bytes")
        magic, version, p, hash_bits, levels, seed, slots, rows, epoch = (
            _WINDOW_HEADER.unpack(data[: _WINDOW_HEADER.size])
        )
        if magic != _WINDOW_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized window")
        if version != _WINDOW_VERSION_MULTI:
            raise ValueError(
                f"unsupported window version {version}; versions 1/2 are "
                "the dense/hybrid rings — parse them with "
                "WindowedBank/HybridWindowedBank.from_bytes"
            )
        if slots < 1 or rows < 1:
            raise ValueError(
                f"window header claims {slots} buckets x {rows} rows"
            )
        (base,) = _MR_BASE.unpack_from(data, _WINDOW_HEADER.size)
        _check_mr_shape(base, levels)
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        bucket_size = _MR_BUCKET.size + (20 + rows * 8 + rows * cfg.m)
        expected = _WINDOW_HEADER.size + _MR_BASE.size + slots * bucket_size
        if len(data) != expected:
            raise ValueError(
                f"window payload is {len(data)} bytes, expected {expected} "
                f"for {slots} buckets, B={rows}, m={cfg.m}"
            )
        horizon = base * ((1 << levels) - 1)
        size_max = 1 << (levels - 1)
        buckets = []
        off = _WINDOW_HEADER.size + _MR_BASE.size
        for w in range(slots):
            start, end, size = _MR_BUCKET.unpack_from(data, off)
            off += _MR_BUCKET.size
            bank = SketchBank.from_bytes(
                data[off : off + bucket_size - _MR_BUCKET.size]
            )
            off += bucket_size - _MR_BUCKET.size
            if bank.cfg != cfg or len(bank) != rows:
                raise ValueError(f"bucket {w} disagrees with the window header")
            buckets.append((start, end, size, bank))
        start0, end0, size0, current = buckets[0]
        if not (start0 == end0 == epoch and size0 == 1):
            raise ValueError(
                "corrupt multi-resolution labels: the first bucket must be "
                "the open current epoch"
            )
        prev_start, prev_size = start0, None
        closed = []
        for w, (start, end, size, bank) in enumerate(buckets[1:], start=1):
            if not (
                0 <= start <= end < prev_start
                and 1 <= size <= size_max
                and size & (size - 1) == 0
                and size <= end - start + 1
                and (prev_size is None or size >= prev_size)
                and end > epoch - horizon
            ):
                raise ValueError(
                    f"corrupt multi-resolution labels: bucket {w} violates "
                    "the slot-merge schedule invariants"
                )
            prev_start, prev_size = start, size
            closed.append(_MRBucket(start, end, size, bank))
        return cls(current, tuple(closed), epoch, base, levels)


def _check_mr_shape(base: int, levels: int) -> None:
    """Bounds shared by the constructor and the RHLW v3 parser."""
    if base < 1:
        raise ValueError(f"multi-resolution base must be >= 1, got {base}")
    if not 1 <= levels <= _MR_MAX_LEVELS:
        raise ValueError(
            f"multi-resolution levels must be in [1, {_MR_MAX_LEVELS}], "
            f"got {levels}"
        )
    if base * (1 << levels) >= 1 << 31:
        raise ValueError(
            f"horizon base * (2**levels - 1) overflows int32 epochs "
            f"(base={base}, levels={levels})"
        )


def _mr_carry(closed: list, base: int, levels: int) -> list:
    """The exponential-histogram slot-merge schedule (DESIGN.md §14).

    ``closed`` is newest-first with level sizes non-decreasing toward the
    old end.  For each level size s = 1, 2, 4, ...: while the level holds
    more than ``base`` buckets, its two OLDEST merge into one size-2s
    bucket (register max — a lossless union — plus exact counter add).
    The merged bucket is the newest of its new level, so the
    monotone-size invariant is preserved; a top-level overflow drops the
    oldest bucket instead (it sits at the horizon boundary).  Each
    insertion cascades at most once per level: O(levels) merges amortized
    O(1) per epoch.
    """
    size_max = 1 << (levels - 1)
    out = list(closed)
    size = 1
    while size <= size_max:
        idxs = [i for i, b in enumerate(out) if b.size == size]
        while len(idxs) > base:
            oldest = idxs[-1]
            if 2 * size > size_max:
                out.pop(oldest)
                idxs.pop()
                continue
            older, newer = out[oldest], out[oldest - 1]
            out[oldest - 1] = _MRBucket(
                older.start,
                newer.end,
                2 * size,
                newer.bank.merge(older.bank),
            )
            out.pop(oldest)
            idxs.pop()
            idxs.pop()
        size *= 2
    return out
