"""WindowedBank: time-bucketed bank rings with fused sliding-window estimates.

Every query the flat carriers answer is "distinct items since the beginning
of time"; production traffic analytics asks "distinct users in the last 60
seconds".  The sliding-window FPGA follow-up (arXiv:2504.16896) keeps one
BRAM sketch slice per time bucket and merges the live slices on query —
this module is that structure over :class:`repro.sketch.bank.SketchBank`
primitives: a window is a ring of W time-bucket banks, and a windowed
estimate is ONE fused masked max-fold across the ring axis followed by the
existing batched ``estimate_many`` (estimator registry, DESIGN.md §8).

Ring/rotation contract (DESIGN.md §11):

* ``registers`` is (W, B, m): W time buckets of a B-row bank sharing one
  static ``HLLConfig``; ``n_items`` is (W, B, 2) exact per-bucket-per-row
  uint32 limb counters.
* ``epochs`` labels each slot with the absolute time bucket it holds;
  slot s always holds an epoch congruent to s modulo W, and the slot at
  ``cursor`` holds the newest epoch.  ``advance()`` rotates the cursor and
  zero-fills the slot it enters; ``advance_to(t)`` jumps forward any
  distance, expiring every overwritten bucket, with no python loop.
* ``observe(keys, items, plan)`` ingests into the CURRENT bucket through
  the same fused bank scatter as ``SketchBank.update_many`` (key-routing
  and drop rules of DESIGN.md §9 apply unchanged).
* ``estimate_window(last_k, plan)`` masks the k newest live epochs, folds
  the ring with the window backend registered under ``plan.backend``
  (``register_window_backend`` in plan.py), and finalizes the scratch
  (B, m) bank with one batched ``estimate_many`` — never a python loop
  over buckets or rows.  Every registered fold is bit-identical to
  merging the live buckets one by one (tests/test_window.py).

``to_bytes``/``from_bytes`` is the RHLW wire format: a 28-byte window
header + W int32 epoch labels + W per-bucket RHLB payloads, with the same
garbage/truncation rejection contract as RHLL/RHLB (DESIGN.md §7, §9).
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sketch import hll
from repro.sketch.bank import SketchBank
from repro.sketch.hll import HLLConfig
from repro.sketch.plan import DEFAULT_PLAN, ExecutionPlan, get_window_backend

_WINDOW_HEADER = struct.Struct("<4sBBBBQIII")
# magic, ver, p, H, flags, seed, W, B, cursor
_WINDOW_MAGIC = b"RHLW"
_WINDOW_VERSION = 1
_EPOCH = np.dtype("<i4")


def _initial_epochs(window: int) -> np.ndarray:
    """Epoch labels of a fresh ring at epoch 0: slot s holds the unique
    epoch in (0 - W, 0] congruent to s mod W (negative = never filled)."""
    slots = np.arange(window, dtype=np.int64)
    return (0 - np.mod(0 - slots, window)).astype(_EPOCH)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WindowedBank:
    """A (W, B, m) ring of time-bucket banks as one frozen pytree."""

    registers: jnp.ndarray  # (W, B, m) uint8
    n_items: jnp.ndarray  # (W, B, 2) uint32 limb pairs per bucket row
    cursor: jnp.ndarray  # () int32: ring slot of the newest epoch
    epochs: jnp.ndarray  # (W,) int32: absolute epoch held by each slot
    cfg: HLLConfig = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(
        cls, window: int, rows: int, cfg: Optional[HLLConfig] = None
    ) -> "WindowedBank":
        cfg = cfg or HLLConfig()
        if window < 1:
            raise ValueError(f"a window needs at least one bucket, got {window}")
        if rows < 1:
            raise ValueError(f"a bank needs at least one row, got {rows}")
        return cls(
            jnp.zeros((window, rows, cfg.m), hll.REGISTER_DTYPE),
            jnp.zeros((window, rows, 2), jnp.uint32),
            jnp.zeros((), jnp.int32),
            jnp.asarray(_initial_epochs(window)),
            cfg,
        )

    def with_rows(self, rows: int) -> "WindowedBank":
        """Grow the bank axis to ``rows`` (new rows start empty)."""
        have = self.rows
        if rows < have:
            raise ValueError(f"cannot shrink a {have}-row window to {rows}")
        if rows == have:
            return self
        pad = ((0, 0), (0, rows - have), (0, 0))
        return dataclasses.replace(
            self,
            registers=jnp.pad(self.registers, pad),
            n_items=jnp.pad(self.n_items, pad),
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def window(self) -> int:
        return int(self.registers.shape[0])

    @property
    def rows(self) -> int:
        return int(self.registers.shape[1])

    def __len__(self) -> int:
        return self.rows

    @property
    def epoch(self) -> int:
        """The newest (current) absolute epoch — host-side read."""
        return int(self.epochs[self.cursor])

    @property
    def counts(self) -> np.ndarray:
        """(W, B) exact per-bucket-per-row observation counts as uint64."""
        limbs = np.asarray(self.n_items)
        hi = limbs[..., 0].astype(np.uint64)
        lo = limbs[..., 1].astype(np.uint64)
        return (hi << np.uint64(32)) | lo

    def window_counts(self, last_k: Optional[int] = None) -> np.ndarray:
        """(B,) exact observation counts over the last ``last_k`` epochs."""
        mask = np.asarray(self._live_mask(self._check_last_k(last_k)))
        return self.counts[mask].sum(axis=0, dtype=np.uint64)

    def _check_last_k(self, last_k: Optional[int]) -> int:
        if last_k is None:
            return self.window
        if not 1 <= int(last_k) <= self.window:
            raise ValueError(f"last_k must be in [1, {self.window}], got {last_k}")
        return int(last_k)

    def _live_mask(self, last_k: int) -> jnp.ndarray:
        """(W,) bool: slots holding one of the ``last_k`` newest epochs."""
        newest = self.epochs[self.cursor]
        return self.epochs > newest - last_k

    # ------------------------------------------------------------------
    # ingestion (current bucket; paper phase 3)
    # ------------------------------------------------------------------

    def observe(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "WindowedBank":
        """Route each item to row ``keys[i]`` of the CURRENT time bucket.

        The current bucket IS a ``SketchBank``, so the ingest delegates to
        ``SketchBank.update_many`` wholesale — one fused bank scatter, and
        the §9 validation/drop/counter rules cannot drift from the flat
        path.  Empty streams return ``self`` without dispatching anything.
        """
        cur = SketchBank(
            jax.lax.dynamic_index_in_dim(
                self.registers, self.cursor, 0, keepdims=False
            ),
            jax.lax.dynamic_index_in_dim(self.n_items, self.cursor, 0, keepdims=False),
            self.cfg,
        )
        new = cur.update_many(keys, items, plan)
        if new is cur:  # the empty-stream short-circuit: nothing to write back
            return self
        return dataclasses.replace(
            self,
            registers=jax.lax.dynamic_update_index_in_dim(
                self.registers, new.registers, self.cursor, 0
            ),
            n_items=jax.lax.dynamic_update_index_in_dim(
                self.n_items, new.n_items, self.cursor, 0
            ),
        )

    # ------------------------------------------------------------------
    # rotation (the sliding part of the window)
    # ------------------------------------------------------------------

    def advance(self, steps: int = 1) -> "WindowedBank":
        """Open ``steps`` new epochs, expiring the buckets they overwrite."""
        if steps < 1:
            raise ValueError(f"advance needs steps >= 1, got {steps}")
        return self.advance_to(self.epochs[self.cursor] + steps)

    def advance_to(self, epoch) -> "WindowedBank":
        """Rotate forward so ``epoch`` is current; the past never returns.

        Every slot whose label changes is zero-filled (its old bucket has
        slid out of the window); jumping W or more epochs expires the whole
        ring.  ``epoch`` at or before the current epoch is a no-op, so
        replaying an old timestamp cannot resurrect expired data.  All
        vectorized — no python loop over buckets.
        """
        target = jnp.maximum(jnp.asarray(epoch, jnp.int32), self.epochs[self.cursor])
        window = self.window
        slots = jnp.arange(window, dtype=jnp.int32)
        # the unique epoch in (target - W, target] congruent to s mod W
        new_epochs = target - jnp.mod(target - slots, window)
        stale = new_epochs > self.epochs  # slots being overwritten
        keep = ~stale[:, None, None]
        return dataclasses.replace(
            self,
            registers=jnp.where(keep, self.registers, 0).astype(self.registers.dtype),
            n_items=jnp.where(keep, self.n_items, 0).astype(self.n_items.dtype),
            cursor=jnp.mod(target, window).astype(jnp.int32),
            epochs=new_epochs.astype(jnp.int32),
        )

    # ------------------------------------------------------------------
    # estimation (paper phase 4, windowed)
    # ------------------------------------------------------------------

    def estimate_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
        estimator: Optional[str] = None,
    ) -> jnp.ndarray:
        """(B,) float32 distinct counts over the ``last_k`` newest epochs.

        ONE fused masked max-reduce over the ring axis (the window backend
        registered under ``plan.backend``) into a scratch (B, m) bank,
        then one batched ``estimate_many`` dispatch — never a python loop
        over buckets or rows.  The fold reads replicated ring state, so
        mesh plans fold locally (placement only moves ingest streams).
        """
        folded = self._fold_registers(self._check_last_k(last_k), plan)
        plan = DEFAULT_PLAN if plan is None else plan
        from repro.sketch import estimators as _estimators

        return _estimators.estimate_many(
            folded, self.cfg, estimator=estimator or plan.estimator
        )

    def _fold_registers(
        self, last_k: int, plan: Optional[ExecutionPlan]
    ) -> jnp.ndarray:
        plan = (DEFAULT_PLAN if plan is None else plan).validate()
        backend = get_window_backend(plan.backend)
        return backend(self.registers, self._live_mask(last_k), self.cfg, plan)

    def fold_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> SketchBank:
        """The ``last_k``-epoch suffix collapsed to a flat ``SketchBank``.

        Registers come from the fused ring fold; the exact per-row
        counters sum the live buckets' counts (host-side, exact to 2^64).
        """
        last_k = self._check_last_k(last_k)
        regs = self._fold_registers(last_k, plan)
        totals = self.window_counts(last_k)
        limbs = np.stack(
            [
                (totals >> np.uint64(32)).astype(np.uint32),
                totals.astype(np.uint32),
            ],
            axis=-1,
        )
        return SketchBank(regs, jnp.asarray(limbs), self.cfg)

    # ------------------------------------------------------------------
    # serialization (RHLW: window header + epochs + RHLB payloads)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        """28-byte window header + W int32 epochs + W RHLB bucket blobs."""
        header = _WINDOW_HEADER.pack(
            _WINDOW_MAGIC,
            _WINDOW_VERSION,
            self.cfg.p,
            self.cfg.hash_bits,
            0,
            self.cfg.seed,
            self.window,
            self.rows,
            int(self.cursor),
        )
        epochs = np.asarray(self.epochs, dtype=_EPOCH).tobytes()
        buckets = b"".join(
            SketchBank(self.registers[w], self.n_items[w], self.cfg).to_bytes()
            for w in range(self.window)
        )
        return header + epochs + buckets

    @classmethod
    def from_bytes(cls, data: bytes) -> "WindowedBank":
        if len(data) < _WINDOW_HEADER.size:
            raise ValueError(f"truncated window: {len(data)} bytes")
        magic, version, p, hash_bits, _flags, seed, window, rows, cursor = (
            _WINDOW_HEADER.unpack(data[: _WINDOW_HEADER.size])
        )
        if magic != _WINDOW_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized window")
        if version != _WINDOW_VERSION:
            hint = (
                "; version 2 is the hybrid sparse ring — parse it with "
                "HybridWindowedBank.from_bytes"
                if version == 2
                else ""
            )
            raise ValueError(f"unsupported window version {version}{hint}")
        if window < 1 or rows < 1:
            raise ValueError(f"window header claims {window} buckets x {rows} rows")
        if cursor >= window:
            raise ValueError(f"cursor {cursor} out of range for W={window}")
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        epochs_end = _WINDOW_HEADER.size + window * _EPOCH.itemsize
        bucket_size = 20 + rows * 8 + rows * cfg.m
        expected = epochs_end + window * bucket_size
        if len(data) != expected:
            # covers payloads cut mid-bucket and mid-row alike
            raise ValueError(
                f"window payload is {len(data)} bytes, expected {expected} "
                f"for W={window}, B={rows}, m={cfg.m}"
            )
        epochs = np.frombuffer(data[_WINDOW_HEADER.size : epochs_end], _EPOCH)
        epochs = epochs.astype(np.int64)
        _validate_epoch_ring(epochs, cursor, window)
        regs, limbs = [], []
        for w in range(window):
            start = epochs_end + w * bucket_size
            bucket = SketchBank.from_bytes(data[start : start + bucket_size])
            if bucket.cfg != cfg or len(bucket) != rows:
                raise ValueError(f"bucket {w} disagrees with the window header")
            regs.append(bucket.registers)
            limbs.append(bucket.n_items)
        return cls(
            jnp.stack(regs),
            jnp.stack(limbs),
            jnp.asarray(cursor, jnp.int32),
            jnp.asarray(epochs.astype(_EPOCH)),
            cfg,
        )


# ----------------------------------------------------------------------------
# hybrid (sparse-bucket) rings — DESIGN.md §12
# ----------------------------------------------------------------------------

_WINDOW_VERSION_SPARSE = 2
_BUCKET_LEN = struct.Struct("<Q")


def _validate_epoch_ring(epochs: np.ndarray, cursor: int, window: int) -> None:
    """The slot-congruence invariant shared by RHLW v1 and v2 parsers."""
    epochs = epochs.astype(np.int64)
    slots = np.arange(window, dtype=np.int64)
    if not (
        np.array_equal(np.mod(epochs, window), slots)
        and int(np.argmax(epochs)) == cursor
        and int(epochs.max() - epochs.min()) == window - 1
    ):
        raise ValueError("corrupt epoch labels: ring invariant violated")


@dataclasses.dataclass(frozen=True)
class HybridWindowedBank:
    """A ring of W sparse/dense ``HybridBank`` time buckets.

    The dense ``WindowedBank`` above carries a (W, B, m) block no matter
    how empty the tenants are; this ring carries one hybrid bank per time
    bucket instead, so near-empty rows cost COO pairs per epoch rather
    than m bytes per epoch.  The ring/rotation contract (epoch labels,
    cursor, expiry-on-overwrite, monotone ``advance_to``) is identical to
    ``WindowedBank``; promotion state is PER BUCKET and rides the slot as
    it ages — a bucket promoted while current stays dense until the slot
    is overwritten, so ``advance()`` never demotes or re-ingests anything.

    Like ``HybridBank``, the ring is host-orchestrated (bucket shapes
    change under promotion), so it is not a jit-traceable pytree; each
    bucket's ingest still runs the fused hybrid dispatch.  Window folds
    merge the live hybrid buckets pairwise (W is small — the fused ring
    fold of §11 stays the dense path's job) and finalize with one batched
    ``estimate_many``; merges and serialization settle each bucket's
    deferred append buffer first (``HybridBank.compact``), so every read
    of the ring observes fully deduped state.  ``to_bytes``/``from_bytes`` is RHLW v2: the window
    header with version=2, the epoch labels, then W length-prefixed RHLB
    v2 bucket payloads (v1 dense bucket payloads still parse,
    version-gated, matching ``HybridBank.from_bytes``).
    """

    buckets: tuple  # W HybridBanks, slot order
    cursor: int
    epochs: np.ndarray  # (W,) int32 absolute epoch per slot

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(
        cls,
        window: int,
        rows: int,
        cfg: Optional[HLLConfig] = None,
        threshold: Optional[int] = None,
    ) -> "HybridWindowedBank":
        from repro.sketch.sparse import HybridBank

        if window < 1:
            raise ValueError(f"a window needs at least one bucket, got {window}")
        return cls(
            tuple(
                HybridBank.empty(rows, cfg, threshold) for _ in range(window)
            ),
            0,
            _initial_epochs(window),
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def window(self) -> int:
        return len(self.buckets)

    @property
    def rows(self) -> int:
        return len(self.buckets[0])

    def __len__(self) -> int:
        return self.rows

    @property
    def cfg(self) -> HLLConfig:
        return self.buckets[0].cfg

    @property
    def threshold(self) -> int:
        return self.buckets[0].threshold

    @property
    def epoch(self) -> int:
        return int(self.epochs[self.cursor])

    @property
    def counts(self) -> np.ndarray:
        """(W, B) exact per-bucket-per-row observation counts as uint64."""
        return np.stack([b.counts for b in self.buckets])

    def window_counts(self, last_k: Optional[int] = None) -> np.ndarray:
        """(B,) exact observation counts over the last ``last_k`` epochs."""
        mask = self._live_mask(self._check_last_k(last_k))
        return self.counts[mask].sum(axis=0, dtype=np.uint64)

    def density(self) -> dict:
        """Ring-wide storage stats: the §12 introspection summed over W."""
        per = [b.density() for b in self.buckets]
        nbytes = sum(d["nbytes"] for d in per)
        dense_nbytes = sum(d["dense_nbytes"] for d in per)
        return {
            "window": self.window,
            "rows": self.rows,
            "dense_rows": sum(d["dense_rows"] for d in per),
            "sparse_rows": sum(d["sparse_rows"] for d in per),
            "threshold": self.threshold,
            "occupancy_mean": float(
                np.mean([d["occupancy_mean"] for d in per])
            ),
            "nbytes": nbytes,
            "dense_nbytes": dense_nbytes,
            "reduction": dense_nbytes / nbytes if nbytes else 0.0,
        }

    def _check_last_k(self, last_k: Optional[int]) -> int:
        if last_k is None:
            return self.window
        if not 1 <= int(last_k) <= self.window:
            raise ValueError(f"last_k must be in [1, {self.window}], got {last_k}")
        return int(last_k)

    def _live_mask(self, last_k: int) -> np.ndarray:
        newest = int(self.epochs[self.cursor])
        return np.asarray(self.epochs) > newest - last_k

    # ------------------------------------------------------------------
    # ingestion + rotation
    # ------------------------------------------------------------------

    def observe(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "HybridWindowedBank":
        """Hybrid-route each item into the CURRENT time bucket.

        Delegates to ``HybridBank.update_many`` wholesale (sparse/dense
        routing, promotion, §9 drop/counter rules — including the
        deferred append buffer: sparse-destined pairs accumulate raw in
        the current bucket's pending log and dedup only under capacity
        pressure or when a read settles the bucket, so per-epoch ingest
        stays O(append)); empty streams return ``self`` without
        dispatching anything.
        """
        cur = self.buckets[self.cursor]
        new = cur.update_many(keys, items, plan)
        if new is cur:  # the empty-stream short-circuit
            return self
        buckets = list(self.buckets)
        buckets[self.cursor] = new
        return dataclasses.replace(self, buckets=tuple(buckets))

    def advance(self, steps: int = 1) -> "HybridWindowedBank":
        if steps < 1:
            raise ValueError(f"advance needs steps >= 1, got {steps}")
        return self.advance_to(self.epoch + steps)

    def advance_to(self, epoch: int) -> "HybridWindowedBank":
        """Rotate forward; overwritten buckets expire (same rules as the
        dense ring: monotone, whole-ring expiry on jumps >= W)."""
        from repro.sketch.sparse import HybridBank

        target = max(int(epoch), self.epoch)
        window = self.window
        slots = np.arange(window, dtype=np.int64)
        new_epochs = target - np.mod(target - slots, window)
        stale = new_epochs > np.asarray(self.epochs, np.int64)
        fresh = lambda: HybridBank.empty(self.rows, self.cfg, self.threshold)
        buckets = tuple(
            fresh() if stale[s] else self.buckets[s] for s in range(window)
        )
        return dataclasses.replace(
            self,
            buckets=buckets,
            cursor=int(target % window),
            epochs=new_epochs.astype(_EPOCH),
        )

    # ------------------------------------------------------------------
    # estimation
    # ------------------------------------------------------------------

    def fold_window(self, last_k: Optional[int] = None):
        """The live ``last_k``-epoch suffix merged into one ``HybridBank``.

        Pairwise hybrid merges over at most W (small) live buckets;
        promotion stays infectious, so a row dense in ANY live bucket is
        dense in the fold.
        """
        mask = self._live_mask(self._check_last_k(last_k))
        live = [self.buckets[s] for s in range(self.window) if mask[s]]
        out = live[0]
        for b in live[1:]:
            out = out.merge(b)
        return out

    def estimate_window(
        self,
        last_k: Optional[int] = None,
        plan: Optional[ExecutionPlan] = None,
        estimator: Optional[str] = None,
    ) -> jnp.ndarray:
        """(B,) float32 distinct counts over the ``last_k`` newest epochs."""
        plan = DEFAULT_PLAN if plan is None else plan
        return self.fold_window(last_k).estimate_many(
            estimator or plan.estimator
        )

    # ------------------------------------------------------------------
    # serialization (RHLW v2: length-prefixed hybrid bucket payloads)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        header = _WINDOW_HEADER.pack(
            _WINDOW_MAGIC,
            _WINDOW_VERSION_SPARSE,
            self.cfg.p,
            self.cfg.hash_bits,
            0,
            self.cfg.seed,
            self.window,
            self.rows,
            self.cursor,
        )
        out = [header, np.asarray(self.epochs, dtype=_EPOCH).tobytes()]
        for b in self.buckets:
            blob = b.to_bytes()
            out.append(_BUCKET_LEN.pack(len(blob)))
            out.append(blob)
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "HybridWindowedBank":
        from repro.sketch.sparse import HybridBank

        if len(data) < _WINDOW_HEADER.size:
            raise ValueError(f"truncated window: {len(data)} bytes")
        magic, version, p, hash_bits, _flags, seed, window, rows, cursor = (
            _WINDOW_HEADER.unpack(data[: _WINDOW_HEADER.size])
        )
        if magic != _WINDOW_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized window")
        if version == _WINDOW_VERSION:
            # dense rings still parse, version-gated: all-dense buckets
            dense = WindowedBank.from_bytes(data)
            buckets = tuple(
                SketchBank(
                    dense.registers[w], dense.n_items[w], dense.cfg
                ).to_hybrid(dense_rows=np.ones(dense.rows, bool))
                for w in range(dense.window)
            )
            return cls(
                buckets, int(dense.cursor), np.asarray(dense.epochs, _EPOCH)
            )
        if version != _WINDOW_VERSION_SPARSE:
            raise ValueError(f"unsupported window version {version}")
        if window < 1 or rows < 1:
            raise ValueError(f"window header claims {window} buckets x {rows} rows")
        if cursor >= window:
            raise ValueError(f"cursor {cursor} out of range for W={window}")
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        epochs_end = _WINDOW_HEADER.size + window * _EPOCH.itemsize
        if len(data) < epochs_end:
            raise ValueError("truncated window: epoch labels cut short")
        epochs = np.frombuffer(data[_WINDOW_HEADER.size : epochs_end], _EPOCH)
        _validate_epoch_ring(epochs, cursor, window)
        off = epochs_end
        buckets, was_v1 = [], []
        for w in range(window):
            if len(data) < off + _BUCKET_LEN.size:
                raise ValueError(f"bucket {w}: length prefix cut short")
            (blen,) = _BUCKET_LEN.unpack_from(data, off)
            off += _BUCKET_LEN.size
            if len(data) < off + blen:
                raise ValueError(f"bucket {w}: payload cut short")
            payload = data[off : off + blen]
            bucket = HybridBank.from_bytes(payload)
            if bucket.cfg != cfg or len(bucket) != rows:
                raise ValueError(f"bucket {w} disagrees with the window header")
            buckets.append(bucket)
            # a version-gated v1 dense payload carries no threshold of its
            # own; it adopts the ring's below instead of vetoing it
            was_v1.append(len(payload) > 5 and payload[4] == 1)
            off += blen
        if off != len(data):
            raise ValueError(
                f"window payload is {len(data)} bytes, expected {off}"
            )
        v2_thresholds = {
            b.threshold for b, v1 in zip(buckets, was_v1) if not v1
        }
        if len(v2_thresholds) > 1:
            raise ValueError(
                f"bucket thresholds disagree across the ring: "
                f"{sorted(v2_thresholds)}"
            )
        if v2_thresholds:
            (ring_threshold,) = v2_thresholds
            buckets = [
                dataclasses.replace(b, threshold=ring_threshold)
                if v1
                else b
                for b, v1 in zip(buckets, was_v1)
            ]
        return cls(tuple(buckets), int(cursor), epochs.copy())
