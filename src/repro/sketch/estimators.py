"""Pluggable cardinality estimators over the register histogram (phase 4).

The paper treats the computation phase as a fixed one-shot step (constant
203 us, §V).  This module generalizes it: every estimator consumes the
**register histogram** C[k] = |{j : M[j] = k}| (length max_rank + 1), an
O(m) -> O(H - p) reduction computed with one device bincount, and the
finalizers themselves are O(H - p).  Estimators register by name, mirroring
``repro.sketch.plan.register_backend``:

  original       Flajolet harmonic mean + the paper's empirical-threshold
                 small/large-range corrections.  The host path is
                 bit-compatible with the pre-registry ``hll.estimate``
                 (exact python-int harmonic accumulator).
  ertl_improved  Ertl's improved raw estimator (arXiv:1702.01284 Alg. 6):
                 sigma/tau tail corrections replace the empirical
                 thresholds, removing the LC->HLL transition bump.
  ertl_mle       Ertl's Poisson maximum-likelihood estimator: solves
                 dL/dlambda = 0 over the histogram by bisection (the
                 log-likelihood is strictly concave in lambda).

Each estimator ships two finalizers:

  host    (np int histogram, cfg) -> python float; exact float64/bignum
          arithmetic — the authoritative path.
  device  ((..., K) float32 histogram batch, cfg) -> (...,) float32;
          jit-safe, fixed-iteration, and batch-vectorized — the telemetry
          path, and the engine behind :func:`estimate_many`, which
          finalizes a stacked (B, m) register bank in ONE jitted dispatch
          instead of B python loop iterations.

See DESIGN.md §8 for the histogram contract and estimator selection guide.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.sketch.hll import HLLConfig, alpha

# alpha_infinity = 1 / (2 ln 2): the bias constant of Ertl's raw estimator.
ALPHA_INF = 1.0 / (2.0 * math.log(2.0))


# ----------------------------------------------------------------------------
# register validation + the histogram intermediate
# ----------------------------------------------------------------------------


def validate_registers(registers, cfg: HLLConfig, batched: bool = False):
    """Raise ValueError unless ``registers`` is an integer (m,) array.

    With ``batched=True`` any (..., m) stack is accepted.  Shared by the
    host and device entry points so a wrong-shaped or float register array
    fails loudly instead of finalizing to a bogus estimate.
    """
    shape = tuple(registers.shape)
    if batched:
        if len(shape) < 1 or shape[-1] != cfg.m:
            raise ValueError(
                f"expected a (..., {cfg.m}) register bank, got {shape}"
            )
    elif shape != (cfg.m,):
        raise ValueError(f"expected {(cfg.m,)} registers, got {shape}")
    dtype = registers.dtype
    if not (
        jnp.issubdtype(dtype, jnp.integer) or np.issubdtype(dtype, np.integer)
    ):
        raise ValueError(f"registers must be an integer array, got {dtype}")


def histogram_size(cfg: HLLConfig) -> int:
    """K = max_rank + 1 bins: register values live in [0, H - p + 1]."""
    return cfg.max_rank + 1


def register_histogram(registers: jnp.ndarray, cfg: HLLConfig) -> jnp.ndarray:
    """Device histogram: (..., m) registers -> (..., K) int32 counts.

    One bincount for the whole (possibly batched) bank: batch b's registers
    are offset by b*K so a single O(B*m) scatter-add produces every
    histogram at once — no python loop, no O(m*K) one-hot.  Jit-safe;
    shape errors surface at trace time.  A register value beyond max_rank
    (possible only via a corrupted blob — update() cannot produce one) is
    routed to an out-of-range index that bincount drops, so it skews only
    its own sketch's histogram and can never leak a count into a
    neighboring batch; the host path raises on the same input.
    """
    validate_registers(registers, cfg, batched=True)
    k = histogram_size(cfg)
    batch_shape = registers.shape[:-1]
    b = math.prod(batch_shape)
    flat = registers.reshape(b, cfg.m).astype(jnp.int32)
    idx = flat + k * jnp.arange(b, dtype=jnp.int32)[:, None]
    # invalid (negative or > max_rank) -> dropped, never leaked to a neighbor
    idx = jnp.where((flat >= 0) & (flat < k), idx, b * k)
    counts = jnp.bincount(idx.reshape(-1), length=b * k)
    return counts.reshape(batch_shape + (k,)).astype(jnp.int32)


def register_histogram_host(registers, cfg: HLLConfig) -> np.ndarray:
    """Host histogram (exact int64 counts) with full validation."""
    regs = np.asarray(registers)
    validate_registers(regs, cfg, batched=False)
    counts = np.bincount(regs.astype(np.int64), minlength=histogram_size(cfg))
    if counts.shape[0] != histogram_size(cfg):
        raise ValueError(
            f"register value {regs.max()} exceeds max_rank {cfg.max_rank}"
        )
    return counts


# ----------------------------------------------------------------------------
# the estimator registry (mirrors plan.register_backend)
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Estimator:
    """A named finalization strategy over the register histogram."""

    name: str
    host: Callable  # (np int histogram (K,), cfg) -> float, exact
    device: Callable  # ((..., K) f32 histogram, cfg) -> (...,) f32
    doc: str = ""


_ESTIMATORS: Dict[str, Estimator] = {}

DEFAULT_ESTIMATOR = "original"


def register_estimator(
    name: str, host: Callable, device: Callable, doc: str = ""
) -> Estimator:
    """Register an estimator under ``name``; the seam future PRs plug into."""
    if name in _ESTIMATORS:
        raise ValueError(f"estimator {name!r} already registered")
    est = Estimator(name=name, host=host, device=device, doc=doc)
    _ESTIMATORS[name] = est
    return est


def get_estimator(name: str) -> Estimator:
    try:
        return _ESTIMATORS[name]
    except KeyError:
        raise ValueError(
            f"unknown estimator {name!r}; registered: {sorted(_ESTIMATORS)}"
        ) from None


def available_estimators() -> Tuple[str, ...]:
    return tuple(sorted(_ESTIMATORS))


# ----------------------------------------------------------------------------
# "original": Flajolet + empirical-threshold corrections (paper Algorithm 1)
# ----------------------------------------------------------------------------


def _linear_counting(m: int, v: int) -> float:
    """LinearCounting(m, V) = m * ln(m / V)   (Algorithm 1 line 25)."""
    return m * math.log(m / v)


def _original_host(counts: np.ndarray, cfg: HLLConfig) -> float:
    """Exact host finalizer, bit-compatible with the pre-registry estimate.

    The harmonic sum of 2^-M[j] is accumulated as the *integer*
    S = sum_k C[k] 2^(max_rank - k) using python bignums, so the raw
    estimate E = alpha * m^2 * 2^max_rank / S is exact up to one final
    division — the same exactness the paper buys with its fixed-point
    accumulator, now in O(H - p) given the histogram.
    """
    m = cfg.m
    s = 0
    for k, c in enumerate(counts):
        if c:
            s += int(c) << int(cfg.max_rank - k)
    e_raw = alpha(m) * m * m * (1 << cfg.max_rank) / s

    v = int(counts[0])
    if e_raw <= 2.5 * m:
        if v != 0:
            return _linear_counting(m, v)  # small range correction
        return e_raw
    if cfg.hash_bits == 32:
        two32 = float(1 << 32)
        if e_raw <= two32 / 30.0:
            return e_raw
        if e_raw >= two32:
            # the correction diverges as E -> 2^32: a 32-bit hash cannot
            # distinguish beyond its own range, so saturate explicitly
            # instead of a bare math-domain error (seed behavior)
            return math.inf
        return -two32 * math.log(1.0 - e_raw / two32)  # large range correction
    # 64-bit hash: large-range correction obsolete (paper §V-A.7)
    return e_raw


def _original_device(counts: jnp.ndarray, cfg: HLLConfig) -> jnp.ndarray:
    m = float(cfg.m)
    w = jnp.exp2(-jnp.arange(histogram_size(cfg), dtype=jnp.float32))
    harm = counts @ w
    e_raw = alpha(cfg.m) * m * m / harm
    v = counts[..., 0]
    lc = m * jnp.log(m / jnp.maximum(v, 1.0))
    out = jnp.where((e_raw <= 2.5 * m) & (v > 0), lc, e_raw)
    if cfg.hash_bits == 32:
        two32 = float(1 << 32)
        large = -two32 * jnp.log1p(-(e_raw / two32))
        large = jnp.where(e_raw >= two32, jnp.inf, large)  # saturated, not NaN
        out = jnp.where(e_raw > two32 / 30.0, large, out)
    return out


# ----------------------------------------------------------------------------
# "ertl_improved": sigma/tau-corrected raw estimator (1702.01284 Alg. 6)
# ----------------------------------------------------------------------------


def _sigma(x: float) -> float:
    """sigma(x) = x + sum_{k>=1} x^(2^k) 2^(k-1); the C[0] tail correction."""
    if x >= 1.0:
        return math.inf
    y, z = 1.0, x
    while True:
        x *= x
        z_prev = z
        z += x * y
        y += y
        if z == z_prev or x == 0.0:
            return z


def _tau(x: float) -> float:
    """tau(x) = (1/3)(1 - x - sum_{k>=1}(1 - x^(2^-k))^2 2^-k); C[q+1] tail."""
    if x <= 0.0 or x >= 1.0:
        return 0.0
    y, z = 1.0, 1.0 - x
    while True:
        x = math.sqrt(x)
        z_prev = z
        y *= 0.5
        z -= (1.0 - x) ** 2 * y
        if z == z_prev:
            return z / 3.0


def _ertl_z(counts, cfg: HLLConfig, sigma_fn, tau_fn):
    """The corrected harmonic denominator z shared by improved + MLE seed.

    z = m tau(1 - C[q+1]/m) 2^-q + sum_{k=1..q} C[k] 2^-k + m sigma(C[0]/m)
    evaluated with Ertl's halving recurrence (deepest registers first).
    """
    m = cfg.m
    q = cfg.max_rank - 1  # = H - p
    z = m * tau_fn(1.0 - counts[q + 1] / m)
    for k in range(q, 0, -1):
        z = 0.5 * (z + float(counts[k]))
    return z + m * sigma_fn(counts[0] / m)


def _ertl_improved_host(counts: np.ndarray, cfg: HLLConfig) -> float:
    z = _ertl_z(counts, cfg, _sigma, _tau)
    if math.isinf(z):
        return 0.0  # every register zero: the sketch has seen nothing
    if z == 0.0:
        return math.inf  # every register saturated
    return ALPHA_INF * cfg.m * cfg.m / z


def _sigma_device(x: jnp.ndarray, iters: int = 32) -> jnp.ndarray:
    def body(_, carry):
        xx, y, z = carry
        xx = xx * xx
        z = z + xx * y
        return xx, y + y, z

    _, _, z = jax.lax.fori_loop(0, iters, body, (x, jnp.ones_like(x), x))
    # x^(2^i) underflows to 0 well inside `iters` for any float32 x < 1;
    # x == 1 diverges and is patched to the analytic limit here.
    return jnp.where(x >= 1.0, jnp.inf, z)


def _tau_device(x: jnp.ndarray, iters: int = 32) -> jnp.ndarray:
    def body(_, carry):
        xx, y, z = carry
        xx = jnp.sqrt(xx)
        y = 0.5 * y
        z = z - jnp.square(1.0 - xx) * y
        return xx, y, z

    _, _, z = jax.lax.fori_loop(
        0, iters, body, (x, jnp.ones_like(x), 1.0 - x)
    )
    return jnp.where((x <= 0.0) | (x >= 1.0), 0.0, z / 3.0)


def _ertl_improved_device(counts: jnp.ndarray, cfg: HLLConfig) -> jnp.ndarray:
    m = float(cfg.m)
    q = cfg.max_rank - 1
    # closed form of the halving recurrence: z = z_tau 2^-q + sum C[k] 2^-k
    w = jnp.exp2(-jnp.arange(1, q + 1, dtype=jnp.float32))
    z = (
        m * _tau_device(1.0 - counts[..., q + 1] / m) * (2.0**-q)
        + counts[..., 1 : q + 1] @ w
        + m * _sigma_device(counts[..., 0] / m)
    )
    # z = +inf (all-zero sketch) -> 0; z = 0 (saturated) -> +inf: both are
    # the correct limits and fall out of the float division for free.
    return ALPHA_INF * m * m / z


# ----------------------------------------------------------------------------
# "ertl_mle": Poisson maximum-likelihood over the histogram
# ----------------------------------------------------------------------------
#
# Under the Poisson(lambda) model with per-register rate x = lambda / m:
#   P(K = 0)    = e^-x
#   P(K = k)    = e^(-x 2^-k) - e^(-x 2^-(k-1)),  1 <= k <= q
#   P(K = q+1)  = 1 - e^(-x 2^-q)
# The log-likelihood derivative reduces to the strictly decreasing
#   f(x) = -C[0] + sum_{k=1..q} C[k] 2^-k (1/expm1(x 2^-k) - 1)
#               + C[q+1] 2^-q / expm1(x 2^-q)
# whose unique positive root x* gives lambda_hat = m x*.  Strict concavity
# (Ertl 1702.01284 §6) makes bisection globally convergent.


def _mle_dlogl_host(x: float, counts: np.ndarray, q: int) -> float:
    s = -float(counts[0])
    for k in range(1, q + 1):
        c = counts[k]
        if c:
            u = x * 2.0**-k
            s += float(c) * 2.0**-k * (1.0 / float(np.expm1(u)) - 1.0)
    if counts[q + 1]:
        u = x * 2.0**-q
        s += float(counts[q + 1]) * 2.0**-q / float(np.expm1(u))
    return s


def _ertl_mle_host(counts: np.ndarray, cfg: HLLConfig) -> float:
    m = cfg.m
    q = cfg.max_rank - 1
    if counts[0] == m:
        return 0.0
    if counts[q + 1] == m:
        return math.inf
    # seed the bracket from the improved estimator (always within a small
    # constant factor of the MLE) and expand geometrically to be safe
    x0 = _ertl_improved_host(counts, cfg) / m
    if not (0.0 < x0 < math.inf):
        x0 = 1.0
    lo = hi = x0
    while _mle_dlogl_host(hi, counts, q) > 0.0 and hi < 2.0**80:
        hi *= 2.0
    while _mle_dlogl_host(lo, counts, q) < 0.0 and lo > 2.0**-80:
        lo *= 0.5
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if mid == lo or mid == hi:  # float64 exhausted
            break
        if _mle_dlogl_host(mid, counts, q) > 0.0:
            lo = mid
        else:
            hi = mid
    return m * 0.5 * (lo + hi)


def _mle_dlogl_device(x: jnp.ndarray, counts: jnp.ndarray, q: int):
    pw = jnp.exp2(-jnp.arange(1, q + 1, dtype=jnp.float32))  # (q,)
    t = pw * (1.0 / jnp.expm1(x[..., None] * pw) - 1.0)  # (..., q)
    ck = counts[..., 1 : q + 1]
    s = jnp.sum(jnp.where(ck > 0, ck * t, 0.0), axis=-1)
    tq = (2.0**-q) / jnp.expm1(x * (2.0**-q))
    cq1 = counts[..., q + 1]
    return s + jnp.where(cq1 > 0, cq1 * tq, 0.0) - counts[..., 0]


def _ertl_mle_device(counts: jnp.ndarray, cfg: HLLConfig) -> jnp.ndarray:
    m = float(cfg.m)
    q = cfg.max_rank - 1
    x0 = _ertl_improved_device(counts, cfg) / m
    mid0 = jnp.log2(x0)
    # 40 bisections over a 2^10-wide log2 bracket around the improved seed:
    # terminal interval 2^-30, below float32 resolution.
    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        going_up = _mle_dlogl_device(jnp.exp2(mid), counts, q) > 0.0
        return jnp.where(going_up, mid, lo), jnp.where(going_up, hi, mid)

    lo, hi = jax.lax.fori_loop(0, 40, body, (mid0 - 5.0, mid0 + 5.0))
    est = m * jnp.exp2(0.5 * (lo + hi))
    # degenerate sketches never enter the bisection result
    est = jnp.where(counts[..., 0] >= m, 0.0, est)
    return jnp.where(counts[..., q + 1] >= m, jnp.inf, est)


register_estimator(
    "original",
    _original_host,
    _original_device,
    doc="Flajolet harmonic mean + empirical small/large-range corrections "
    "(paper Algorithm 1); host path bit-compatible with the seed.",
)
register_estimator(
    "ertl_improved",
    _ertl_improved_host,
    _ertl_improved_device,
    doc="Ertl improved raw estimator (1702.01284 Alg. 6): sigma/tau tail "
    "corrections, no empirical thresholds, no LC transition bump.",
)
register_estimator(
    "ertl_mle",
    _ertl_mle_host,
    _ertl_mle_device,
    doc="Ertl Poisson maximum-likelihood estimator: bisection on the "
    "concave log-likelihood derivative over the histogram.",
)


# ----------------------------------------------------------------------------
# dispatch: the four public finalization entry points
# ----------------------------------------------------------------------------


def resolve_estimator(estimator: Optional[str]) -> str:
    """None -> the package-wide default (the seam for flipping it once)."""
    return DEFAULT_ESTIMATOR if estimator is None else estimator


def estimate_from_histogram(
    counts, cfg: HLLConfig, estimator: Optional[str] = None
) -> float:
    """Exact host finalization of a precomputed histogram — O(H - p)."""
    estimator = resolve_estimator(estimator)
    counts = np.asarray(counts)
    if counts.shape != (histogram_size(cfg),):
        raise ValueError(
            f"expected a ({histogram_size(cfg)},) histogram, got {counts.shape}"
        )
    if int(counts.sum()) != cfg.m:
        raise ValueError(
            f"histogram sums to {int(counts.sum())}, expected m={cfg.m}"
        )
    return float(get_estimator(estimator).host(counts, cfg))


def estimate(
    registers, cfg: HLLConfig, estimator: Optional[str] = None
) -> float:
    """Phase 4, host-exact: histogram the registers, then finalize."""
    name = resolve_estimator(estimator)
    # finalization time per estimator (DESIGN.md §15) — the "estimate"
    # axis reuses the dispatch-seam shape the backend registries get from
    # plan.register_*, with the estimator name in the backend slot
    with obs_metrics.seam("estimate", name):
        counts = register_histogram_host(registers, cfg)
        return float(get_estimator(name).host(counts, cfg))


@partial(jax.jit, static_argnames=("cfg", "estimator"))
def _estimate_device(
    registers: jnp.ndarray, cfg: HLLConfig, estimator: str
) -> jnp.ndarray:
    counts = register_histogram(registers, cfg).astype(jnp.float32)
    return get_estimator(estimator).device(counts, cfg)


def estimate_device(
    registers: jnp.ndarray,
    cfg: HLLConfig,
    estimator: Optional[str] = None,
) -> jnp.ndarray:
    """Float32 on-device estimate of one (m,) sketch (telemetry path)."""
    validate_registers(registers, cfg, batched=False)
    name = resolve_estimator(estimator)
    with obs_metrics.seam("estimate", name):
        return _estimate_device(registers, cfg, name)


def estimate_many(
    register_bank: jnp.ndarray,
    cfg: HLLConfig,
    estimator: Optional[str] = None,
) -> jnp.ndarray:
    """Batched device finalization: (..., m) bank -> (...,) float32.

    One jitted dispatch for the whole bank — a StreamSketch board, mesh
    shards, or a serving fleet finalize together instead of iterating
    sketches in python.  Matches per-sketch :func:`estimate_device` to
    float32 tolerance (property-tested in tests/test_estimators.py).
    """
    validate_registers(register_bank, cfg, batched=True)
    name = resolve_estimator(estimator)
    with obs_metrics.seam("estimate", name):
        return _estimate_device(register_bank, cfg, name)
