"""Aggregation backends behind the ExecutionPlan registry.

Three backends ship by default, all bit-identical on the same stream (the
max-lattice makes slicing/padding invisible — DESIGN.md §6):

  jnp              XLA scatter-max; ``pipelines`` k slices the stream into k
                   sub-sketches folded by one fused segment-max (Fig. 3)
  pallas           fully-fused Pallas kernel, registers VMEM-resident for the
                   whole sweep (small-p sketches, p <= 12 — DESIGN.md §2)
  pallas_pipelined k fused Pallas pipelines + the bucket-fold kernel

This module also owns the tiling/padding wrappers that used to live in
``repro.kernels.ops`` (now a deprecated shim).  Non-divisible streams are
always padded, never rejected: padded positions get rank 0, and a rank-0
update is the identity of the bucket max.

``interpret`` defaults to True off-TPU (this container) and False on TPU,
where the Mosaic-compiled kernel runs.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sketch import hll
from repro.sketch.hll import HLLConfig
from repro.sketch.plan import (
    DEFAULT_PIPELINES,
    ExecutionPlan,
    SparseDedup,
    register_backend,
    register_bank_backend,
    register_cm_backend,
    register_cm_window_backend,
    register_sparse_backend,
    register_window_backend,
    register_window_merge_backend,
)

# The kernel modules themselves import repro.sketch.hll, so they are loaded
# lazily (first wrapper call) rather than at module import — this keeps
# `import repro.kernels.hash_rank` (a documented, non-deprecated entry)
# working as a process's very first import instead of dying in the cycle
# repro.kernels.* -> repro.sketch -> backends -> repro.kernels.*.
LANES = 128  # pltpu lane width; asserted against the kernel modules on load


def _kernels():
    from repro.kernels import bucket_fold as _fold
    from repro.kernels import hash_rank as _hash
    from repro.kernels import hll_fused as _fused

    assert _hash.LANES == _fold.LANES == _fused.LANES == LANES
    return _hash, _fold, _fused


def _bank_kernel_module():
    from repro.kernels import bank_scatter as _bank

    assert _bank.LANES == LANES
    return _bank


def _window_kernel_module():
    from repro.kernels import window_fold as _window

    assert _window.LANES == LANES
    return _window


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to_tiles(flat: jnp.ndarray, tile_items: int) -> Tuple[jnp.ndarray, int]:
    """Pad a flat stream up to a whole number of (block_rows, 128) tiles.

    Always at least one tile, so empty streams/slices (e.g. a short last
    pipeline when n < k) lower cleanly; the kernels' n_valid masking turns
    the all-padding tile into a no-op.
    """
    n = flat.shape[0]
    padded = max(1, -(-n // tile_items)) * tile_items
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(padded // LANES, LANES), n


# ----------------------------------------------------------------------------
# jnp backend (reference scatter path + lane-pipelined variant)
# ----------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "pipelines"))
def update_pipelined(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    pipelines: int = DEFAULT_PIPELINES,
) -> jnp.ndarray:
    """Fig. 3 on one device: slice the stream over k pipelines, fold with max.

    Streams that do not divide ``pipelines`` are zero-padded and the padded
    positions' ranks masked to 0 (the bucket-max identity), so any length is
    accepted and the result stays bit-identical to the single-pipeline path.
    """
    flat = items.reshape(-1)
    n = flat.shape[0]
    if pipelines <= 1 or n == 0:
        return hll.update(registers, flat, cfg)
    padded = -(-n // pipelines) * pipelines
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    slices = flat.reshape(pipelines, padded // pipelines)
    idx, rank = hll.hash_index_rank(slices, cfg)
    if padded != n:
        pos = jnp.arange(padded, dtype=jnp.int32).reshape(slices.shape)
        rank = jnp.where(pos < n, rank, 0)
    # per-pipeline partial sketches: offset bucket ids per pipeline then one
    # segment_max over k*m segments (single fused scatter).
    offsets = (jnp.arange(pipelines, dtype=jnp.int32) * cfg.m)[:, None]
    seg = (idx + offsets).reshape(-1)
    partial_regs = jax.ops.segment_max(
        rank.reshape(-1), seg, num_segments=pipelines * cfg.m
    )
    partial_regs = jnp.maximum(partial_regs, 0).astype(hll.REGISTER_DTYPE)
    folded = jnp.max(partial_regs.reshape(pipelines, cfg.m), axis=0)
    return jnp.maximum(registers, folded)


# ----------------------------------------------------------------------------
# Pallas kernel wrappers (absorb tiling, dtype casts, block clamping)
# ----------------------------------------------------------------------------


def hash_rank(
    items: jnp.ndarray,
    cfg: HLLConfig,
    *,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused murmur3+rank of a flat item stream -> (idx, rank) int32 arrays."""
    _hash, _, _ = _kernels()
    block_rows = _hash.DEFAULT_BLOCK_ROWS if block_rows is None else block_rows
    interpret = _default_interpret() if interpret is None else interpret
    flat = items.reshape(-1)
    tiled, n = _pad_to_tiles(flat, block_rows * LANES)
    idx, rank = _hash.hash_rank(
        tiled, cfg, block_rows=block_rows, interpret=interpret
    )
    return idx.reshape(-1)[:n], rank.reshape(-1)[:n]


def bucket_fold(
    partials: jnp.ndarray,
    *,
    block_m: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fold (k, m) partial registers (any int dtype) -> (m,) by max."""
    _, _fold, _ = _kernels()
    block_m = _fold.DEFAULT_BLOCK_M if block_m is None else block_m
    interpret = _default_interpret() if interpret is None else interpret
    out = _fold.bucket_fold(
        partials.astype(jnp.int32), block_m=block_m, interpret=interpret
    )
    return out.astype(partials.dtype)


def hll_update(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    *,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fully-fused aggregation of a flat stream into (m,) uint8 registers.

    Small-p sketches only (p <= 12); the p=16 production sketch uses the
    scatter path in sketch/hll.py — see the kernel docstring for why.
    """
    _, _, _fused = _kernels()
    block_rows = _fused.DEFAULT_BLOCK_ROWS if block_rows is None else block_rows
    interpret = _default_interpret() if interpret is None else interpret
    flat = items.reshape(-1)
    tiled, n = _pad_to_tiles(flat, block_rows * LANES)
    n_valid = jnp.full((1, 1), n, jnp.int32)
    regs2d = registers.astype(jnp.int32).reshape(1, cfg.m)
    out = _fused.hll_update_fused(
        regs2d, tiled, n_valid, cfg, block_rows=block_rows, interpret=interpret
    )
    return out.reshape(cfg.m).astype(hll.REGISTER_DTYPE)


def pipelined_update(
    registers: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    pipelines: int = DEFAULT_PIPELINES,
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Paper Fig. 3 built from the kernels: k fused pipelines + fold kernel.

    Slices the stream across ``pipelines`` sub-sketches, aggregates each with
    the fused kernel, folds partials with the bucket_fold kernel, and merges
    into the running registers.
    """
    interpret = _default_interpret() if interpret is None else interpret
    flat = items.reshape(-1)
    n = flat.shape[0]
    per = -(-n // pipelines)
    partials = []
    for k in range(pipelines):
        part = flat[k * per : (k + 1) * per]  # static slice; last may be short
        partials.append(
            hll_update(
                jnp.zeros((cfg.m,), hll.REGISTER_DTYPE), part, cfg,
                interpret=interpret,
            )
        )
    folded = bucket_fold(jnp.stack(partials), interpret=interpret)
    return jnp.maximum(registers, folded)


# ----------------------------------------------------------------------------
# registry entries: fn(registers, items, cfg, plan) -> registers
# ----------------------------------------------------------------------------


@register_backend("jnp")
def _jnp_backend(registers, items, cfg: HLLConfig, plan: ExecutionPlan):
    return update_pipelined(registers, items, cfg, plan.pipelines)


@register_backend("pallas")
def _pallas_backend(registers, items, cfg: HLLConfig, plan: ExecutionPlan):
    # the fused kernel is one hardware pipeline; k>1 belongs to
    # "pallas_pipelined", so `pipelines` is intentionally not consulted here.
    return hll_update(registers, items, cfg, interpret=plan.interpret)


@register_backend("pallas_pipelined")
def _pallas_pipelined_backend(registers, items, cfg: HLLConfig, plan: ExecutionPlan):
    return pipelined_update(
        registers, items, cfg, plan.pipelines, interpret=plan.interpret
    )


# ----------------------------------------------------------------------------
# SketchBank ingest paths (keyed scatter-max; DESIGN.md §9)
# ----------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg",))
def bank_update_jnp(
    registers: jnp.ndarray,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
) -> jnp.ndarray:
    """Reference bank ingest: ONE segment-max over (key, bucket) cells.

    Row b's bucket idx lands in flattened segment ``b*m + idx`` — the same
    offset trick the batched register histogram uses (DESIGN.md §8), so the
    whole (B, m) bank aggregates a keyed stream with a single fused scatter.
    Out-of-range keys route to a discarded trailing segment (never clamped
    into a neighboring row); ``pipelines`` is ignored because the scatter is
    already one fused op — there is no fold to parallelize.

    The flattened cell space must fit int32 (TPU has no 64-bit datapath):
    banks with B*m >= 2^31 would silently wrap the segment ids, so they are
    rejected loudly — shard such fleets across banks (or devices) instead.
    """
    bank_rows, m = registers.shape
    if bank_rows * m >= 1 << 31:
        raise ValueError(
            f"bank cell space B*m = {bank_rows}*{m} overflows int32 segment "
            f"ids; split the fleet across multiple banks or mesh shards"
        )
    idx, rank = hll.hash_index_rank(items, cfg)
    valid = (keys >= 0) & (keys < bank_rows)
    seg = jnp.where(valid, keys * m + idx, bank_rows * m)
    new = jax.ops.segment_max(
        jnp.where(valid, rank, 0).astype(hll.REGISTER_DTYPE),
        seg,
        num_segments=bank_rows * m + 1,
    )
    folded = new[: bank_rows * m].reshape(bank_rows, m)
    return jnp.maximum(registers, folded)


def bank_update(
    registers: jnp.ndarray,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    cfg: HLLConfig,
    *,
    row_block: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Pallas bank ingest: hash_rank kernel + the bank_scatter kernel.

    The (key, bucket, rank) stream is computed once by the fused hash
    kernel; the scatter kernel then tiles the BANK over row blocks the way
    ``bucket_fold`` tiles m, keeping ``row_block * m`` registers VMEM-
    resident per sweep.  Small-m banks only (the hll_fused trade); the
    default row_block picks the largest block under the VMEM cell cap.
    """
    _bank = _bank_kernel_module()
    _hash, _, _ = _kernels()
    interpret = _default_interpret() if interpret is None else interpret
    bank_rows, m = registers.shape
    if m > _bank.MAX_BLOCK_CELLS:
        raise ValueError(
            f"pallas bank ingest supports m <= {_bank.MAX_BLOCK_CELLS} "
            f"(p <= 12); use the jnp scatter path for m={m}"
        )
    flat_keys = keys.reshape(-1).astype(jnp.int32)
    flat_items = items.reshape(-1)
    valid = (flat_keys >= 0) & (flat_keys < bank_rows)
    # one padding serves both kernels: the hash tile (64 rows) is a
    # multiple of the scatter tile (8 rows), so the hashed stream feeds the
    # scatter kernel with no slice/re-pad round-trip in between
    assert (_hash.DEFAULT_BLOCK_ROWS * LANES) % (
        _bank.DEFAULT_BLOCK_ROWS * LANES
    ) == 0
    tile_items = _hash.DEFAULT_BLOCK_ROWS * LANES
    items_t, _ = _pad_to_tiles(flat_items, tile_items)
    keys_t, _ = _pad_to_tiles(jnp.where(valid, flat_keys, 0), tile_items)
    valid_t, _ = _pad_to_tiles(valid.astype(jnp.int32), tile_items)
    idx_t, rank_t = _hash.hash_rank(
        items_t, cfg, block_rows=_hash.DEFAULT_BLOCK_ROWS, interpret=interpret
    )
    # same drop rule as the jnp path: padding and foreign keys are masked
    # to rank 0 (the bucket-max identity), never clamped into a neighbor
    rank_t = jnp.where(valid_t > 0, rank_t, 0)

    if row_block is None:
        row_block = max(1, _bank.MAX_BLOCK_CELLS // m)
    row_block = min(row_block, bank_rows)
    padded_rows = -(-bank_rows // row_block) * row_block
    regs32 = registers.astype(jnp.int32)
    if padded_rows != bank_rows:
        # phantom rows receive nothing (keys < bank_rows) and are sliced off
        regs32 = jnp.pad(regs32, ((0, padded_rows - bank_rows), (0, 0)))
    out = _bank.bank_scatter_max(
        regs32,
        keys_t,
        idx_t,
        rank_t,
        m=m,
        row_block=row_block,
        interpret=interpret,
    )
    return out[:bank_rows].astype(hll.REGISTER_DTYPE)


@register_bank_backend("jnp")
def _jnp_bank_backend(registers, keys, items, cfg: HLLConfig, plan: ExecutionPlan):
    return bank_update_jnp(registers, keys, items, cfg)


@register_bank_backend("pallas")
def _pallas_bank_backend(registers, keys, items, cfg: HLLConfig, plan: ExecutionPlan):
    # one datapath, widest row block under the VMEM cap
    return bank_update(registers, keys, items, cfg, interpret=plan.interpret)


@register_bank_backend("pallas_pipelined")
def _pallas_pipelined_bank_backend(
    registers, keys, items, cfg: HLLConfig, plan: ExecutionPlan
):
    # tile the bank over k pipelines (paper Fig. 3 applied to rows): each
    # grid block owns ceil(B/k) sketches, still under the VMEM cell cap
    rows = registers.shape[0]
    row_block = max(1, -(-rows // plan.pipelines))
    _bank = _bank_kernel_module()
    row_block = min(row_block, max(1, _bank.MAX_BLOCK_CELLS // cfg.m))
    return bank_update(
        registers,
        keys,
        items,
        cfg,
        row_block=row_block,
        interpret=plan.interpret,
    )


# ----------------------------------------------------------------------------
# WindowedBank ring folds (masked max over the W axis; DESIGN.md §11)
# ----------------------------------------------------------------------------


@jax.jit
def window_fold_jnp(ring: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Reference ring fold: ONE masked max-reduce over the W axis.

    Expired/unselected buckets fold as all-zero registers (rank 0 is the
    identity of the bucket max), so any suffix window is bit-identical to
    merging its live buckets one by one.
    """
    masked = jnp.where(mask[:, None, None], ring, jnp.zeros_like(ring))
    return jnp.max(masked, axis=0)


def window_fold(
    ring: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    row_block: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Pallas ring fold: the window_fold kernel over row-block tiles.

    Tiles the (W, B, m) ring over bank-row blocks exactly like
    ``bank_update`` tiles ingest — ``row_block * m`` registers VMEM-
    resident per grid step — and sweeps the ring axis in the inner grid
    dimension with a scratch accumulator.  Small-m banks only (the
    hll_fused trade); the default row_block picks the largest block under
    the VMEM cell cap.
    """
    _window = _window_kernel_module()
    interpret = _default_interpret() if interpret is None else interpret
    window, bank_rows, m = ring.shape
    if m > _window.MAX_BLOCK_CELLS:
        raise ValueError(
            f"pallas window fold supports m <= {_window.MAX_BLOCK_CELLS} "
            f"(p <= 12); use the jnp fold for m={m}"
        )
    if row_block is None:
        row_block = max(1, _window.MAX_BLOCK_CELLS // m)
    row_block = min(row_block, bank_rows)
    padded_rows = -(-bank_rows // row_block) * row_block
    ring32 = ring.astype(jnp.int32)
    if padded_rows != bank_rows:
        # phantom rows fold all-zero registers and are sliced off
        ring32 = jnp.pad(ring32, ((0, 0), (0, padded_rows - bank_rows), (0, 0)))
    out = _window.window_fold_max(
        ring32,
        mask.astype(jnp.int32),
        m=m,
        row_block=row_block,
        interpret=interpret,
    )
    return out[:bank_rows].astype(ring.dtype)


@register_window_backend("jnp")
def _jnp_window_backend(ring, mask, cfg: HLLConfig, plan: ExecutionPlan):
    return window_fold_jnp(ring, mask)


@register_window_backend("pallas")
def _pallas_window_backend(ring, mask, cfg: HLLConfig, plan: ExecutionPlan):
    # one datapath, widest row block under the VMEM cap
    return window_fold(ring, mask, interpret=plan.interpret)


@register_window_backend("pallas_pipelined")
def _pallas_pipelined_window_backend(
    ring, mask, cfg: HLLConfig, plan: ExecutionPlan
):
    # tile the fold over k pipelines: each grid block owns ceil(B/k)
    # sketches, still under the VMEM cell cap
    rows = ring.shape[1]
    row_block = max(1, -(-rows // plan.pipelines))
    _window = _window_kernel_module()
    row_block = min(row_block, max(1, _window.MAX_BLOCK_CELLS // cfg.m))
    return window_fold(ring, mask, row_block=row_block, interpret=plan.interpret)


# ----------------------------------------------------------------------------
# incremental window merges (K fold fragments -> one bank; DESIGN.md §14)
# ----------------------------------------------------------------------------


@jax.jit
def window_merge_jnp(parts: jnp.ndarray) -> jnp.ndarray:
    """Reference incremental merge: ONE max-reduce over the K fragments."""
    return jnp.max(parts, axis=0)


def window_merge(
    parts: jnp.ndarray,
    *,
    row_block: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Pallas incremental merge: the window_merge_max kernel entry point.

    Same padding/dtype contract as ``window_fold`` — the (K, B, m) stack
    of fold fragments is row-block tiled under the VMEM cell cap, and the
    kernel sweeps the K axis (tiny, W-independent) with the ring fold's
    scratch accumulator.
    """
    _window = _window_kernel_module()
    interpret = _default_interpret() if interpret is None else interpret
    _, bank_rows, m = parts.shape
    if m > _window.MAX_BLOCK_CELLS:
        raise ValueError(
            f"pallas window merge supports m <= {_window.MAX_BLOCK_CELLS} "
            f"(p <= 12); use the jnp merge for m={m}"
        )
    if row_block is None:
        row_block = max(1, _window.MAX_BLOCK_CELLS // m)
    row_block = min(row_block, bank_rows)
    padded_rows = -(-bank_rows // row_block) * row_block
    parts32 = parts.astype(jnp.int32)
    if padded_rows != bank_rows:
        # phantom rows merge all-zero registers and are sliced off
        parts32 = jnp.pad(parts32, ((0, 0), (0, padded_rows - bank_rows), (0, 0)))
    out = _window.window_merge_max(
        parts32, m=m, row_block=row_block, interpret=interpret
    )
    return out[:bank_rows].astype(parts.dtype)


@register_window_merge_backend("jnp")
def _jnp_window_merge_backend(parts, cfg: HLLConfig, plan: ExecutionPlan):
    return window_merge_jnp(parts)


@register_window_merge_backend("pallas")
def _pallas_window_merge_backend(parts, cfg: HLLConfig, plan: ExecutionPlan):
    return window_merge(parts, interpret=plan.interpret)


@register_window_merge_backend("pallas_pipelined")
def _pallas_pipelined_window_merge_backend(
    parts, cfg: HLLConfig, plan: ExecutionPlan
):
    rows = parts.shape[1]
    row_block = max(1, -(-rows // plan.pipelines))
    _window = _window_kernel_module()
    row_block = min(row_block, max(1, _window.MAX_BLOCK_CELLS // cfg.m))
    return window_merge(parts, row_block=row_block, interpret=plan.interpret)


# ----------------------------------------------------------------------------
# HybridBank sparse dedup (append-buffer compaction; DESIGN.md §12)
# ----------------------------------------------------------------------------


def _sparse_kernel_module():
    from repro.kernels import sparse_scatter as _sparse

    assert _sparse.LANES == LANES
    return _sparse


# the jnp dedup picks its layout by stream-vs-bank size: below this fraction
# of the bank's rows*m cell count the O(n log n) sort wins, above it the
# O(n + rows*m) scatter does (measured crossover on CPU is ~cells/45; /32
# keeps a safety margin on the scatter side, whose cost is flat in n)
_SPARSE_CELLS_CROSSOVER = 32


@partial(jax.jit, static_argnames=("rows", "m"))
def sparse_merge_sorted(row, bucket, rank, *, rows, m):
    """Sorted-stream dedup: two-pass stable argsort over (row, bucket) cells.

    ONE stable sort by rank ascending, then (stably) by ``row * m + bucket``
    cell id, so within each equal-cell run ranks ascend and the LAST element
    carries the cell's max.  Invalid entries (padding, out-of-range rows)
    sort to a trailing sentinel cell and never survive.  Cost tracks the
    stream, not the bank — the right trade for small compactions.
    """
    valid = (row >= 0) & (row < rows)
    cell = jnp.where(valid, row * m + bucket, rows * m)
    order1 = jnp.argsort(rank, stable=True)
    cell1, rank1 = cell[order1], rank[order1]
    order2 = jnp.argsort(cell1, stable=True)
    cell_s, rank_s = cell1[order2], rank1[order2]
    is_last = jnp.concatenate([cell_s[1:] != cell_s[:-1], jnp.ones((1,), bool)])
    survivor = is_last & (cell_s < rows * m)
    row_s = cell_s // m
    distinct = jnp.bincount(jnp.where(survivor, row_s, rows), length=rows + 1)[
        :rows
    ]
    return cell_s, rank_s, survivor, distinct.astype(jnp.int32)


@partial(jax.jit, static_argnames=("rows", "m"))
def sparse_merge_cells(row, bucket, rank, *, rows, m):
    """Dense-cells dedup: ONE segment-max over ``row * m + bucket`` cells.

    The same fused scatter as ``bank_update_jnp``, landing in a zeroed
    (rows, m) max-rank map instead of live registers; per-row distinct
    counts fall out of one popcount over the map.  Cost is O(n + rows*m)
    flat in the stream — the right trade once the stream rivals the bank.
    """
    valid = (row >= 0) & (row < rows)
    seg = jnp.where(valid, row * m + bucket, rows * m)
    cells = jax.ops.segment_max(
        jnp.where(valid, rank, 0).astype(jnp.int32),
        seg,
        num_segments=rows * m + 1,
    )[: rows * m].reshape(rows, m)
    # segment_max fills untouched segments with INT32_MIN; the cells
    # contract is "0 = empty" (what the pallas kernel's zeroed scratch
    # produces), so clamp before anything scans for nonzero cells
    cells = jnp.maximum(cells, 0)
    distinct = jnp.sum(cells > 0, axis=1, dtype=jnp.int32)
    return cells, distinct


def sparse_merge(
    row,
    bucket,
    rank,
    rows: int,
    cfg: HLLConfig,
    *,
    row_block: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """Pallas sparse dedup: the sparse_scatter kernel over COO row blocks.

    The triple stream tiles like every other kernel stream; padding and
    out-of-range rows are masked to rank 0 (the bucket-max identity), never
    clamped into a neighbor.  The kernel keeps each row block's
    ``row_block * m`` pair cells VMEM-resident for the whole sweep and
    flushes per-row distinct counts alongside the deduped map, so promotion
    detection costs no second pass.  Small-m banks only (the hll_fused
    trade); the default row_block is the widest under the VMEM cell cap.
    """
    _sparse = _sparse_kernel_module()
    interpret = _default_interpret() if interpret is None else interpret
    m = cfg.m
    if m > _sparse.MAX_BLOCK_CELLS:
        raise ValueError(
            f"pallas sparse dedup supports m <= {_sparse.MAX_BLOCK_CELLS} "
            f"(p <= 12); use the jnp dedup path for m={m}"
        )
    flat_row = jnp.asarray(row).reshape(-1).astype(jnp.int32)
    valid = (flat_row >= 0) & (flat_row < rows)
    tile_items = _sparse.DEFAULT_BLOCK_ROWS * LANES
    keys_t, _ = _pad_to_tiles(jnp.where(valid, flat_row, 0), tile_items)
    idx_t, _ = _pad_to_tiles(
        jnp.where(valid, jnp.asarray(bucket).reshape(-1), 0).astype(jnp.int32),
        tile_items,
    )
    rank_t, _ = _pad_to_tiles(
        jnp.where(valid, jnp.asarray(rank).reshape(-1), 0).astype(jnp.int32),
        tile_items,
    )
    if row_block is None:
        row_block = max(1, _sparse.MAX_BLOCK_CELLS // m)
    row_block = min(row_block, rows)
    padded_rows = -(-rows // row_block) * row_block
    cells, distinct = _sparse.sparse_scatter_coo(
        keys_t,
        idx_t,
        rank_t,
        rows=padded_rows,
        m=m,
        row_block=row_block,
        interpret=interpret,
    )
    # phantom padding rows receive nothing (keys < rows) and are sliced off
    return cells[:rows], distinct[:rows]


@register_sparse_backend("jnp")
def _jnp_sparse_backend(row, bucket, rank, rows, cfg: HLLConfig, plan: ExecutionPlan):
    m = cfg.m
    n = row.shape[0]
    if n * _SPARSE_CELLS_CROSSOVER >= rows * m:
        cells, distinct = sparse_merge_cells(row, bucket, rank, rows=rows, m=m)
        return SparseDedup(distinct=distinct, cells=cells)
    cell_s, rank_s, survivor, distinct = sparse_merge_sorted(
        row, bucket, rank, rows=rows, m=m
    )
    return SparseDedup(
        distinct=distinct, cell_s=cell_s, rank_s=rank_s, survivor=survivor
    )


@register_sparse_backend("pallas")
def _pallas_sparse_backend(
    row, bucket, rank, rows, cfg: HLLConfig, plan: ExecutionPlan
):
    # one datapath, widest row block under the VMEM cap
    cells, distinct = sparse_merge(
        row, bucket, rank, rows, cfg, interpret=plan.interpret
    )
    return SparseDedup(distinct=distinct, cells=cells)


@register_sparse_backend("pallas_pipelined")
def _pallas_pipelined_sparse_backend(
    row, bucket, rank, rows, cfg: HLLConfig, plan: ExecutionPlan
):
    # tile the dedup over k pipelines: each grid block owns ceil(B/k) rows,
    # still under the VMEM cell cap
    row_block = max(1, -(-rows // plan.pipelines))
    _sparse = _sparse_kernel_module()
    row_block = min(row_block, max(1, _sparse.MAX_BLOCK_CELLS // cfg.m))
    cells, distinct = sparse_merge(
        row, bucket, rank, rows, cfg, row_block=row_block, interpret=plan.interpret
    )
    return SparseDedup(distinct=distinct, cells=cells)


# ----------------------------------------------------------------------------
# CountMinBank paths (keyed scatter-add + gather-min; DESIGN.md §13)
# ----------------------------------------------------------------------------


def _cm_kernel_module():
    from repro.kernels import cm_scatter as _cms

    assert _cms.LANES == LANES
    return _cms


def _cm_module():
    # lazy for the same reason as the kernel modules: countmin pulls in the
    # bank/window carriers, which must not load mid-way through this module
    from repro.sketch import countmin as _cm

    return _cm


@partial(jax.jit, static_argnames=("cfg",))
def cm_update_jnp(
    counters: jnp.ndarray,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    cfg,
) -> jnp.ndarray:
    """Reference cm ingest: ONE segment-sum over (key, depth, column) cells.

    Item i with key b lands d increments, at flattened cells
    ``b*d*w + r*w + idx_r(i)`` — the bank_update_jnp offset trick with the
    depth lane folded into the cell id, so the whole (B, d, w) bank
    ingests a keyed stream with a single fused scatter-add.  Out-of-range
    keys route to a discarded trailing segment (the §9 drop rule; never
    clamped into a neighboring row).  Counters wrap mod 2^32 by uint32
    arithmetic.  Like the HLL bank, the flattened cell space must fit
    int32 segment ids: B*d*w >= 2^31 is rejected loudly.
    """
    _cm = _cm_module()
    rows, depth, width = counters.shape
    cells = depth * width
    if rows * cells >= 1 << 31:
        raise ValueError(
            f"cm cell space B*d*w = {rows}*{depth}*{width} overflows int32 "
            f"segment ids; split the fleet across multiple banks or shards"
        )
    idx = _cm.cm_hash_index(items, cfg)  # (d, n)
    valid = (keys >= 0) & (keys < rows)
    lane = jnp.arange(depth, dtype=jnp.int32)[:, None] * width
    seg = jnp.where(
        valid[None, :], keys[None, :] * cells + lane + idx, rows * cells
    ).reshape(-1)
    hits = jnp.broadcast_to(
        valid.astype(counters.dtype)[None, :], idx.shape
    ).reshape(-1)
    delta = jax.ops.segment_sum(hits, seg, num_segments=rows * cells + 1)
    return counters + delta[: rows * cells].reshape(rows, depth, width)


@partial(jax.jit, static_argnames=("cfg",))
def cm_query_jnp(
    counters: jnp.ndarray, items: jnp.ndarray, cfg
) -> jnp.ndarray:
    """Reference cm point query: gather d cells per (row, item), min-reduce.

    Returns (B, n) estimated counts — the classical count-min upper
    bound.  One fused gather + reduce; there is no Pallas flavor because
    a gather-min has no scatter hazard to fuse away, so every backend
    pair shares this query.
    """
    _cm = _cm_module()
    rows, depth, width = counters.shape
    idx = _cm.cm_hash_index(items, cfg)  # (d, n)
    r = jnp.broadcast_to(jnp.arange(depth, dtype=jnp.int32)[:, None], idx.shape)
    gathered = counters[:, r, idx]  # (B, d, n)
    return jnp.min(gathered, axis=1)


def cm_update(
    counters: jnp.ndarray,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    cfg,
    *,
    row_block: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Pallas cm ingest: d-expanded stream through the cm_scatter kernel.

    The d column indices per item come from the one-murmur double-hash
    (shared with the jnp path, so routing is bit-identical); the stream is
    then expanded d-fold into (key, cell, hit) triples and summed into
    ``row_block`` whole (d, w) counter slabs held VMEM-resident per grid
    step, exactly as ``bank_update`` tiles the HLL bank.  Padding and
    foreign keys are masked to hit 0 (the additive identity), never
    clamped into a neighbor.  Counters are bitcast uint32<->int32 around
    the kernel: int32 two's-complement adds are bit-identical to uint32
    mod-2^32 adds.  Small-slab banks only (d*w under the VMEM cell cap).
    """
    _cms = _cm_kernel_module()
    _cm = _cm_module()
    interpret = _default_interpret() if interpret is None else interpret
    rows, depth, width = counters.shape
    cells = depth * width
    if cells > _cms.MAX_BLOCK_CELLS:
        raise ValueError(
            f"pallas cm ingest supports d*w <= {_cms.MAX_BLOCK_CELLS}; use "
            f"the jnp scatter path for d*w={cells}"
        )
    flat_keys = keys.reshape(-1).astype(jnp.int32)
    flat_items = items.reshape(-1)
    valid = (flat_keys >= 0) & (flat_keys < rows)
    idx = _cm.cm_hash_index(flat_items, cfg)  # (d, n)
    keys_d = jnp.broadcast_to(flat_keys[None, :], idx.shape)
    col_d = jnp.arange(depth, dtype=jnp.int32)[:, None] * width + idx
    val_d = jnp.broadcast_to(valid[None, :], idx.shape)
    # same drop rule as the jnp path: foreign keys mask to hit 0 aimed at
    # cell 0 of row 0 — a no-op under the cell sum
    keys_d = jnp.where(val_d, keys_d, 0).reshape(-1)
    col_d = jnp.where(val_d, col_d, 0).reshape(-1)
    val_d = val_d.astype(jnp.int32).reshape(-1)
    tile_items = _cms.DEFAULT_BLOCK_ROWS * LANES
    keys_t, _ = _pad_to_tiles(keys_d, tile_items)
    col_t, _ = _pad_to_tiles(col_d, tile_items)
    val_t, _ = _pad_to_tiles(val_d, tile_items)

    if row_block is None:
        row_block = max(1, _cms.MAX_BLOCK_CELLS // cells)
    row_block = min(row_block, rows)
    padded_rows = -(-rows // row_block) * row_block
    cnt32 = jax.lax.bitcast_convert_type(counters, jnp.int32).reshape(rows, cells)
    if padded_rows != rows:
        # phantom rows receive nothing (keys < rows) and are sliced off
        cnt32 = jnp.pad(cnt32, ((0, padded_rows - rows), (0, 0)))
    out = _cms.cm_scatter_add(
        cnt32,
        keys_t,
        col_t,
        val_t,
        cells_per_row=cells,
        row_block=row_block,
        interpret=interpret,
    )
    out = out[:rows].reshape(rows, depth, width)
    return jax.lax.bitcast_convert_type(out, counters.dtype)


@jax.jit
def cm_window_fold_jnp(ring: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Reference cm ring fold: ONE masked SUM-reduce over the W axis.

    Expired/unselected buckets fold as all-zero counters (0 is the
    identity of the cell sum), so any suffix window is bit-identical to
    summing its live buckets one by one.  uint32 arithmetic wraps.
    """
    masked = jnp.where(mask[:, None, None, None], ring, jnp.zeros_like(ring))
    return jnp.sum(masked, axis=0, dtype=ring.dtype)


def cm_window_fold(
    ring: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    row_block: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Pallas cm ring fold: the cm_window_fold_sum kernel over row blocks.

    The fourth sibling of ``window_fold`` — same (W, B, ·) sweep with a
    VMEM scratch accumulator, + replacing max.  Counters are bitcast
    uint32<->int32 around the kernel (two's-complement adds are exact mod
    2^32).  Small-slab banks only (d*w under the VMEM cell cap).
    """
    _cms = _cm_kernel_module()
    interpret = _default_interpret() if interpret is None else interpret
    window, rows, depth, width = ring.shape
    cells = depth * width
    if cells > _cms.MAX_BLOCK_CELLS:
        raise ValueError(
            f"pallas cm window fold supports d*w <= {_cms.MAX_BLOCK_CELLS}; "
            f"use the jnp fold for d*w={cells}"
        )
    if row_block is None:
        row_block = max(1, _cms.MAX_BLOCK_CELLS // cells)
    row_block = min(row_block, rows)
    padded_rows = -(-rows // row_block) * row_block
    ring32 = jax.lax.bitcast_convert_type(ring, jnp.int32).reshape(
        window, rows, cells
    )
    if padded_rows != rows:
        # phantom rows fold all-zero counters and are sliced off
        ring32 = jnp.pad(ring32, ((0, 0), (0, padded_rows - rows), (0, 0)))
    out = _cms.cm_window_fold_sum(
        ring32,
        mask.astype(jnp.int32),
        cells_per_row=cells,
        row_block=row_block,
        interpret=interpret,
    )
    out = out[:rows].reshape(rows, depth, width)
    return jax.lax.bitcast_convert_type(out, ring.dtype)


def _jnp_cm_ingest(counters, keys, items, cfg, plan: ExecutionPlan):
    # the scatter-add is already one fused op; `pipelines` has no fold to
    # parallelize, exactly as in bank_update_jnp
    return cm_update_jnp(counters, keys, items, cfg)


def _jnp_cm_query(counters, items, cfg, plan: ExecutionPlan):
    return cm_query_jnp(counters, items, cfg)


def _pallas_cm_ingest(counters, keys, items, cfg, plan: ExecutionPlan):
    # one datapath, widest row block under the VMEM cap
    return cm_update(counters, keys, items, cfg, interpret=plan.interpret)


def _pallas_pipelined_cm_ingest(counters, keys, items, cfg, plan: ExecutionPlan):
    # tile the bank over k pipelines (paper Fig. 3 applied to rows): each
    # grid block owns ceil(B/k) sketches, still under the VMEM cell cap
    rows, depth, width = counters.shape
    row_block = max(1, -(-rows // plan.pipelines))
    _cms = _cm_kernel_module()
    row_block = min(row_block, max(1, _cms.MAX_BLOCK_CELLS // (depth * width)))
    return cm_update(
        counters, keys, items, cfg, row_block=row_block, interpret=plan.interpret
    )


# the query side is the same fused gather-min everywhere: a gather has no
# scatter hazard for a Pallas kernel to fuse away
register_cm_backend("jnp", _jnp_cm_ingest, _jnp_cm_query)
register_cm_backend("pallas", _pallas_cm_ingest, _jnp_cm_query)
register_cm_backend("pallas_pipelined", _pallas_pipelined_cm_ingest, _jnp_cm_query)


@register_cm_window_backend("jnp")
def _jnp_cm_window_backend(ring, mask, cfg, plan: ExecutionPlan):
    return cm_window_fold_jnp(ring, mask)


@register_cm_window_backend("pallas")
def _pallas_cm_window_backend(ring, mask, cfg, plan: ExecutionPlan):
    # one datapath, widest row block under the VMEM cap
    return cm_window_fold(ring, mask, interpret=plan.interpret)


@register_cm_window_backend("pallas_pipelined")
def _pallas_pipelined_cm_window_backend(ring, mask, cfg, plan: ExecutionPlan):
    # tile the fold over k pipelines: each grid block owns ceil(B/k)
    # sketches, still under the VMEM cell cap
    rows, depth, width = ring.shape[1], ring.shape[2], ring.shape[3]
    row_block = max(1, -(-rows // plan.pipelines))
    _cms = _cm_kernel_module()
    row_block = min(row_block, max(1, _cms.MAX_BLOCK_CELLS // (depth * width)))
    return cm_window_fold(
        ring, mask, row_block=row_block, interpret=plan.interpret
    )
