"""HyperLogLog — faithful implementation of the paper's Algorithm 1.

Four phases (paper §III):
  1. Hashing      — Murmur3, 32- or 64-bit (core/murmur3.py).
  2. Initialization — alpha_m bias constant, m = 2^p zeroed registers.
  3. Aggregation  — idx = top p hash bits, rank = CLZ(remaining bits)+1,
                    M[idx] = max(M[idx], rank).
  4. Computation  — harmonic-mean raw estimate + small/large-range correction.

Aggregation is the streaming hot path and stays device-side (jnp; the Pallas
kernels in repro/kernels accelerate it).  The computation phase is a one-shot
finalization — the paper measures it at a constant 203 us — and dispatches
through the pluggable estimator registry (repro/sketch/estimators.py): every
estimator consumes the register-value histogram and ships an exact host path
(python-int / float64 arithmetic, mirroring the paper's exact fixed-point
harmonic-mean accumulator for the default "original" estimator) plus a
float32 batched device path for in-step telemetry.

Registers form a max-lattice: ``merge`` is element-wise max, which is the
paper's "Merge buckets" fold and the basis for all distribution here.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sketch import murmur3, u64 as u64lib

REGISTER_DTYPE = jnp.uint8


def alpha(m: int) -> float:
    """Bias-correction constant (Algorithm 1, lines 2-3)."""
    if m == 16:
        return 0.673
    if m == 32:
        return 0.697
    if m == 64:
        return 0.709
    return 0.7213 / (1.0 + 1.079 / m)


@dataclasses.dataclass(frozen=True)
class HLLConfig:
    """Static sketch parameters; the paper explores (p,H) in {14,16}x{32,64}."""

    p: int = 16  # precision: m = 2^p buckets
    hash_bits: int = 64  # H: 32 or 64
    seed: int = 0

    def __post_init__(self):
        if not 4 <= self.p <= 16:
            raise ValueError(f"p must be in [4,16], got {self.p}")
        if self.hash_bits not in (32, 64):
            raise ValueError(f"hash_bits must be 32 or 64, got {self.hash_bits}")
        if not 0 <= self.seed < 1 << 64:
            # keeps the serialized header (uint64 seed) total: a negative
            # seed would sketch fine (numpy coerces) but fail to_bytes()
            raise ValueError(f"seed must be a uint64, got {self.seed}")

    @property
    def m(self) -> int:
        return 1 << self.p

    @property
    def max_rank(self) -> int:
        # paper eq. (2): rank <= H - p + 1
        return self.hash_bits - self.p + 1

    @property
    def register_bits(self) -> int:
        # paper eq. (3): ceil(log2(H - p + 1)) bits per register
        return math.ceil(math.log2(self.hash_bits - self.p + 1))

    @property
    def memory_footprint_bits(self) -> int:
        # paper eq. (3): B = 2^p * ceil(log2(H - p + 1))
        return self.m * self.register_bits


def init_registers(cfg: HLLConfig) -> jnp.ndarray:
    """Phase 2: m zeroed bucket counters."""
    return jnp.zeros((cfg.m,), REGISTER_DTYPE)


def hash_index_rank(
    items: jnp.ndarray, cfg: HLLConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Phases 1 + 3a: hash each item, split into (bucket index, rank).

    idx  = first p bits of the hash (Algorithm 1 line 7)
    rank = leading-zero count of the remaining H-p bits, + 1 (line 9),
           capped at H - p + 1 when the remainder is all-zero.
    Returns (idx int32 in [0, m), rank int32 in [1, H-p+1]).
    """
    p = cfg.p
    if cfg.hash_bits == 32:
        h = murmur3.murmur3_32(items, cfg.seed)
        idx = (h >> (32 - p)).astype(jnp.int32)
        w_shifted = (h << p).astype(jnp.uint32)  # remaining bits at the top
        clz_w = u64lib.clz32(w_shifted)
        rank = jnp.minimum(clz_w, 32 - p) + 1
    else:
        h = murmur3.murmur3_64(items, cfg.seed)
        idx = (h.hi >> (32 - p)).astype(jnp.int32)
        w_shifted = u64lib.shl(h, p)
        clz_w = u64lib.clz(w_shifted)
        rank = jnp.minimum(clz_w, 64 - p) + 1
    return idx, rank.astype(jnp.int32)


@partial(jax.jit, static_argnames=("cfg",))
def update(registers: jnp.ndarray, items: jnp.ndarray, cfg: HLLConfig) -> jnp.ndarray:
    """Phase 3: aggregate a batch of items into the registers (pure jnp ref).

    Equivalent to the paper's read-max-write bucket pipeline; XLA lowers the
    segment_max to a scatter-max.  Items may have any shape; they are
    flattened.
    """
    idx, rank = hash_index_rank(items.reshape(-1), cfg)
    # scatter-max directly on uint8 ranks: narrows the materialized operand
    # 4x and makes the empty-segment fill value 0 (uint8 min) — no clamp
    # needed.  §Perf sketch iteration 1: 25.3 -> fewer HLO bytes/item.
    new = jax.ops.segment_max(
        rank.astype(REGISTER_DTYPE), idx, num_segments=cfg.m,
        indices_are_sorted=False,
    )
    return jnp.maximum(registers, new)


def merge(*register_arrays: jnp.ndarray) -> jnp.ndarray:
    """The paper's 'Merge buckets' fold: element-wise max across sketches."""
    out = register_arrays[0]
    for r in register_arrays[1:]:
        out = jnp.maximum(out, r)
    return out


# ----------------------------------------------------------------------------
# Phase 4 — computation, dispatched through the estimator registry
# ----------------------------------------------------------------------------
#
# The finalizers live in repro/sketch/estimators.py: every estimator
# consumes the register histogram C[k] (one device bincount, DESIGN.md §8)
# and ships an exact O(H-p) host path plus a float32 batched device path.
# These wrappers keep the historical ``hll.estimate`` surface; the imports
# are deferred because estimators.py imports HLLConfig/alpha from here.


def estimate(
    registers, cfg: HLLConfig, estimator: Optional[str] = None
) -> float:
    """Phase 4: exact host-side cardinality estimate.

    ``estimator`` selects the registered finalizer (None -> the registry
    default, "original", which keeps the paper's Algorithm 1 corrections
    bit-compatibly; "ertl_improved" / "ertl_mle" are Ertl's histogram
    estimators — see estimators.py).
    """
    from repro.sketch import estimators as _estimators

    return _estimators.estimate(registers, cfg, estimator=estimator)


def estimate_device(
    registers: jnp.ndarray, cfg: HLLConfig, estimator: Optional[str] = None
) -> jnp.ndarray:
    """Float32 on-device estimator for in-step telemetry.

    Validates shape/dtype exactly like :func:`estimate`, then finalizes
    through the registered device path (authoritative path: ``estimate``).
    """
    from repro.sketch import estimators as _estimators

    return _estimators.estimate_device(registers, cfg, estimator=estimator)


def standard_error(cfg: HLLConfig) -> float:
    """Theoretical HLL standard error 1.04/sqrt(m) (paper §III)."""
    return 1.04 / math.sqrt(cfg.m)


# ----------------------------------------------------------------------------
# Convenience one-shot API
# ----------------------------------------------------------------------------


def cardinality(
    items: jnp.ndarray,
    cfg: Optional[HLLConfig] = None,
    estimator: Optional[str] = None,
) -> float:
    """Sketch a whole array and return the exact-finalized estimate."""
    cfg = cfg or HLLConfig()
    regs = update(init_registers(cfg), items, cfg)
    return estimate(regs, cfg, estimator=estimator)
