"""Sparse tenant-row storage with automatic dense promotion (DESIGN.md §12).

The paper's premise is sub-linear memory on the input domain, yet a
``SketchBank`` allocates a dense (B, m) register block no matter how empty
its rows are — at "millions of users" scale most tenant rows hold a
handful of distinct items and waste ~m bytes each.  HyperLogLogLog
(arXiv:2205.11327) and the memory-efficient FPGA sketch follow-up
(arXiv:2504.16896) both show compressed/sparse register storage preserves
estimate quality while cutting memory by an order of magnitude; this
module is that idea over the bank subsystem of DESIGN.md §9.

A ``HybridBank`` keeps every row in one of two representations:

* **sparse** — the row's distinct ``(bucket_idx, rank)`` pairs, packed as
  ``bucket << 8 | rank`` int32 values in a capped per-row COO buffer of
  shape (B, C).  C adapts to the actual occupancy of the sparse rows
  (grown/shrunk at ingest), so near-empty tenants cost a few dozen bytes
  instead of m.
* **dense** — the usual (m,) uint8 register row, held in a compact
  (D, m) block that only promoted rows occupy (``dense_slot`` maps row ->
  block slot, -1 for sparse rows).

**Promotion contract.** A row is promoted exactly when its distinct-bucket
count crosses ``threshold`` (default m // 4): sparse rows always satisfy
``len <= threshold``.  Promotion materializes the row's full
bucket -> max-rank map with one scatter, so a promoted row's registers are
**bit-identical** to dense-from-scratch ingestion of the same stream, and
estimates cannot shift at the boundary (tests/test_sparse.py).  Promotion
is one-way; ``merge`` keeps dense mode infectious (a row dense on either
side stays dense).

**Fused ingest.** ``update_many(keys, items, plan)`` routes the whole
keyed stream in one pass with no python loop over rows: dense-destined
items dispatch through the registered bank backend of ``plan`` (the §9
scatter — jnp or the Pallas bank kernel), sparse-destined items merge
through ONE two-pass stable sort over (row*m + bucket) cells that
deduplicates to per-cell max rank, recompacts every sparse row, and
detects promotions for the whole bank at once.  The §9 key-routing
contract holds unchanged: out-of-range keys are dropped, never leaked,
and never counted.

**Estimation.** ``estimate_many`` finalizes sparse rows with the
linear-counting fast path: a sparse row has at most ``threshold <= m/2``
non-zero registers, which provably pins the ``original`` estimator to its
small-range LinearCounting branch (E_raw <= 2*alpha*m < 2.5m and V > 0),
so ``m * log(m / (m - len))`` is bit-identical to the dense device path
while reading only the per-row pair count.  Other registered estimators
build the (B, K) register histogram straight from the pairs
(C[0] = m - len) and run their normal device finalizer — also
bit-identical to the dense path, because the histogram is.

**Wire format v2.** ``to_bytes`` reuses the RHLB framing with
``version=2``: header + u32 threshold + per-row u64 counts + per-row mode
flags + per-row payloads (dense rows: m register bytes; sparse rows: u16
pair count + sorted (u16 bucket, u8 rank) pairs).  ``from_bytes`` parses
v2 strictly (mode flags, pair ordering, rank ranges, exact length) and
still accepts v1 dense blobs — version-gated, producing an all-dense
hybrid — while ``SketchBank.from_bytes`` keeps rejecting v2 with a
targeted error.

``HybridBank`` is host-orchestrated (promotion reshapes the dense block),
so unlike ``SketchBank`` it is NOT a jit-traceable pytree; the fused
device work happens inside the jitted sort-merge/scatter kernels below.
"""

from __future__ import annotations

import dataclasses
import struct
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sketch import hll, u64 as u64lib
from repro.sketch.bank import (
    _BANK_HEADER,
    _BANK_MAGIC,
    _ROW_COUNT,
    SketchBank,
    _counter_add_rows,
    update_bank_registers,
)
from repro.sketch.carrier import HyperLogLog
from repro.sketch.hll import HLLConfig
from repro.sketch.plan import DEFAULT_PLAN, ExecutionPlan

_PACK_SHIFT = 8  # packed pair = bucket << 8 | rank (rank <= 61 fits a byte)
_PACK_MASK = (1 << _PACK_SHIFT) - 1
_EMPTY = -1  # empty-slot sentinel in the packed pair buffer
_SPARSE_VERSION = 2
_THRESHOLD = struct.Struct("<I")
_NPAIRS = struct.Struct("<H")
_PAIR = struct.Struct("<HB")
MODE_SPARSE, MODE_DENSE = 0, 1


def default_threshold(cfg: HLLConfig) -> int:
    """The default promotion threshold: m // 4 distinct buckets."""
    return max(1, cfg.m // 4)


def _check_threshold(threshold: int, cfg: HLLConfig) -> int:
    """Thresholds above m // 2 would leave the LC-regime guarantee (the
    proof in the module docstring needs V = m - len >= m/2)."""
    threshold = int(threshold)
    if not 1 <= threshold <= max(1, cfg.m // 2):
        raise ValueError(
            f"sparse threshold must be in [1, {max(1, cfg.m // 2)}] "
            f"(m // 2 keeps sparse rows in the LinearCounting regime), "
            f"got {threshold}"
        )
    return threshold


def _fit_capacity(needed: int, threshold: int) -> int:
    """Smallest pow2-ish pair capacity holding ``needed`` entries."""
    if needed <= 0:
        return 0
    return min(threshold, max(4, 1 << (needed - 1).bit_length()))


# ----------------------------------------------------------------------------
# fused device kernels (jitted; static shapes per (stream, capacity) pair)
# ----------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg",))
def _hash_stream(items, cfg: HLLConfig):
    """Jitted phase-1+3a hash of the sparse-destined sub-stream.

    ``hash_index_rank`` is ~a hundred murmur3 ops; running it eagerly
    would dominate the whole hybrid ingest pass.
    """
    return hll.hash_index_rank(items, cfg)


@partial(jax.jit, static_argnames=("rows", "m"))
def _sort_merge(row, bucket, rank, *, rows, m):
    """Dedup a (row, bucket, rank) triple stream to per-cell max rank.

    The caller concatenates the existing sparse pairs (extracted to
    triples, pow2-padded so the sort cost tracks LIVE pairs rather than
    allocated buffer slots) with the newly hashed stream.  ONE two-pass
    stable sort over ``row * m + bucket`` cell ids: first by rank
    ascending, then (stably) by cell, so within each equal-cell run ranks
    ascend and the LAST element of the run carries the cell's max.
    Invalid entries (padding, out-of-range rows) sort to a trailing
    sentinel cell and never survive.  Returns the sorted cells, ranks,
    the survivor mask (per-cell max of live cells), and the (B,)
    distinct-bucket counts — everything ingest needs to recompact sparse
    rows and to detect promotions in one pass, with no loop over rows.
    """
    valid = (row >= 0) & (row < rows)
    cell = jnp.where(valid, row * m + bucket, rows * m)
    order1 = jnp.argsort(rank, stable=True)
    cell1, rank1 = cell[order1], rank[order1]
    order2 = jnp.argsort(cell1, stable=True)
    cell_s, rank_s = cell1[order2], rank1[order2]
    is_last = jnp.concatenate(
        [cell_s[1:] != cell_s[:-1], jnp.ones((1,), bool)]
    )
    survivor = is_last & (cell_s < rows * m)
    row_s = cell_s // m
    distinct = jnp.bincount(
        jnp.where(survivor, row_s, rows), length=rows + 1
    )[:rows]
    return cell_s, rank_s, survivor, distinct.astype(jnp.int32)


@partial(jax.jit, static_argnames=("rows", "m", "cap"))
def _compact_pairs(cell_s, rank_s, survivor, keep_row, *, rows, m, cap):
    """Scatter surviving pairs of still-sparse rows into a (B, cap) buffer.

    Survivors arrive sorted by (row, bucket); each kept entry's slot is
    its running index within its row, so the output rows are bucket-sorted
    with ``-1`` padding — the invariant the v2 wire format serializes.
    """
    row_s = cell_s // m
    bucket_s = cell_s - row_s * m
    safe_row = jnp.clip(row_s, 0, rows - 1)
    take = survivor & keep_row[safe_row] & (row_s < rows)
    pos = jnp.cumsum(take.astype(jnp.int32)) - 1
    row_counts = jnp.bincount(
        jnp.where(take, row_s, rows), length=rows + 1
    )[:rows]
    row_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(row_counts)[:-1].astype(jnp.int32)]
    )
    offset = pos - row_start[safe_row]
    idx = jnp.where(take & (offset < cap), safe_row * cap + offset, rows * cap)
    packed = (bucket_s << _PACK_SHIFT) | rank_s
    out = jnp.full((rows * cap,), _EMPTY, jnp.int32)
    out = out.at[idx].set(packed, mode="drop")
    return out.reshape(rows, cap)


@partial(jax.jit, static_argnames=("slots", "rows", "m"))
def _materialize_rows(cell_s, rank_s, survivor, slot_of_row, *, slots, rows, m):
    """Scatter surviving pairs of promoted rows into fresh dense registers.

    ``slot_of_row`` maps each promoted row to a local slot in [0, slots);
    every other row maps to -1 and contributes nothing.  The scatter sees
    the row's FULL deduped bucket -> max-rank map, so the produced
    registers are bit-identical to dense-from-scratch ingestion.
    """
    row_s = cell_s // m
    bucket_s = cell_s - row_s * m
    slot = slot_of_row[jnp.clip(row_s, 0, rows - 1)]
    take = survivor & (row_s < rows) & (slot >= 0)
    seg = jnp.where(take, slot * m + bucket_s, slots * m)
    regs = jax.ops.segment_max(
        jnp.where(take, rank_s, 0).astype(hll.REGISTER_DTYPE),
        seg,
        num_segments=slots * m + 1,
    )
    return regs[: slots * m].reshape(slots, m)


@partial(jax.jit, static_argnames=("rows", "m"))
def _scatter_pairs_dense(pairs, *, rows, m):
    """(B, C) packed pairs -> (B, m) uint8 registers (one scatter-max)."""
    regs = jnp.zeros((rows, m), hll.REGISTER_DTYPE)
    if pairs.shape[1] == 0:
        return regs
    valid = pairs >= 0
    row = jnp.broadcast_to(
        jnp.arange(rows, dtype=jnp.int32)[:, None], pairs.shape
    )
    bucket = jnp.where(valid, pairs >> _PACK_SHIFT, 0)
    rank = jnp.where(valid, pairs & _PACK_MASK, 0)
    return regs.at[row, bucket].max(rank.astype(hll.REGISTER_DTYPE))


@partial(jax.jit, static_argnames=("m",))
def _lc_estimate(sparse_len, *, m):
    """Closed-form LinearCounting over per-row distinct counts.

    Jitted (not eager) so the float32 log lowers through the same XLA
    codegen as the dense device finalizer — eager batched transcendentals
    can differ in the last ulp, and the sparse fast path is pinned
    bit-identical to the dense path (tests/test_sparse.py).
    """
    fm = float(m)
    v = (fm - sparse_len).astype(jnp.float32)
    return fm * jnp.log(fm / jnp.maximum(v, 1.0))


@partial(jax.jit, static_argnames=("cfg", "estimator"))
def _finalize_histograms(hist, cfg: HLLConfig, estimator: str):
    """Jitted registry finalizer over prebuilt (B, K) histograms."""
    from repro.sketch import estimators as _estimators

    return _estimators.get_estimator(estimator).device(
        hist.astype(jnp.float32), cfg
    )


# ----------------------------------------------------------------------------
# the hybrid carrier
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HybridBank:
    """B same-config sketches, each row sparse (COO pairs) or dense."""

    pairs: jnp.ndarray  # (B, C) int32 packed bucket<<8|rank, -1 = empty
    sparse_len: jnp.ndarray  # (B,) int32 distinct buckets (0 for dense rows)
    dense: jnp.ndarray  # (D, m) uint8 registers of promoted rows
    dense_slot: jnp.ndarray  # (B,) int32 slot into dense, -1 = sparse
    n_items: jnp.ndarray  # (B, 2) uint32 limb pairs, exact per-row counts
    cfg: HLLConfig
    threshold: int  # promote when a row's distinct buckets exceed this

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(
        cls,
        rows: int,
        cfg: Optional[HLLConfig] = None,
        threshold: Optional[int] = None,
    ) -> "HybridBank":
        cfg = cfg or HLLConfig()
        if rows < 1:
            raise ValueError(f"a bank needs at least one row, got {rows}")
        threshold = _check_threshold(
            default_threshold(cfg) if threshold is None else threshold, cfg
        )
        return cls(
            jnp.zeros((rows, 0), jnp.int32),
            jnp.zeros((rows,), jnp.int32),
            jnp.zeros((0, cfg.m), hll.REGISTER_DTYPE),
            jnp.full((rows,), -1, jnp.int32),
            jnp.zeros((rows, 2), jnp.uint32),
            cfg,
            threshold,
        )

    @classmethod
    def from_dense(
        cls,
        bank: SketchBank,
        threshold: Optional[int] = None,
        dense_rows=None,
    ) -> "HybridBank":
        """Demote a dense bank: rows at or under ``threshold`` distinct
        buckets become sparse unless forced dense via ``dense_rows``."""
        cfg = bank.cfg
        threshold = _check_threshold(
            default_threshold(cfg) if threshold is None else threshold, cfg
        )
        regs = np.asarray(bank.registers)
        rows = regs.shape[0]
        occ = (regs > 0).sum(axis=1).astype(np.int64)
        force = (
            np.zeros(rows, bool)
            if dense_rows is None
            else np.asarray(dense_rows, bool)
        )
        if force.shape != (rows,):
            raise ValueError(
                f"dense_rows must be a ({rows},) mask, got {force.shape}"
            )
        dense_mask = force | (occ > threshold)
        sparse_mask = ~dense_mask
        sr, sb = np.nonzero(np.where(sparse_mask[:, None], regs, 0))
        counts = np.bincount(sr, minlength=rows)
        cap = _fit_capacity(int(counts.max(initial=0)), threshold)
        pairs = np.full((rows, cap), _EMPTY, np.int32)
        if sr.size:
            start = np.concatenate([[0], np.cumsum(counts)[:-1]])
            off = np.arange(sr.size) - start[sr]
            pairs[sr, off] = (sb.astype(np.int32) << _PACK_SHIFT) | regs[
                sr, sb
            ].astype(np.int32)
        dense_idx = np.nonzero(dense_mask)[0]
        dense_slot = np.full(rows, -1, np.int32)
        dense_slot[dense_idx] = np.arange(dense_idx.size, dtype=np.int32)
        return cls(
            jnp.asarray(pairs),
            jnp.asarray(np.where(sparse_mask, occ, 0).astype(np.int32)),
            jnp.asarray(regs[dense_idx]),
            jnp.asarray(dense_slot),
            bank.n_items,
            cfg,
            threshold,
        )

    @classmethod
    def from_sketches(
        cls,
        sketches: Sequence[HyperLogLog],
        threshold: Optional[int] = None,
    ) -> "HybridBank":
        return cls.from_dense(SketchBank.from_sketches(sketches), threshold)

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return int(self.n_items.shape[0])

    @property
    def capacity(self) -> int:
        """Current per-row sparse pair capacity C."""
        return int(self.pairs.shape[1])

    @property
    def dense_rows(self) -> int:
        """Number of promoted rows (the D of the dense block)."""
        return int(self.dense.shape[0])

    @property
    def modes(self) -> np.ndarray:
        """(B,) uint8 row modes: MODE_SPARSE (0) or MODE_DENSE (1)."""
        return (np.asarray(self.dense_slot) >= 0).astype(np.uint8)

    @property
    def counts(self) -> np.ndarray:
        """(B,) exact per-row observation counts as uint64."""
        limbs = np.asarray(self.n_items)
        hi = limbs[:, 0].astype(np.uint64)
        lo = limbs[:, 1].astype(np.uint64)
        return (hi << np.uint64(32)) | lo

    @property
    def nbytes(self) -> int:
        """Actual storage footprint of the hybrid representation."""
        return int(
            self.pairs.nbytes
            + self.sparse_len.nbytes
            + self.dense.nbytes
            + self.dense_slot.nbytes
            + self.n_items.nbytes
        )

    def density(self) -> dict:
        """Storage introspection: modes, occupancy, and the memory win."""
        rows = len(self)
        m = self.cfg.m
        d = self.dense_rows
        occ = np.asarray(self.sparse_len).astype(np.int64)
        if d:
            dense_occ = (np.asarray(self.dense) > 0).sum(axis=1)
            occ = occ + np.zeros_like(occ)
            occ[np.asarray(self.dense_slot) >= 0] = dense_occ[
                np.asarray(self.dense_slot)[np.asarray(self.dense_slot) >= 0]
            ]
        dense_nbytes = rows * m + rows * 8  # what a SketchBank would cost
        return {
            "rows": rows,
            "dense_rows": d,
            "sparse_rows": rows - d,
            "capacity": self.capacity,
            "threshold": self.threshold,
            "occupancy_mean": float(occ.mean() / m) if rows else 0.0,
            "nbytes": self.nbytes,
            "dense_nbytes": dense_nbytes,
            "reduction": dense_nbytes / self.nbytes if self.nbytes else 0.0,
        }

    def row(self, i: int) -> HyperLogLog:
        """Row ``i`` materialized as a standalone dense carrier."""
        rows = len(self)
        if not -rows <= i < rows:
            raise IndexError(f"row {i} out of range for a {rows}-row bank")
        i = i % rows
        slot = int(self.dense_slot[i])
        if slot >= 0:
            regs = self.dense[slot]
        else:
            regs_np = np.zeros(self.cfg.m, np.uint8)
            p = np.asarray(self.pairs[i])
            p = p[p >= 0]
            regs_np[p >> _PACK_SHIFT] = (p & _PACK_MASK).astype(np.uint8)
            regs = jnp.asarray(regs_np)
        return HyperLogLog(regs, self.n_items[i], self.cfg)

    # ------------------------------------------------------------------
    # conversion
    # ------------------------------------------------------------------

    def _pair_triples(self):
        """Live pairs as (row, bucket, rank) int32 triples, pow2-padded.

        The pair buffer allocates capacity C for every row, but only
        ``sum(sparse_len)`` slots are live; extracting them (host-side,
        one vectorized pass) keeps the sort-merge cost proportional to
        LIVE pairs, not B*C, and the pow2 padding (row = -1, dropped by
        the kernel's validity mask) bounds jit recompiles.
        """
        pairs_np = np.asarray(self.pairs)
        rows_np, slots = np.nonzero(pairs_np >= 0)
        packed = pairs_np[rows_np, slots]
        p = packed.size
        pad = 1 << max(6, (p - 1).bit_length()) if p else 64
        row = np.full(pad, -1, np.int32)
        bucket = np.zeros(pad, np.int32)
        rank = np.zeros(pad, np.int32)
        row[:p] = rows_np
        bucket[:p] = packed >> _PACK_SHIFT
        rank[:p] = packed & _PACK_MASK
        return row, bucket, rank

    def _dense_registers(self) -> jnp.ndarray:
        """The whole bank materialized as (B, m) uint8 registers."""
        rows = len(self)
        regs = _scatter_pairs_dense(self.pairs, rows=rows, m=self.cfg.m)
        if self.dense_rows:
            slot = jnp.clip(self.dense_slot, 0, self.dense_rows - 1)
            regs = jnp.where(
                (self.dense_slot >= 0)[:, None], self.dense[slot], regs
            )
        return regs

    def to_dense(self) -> SketchBank:
        """Materialize to a plain dense ``SketchBank`` (lossless)."""
        return SketchBank(self._dense_registers(), self.n_items, self.cfg)

    def to_sketches(self) -> list:
        return [self.row(i) for i in range(len(self))]

    # ------------------------------------------------------------------
    # aggregation (paper phase 3, hybrid-routed)
    # ------------------------------------------------------------------

    def update_many(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "HybridBank":
        """Route each item to row ``keys[i]``'s current representation.

        One host-orchestrated pass, no python loop over rows: the
        dense-destined sub-stream dispatches through the bank backend
        registered under ``plan.backend`` (§9), the sparse-destined
        sub-stream merges through the fused sort-dedup kernel, and rows
        whose distinct-bucket count crosses ``threshold`` promote at the
        end of the batch (order-independent: the register lattice is a
        max).  Zero-length streams and zero-row banks return ``self``
        without dispatching any backend.
        """
        flat_keys = jnp.asarray(keys).reshape(-1).astype(jnp.int32)
        flat_items = jnp.asarray(items).reshape(-1)
        if flat_keys.shape[0] != flat_items.shape[0]:
            raise ValueError(
                f"keys ({flat_keys.shape[0]}) and items "
                f"({flat_items.shape[0]}) must flatten to the same length"
            )
        rows = len(self)
        if flat_items.shape[0] == 0 or rows == 0:
            return self
        m = self.cfg.m
        if rows * m >= 1 << 31:
            raise ValueError(
                f"bank cell space B*m = {rows}*{m} overflows int32 sort "
                f"cells; split the fleet across multiple banks"
            )
        plan = (DEFAULT_PLAN if plan is None else plan).validate()
        keys_np = np.asarray(flat_keys)
        items_np = np.asarray(flat_items)
        slot_np = np.asarray(self.dense_slot)
        valid = (keys_np >= 0) & (keys_np < rows)
        dest = np.where(valid, slot_np[np.clip(keys_np, 0, rows - 1)], -1)
        dense_sel = valid & (dest >= 0)
        sparse_sel = valid & (dest < 0)

        new_dense = self.dense
        if dense_sel.any():
            new_dense = update_bank_registers(
                self.dense,
                jnp.asarray(dest[dense_sel]),
                jnp.asarray(items_np[dense_sel]),
                self.cfg,
                plan,
            )

        new_pairs, new_len, new_slot = self.pairs, self.sparse_len, slot_np
        if sparse_sel.any():
            idx, rank = _hash_stream(jnp.asarray(items_np[sparse_sel]), self.cfg)
            old_rows, old_buckets, old_ranks = self._pair_triples()
            cell_s, rank_s, survivor, distinct = _sort_merge(
                jnp.concatenate(
                    [jnp.asarray(old_rows), jnp.asarray(keys_np[sparse_sel])]
                ),
                jnp.concatenate([jnp.asarray(old_buckets), idx]),
                jnp.concatenate([jnp.asarray(old_ranks), rank]),
                rows=rows,
                m=m,
            )
            distinct_np = np.asarray(distinct)
            was_sparse = slot_np < 0
            promote = was_sparse & (distinct_np > self.threshold)
            keep = was_sparse & ~promote
            needed = int(distinct_np[keep].max(initial=0))
            cap = _fit_capacity(needed, self.threshold)
            new_pairs = _compact_pairs(
                cell_s,
                rank_s,
                survivor,
                jnp.asarray(keep),
                rows=rows,
                m=m,
                cap=cap,
            )
            new_len = jnp.asarray(np.where(keep, distinct_np, 0).astype(np.int32))
            if promote.any():
                promoted = np.nonzero(promote)[0]
                slot_of_row = np.full(rows, -1, np.int32)
                slot_of_row[promoted] = np.arange(promoted.size, dtype=np.int32)
                fresh = _materialize_rows(
                    cell_s,
                    rank_s,
                    survivor,
                    jnp.asarray(slot_of_row),
                    slots=promoted.size,
                    rows=rows,
                    m=m,
                )
                new_dense = (
                    jnp.concatenate([new_dense, fresh])
                    if new_dense.shape[0]
                    else fresh
                )
                new_slot = slot_np.copy()
                new_slot[promoted] = self.dense_rows + np.arange(
                    promoted.size, dtype=np.int32
                )

        routed = jnp.where(valid, flat_keys, rows)
        counts = jnp.bincount(routed, length=rows + 1)[:rows]
        return dataclasses.replace(
            self,
            pairs=new_pairs,
            sparse_len=new_len,
            dense=new_dense,
            dense_slot=jnp.asarray(new_slot),
            n_items=_counter_add_rows(self.n_items, counts),
        )

    def merge(self, other: "HybridBank") -> "HybridBank":
        """Row-wise Merge-buckets fold; dense mode is infectious.

        The fold never materializes a (B, m) block: both sides' live
        sparse pairs dedup through the same sort-merge kernel as ingest,
        rows staying sparse recompact, and only the dense result rows
        (dense on either side, or a sparse union crossing the threshold)
        scatter into a compact block overlaid with each side's dense
        registers — cost tracks live pairs + promoted rows, which is what
        lets ``HybridWindowedBank.fold_window`` stay sparse-sized.
        """
        if self.cfg != other.cfg:
            raise ValueError(
                f"cannot merge banks with different configs: "
                f"{self.cfg} vs {other.cfg}"
            )
        if len(self) != len(other):
            raise ValueError(
                f"cannot merge banks of different sizes: "
                f"{len(self)} vs {len(other)} rows"
            )
        if self.threshold != other.threshold:
            raise ValueError(
                f"cannot merge banks with different sparse thresholds: "
                f"{self.threshold} vs {other.threshold}"
            )
        rows = len(self)
        m = self.cfg.m
        limbs = u64lib.add(
            u64lib.U64(self.n_items[:, 0], self.n_items[:, 1]),
            u64lib.U64(other.n_items[:, 0], other.n_items[:, 1]),
        )
        n_items = jnp.stack([limbs.hi, limbs.lo], axis=-1)
        if rows == 0:
            return dataclasses.replace(self, n_items=n_items)
        if rows * m >= 1 << 31:
            raise ValueError(
                f"bank cell space B*m = {rows}*{m} overflows int32 sort "
                f"cells; split the fleet across multiple banks"
            )
        slot_a = np.asarray(self.dense_slot)
        slot_b = np.asarray(other.dense_slot)
        force_dense = (slot_a >= 0) | (slot_b >= 0)
        # a row dense on one side still contributes the OTHER side's pairs
        # through the triple stream; its dense registers overlay below
        ra, ba, ka = self._pair_triples()
        rb, bb, kb = other._pair_triples()
        cell_s, rank_s, survivor, distinct = _sort_merge(
            jnp.asarray(np.concatenate([ra, rb])),
            jnp.asarray(np.concatenate([ba, bb])),
            jnp.asarray(np.concatenate([ka, kb])),
            rows=rows,
            m=m,
        )
        distinct_np = np.asarray(distinct)
        promote = ~force_dense & (distinct_np > self.threshold)
        keep = ~force_dense & ~promote
        cap = _fit_capacity(int(distinct_np[keep].max(initial=0)), self.threshold)
        pairs = _compact_pairs(
            cell_s, rank_s, survivor, jnp.asarray(keep), rows=rows, m=m, cap=cap
        )
        dense_idx = np.nonzero(force_dense | promote)[0]
        slot_of_row = np.full(rows, -1, np.int32)
        slot_of_row[dense_idx] = np.arange(dense_idx.size, dtype=np.int32)
        if dense_idx.size:
            dense = _materialize_rows(
                cell_s,
                rank_s,
                survivor,
                jnp.asarray(slot_of_row),
                slots=dense_idx.size,
                rows=rows,
                m=m,
            )
            for side, side_slot in ((self, slot_a), (other, slot_b)):
                if side.dense_rows:
                    sel = side_slot[dense_idx]
                    contrib = jnp.where(
                        (jnp.asarray(sel) >= 0)[:, None],
                        side.dense[
                            jnp.clip(jnp.asarray(sel), 0, side.dense_rows - 1)
                        ],
                        0,
                    )
                    dense = jnp.maximum(dense, contrib)
        else:
            dense = jnp.zeros((0, m), hll.REGISTER_DTYPE)
        return dataclasses.replace(
            self,
            pairs=pairs,
            sparse_len=jnp.asarray(np.where(keep, distinct_np, 0).astype(np.int32)),
            dense=dense,
            dense_slot=jnp.asarray(slot_of_row),
            n_items=n_items,
        )

    __or__ = merge

    # ------------------------------------------------------------------
    # estimation (paper phase 4, sparse-aware)
    # ------------------------------------------------------------------

    def _sparse_histograms(self) -> jnp.ndarray:
        """(B, K) int32 histograms straight from the pairs (C[0] = m - len)."""
        from repro.sketch import estimators as _estimators

        rows = len(self)
        k = _estimators.histogram_size(self.cfg)
        flat = self.pairs.reshape(-1)
        valid = flat >= 0
        rank = jnp.where(valid, flat & _PACK_MASK, 0)
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32), max(1, self.capacity))
        if self.capacity == 0:
            counts = jnp.zeros((rows, k), jnp.int32)
        else:
            idx = jnp.where(valid, row * k + rank, rows * k)
            counts = jnp.bincount(idx, length=rows * k + 1)[: rows * k]
            counts = counts.reshape(rows, k).astype(jnp.int32)
        return counts.at[:, 0].set(self.cfg.m - self.sparse_len)

    def estimate_many(
        self, estimator: Optional[str] = None, *, lc_fast: bool = True
    ) -> jnp.ndarray:
        """(B,) float32 estimates, sparse rows via the LC fast path.

        For the default ``original`` estimator, sparse rows finalize with
        the closed-form LinearCounting read (bit-identical to the dense
        device path — see the module docstring proof); other estimators
        (or ``lc_fast=False``) build histograms from the pairs and run
        the registered device finalizer.  Dense rows always finalize
        through the §8 batched ``estimate_many``.
        """
        from repro.sketch import estimators as _estimators

        rows = len(self)
        if rows == 0:
            return jnp.zeros((0,), jnp.float32)
        name = _estimators.resolve_estimator(estimator)
        if name == "original" and lc_fast:
            sparse_est = _lc_estimate(self.sparse_len, m=self.cfg.m)
        else:
            hist = self._sparse_histograms()
            sparse_est = _finalize_histograms(hist, self.cfg, name)
        if self.dense_rows:
            dense_est = _estimators.estimate_many(
                self.dense, self.cfg, estimator=name
            )
            slot = jnp.clip(self.dense_slot, 0, self.dense_rows - 1)
            return jnp.where(self.dense_slot >= 0, dense_est[slot], sparse_est)
        return sparse_est

    def estimate(self, i: int, estimator: Optional[str] = None) -> float:
        """Exact host-side estimate of one row."""
        return self.row(i).estimate(estimator)

    # ------------------------------------------------------------------
    # serialization (RHLB v2: per-row mode flags + sparse payloads)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        """RHLB v2: header + threshold + counts + mode flags + payloads."""
        rows = len(self)
        header = _BANK_HEADER.pack(
            _BANK_MAGIC,
            _SPARSE_VERSION,
            self.cfg.p,
            self.cfg.hash_bits,
            0,
            self.cfg.seed,
            rows,
        )
        out = [header, _THRESHOLD.pack(self.threshold)]
        out.append(self.counts.astype("<u8").tobytes())
        modes = self.modes
        out.append(modes.tobytes())
        pairs_np = np.asarray(self.pairs)
        dense_np = np.asarray(self.dense, dtype=np.uint8)
        slot_np = np.asarray(self.dense_slot)
        for i in range(rows):
            if modes[i] == MODE_DENSE:
                out.append(dense_np[slot_np[i]].tobytes())
            else:
                p = pairs_np[i]
                p = p[p >= 0]
                out.append(_NPAIRS.pack(p.size))
                buckets = (p >> _PACK_SHIFT).astype("<u2")
                ranks = (p & _PACK_MASK).astype(np.uint8)
                pair_bytes = np.zeros((p.size, 3), np.uint8)
                pair_bytes[:, :2] = buckets.view(np.uint8).reshape(-1, 2)
                pair_bytes[:, 2] = ranks
                out.append(pair_bytes.tobytes())
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "HybridBank":
        """Parse RHLB v2 strictly; v1 dense blobs parse as all-dense."""
        if len(data) < _BANK_HEADER.size:
            raise ValueError(f"truncated bank: {len(data)} bytes")
        magic, version, p, hash_bits, _flags, seed, rows = _BANK_HEADER.unpack(
            data[: _BANK_HEADER.size]
        )
        if magic != _BANK_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized bank")
        if version == 1:
            # dense blobs still parse, version-gated: every row stays dense
            bank = SketchBank.from_bytes(data)
            return cls.from_dense(
                bank, dense_rows=np.ones(len(bank), bool)
            )
        if version != _SPARSE_VERSION:
            raise ValueError(f"unsupported bank version {version}")
        if rows < 1:
            raise ValueError(f"bank header claims {rows} rows")
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        off = _BANK_HEADER.size
        if len(data) < off + _THRESHOLD.size:
            raise ValueError("truncated bank: threshold missing")
        (threshold,) = _THRESHOLD.unpack_from(data, off)
        threshold = _check_threshold(threshold, cfg)
        off += _THRESHOLD.size
        counts_end = off + rows * _ROW_COUNT.size
        modes_end = counts_end + rows
        if len(data) < modes_end:
            raise ValueError("truncated bank: counts/mode flags cut short")
        raw_counts = np.frombuffer(data[off:counts_end], dtype="<u8")
        modes = np.frombuffer(data[counts_end:modes_end], dtype=np.uint8)
        if not np.isin(modes, (MODE_SPARSE, MODE_DENSE)).all():
            raise ValueError(
                f"corrupt mode flag {int(modes.max())}; rows are sparse (0) "
                f"or dense (1)"
            )
        off = modes_end
        sparse_pairs, dense_regs = [], []
        for i in range(rows):
            if modes[i] == MODE_DENSE:
                if len(data) < off + cfg.m:
                    raise ValueError(f"row {i}: dense payload cut short")
                dense_regs.append(
                    np.frombuffer(data[off : off + cfg.m], np.uint8)
                )
                off += cfg.m
                continue
            if len(data) < off + _NPAIRS.size:
                raise ValueError(f"row {i}: pair count cut short")
            (npairs,) = _NPAIRS.unpack_from(data, off)
            off += _NPAIRS.size
            if npairs > threshold:
                raise ValueError(
                    f"row {i}: {npairs} pairs exceeds threshold {threshold}"
                )
            end = off + npairs * 3
            if len(data) < end:
                raise ValueError(f"row {i}: pair list cut short")
            raw = np.frombuffer(data[off:end], np.uint8).reshape(npairs, 3)
            buckets = raw[:, :2].copy().view("<u2").reshape(-1).astype(np.int64)
            ranks = raw[:, 2].astype(np.int64)
            if npairs:
                if buckets.max() >= cfg.m:
                    raise ValueError(
                        f"row {i}: bucket {int(buckets.max())} out of range "
                        f"for m={cfg.m}"
                    )
                if not (np.diff(buckets) > 0).all():
                    raise ValueError(
                        f"row {i}: pair buckets must be strictly increasing"
                    )
                if ranks.min() < 1 or ranks.max() > cfg.max_rank:
                    raise ValueError(
                        f"row {i}: rank outside [1, {cfg.max_rank}]"
                    )
            sparse_pairs.append(
                ((buckets << _PACK_SHIFT) | ranks).astype(np.int32)
            )
            off = end
        if off != len(data):
            raise ValueError(
                f"bank payload is {len(data)} bytes, expected {off}"
            )
        cap = _fit_capacity(
            max((p.size for p in sparse_pairs), default=0), threshold
        )
        pairs = np.full((rows, cap), _EMPTY, np.int32)
        sparse_len = np.zeros(rows, np.int32)
        dense_slot = np.full(rows, -1, np.int32)
        # assign dense slots in row order (matching to_bytes)
        d = s = 0
        for i in range(rows):
            if modes[i] == MODE_DENSE:
                dense_slot[i] = d
                d += 1
            else:
                pr = sparse_pairs[s]
                pairs[i, : pr.size] = pr
                sparse_len[i] = pr.size
                s += 1
        limbs = np.stack(
            [(raw_counts >> 32).astype(np.uint32), raw_counts.astype(np.uint32)],
            axis=-1,
        )
        dense = (
            np.stack(dense_regs)
            if dense_regs
            else np.zeros((0, cfg.m), np.uint8)
        )
        return cls(
            jnp.asarray(pairs),
            jnp.asarray(sparse_len),
            jnp.asarray(dense),
            jnp.asarray(dense_slot),
            jnp.asarray(limbs),
            cfg,
            threshold,
        )


# ----------------------------------------------------------------------------
# module-level entry point (mirrors bank.update_many)
# ----------------------------------------------------------------------------


def update_many(
    bank: HybridBank,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    plan: Optional[ExecutionPlan] = None,
) -> HybridBank:
    """Batched hybrid ingestion: sparse/dense routing in one fused pass."""
    return bank.update_many(keys, items, plan)
