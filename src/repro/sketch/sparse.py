"""Sparse tenant-row storage with automatic dense promotion (DESIGN.md §12).

The paper's premise is sub-linear memory on the input domain, yet a
``SketchBank`` allocates a dense (B, m) register block no matter how empty
its rows are — at "millions of users" scale most tenant rows hold a
handful of distinct items and waste ~m bytes each.  HyperLogLogLog
(arXiv:2205.11327) and the memory-efficient FPGA sketch follow-up
(arXiv:2504.16896) both show compressed/sparse register storage preserves
estimate quality while cutting memory by an order of magnitude; this
module is that idea over the bank subsystem of DESIGN.md §9.

A ``HybridBank`` keeps every row in one of two representations:

* **sparse** — the row's distinct ``(bucket_idx, rank)`` pairs, packed as
  ``bucket << 8 | rank`` int32 values in a capped per-row COO buffer of
  shape (B, C).  C adapts to the actual occupancy of the sparse rows
  (grown/shrunk at compaction), so near-empty tenants cost a few dozen
  bytes instead of m.
* **dense** — the usual (m,) uint8 register row, held in a compact
  (D, m) block that only promoted rows occupy (``slot_map`` maps row ->
  block slot, -1 for sparse rows).

**Promotion contract.** A row is promoted exactly when its distinct-bucket
count crosses ``threshold`` (default m // 4): sparse rows always satisfy
``len <= threshold``.  Promotion materializes the row's full
bucket -> max-rank map, so a promoted row's registers are **bit-identical**
to dense-from-scratch ingestion of the same stream, and estimates cannot
shift at the boundary (tests/test_sparse.py).  Promotion is one-way;
``merge`` keeps dense mode infectious (a row dense on either side stays
dense).

**Amortized ingest (append buffer + deferred compaction).**
``update_many(keys, items, plan)`` routes the whole keyed stream in one
pass with no python loop over rows: dense-destined items dispatch through
the registered bank backend of ``plan`` (the §9 scatter — jnp or the
Pallas bank kernel), while sparse-destined items land in a per-bank
**append buffer** of raw (row, item) entries with NO dedup — an O(new)
append, so steady-state ingest cost tracks new pairs instead of all live
pairs.  Dedup runs as a **compaction** step only under capacity pressure
(the buffer outgrowing ``max(_FLUSH_MIN_PAIRS, _FLUSH_FACTOR * live)``)
or before any read — every estimate / serialize / merge / to_dense /
introspection surface settles the bank first, so deferral is invisible:
compacted state is bit-identical to eagerly deduplicating every batch
(the register lattice is an associative, commutative, idempotent max).
Compaction hashes the buffered items once (pow2-padded, jitted), re-emits
the live COO pairs as triples, and dispatches the combined stream through
the **sparse backend registry** (``register_sparse_backend`` /
``dedup_pairs``): the jnp entry picks sort-merge or segment-max scatter by
stream-vs-bank size, the pallas entries run the ``sparse_scatter`` kernel
(VMEM-resident pair tiles per COO row block) — all bit-identical.  The §9
key-routing contract holds unchanged: out-of-range keys are dropped,
never buffered, and never counted.

**Estimation.** ``estimate_many`` finalizes sparse rows with the
linear-counting fast path: a sparse row has at most ``threshold <= m/2``
non-zero registers, which provably pins the ``original`` estimator to its
small-range LinearCounting branch (E_raw <= 2*alpha*m < 2.5m and V > 0),
so ``m * log(m / (m - len))`` is bit-identical to the dense device path
while reading only the per-row pair count.  Other registered estimators
build the (B, K) register histogram straight from the pairs
(C[0] = m - len) and run their normal device finalizer — also
bit-identical to the dense path, because the histogram is.

**Wire format v2.** ``to_bytes`` reuses the RHLB framing with
``version=2``: header + u32 threshold + per-row u64 counts + per-row mode
flags + per-row payloads (dense rows: m register bytes; sparse rows: u16
pair count + sorted (u16 bucket, u8 rank) pairs).  ``from_bytes`` parses
v2 strictly (mode flags, pair ordering, rank ranges, exact length) and
still accepts v1 dense blobs — version-gated, producing an all-dense
hybrid — while ``SketchBank.from_bytes`` keeps rejecting v2 with a
targeted error.  Serialization always writes the compacted state: the
append buffer is transient and never hits the wire.

``HybridBank`` is host-orchestrated (promotion reshapes the dense block),
so unlike ``SketchBank`` it is NOT a jit-traceable pytree; the fused
device work happens inside the jitted dedup/scatter kernels behind
``dedup_pairs``.
"""

from __future__ import annotations

import dataclasses
import struct
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.sketch import hll, u64 as u64lib
from repro.sketch.bank import (
    _BANK_HEADER,
    _BANK_MAGIC,
    _ROW_COUNT,
    SketchBank,
    _counter_add_rows,
    _sharded_estimate_fn,
    update_bank_registers,
)
from repro.sketch.carrier import HyperLogLog
from repro.sketch.dispatch import dedup_pairs, row_shard_apply
from repro.sketch.hll import HLLConfig
from repro.sketch.plan import DEFAULT_PLAN, ExecutionPlan, SparseDedup

_PACK_SHIFT = 8  # packed pair = bucket << 8 | rank (rank <= 61 fits a byte)
_PACK_MASK = (1 << _PACK_SHIFT) - 1
_EMPTY = -1  # empty-slot sentinel in the packed pair buffer
_SPARSE_VERSION = 2
_THRESHOLD = struct.Struct("<I")
_NPAIRS = struct.Struct("<H")
_PAIR = struct.Struct("<HB")
MODE_SPARSE, MODE_DENSE = 0, 1

# Append-buffer pressure policy (DESIGN.md §12): a compaction is forced from
# inside update_many only once the buffered raw pairs pass BOTH floors —
# an absolute floor (below it the buffer is cheap: 8 bytes/pair of host
# memory, nothing device-resident) and a multiple of the live deduped pairs
# (so each compaction ingests at least _FLUSH_FACTOR times the pairs it
# re-sorts, keeping total compaction work O(total appends) — the classic
# amortized-doubling argument).  Reads never see the buffer: every
# estimate/serialize/merge/introspection surface compacts first.
_FLUSH_MIN_PAIRS = 1 << 22
_FLUSH_FACTOR = 4


def default_threshold(cfg: HLLConfig) -> int:
    """The default promotion threshold: m // 4 distinct buckets."""
    return max(1, cfg.m // 4)


def _check_threshold(threshold: int, cfg: HLLConfig) -> int:
    """Thresholds above m // 2 would leave the LC-regime guarantee (the
    proof in the module docstring needs V = m - len >= m/2)."""
    threshold = int(threshold)
    if not 1 <= threshold <= max(1, cfg.m // 2):
        raise ValueError(
            f"sparse threshold must be in [1, {max(1, cfg.m // 2)}] "
            f"(m // 2 keeps sparse rows in the LinearCounting regime), "
            f"got {threshold}"
        )
    return threshold


def _check_cell_space(rows: int, m: int) -> None:
    """The one guard for every dedup entry: flattened (row, bucket) cell
    ids must fit int32 (TPU has no 64-bit datapath), or the dedup backends
    would silently wrap them."""
    if rows * m >= 1 << 31:
        raise ValueError(
            f"bank cell space B*m = {rows}*{m} overflows int32 sort "
            f"cells; split the fleet across multiple banks"
        )


def _fit_capacity(needed: int, threshold: int) -> int:
    """Smallest pow2-ish pair capacity holding ``needed`` entries."""
    if needed <= 0:
        return 0
    return min(threshold, max(4, 1 << (needed - 1).bit_length()))


@dataclasses.dataclass(frozen=True)
class _PendingLog:
    """The append buffer: raw sparse-destined (keys, items) sub-streams.

    Appending is a tuple concat of host arrays — O(chunks), no device
    dispatch, no dedup — so ingest cost between compactions tracks NEW
    pairs only.  ``plan`` remembers the most recent ingest plan so a
    read-triggered compaction runs the same registered sparse backend the
    writer chose (the differential harness depends on this to exercise
    every backend's dedup path).
    """

    chunks: Tuple[Tuple[np.ndarray, np.ndarray], ...]
    total: int
    plan: ExecutionPlan


# ----------------------------------------------------------------------------
# fused device kernels (jitted; static shapes per (stream, capacity) pair)
# ----------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg",))
def _hash_stream(items, cfg: HLLConfig):
    """Jitted phase-1+3a hash of the buffered sparse-destined sub-stream.

    ``hash_index_rank`` is ~a hundred murmur3 ops; running it eagerly
    would dominate the whole hybrid compaction pass.
    """
    return hll.hash_index_rank(items, cfg)


@partial(jax.jit, static_argnames=("rows", "m", "cap"))
def _compact_pairs(cell_s, rank_s, survivor, keep_row, *, rows, m, cap):
    """Scatter surviving pairs of still-sparse rows into a (B, cap) buffer.

    Survivors arrive sorted by (row, bucket); each kept entry's slot is
    its running index within its row, so the output rows are bucket-sorted
    with ``-1`` padding — the invariant the v2 wire format serializes.
    """
    row_s = cell_s // m
    bucket_s = cell_s - row_s * m
    safe_row = jnp.clip(row_s, 0, rows - 1)
    take = survivor & keep_row[safe_row] & (row_s < rows)
    pos = jnp.cumsum(take.astype(jnp.int32)) - 1
    row_counts = jnp.bincount(
        jnp.where(take, row_s, rows), length=rows + 1
    )[:rows]
    row_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(row_counts)[:-1].astype(jnp.int32)]
    )
    offset = pos - row_start[safe_row]
    idx = jnp.where(take & (offset < cap), safe_row * cap + offset, rows * cap)
    packed = (bucket_s << _PACK_SHIFT) | rank_s
    out = jnp.full((rows * cap,), _EMPTY, jnp.int32)
    out = out.at[idx].set(packed, mode="drop")
    return out.reshape(rows, cap)


def _compact_cells(cells_np, keep_row, distinct, *, cap):
    """Dense-cells twin of ``_compact_pairs``: (B, m) max-rank map -> pairs.

    Host-side on purpose: an XLA scatter over all B*m cells lowers to a
    serial loop on CPU (seconds at B=16384), while a row-major
    ``np.flatnonzero`` scan is one vectorized pass — and it emits each
    row's surviving buckets in ascending order, exactly the slot order
    the sorted path produces, so the two layouts compact to bit-identical
    buffers.  ``distinct`` is the dedup's per-row survivor count, reused
    as the per-row offset base instead of re-counting the mask.
    """
    rows, m = cells_np.shape
    nz = np.flatnonzero(cells_np.reshape(-1))
    r = nz // m
    c = nz - r * m
    sel_rows = keep_row[r]
    r, c = r[sel_rows], c[sel_rows]
    kept_counts = np.where(keep_row, distinct, 0)
    start = np.concatenate([[0], np.cumsum(kept_counts)[:-1]])
    off = np.arange(r.size) - start[r]
    pairs = np.full((rows, cap), _EMPTY, np.int32)
    sel = off < cap
    pairs[r[sel], off[sel]] = (c[sel].astype(np.int32) << _PACK_SHIFT) | (
        cells_np[r[sel], c[sel]].astype(np.int32)
    )
    return jnp.asarray(pairs)


@partial(jax.jit, static_argnames=("slots", "rows", "m"))
def _materialize_rows(cell_s, rank_s, survivor, slot_of_row, *, slots, rows, m):
    """Scatter surviving pairs of promoted rows into fresh dense registers.

    ``slot_of_row`` maps each promoted row to a local slot in [0, slots);
    every other row maps to -1 and contributes nothing.  The scatter sees
    the row's FULL deduped bucket -> max-rank map, so the produced
    registers are bit-identical to dense-from-scratch ingestion.
    """
    row_s = cell_s // m
    bucket_s = cell_s - row_s * m
    slot = slot_of_row[jnp.clip(row_s, 0, rows - 1)]
    take = survivor & (row_s < rows) & (slot >= 0)
    seg = jnp.where(take, slot * m + bucket_s, slots * m)
    regs = jax.ops.segment_max(
        jnp.where(take, rank_s, 0).astype(hll.REGISTER_DTYPE),
        seg,
        num_segments=slots * m + 1,
    )
    return regs[: slots * m].reshape(slots, m)


def _dedup_products(
    dd: SparseDedup,
    keep: np.ndarray,
    slot_of_row: np.ndarray,
    *,
    rows: int,
    m: int,
    cap: int,
    slots: int,
):
    """Compacted (B, cap) pairs + (slots, m) promoted registers from a dedup.

    Handles both :class:`SparseDedup` layouts; either way the promoted
    rows' registers carry the full deduped bucket -> max-rank map (in the
    cells layout that map IS the register row — promotion is a gather).
    ``slot_of_row`` must assign slots in ascending row order, which both
    call sites do.
    """
    if dd.cells is not None:
        cells_np = np.asarray(dd.cells)
        pairs = _compact_cells(cells_np, keep, np.asarray(dd.distinct), cap=cap)
        dense = (
            jnp.asarray(
                cells_np[np.nonzero(slot_of_row >= 0)[0]].astype(
                    hll.REGISTER_DTYPE
                )
            )
            if slots
            else None
        )
    else:
        pairs = _compact_pairs(
            dd.cell_s,
            dd.rank_s,
            dd.survivor,
            jnp.asarray(keep),
            rows=rows,
            m=m,
            cap=cap,
        )
        dense = (
            _materialize_rows(
                dd.cell_s,
                dd.rank_s,
                dd.survivor,
                jnp.asarray(slot_of_row),
                slots=slots,
                rows=rows,
                m=m,
            )
            if slots
            else None
        )
    return pairs, dense


@partial(jax.jit, static_argnames=("rows", "m"))
def _scatter_pairs_dense(pairs, *, rows, m):
    """(B, C) packed pairs -> (B, m) uint8 registers (one scatter-max)."""
    regs = jnp.zeros((rows, m), hll.REGISTER_DTYPE)
    if pairs.shape[1] == 0:
        return regs
    valid = pairs >= 0
    row = jnp.broadcast_to(
        jnp.arange(rows, dtype=jnp.int32)[:, None], pairs.shape
    )
    bucket = jnp.where(valid, pairs >> _PACK_SHIFT, 0)
    rank = jnp.where(valid, pairs & _PACK_MASK, 0)
    return regs.at[row, bucket].max(rank.astype(hll.REGISTER_DTYPE))


@partial(jax.jit, static_argnames=("m",))
def _lc_estimate(sparse_len, *, m):
    """Closed-form LinearCounting over per-row distinct counts.

    Jitted (not eager) so the float32 log lowers through the same XLA
    codegen as the dense device finalizer — eager batched transcendentals
    can differ in the last ulp, and the sparse fast path is pinned
    bit-identical to the dense path (tests/test_sparse.py).
    """
    fm = float(m)
    v = (fm - sparse_len).astype(jnp.float32)
    return fm * jnp.log(fm / jnp.maximum(v, 1.0))


@partial(jax.jit, static_argnames=("cfg", "estimator"))
def _finalize_histograms(hist, cfg: HLLConfig, estimator: str):
    """Jitted registry finalizer over prebuilt (B, K) histograms."""
    from repro.sketch import estimators as _estimators

    return _estimators.get_estimator(estimator).device(
        hist.astype(jnp.float32), cfg
    )


# ----------------------------------------------------------------------------
# the hybrid carrier
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HybridBank:
    """B same-config sketches, each row sparse (COO pairs) or dense.

    The stored fields are the SETTLED state plus the transient append
    buffer; external readers should use the ``pairs`` / ``sparse_len`` /
    ``dense`` / ``dense_slot`` properties (or any read method), which
    compact the buffer first — raw fields are only safe on a bank whose
    ``pending`` is None.
    """

    pair_buf: jnp.ndarray  # (B, C) int32 packed bucket<<8|rank, -1 = empty
    pair_len: jnp.ndarray  # (B,) int32 distinct buckets (0 for dense rows)
    dense_block: jnp.ndarray  # (D, m) uint8 registers of promoted rows
    slot_map: jnp.ndarray  # (B,) int32 slot into dense_block, -1 = sparse
    n_items: jnp.ndarray  # (B, 2) uint32 limb pairs, exact per-row counts
    cfg: HLLConfig
    threshold: int  # promote when a row's distinct buckets exceed this
    pending: Optional[_PendingLog] = None  # un-deduplicated append buffer

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(
        cls,
        rows: int,
        cfg: Optional[HLLConfig] = None,
        threshold: Optional[int] = None,
    ) -> "HybridBank":
        cfg = cfg or HLLConfig()
        if rows < 1:
            raise ValueError(f"a bank needs at least one row, got {rows}")
        threshold = _check_threshold(
            default_threshold(cfg) if threshold is None else threshold, cfg
        )
        return cls(
            jnp.zeros((rows, 0), jnp.int32),
            jnp.zeros((rows,), jnp.int32),
            jnp.zeros((0, cfg.m), hll.REGISTER_DTYPE),
            jnp.full((rows,), -1, jnp.int32),
            jnp.zeros((rows, 2), jnp.uint32),
            cfg,
            threshold,
        )

    @classmethod
    def from_dense(
        cls,
        bank: SketchBank,
        threshold: Optional[int] = None,
        dense_rows=None,
    ) -> "HybridBank":
        """Demote a dense bank: rows at or under ``threshold`` distinct
        buckets become sparse unless forced dense via ``dense_rows``."""
        cfg = bank.cfg
        threshold = _check_threshold(
            default_threshold(cfg) if threshold is None else threshold, cfg
        )
        regs = np.asarray(bank.registers)
        rows = regs.shape[0]
        occ = (regs > 0).sum(axis=1).astype(np.int64)
        force = (
            np.zeros(rows, bool)
            if dense_rows is None
            else np.asarray(dense_rows, bool)
        )
        if force.shape != (rows,):
            raise ValueError(
                f"dense_rows must be a ({rows},) mask, got {force.shape}"
            )
        dense_mask = force | (occ > threshold)
        sparse_mask = ~dense_mask
        sr, sb = np.nonzero(np.where(sparse_mask[:, None], regs, 0))
        counts = np.bincount(sr, minlength=rows)
        cap = _fit_capacity(int(counts.max(initial=0)), threshold)
        pairs = np.full((rows, cap), _EMPTY, np.int32)
        if sr.size:
            start = np.concatenate([[0], np.cumsum(counts)[:-1]])
            off = np.arange(sr.size) - start[sr]
            pairs[sr, off] = (sb.astype(np.int32) << _PACK_SHIFT) | regs[
                sr, sb
            ].astype(np.int32)
        dense_idx = np.nonzero(dense_mask)[0]
        dense_slot = np.full(rows, -1, np.int32)
        dense_slot[dense_idx] = np.arange(dense_idx.size, dtype=np.int32)
        return cls(
            jnp.asarray(pairs),
            jnp.asarray(np.where(sparse_mask, occ, 0).astype(np.int32)),
            jnp.asarray(regs[dense_idx]),
            jnp.asarray(dense_slot),
            bank.n_items,
            cfg,
            threshold,
        )

    @classmethod
    def from_sketches(
        cls,
        sketches: Sequence[HyperLogLog],
        threshold: Optional[int] = None,
    ) -> "HybridBank":
        return cls.from_dense(SketchBank.from_sketches(sketches), threshold)

    # ------------------------------------------------------------------
    # compaction (the append buffer's one exit; every read routes here)
    # ------------------------------------------------------------------

    @property
    def pending_pairs(self) -> int:
        """Raw (bucket, rank) appends buffered since the last compaction."""
        return 0 if self.pending is None else self.pending.total

    def _pending_pressure(self) -> bool:
        """True once the buffer passes both flush floors (module note)."""
        pend = self.pending
        if pend is None or pend.total < _FLUSH_MIN_PAIRS:
            return False
        live = int(np.asarray(self.pair_len, dtype=np.int64).sum())
        return pend.total >= max(_FLUSH_MIN_PAIRS, _FLUSH_FACTOR * live)

    def compact(self, _reason: str = "read") -> "HybridBank":
        """Settle the append buffer: dedup, recompact, promote — one pass.

        Idempotent and cached (a bank is immutable, so its settled form
        is too): repeated reads on the same instance compact once.  The
        result is bit-identical to having eagerly deduplicated every
        ``update_many`` batch — the register lattice is an associative,
        commutative, idempotent max, so batching order is invisible.

        ``_reason`` labels the flush for the metrics registry: "read" for
        settle-reads (a read surface forcing the buffer down), "pressure"
        when the ingest path crossed the flush floors.
        """
        if self.pending is None:
            return self
        cached = self.__dict__.get("_settled")
        if cached is None:
            obs_metrics.inc(f"sparse.flush.{_reason}")
            cached = self._compact_now()
            object.__setattr__(self, "_settled", cached)
        return cached

    def _compact_now(self) -> "HybridBank":
        pend = self.pending
        rows, m = len(self), self.cfg.m
        keys_np = np.concatenate([k for k, _ in pend.chunks])
        items_np = np.concatenate([v for _, v in pend.chunks])
        n = keys_np.size
        # pow2 padding (row = -1, dropped by the dedup validity mask)
        # bounds jit recompiles of the hash and dedup kernels
        pad = 1 << max(6, (n - 1).bit_length()) if n else 64
        items_pad = np.zeros(pad, items_np.dtype)
        items_pad[:n] = items_np
        new_rows = np.full(pad, -1, np.int32)
        new_rows[:n] = keys_np
        idx, rank = _hash_stream(jnp.asarray(items_pad), self.cfg)
        old_rows, old_buckets, old_ranks = self._pair_triples()
        dd = dedup_pairs(
            jnp.concatenate([jnp.asarray(old_rows), jnp.asarray(new_rows)]),
            jnp.concatenate([jnp.asarray(old_buckets), idx]),
            jnp.concatenate([jnp.asarray(old_ranks), rank]),
            rows,
            self.cfg,
            pend.plan,
        )
        distinct_np = np.asarray(dd.distinct)
        slot_np = np.asarray(self.slot_map)
        was_sparse = slot_np < 0
        promote = was_sparse & (distinct_np > self.threshold)
        keep = was_sparse & ~promote
        cap = _fit_capacity(
            int(distinct_np[keep].max(initial=0)), self.threshold
        )
        promoted = np.nonzero(promote)[0]
        if promoted.size:
            obs_metrics.inc("sparse.promotions", int(promoted.size))
        slot_of_row = np.full(rows, -1, np.int32)
        slot_of_row[promoted] = np.arange(promoted.size, dtype=np.int32)
        new_pairs, fresh = _dedup_products(
            dd, keep, slot_of_row, rows=rows, m=m, cap=cap, slots=promoted.size
        )
        new_dense = self.dense_block
        new_slot = slot_np
        if promoted.size:
            new_dense = (
                jnp.concatenate([new_dense, fresh])
                if new_dense.shape[0]
                else fresh
            )
            new_slot = slot_np.copy()
            new_slot[promoted] = self.dense_block.shape[0] + np.arange(
                promoted.size, dtype=np.int32
            )
        return dataclasses.replace(
            self,
            pair_buf=new_pairs,
            pair_len=jnp.asarray(np.where(keep, distinct_np, 0).astype(np.int32)),
            dense_block=new_dense,
            slot_map=jnp.asarray(new_slot),
            pending=None,
        )

    # ------------------------------------------------------------------
    # introspection (every surface reads the SETTLED state)
    # ------------------------------------------------------------------

    def __len__(self) -> int:
        return int(self.n_items.shape[0])

    @property
    def pairs(self) -> jnp.ndarray:
        """(B, C) packed pair buffer of the settled state."""
        return self.compact().pair_buf

    @property
    def sparse_len(self) -> jnp.ndarray:
        """(B,) int32 distinct-bucket counts of the settled state."""
        return self.compact().pair_len

    @property
    def dense(self) -> jnp.ndarray:
        """(D, m) uint8 dense block of the settled state."""
        return self.compact().dense_block

    @property
    def dense_slot(self) -> jnp.ndarray:
        """(B,) int32 row -> dense slot map of the settled state."""
        return self.compact().slot_map

    @property
    def capacity(self) -> int:
        """Current per-row sparse pair capacity C."""
        return int(self.compact().pair_buf.shape[1])

    @property
    def dense_rows(self) -> int:
        """Number of promoted rows (the D of the dense block)."""
        return int(self.compact().dense_block.shape[0])

    @property
    def modes(self) -> np.ndarray:
        """(B,) uint8 row modes: MODE_SPARSE (0) or MODE_DENSE (1)."""
        return (np.asarray(self.compact().slot_map) >= 0).astype(np.uint8)

    @property
    def counts(self) -> np.ndarray:
        """(B,) exact per-row observation counts as uint64.

        Counters update eagerly at ingest (one bincount per batch), so
        they never wait on a compaction.
        """
        limbs = np.asarray(self.n_items)
        hi = limbs[:, 0].astype(np.uint64)
        lo = limbs[:, 1].astype(np.uint64)
        return (hi << np.uint64(32)) | lo

    @property
    def nbytes(self) -> int:
        """Storage footprint of the settled hybrid representation."""
        s = self.compact()
        return int(
            s.pair_buf.nbytes
            + s.pair_len.nbytes
            + s.dense_block.nbytes
            + s.slot_map.nbytes
            + s.n_items.nbytes
        )

    def density(self) -> dict:
        """Storage introspection: modes, occupancy, and the memory win."""
        s = self.compact()
        rows = len(s)
        m = s.cfg.m
        d = int(s.dense_block.shape[0])
        occ = np.asarray(s.pair_len).astype(np.int64)
        if d:
            dense_occ = (np.asarray(s.dense_block) > 0).sum(axis=1)
            slot_np = np.asarray(s.slot_map)
            occ = occ + np.zeros_like(occ)
            occ[slot_np >= 0] = dense_occ[slot_np[slot_np >= 0]]
        dense_nbytes = rows * m + rows * 8  # what a SketchBank would cost
        return {
            "rows": rows,
            "dense_rows": d,
            "sparse_rows": rows - d,
            "capacity": int(s.pair_buf.shape[1]),
            "threshold": s.threshold,
            "occupancy_mean": float(occ.mean() / m) if rows else 0.0,
            "nbytes": s.nbytes,
            "dense_nbytes": dense_nbytes,
            "reduction": dense_nbytes / s.nbytes if s.nbytes else 0.0,
        }

    def row(self, i: int) -> HyperLogLog:
        """Row ``i`` materialized as a standalone dense carrier."""
        rows = len(self)
        if not -rows <= i < rows:
            raise IndexError(f"row {i} out of range for a {rows}-row bank")
        i = i % rows
        s = self.compact()
        slot = int(s.slot_map[i])
        if slot >= 0:
            regs = s.dense_block[slot]
        else:
            regs_np = np.zeros(s.cfg.m, np.uint8)
            p = np.asarray(s.pair_buf[i])
            p = p[p >= 0]
            regs_np[p >> _PACK_SHIFT] = (p & _PACK_MASK).astype(np.uint8)
            regs = jnp.asarray(regs_np)
        return HyperLogLog(regs, s.n_items[i], s.cfg)

    # ------------------------------------------------------------------
    # conversion
    # ------------------------------------------------------------------

    def _pair_triples(self):
        """Live pairs as (row, bucket, rank) int32 triples, pow2-padded.

        Reads the raw ``pair_buf`` — settled banks only (compaction and
        merge call it after settling).  The pair buffer allocates capacity
        C for every row, but only ``sum(pair_len)`` slots are live;
        extracting them (host-side, one vectorized pass) keeps the dedup
        cost proportional to LIVE pairs, not B*C, and the pow2 padding
        (row = -1, dropped by the dedup validity mask) bounds jit
        recompiles.
        """
        pairs_np = np.asarray(self.pair_buf)
        rows_np, slots = np.nonzero(pairs_np >= 0)
        packed = pairs_np[rows_np, slots]
        p = packed.size
        pad = 1 << max(6, (p - 1).bit_length()) if p else 64
        row = np.full(pad, -1, np.int32)
        bucket = np.zeros(pad, np.int32)
        rank = np.zeros(pad, np.int32)
        row[:p] = rows_np
        bucket[:p] = packed >> _PACK_SHIFT
        rank[:p] = packed & _PACK_MASK
        return row, bucket, rank

    def _dense_registers(self) -> jnp.ndarray:
        """The settled bank materialized as (B, m) uint8 registers."""
        s = self.compact()
        rows = len(s)
        regs = _scatter_pairs_dense(s.pair_buf, rows=rows, m=s.cfg.m)
        d = int(s.dense_block.shape[0])
        if d:
            slot = jnp.clip(s.slot_map, 0, d - 1)
            regs = jnp.where(
                (s.slot_map >= 0)[:, None], s.dense_block[slot], regs
            )
        return regs

    def to_dense(self) -> SketchBank:
        """Materialize to a plain dense ``SketchBank`` (lossless)."""
        return SketchBank(self._dense_registers(), self.n_items, self.cfg)

    def to_sketches(self) -> list:
        return [self.row(i) for i in range(len(self))]

    # ------------------------------------------------------------------
    # aggregation (paper phase 3, hybrid-routed)
    # ------------------------------------------------------------------

    def update_many(
        self,
        keys: jnp.ndarray,
        items: jnp.ndarray,
        plan: Optional[ExecutionPlan] = None,
    ) -> "HybridBank":
        """Route each item to row ``keys[i]``'s current representation.

        One host-orchestrated pass, no python loop over rows: the
        dense-destined sub-stream dispatches through the bank backend
        registered under ``plan.backend`` (§9) immediately, while the
        sparse-destined sub-stream APPENDS to the raw pair buffer — no
        hash, no dedup, no device dispatch — and only compacts here if
        the buffer passes the pressure floors (module note).  Promotions
        therefore fire at compaction rather than per batch, which cannot
        change the outcome: the register lattice is a max, so the settled
        state is bit-identical to eager per-batch dedup.  Zero-length
        streams and zero-row banks return ``self`` without dispatching
        any backend.
        """
        keys_np = np.asarray(keys).reshape(-1)
        items_np = np.asarray(items).reshape(-1)
        if keys_np.shape[0] != items_np.shape[0]:
            raise ValueError(
                f"keys ({keys_np.shape[0]}) and items "
                f"({items_np.shape[0]}) must flatten to the same length"
            )
        rows = len(self)
        if items_np.shape[0] == 0 or rows == 0:
            return self
        _check_cell_space(rows, self.cfg.m)
        plan = (DEFAULT_PLAN if plan is None else plan).validate()
        keys_np = keys_np.astype(np.int32, copy=False)
        slot_np = np.asarray(self.slot_map)
        valid = (keys_np >= 0) & (keys_np < rows)
        dest = np.where(valid, slot_np[np.clip(keys_np, 0, rows - 1)], -1)
        dense_sel = valid & (dest >= 0)
        sparse_sel = valid & (dest < 0)

        new_dense = self.dense_block
        if dense_sel.any():
            new_dense = update_bank_registers(
                self.dense_block,
                jnp.asarray(dest[dense_sel]),
                jnp.asarray(items_np[dense_sel]),
                self.cfg,
                plan,
            )

        pending = self.pending
        if sparse_sel.any():
            appended = int(sparse_sel.sum())
            chunk = (keys_np[sparse_sel], items_np[sparse_sel])
            chunks = (chunk,) if pending is None else pending.chunks + (chunk,)
            total = appended + (pending.total if pending else 0)
            pending = _PendingLog(chunks, total, plan)
            obs_metrics.inc("sparse.pending.appends")
            obs_metrics.inc("sparse.pending.pairs", appended)

        # one host bincount keeps the counters exact without a device
        # round-trip on the pure-append path
        counts = np.bincount(keys_np[valid], minlength=rows)[:rows]
        out = dataclasses.replace(
            self,
            dense_block=new_dense,
            n_items=_counter_add_rows(
                self.n_items, jnp.asarray(counts.astype(np.uint32))
            ),
            pending=pending,
        )
        if out._pending_pressure():
            return out.compact(_reason="pressure")
        return out

    def merge(
        self, other: "HybridBank", plan: Optional[ExecutionPlan] = None
    ) -> "HybridBank":
        """Row-wise Merge-buckets fold; dense mode is infectious.

        Both sides settle their append buffers first (each under its own
        recorded ingest plan), then the fold dedups both sides' live
        sparse pairs through the same ``dedup_pairs`` dispatch as
        compaction — under ``plan`` (default jnp) — rows staying sparse
        recompact, and only the dense result rows (dense on either side,
        or a sparse union crossing the threshold) materialize registers
        overlaid with each side's dense blocks, so cost tracks live pairs
        + promoted rows — which is what lets
        ``HybridWindowedBank.fold_window`` stay sparse-sized.
        """
        if self.cfg != other.cfg:
            raise ValueError(
                f"cannot merge banks with different configs: "
                f"{self.cfg} vs {other.cfg}"
            )
        if len(self) != len(other):
            raise ValueError(
                f"cannot merge banks of different sizes: "
                f"{len(self)} vs {len(other)} rows"
            )
        if self.threshold != other.threshold:
            raise ValueError(
                f"cannot merge banks with different sparse thresholds: "
                f"{self.threshold} vs {other.threshold}"
            )
        a, b = self.compact(), other.compact()
        rows = len(a)
        m = a.cfg.m
        limbs = u64lib.add(
            u64lib.U64(a.n_items[:, 0], a.n_items[:, 1]),
            u64lib.U64(b.n_items[:, 0], b.n_items[:, 1]),
        )
        n_items = jnp.stack([limbs.hi, limbs.lo], axis=-1)
        if rows == 0:
            return dataclasses.replace(a, n_items=n_items)
        _check_cell_space(rows, m)
        plan = (DEFAULT_PLAN if plan is None else plan).validate()
        slot_a = np.asarray(a.slot_map)
        slot_b = np.asarray(b.slot_map)
        force_dense = (slot_a >= 0) | (slot_b >= 0)
        # a row dense on one side still contributes the OTHER side's pairs
        # through the triple stream; its dense registers overlay below
        ra, ba, ka = a._pair_triples()
        rb, bb, kb = b._pair_triples()
        dd = dedup_pairs(
            jnp.asarray(np.concatenate([ra, rb])),
            jnp.asarray(np.concatenate([ba, bb])),
            jnp.asarray(np.concatenate([ka, kb])),
            rows,
            a.cfg,
            plan,
        )
        distinct_np = np.asarray(dd.distinct)
        promote = ~force_dense & (distinct_np > a.threshold)
        keep = ~force_dense & ~promote
        cap = _fit_capacity(int(distinct_np[keep].max(initial=0)), a.threshold)
        dense_idx = np.nonzero(force_dense | promote)[0]
        slot_of_row = np.full(rows, -1, np.int32)
        slot_of_row[dense_idx] = np.arange(dense_idx.size, dtype=np.int32)
        pairs, dense = _dedup_products(
            dd, keep, slot_of_row, rows=rows, m=m, cap=cap, slots=dense_idx.size
        )
        if dense_idx.size:
            for side, side_slot in ((a, slot_a), (b, slot_b)):
                d = int(side.dense_block.shape[0])
                if d:
                    sel = side_slot[dense_idx]
                    contrib = jnp.where(
                        (jnp.asarray(sel) >= 0)[:, None],
                        side.dense_block[jnp.clip(jnp.asarray(sel), 0, d - 1)],
                        0,
                    )
                    dense = jnp.maximum(dense, contrib)
        else:
            dense = jnp.zeros((0, m), hll.REGISTER_DTYPE)
        return dataclasses.replace(
            a,
            pair_buf=pairs,
            pair_len=jnp.asarray(np.where(keep, distinct_np, 0).astype(np.int32)),
            dense_block=dense,
            slot_map=jnp.asarray(slot_of_row),
            n_items=n_items,
        )

    __or__ = merge

    # ------------------------------------------------------------------
    # estimation (paper phase 4, sparse-aware)
    # ------------------------------------------------------------------

    def _sparse_histograms(self) -> jnp.ndarray:
        """(B, K) int32 histograms straight from the settled pairs
        (C[0] = m - len)."""
        from repro.sketch import estimators as _estimators

        s = self.compact()
        rows = len(s)
        k = _estimators.histogram_size(s.cfg)
        cap = int(s.pair_buf.shape[1])
        flat = s.pair_buf.reshape(-1)
        valid = flat >= 0
        rank = jnp.where(valid, flat & _PACK_MASK, 0)
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32), max(1, cap))
        if cap == 0:
            counts = jnp.zeros((rows, k), jnp.int32)
        else:
            idx = jnp.where(valid, row * k + rank, rows * k)
            counts = jnp.bincount(idx, length=rows * k + 1)[: rows * k]
            counts = counts.reshape(rows, k).astype(jnp.int32)
        return counts.at[:, 0].set(s.cfg.m - s.pair_len)

    def estimate_many(
        self,
        estimator: Optional[str] = None,
        *,
        lc_fast: bool = True,
        plan: Optional[ExecutionPlan] = None,
    ) -> jnp.ndarray:
        """(B,) float32 estimates, sparse rows via the LC fast path.

        For the default ``original`` estimator, sparse rows finalize with
        the closed-form LinearCounting read (bit-identical to the dense
        device path — see the module docstring proof); other estimators
        (or ``lc_fast=False``) build histograms from the pairs and run
        the registered device finalizer.  Dense rows always finalize
        through the §8 batched ``estimate_many`` — per promoted-row block
        under a placement="sharded" ``plan`` (§16); the sparse side is
        host/COO math with no row axis on device, so placement cannot
        move it.
        """
        from repro.sketch import estimators as _estimators

        s = self.compact()
        rows = len(s)
        if rows == 0:
            return jnp.zeros((0,), jnp.float32)
        name = _estimators.resolve_estimator(
            estimator or (plan.estimator if plan is not None else None)
        )
        if name == "original" and lc_fast:
            sparse_est = _lc_estimate(s.pair_len, m=s.cfg.m)
        else:
            hist = s._sparse_histograms()
            sparse_est = _finalize_histograms(hist, s.cfg, name)
        d = int(s.dense_block.shape[0])
        if d:
            if plan is not None and plan.validate().placement == "sharded":
                dense_est = row_shard_apply(
                    plan,
                    _sharded_estimate_fn(s.cfg, name),
                    (s.dense_block,),
                    (0,),
                )
            else:
                dense_est = _estimators.estimate_many(
                    s.dense_block, s.cfg, estimator=name
                )
            slot = jnp.clip(s.slot_map, 0, d - 1)
            return jnp.where(s.slot_map >= 0, dense_est[slot], sparse_est)
        return sparse_est

    def estimate(self, i: int, estimator: Optional[str] = None) -> float:
        """Exact host-side estimate of one row."""
        return self.row(i).estimate(estimator)

    # ------------------------------------------------------------------
    # serialization (RHLB v2: per-row mode flags + sparse payloads)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        """RHLB v2: header + threshold + counts + mode flags + payloads.

        Always serializes the SETTLED state — buffered appends compact
        first, so the wire never carries (and never needs to encode) the
        transient append log.
        """
        s = self.compact()
        rows = len(s)
        header = _BANK_HEADER.pack(
            _BANK_MAGIC,
            _SPARSE_VERSION,
            s.cfg.p,
            s.cfg.hash_bits,
            0,
            s.cfg.seed,
            rows,
        )
        out = [header, _THRESHOLD.pack(s.threshold)]
        out.append(s.counts.astype("<u8").tobytes())
        modes = (np.asarray(s.slot_map) >= 0).astype(np.uint8)
        out.append(modes.tobytes())
        pairs_np = np.asarray(s.pair_buf)
        dense_np = np.asarray(s.dense_block, dtype=np.uint8)
        slot_np = np.asarray(s.slot_map)
        for i in range(rows):
            if modes[i] == MODE_DENSE:
                out.append(dense_np[slot_np[i]].tobytes())
            else:
                p = pairs_np[i]
                p = p[p >= 0]
                out.append(_NPAIRS.pack(p.size))
                buckets = (p >> _PACK_SHIFT).astype("<u2")
                ranks = (p & _PACK_MASK).astype(np.uint8)
                pair_bytes = np.zeros((p.size, 3), np.uint8)
                pair_bytes[:, :2] = buckets.view(np.uint8).reshape(-1, 2)
                pair_bytes[:, 2] = ranks
                out.append(pair_bytes.tobytes())
        return b"".join(out)

    @classmethod
    def from_bytes(cls, data: bytes) -> "HybridBank":
        """Parse RHLB v2 strictly; v1 dense blobs parse as all-dense."""
        if len(data) < _BANK_HEADER.size:
            raise ValueError(f"truncated bank: {len(data)} bytes")
        magic, version, p, hash_bits, _flags, seed, rows = _BANK_HEADER.unpack(
            data[: _BANK_HEADER.size]
        )
        if magic != _BANK_MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized bank")
        if version == 1:
            # dense blobs still parse, version-gated: every row stays dense
            bank = SketchBank.from_bytes(data)
            return cls.from_dense(
                bank, dense_rows=np.ones(len(bank), bool)
            )
        if version != _SPARSE_VERSION:
            raise ValueError(f"unsupported bank version {version}")
        if rows < 1:
            raise ValueError(f"bank header claims {rows} rows")
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        off = _BANK_HEADER.size
        if len(data) < off + _THRESHOLD.size:
            raise ValueError("truncated bank: threshold missing")
        (threshold,) = _THRESHOLD.unpack_from(data, off)
        threshold = _check_threshold(threshold, cfg)
        off += _THRESHOLD.size
        counts_end = off + rows * _ROW_COUNT.size
        modes_end = counts_end + rows
        if len(data) < modes_end:
            raise ValueError("truncated bank: counts/mode flags cut short")
        raw_counts = np.frombuffer(data[off:counts_end], dtype="<u8")
        modes = np.frombuffer(data[counts_end:modes_end], dtype=np.uint8)
        if not np.isin(modes, (MODE_SPARSE, MODE_DENSE)).all():
            raise ValueError(
                f"corrupt mode flag {int(modes.max())}; rows are sparse (0) "
                f"or dense (1)"
            )
        off = modes_end
        sparse_pairs, dense_regs = [], []
        for i in range(rows):
            if modes[i] == MODE_DENSE:
                if len(data) < off + cfg.m:
                    raise ValueError(f"row {i}: dense payload cut short")
                dense_regs.append(
                    np.frombuffer(data[off : off + cfg.m], np.uint8)
                )
                off += cfg.m
                continue
            if len(data) < off + _NPAIRS.size:
                raise ValueError(f"row {i}: pair count cut short")
            (npairs,) = _NPAIRS.unpack_from(data, off)
            off += _NPAIRS.size
            if npairs > threshold:
                raise ValueError(
                    f"row {i}: {npairs} pairs exceeds threshold {threshold}"
                )
            end = off + npairs * 3
            if len(data) < end:
                raise ValueError(f"row {i}: pair list cut short")
            raw = np.frombuffer(data[off:end], np.uint8).reshape(npairs, 3)
            buckets = raw[:, :2].copy().view("<u2").reshape(-1).astype(np.int64)
            ranks = raw[:, 2].astype(np.int64)
            if npairs:
                if buckets.max() >= cfg.m:
                    raise ValueError(
                        f"row {i}: bucket {int(buckets.max())} out of range "
                        f"for m={cfg.m}"
                    )
                if not (np.diff(buckets) > 0).all():
                    raise ValueError(
                        f"row {i}: pair buckets must be strictly increasing"
                    )
                if ranks.min() < 1 or ranks.max() > cfg.max_rank:
                    raise ValueError(
                        f"row {i}: rank outside [1, {cfg.max_rank}]"
                    )
            sparse_pairs.append(
                ((buckets << _PACK_SHIFT) | ranks).astype(np.int32)
            )
            off = end
        if off != len(data):
            raise ValueError(
                f"bank payload is {len(data)} bytes, expected {off}"
            )
        cap = _fit_capacity(
            max((p.size for p in sparse_pairs), default=0), threshold
        )
        pairs = np.full((rows, cap), _EMPTY, np.int32)
        sparse_len = np.zeros(rows, np.int32)
        dense_slot = np.full(rows, -1, np.int32)
        # assign dense slots in row order (matching to_bytes)
        d = s = 0
        for i in range(rows):
            if modes[i] == MODE_DENSE:
                dense_slot[i] = d
                d += 1
            else:
                pr = sparse_pairs[s]
                pairs[i, : pr.size] = pr
                sparse_len[i] = pr.size
                s += 1
        limbs = np.stack(
            [(raw_counts >> 32).astype(np.uint32), raw_counts.astype(np.uint32)],
            axis=-1,
        )
        dense = (
            np.stack(dense_regs)
            if dense_regs
            else np.zeros((0, cfg.m), np.uint8)
        )
        return cls(
            jnp.asarray(pairs),
            jnp.asarray(sparse_len),
            jnp.asarray(dense),
            jnp.asarray(dense_slot),
            jnp.asarray(limbs),
            cfg,
            threshold,
        )


# ----------------------------------------------------------------------------
# module-level entry point (mirrors bank.update_many)
# ----------------------------------------------------------------------------


def update_many(
    bank: HybridBank,
    keys: jnp.ndarray,
    items: jnp.ndarray,
    plan: Optional[ExecutionPlan] = None,
) -> HybridBank:
    """Batched hybrid ingestion: sparse/dense routing in one fused pass."""
    return bank.update_many(keys, items, plan)
