"""HyperLogLog: the frozen-pytree sketch carrier — the public object API.

Bundles the (m,) uint8 register array with an exact 64-bit item counter and
the static HLLConfig, so a sketch moves through jit, shard_map, checkpoints
and process boundaries as one value.  All methods are pure (return new
carriers); ``merge``/``|`` is the paper's Merge-buckets fold and obeys the
max-lattice laws (associative, commutative, idempotent — DESIGN.md §6).

The item counter is carried as two uint32 limbs (TPU has no int64 datapath;
int32 overflows at 2.1e9 items, far below the paper's high-cardinality
regime), giving an exact count to 2^64 items.

``to_bytes``/``from_bytes`` is the dense wire format (DESIGN.md §7): a 24-byte
header + the raw registers, so a p=16 sketch checkpoints in 64 KiB and merges
across machines that share nothing but this file format.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sketch import hll, setops, u64 as u64lib
from repro.sketch.dispatch import update_registers
from repro.sketch.hll import HLLConfig
from repro.sketch.plan import ExecutionPlan

_HEADER = struct.Struct("<4sBBBBQQ")  # magic, ver, p, H, flags, seed, n_items
_MAGIC = b"RHLL"
_VERSION = 1


def _counter_zero() -> jnp.ndarray:
    return jnp.zeros((2,), jnp.uint32)


def _counter_add(counter: jnp.ndarray, value) -> jnp.ndarray:
    """64-bit add on the (hi, lo) uint32 limb pair; value is int or limbs."""
    if isinstance(value, (int, np.integer)):
        b = u64lib.from_py(int(value))
    else:
        b = u64lib.U64(value[0], value[1])
    s = u64lib.add(u64lib.U64(counter[0], counter[1]), b)
    return jnp.stack([s.hi, s.lo])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HyperLogLog:
    """Registers + exact item counter + static config, as one pytree."""

    registers: jnp.ndarray  # (m,) uint8
    n_items: jnp.ndarray  # (2,) uint32: (hi, lo) limbs of the 64-bit count
    cfg: HLLConfig = dataclasses.field(metadata=dict(static=True))

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def empty(cls, cfg: Optional[HLLConfig] = None) -> "HyperLogLog":
        cfg = cfg or HLLConfig()
        return cls(hll.init_registers(cfg), _counter_zero(), cfg)

    @classmethod
    def of(
        cls,
        items: jnp.ndarray,
        cfg: Optional[HLLConfig] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> "HyperLogLog":
        """One-shot: sketch a whole array."""
        return cls.empty(cfg).update(items, plan)

    # ------------------------------------------------------------------
    # aggregation (paper phase 3)
    # ------------------------------------------------------------------

    def update(
        self, items: jnp.ndarray, plan: Optional[ExecutionPlan] = None
    ) -> "HyperLogLog":
        """Aggregate a batch under ``plan`` (any backend/placement/pipelines).

        A zero-length batch returns ``self`` without dispatching any
        backend (the update is the lattice identity).
        """
        if items.size == 0:
            return self
        regs = update_registers(self.registers, items, self.cfg, plan)
        return dataclasses.replace(
            self, registers=regs, n_items=_counter_add(self.n_items, items.size)
        )

    def merge(self, other: "HyperLogLog") -> "HyperLogLog":
        """Merge-buckets fold: element-wise max; counters add exactly."""
        if self.cfg != other.cfg:
            raise ValueError(
                f"cannot merge sketches with different configs: "
                f"{self.cfg} vs {other.cfg}"
            )
        return dataclasses.replace(
            self,
            registers=jnp.maximum(self.registers, other.registers),
            n_items=_counter_add(self.n_items, other.n_items),
        )

    __or__ = merge

    # ------------------------------------------------------------------
    # estimation (paper phase 4) + set algebra
    # ------------------------------------------------------------------

    def estimate(self, estimator: Optional[str] = None) -> float:
        """Exact host-side cardinality estimate (registry-dispatched)."""
        return hll.estimate(self.registers, self.cfg, estimator=estimator)

    def estimate_device(self, estimator: Optional[str] = None) -> jnp.ndarray:
        """Float32 on-device estimator for in-step telemetry."""
        return hll.estimate_device(
            self.registers, self.cfg, estimator=estimator
        )

    def histogram(self) -> jnp.ndarray:
        """Register-value histogram C[k] — the phase-4 intermediate."""
        from repro.sketch.estimators import register_histogram

        return register_histogram(self.registers, self.cfg)

    def union_estimate(
        self, other: "HyperLogLog", estimator: Optional[str] = None
    ) -> float:
        self._check_peer(other)
        return setops.union_estimate(
            self.registers, other.registers, self.cfg, estimator=estimator
        )

    def intersection_estimate(
        self, other: "HyperLogLog", estimator: Optional[str] = None
    ) -> Tuple[float, float]:
        """(|A ∩ B| estimate, absolute-error bound) via inclusion-exclusion."""
        self._check_peer(other)
        return setops.intersection_estimate(
            self.registers, other.registers, self.cfg, estimator=estimator
        )

    def difference_estimate(
        self, other: "HyperLogLog", estimator: Optional[str] = None
    ) -> float:
        self._check_peer(other)
        return setops.difference_estimate(
            self.registers, other.registers, self.cfg, estimator=estimator
        )

    def jaccard(
        self, other: "HyperLogLog", estimator: Optional[str] = None
    ) -> float:
        self._check_peer(other)
        return setops.jaccard_estimate(
            self.registers, other.registers, self.cfg, estimator=estimator
        )

    def _check_peer(self, other: "HyperLogLog") -> None:
        if self.cfg != other.cfg:
            raise ValueError(
                f"set operations need matching configs: {self.cfg} vs {other.cfg}"
            )

    # ------------------------------------------------------------------
    # counters / introspection
    # ------------------------------------------------------------------

    @property
    def count(self) -> int:
        """Exact number of items observed (python int, up to 2^64)."""
        limbs = np.asarray(self.n_items)
        return (int(limbs[0]) << 32) | int(limbs[1])

    @property
    def standard_error(self) -> float:
        return hll.standard_error(self.cfg)

    def duplication(self) -> float:
        """items seen / distinct estimate (stream redundancy factor)."""
        est = self.estimate()
        return (self.count / est) if est > 0 else float("nan")

    # ------------------------------------------------------------------
    # serialization (DESIGN.md §7)
    # ------------------------------------------------------------------

    def to_bytes(self) -> bytes:
        """Dense wire format: 24-byte header + m raw register bytes."""
        header = _HEADER.pack(
            _MAGIC, _VERSION, self.cfg.p, self.cfg.hash_bits, 0,
            self.cfg.seed, self.count,
        )
        regs = np.asarray(self.registers, dtype=np.uint8)
        return header + regs.tobytes()

    @classmethod
    def from_bytes(cls, data: bytes) -> "HyperLogLog":
        if len(data) < _HEADER.size:
            raise ValueError(f"truncated sketch: {len(data)} bytes")
        magic, version, p, hash_bits, _flags, seed, n_items = _HEADER.unpack(
            data[: _HEADER.size]
        )
        if magic != _MAGIC:
            raise ValueError(f"bad magic {magic!r}; not a serialized sketch")
        if version != _VERSION:
            raise ValueError(f"unsupported sketch version {version}")
        cfg = HLLConfig(p=p, hash_bits=hash_bits, seed=seed)
        body = data[_HEADER.size :]
        if len(body) != cfg.m:
            raise ValueError(
                f"register payload is {len(body)} bytes, expected {cfg.m}"
            )
        regs = jnp.asarray(np.frombuffer(body, dtype=np.uint8).copy())
        limbs = jnp.asarray(
            np.asarray([n_items >> 32, n_items & 0xFFFFFFFF], np.uint32)
        )
        return cls(regs, limbs, cfg)
